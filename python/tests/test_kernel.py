"""L1 correctness: Pallas kernels vs pure-jnp oracle.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py is THE
core correctness signal for everything the Rust runtime later executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as pk
from compile.kernels import ref


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------- matmul

@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128), (256, 512, 128), (64, 64, 64), (128, 384, 256),
])
def test_matmul_block_aligned(m, k, n):
    x = _rand(0, (m, k), jnp.float32)
    w = _rand(1, (k, n), jnp.float32)
    # tolerance sized for f32 blocked-vs-flat accumulation order at k<=512
    np.testing.assert_allclose(
        pk.matmul(x, w), ref.matmul(x, w), rtol=1e-4, atol=2e-4
    )


@pytest.mark.parametrize("m,k,n", [
    (1, 1, 1), (3, 5, 7), (17, 129, 33), (100, 100, 100), (127, 255, 63),
])
def test_matmul_ragged_shapes(m, k, n):
    x = _rand(2, (m, k), jnp.float32)
    w = _rand(3, (k, n), jnp.float32)
    np.testing.assert_allclose(
        pk.matmul(x, w), ref.matmul(x, w), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96), k=st.integers(1, 96), n=st.integers(1, 96),
    seed=st.integers(0, 2**16),
)
def test_matmul_hypothesis_shapes(m, k, n, seed):
    x = _rand(seed, (m, k), jnp.float32)
    w = _rand(seed + 1, (k, n), jnp.float32)
    np.testing.assert_allclose(
        pk.matmul(x, w), ref.matmul(x, w), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=10, deadline=None)
@given(
    dt=st.sampled_from(["float32", "bfloat16"]),
    m=st.sampled_from([8, 32, 128]),
    k=st.sampled_from([16, 64, 256]),
)
def test_matmul_dtypes(dt, m, k):
    dtype = jnp.dtype(dt)
    x = _rand(7, (m, k), dtype)
    w = _rand(8, (k, 32), dtype)
    got = pk.matmul(x, w)
    want = ref.matmul(x, w)
    assert got.dtype == want.dtype
    # bf16 keeps ~8 mantissa bits; tiled vs flat accumulation at k<=256
    # legitimately differs by ~2^-3 relative on near-cancelling sums
    tol = 1e-4 if dt == "float32" else 1.5e-1
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), rtol=tol, atol=tol
    )


def test_matmul_custom_blocks():
    x = _rand(9, (64, 96), jnp.float32)
    w = _rand(10, (96, 48), jnp.float32)
    got = pk.matmul(x, w, block_m=16, block_n=16, block_k=32)
    np.testing.assert_allclose(got, ref.matmul(x, w), rtol=1e-5, atol=1e-5)


def test_matmul_contraction_mismatch_raises():
    x = jnp.zeros((4, 5))
    w = jnp.zeros((6, 4))
    with pytest.raises(AssertionError):
        pk.matmul(x, w)


# ---------------------------------------------------------------- linear

@pytest.mark.parametrize("activation", ["none", "relu", "tanh"])
@pytest.mark.parametrize("m,k,n", [(64, 128, 32), (33, 77, 11)])
def test_linear_fused(activation, m, k, n):
    x = _rand(4, (m, k), jnp.float32)
    w = _rand(5, (k, n), jnp.float32)
    b = _rand(6, (n,), jnp.float32)
    np.testing.assert_allclose(
        pk.linear(x, w, b, activation=activation),
        ref.linear(x, w, b, activation=activation),
        rtol=1e-4, atol=1e-4,
    )


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 64), k=st.integers(1, 64), n=st.integers(1, 64),
    act=st.sampled_from(["none", "relu", "tanh"]),
    seed=st.integers(0, 2**16),
)
def test_linear_hypothesis(m, k, n, act, seed):
    x = _rand(seed, (m, k), jnp.float32)
    w = _rand(seed + 1, (k, n), jnp.float32)
    b = _rand(seed + 2, (n,), jnp.float32)
    np.testing.assert_allclose(
        pk.linear(x, w, b, activation=act),
        ref.linear(x, w, b, activation=act),
        rtol=1e-4, atol=1e-4,
    )


def test_linear_bad_activation_raises():
    x = jnp.zeros((4, 4))
    b = jnp.zeros((4,))
    with pytest.raises(AssertionError):
        pk.linear(x, x, b, activation="gelu")


# ------------------------------------------------------------ perf model

def test_vmem_estimate_default_blocks_fit():
    # default 128^3 tiles: 192 KiB << 16 MiB VMEM
    assert pk.vmem_bytes(128, 128, 128) == 3 * 128 * 128 * 4
    assert pk.vmem_bytes(128, 128, 128) < 16 * 2**20


def test_mxu_utilization_bounds():
    assert pk.mxu_utilization(128, 128, 128, 128, 128, 128) == 1.0
    u = pk.mxu_utilization(100, 100, 100, 128, 128, 128)
    assert 0.0 < u < 1.0


def test_pick_block_divides():
    for dim in [1, 7, 100, 128, 129, 1000]:
        b = pk._pick_block(dim, 128)
        assert 1 <= b <= min(dim, 128) and dim % b == 0
