"""AOT path: lowering produces parseable HLO text + a consistent manifest."""

import json
import os

import jax.numpy as jnp
import pytest

from compile import aot, model as M


TINY = M.ModelConfig(input_dim=16, hidden=(8,), classes=4, batch=4, lr=0.1)


@pytest.fixture(scope="module")
def lowered(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.lower_all(TINY, out)
    return out, manifest


def test_all_artifacts_written(lowered):
    out, manifest = lowered
    for name, meta in manifest["artifacts"].items():
        path = os.path.join(out, meta["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert "ENTRY" in text and "HloModule" in text, name


def test_manifest_roundtrip(lowered):
    out, manifest = lowered
    on_disk = json.load(open(os.path.join(out, "manifest.json")))
    assert on_disk == manifest


def test_manifest_model_section(lowered):
    _, manifest = lowered
    m = manifest["model"]
    assert m["param_shapes"] == [[16, 8], [8], [8, 4], [4]]
    assert m["param_count"] == 16 * 8 + 8 + 8 * 4 + 4
    assert m["n_layers"] == 2


def test_grad_step_signature(lowered):
    _, manifest = lowered
    gs = manifest["artifacts"]["grad_step"]
    nparam = len(manifest["model"]["param_shapes"])
    # inputs: params..., x, y ; outputs: loss + grads
    assert len(gs["inputs"]) == nparam + 2
    assert gs["n_outputs"] == 1 + nparam
    assert gs["inputs"][-1]["dtype"] == "s32"


def test_hlo_text_has_tuple_root(lowered):
    out, manifest = lowered
    text = open(os.path.join(out, manifest["artifacts"]["forward"]["file"])).read()
    # lowered with return_tuple=True: root is a tuple
    assert "tuple(" in text or "(f32[" in text


def test_to_hlo_text_simple_fn():
    import jax

    def fn(a, b):
        return (a * b + 1.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text and "ENTRY" in text
