"""L2 correctness: model shapes, gradients, training signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


SMALL = M.ModelConfig(input_dim=32, hidden=(16,), classes=4, batch=8, lr=0.1)


def test_param_shapes_and_count():
    cfg = SMALL
    shapes = cfg.param_shapes()
    assert shapes == [(32, 16), (16,), (16, 4), (4,)]
    assert cfg.param_count() == 32 * 16 + 16 + 16 * 4 + 4
    params = M.init_params(cfg)
    assert [p.shape for p in params] == shapes


def test_forward_shape_and_determinism():
    cfg = SMALL
    params = M.init_params(cfg)
    x, _ = M.synthetic_batch(cfg, 0)
    logits = M.forward(cfg, params, x)
    assert logits.shape == (cfg.batch, cfg.classes)
    np.testing.assert_array_equal(logits, M.forward(cfg, params, x))


def test_pallas_and_ref_layers_agree():
    cfg_p = SMALL
    cfg_r = M.ModelConfig(**{**cfg_p.__dict__, "use_pallas": False})
    params = M.init_params(cfg_p)
    x, _ = M.synthetic_batch(cfg_p, 1)
    np.testing.assert_allclose(
        M.forward(cfg_p, params, x), M.forward(cfg_r, params, x),
        rtol=1e-4, atol=1e-4,
    )


def test_loss_finite_and_positive():
    cfg = SMALL
    params = M.init_params(cfg)
    x, y = M.synthetic_batch(cfg, 0)
    loss = M.loss_fn(cfg, params, x, y)
    assert jnp.isfinite(loss) and loss > 0


def test_grad_step_matches_autodiff():
    cfg = SMALL
    params = M.init_params(cfg)
    x, y = M.synthetic_batch(cfg, 0)
    out = M.loss_and_grads(cfg, params, x, y)
    assert len(out) == 1 + len(params)
    loss, grads = out[0], out[1:]
    want = jax.grad(lambda p: M.loss_fn(cfg, p, x, y))(list(params))
    for g, wg in zip(grads, want):
        np.testing.assert_allclose(g, wg, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(loss, M.loss_fn(cfg, params, x, y), rtol=1e-5)


def test_train_step_is_sgd():
    cfg = SMALL
    params = M.init_params(cfg)
    x, y = M.synthetic_batch(cfg, 0)
    out = M.train_step(cfg, params, x, y)
    loss, new_params = out[0], out[1:]
    _, grads = out[0], M.loss_and_grads(cfg, params, x, y)[1:]
    for p, g, np_ in zip(params, grads, new_params):
        np.testing.assert_allclose(np_, p - cfg.lr * g, rtol=1e-5, atol=1e-6)


def test_loss_decreases_over_training():
    cfg = SMALL
    params = M.init_params(cfg)
    first = None
    last = None
    for step in range(30):
        x, y = M.synthetic_batch(cfg, step)
        out = M.train_step(cfg, params, x, y)
        loss, params = float(out[0]), list(out[1:])
        if first is None:
            first = loss
        last = loss
    assert last < 0.7 * first, (first, last)


def test_synthetic_batch_learnable_structure():
    cfg = SMALL
    x0, y0 = M.synthetic_batch(cfg, 0)
    x1, y1 = M.synthetic_batch(cfg, 1)
    assert x0.shape == (cfg.batch, cfg.input_dim)
    assert y0.shape == (cfg.batch,)
    assert y0.dtype == jnp.int32 or y0.dtype == jnp.int64
    # different steps give different batches
    assert not np.array_equal(np.asarray(x0), np.asarray(x1))
    # same step is deterministic
    x0b, y0b = M.synthetic_batch(cfg, 0)
    np.testing.assert_array_equal(x0, x0b)
    np.testing.assert_array_equal(y0, y0b)
