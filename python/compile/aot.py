"""AOT lowering: JAX (L2, calling the Pallas L1 kernel) → HLO **text**.

HLO text — NOT ``lowered.compile().serialize()`` and NOT the serialized
``HloModuleProto`` — is the interchange format: jax ≥ 0.5 emits protos
with 64-bit instruction ids which the Rust side's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``). The HLO text parser reassigns ids,
so text round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os
from typing import List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import matmul as pk


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR → XlaComputation → HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _shape_entry(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def lower_all(cfg: M.ModelConfig, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    param_specs = [_spec(s) for s in cfg.param_shapes()]
    x_spec = _spec((cfg.batch, cfg.input_dim))
    y_spec = _spec((cfg.batch,), jnp.int32)

    artifacts = {}

    def emit(name: str, fn, specs, n_outputs: int, inputs_desc: List[dict]):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        artifacts[name] = {
            "file": fname,
            "inputs": inputs_desc,
            "n_outputs": n_outputs,
        }
        print(f"  wrote {fname}: {len(text)} chars, "
              f"{len(inputs_desc)} inputs -> {n_outputs} outputs")

    nparam = len(param_specs)
    pdesc = [_shape_entry(s) for s in cfg.param_shapes()]
    xdesc = _shape_entry((cfg.batch, cfg.input_dim))
    ydesc = _shape_entry((cfg.batch,), "s32")

    # forward(params..., x) -> (logits,)
    emit(
        "forward",
        lambda *a: (M.forward(cfg, list(a[:nparam]), a[nparam]),),
        [*param_specs, x_spec],
        1,
        [*pdesc, xdesc],
    )

    # grad_step(params..., x, y) -> (loss, *grads)
    emit(
        "grad_step",
        lambda *a: M.loss_and_grads(cfg, list(a[:nparam]), a[nparam], a[nparam + 1]),
        [*param_specs, x_spec, y_spec],
        1 + nparam,
        [*pdesc, xdesc, ydesc],
    )

    # train_step(params..., x, y) -> (loss, *new_params)
    emit(
        "train_step",
        lambda *a: M.train_step(cfg, list(a[:nparam]), a[nparam], a[nparam + 1]),
        [*param_specs, x_spec, y_spec],
        1 + nparam,
        [*pdesc, xdesc, ydesc],
    )

    # per-layer forward artifacts: the coordinator runs the next step's
    # forward pass layer by layer so each layer only waits for *its own*
    # pulled parameters (the ByteScheduler overlap the MXDAG schedule
    # exploits). act(x @ w + b) via the Pallas fused kernel.
    sizes = (cfg.input_dim, *cfg.hidden, cfg.classes)
    for i, (din, dout) in enumerate(cfg.dims):
        act = "relu" if i < cfg.n_layers - 1 else "none"
        emit(
            f"layer_fwd_{i}",
            lambda x, w, bb, _act=act: (pk.linear(x, w, bb, activation=_act),),
            [_spec((cfg.batch, din)), _spec((din, dout)), _spec((dout,))],
            1,
            [
                _shape_entry((cfg.batch, din)),
                _shape_entry((din, dout)),
                _shape_entry((dout,)),
            ],
        )
    del sizes

    # standalone Pallas matmul artifact (quickstart + runtime bench)
    mm_m, mm_k, mm_n = 128, 256, 128
    emit(
        "matmul",
        lambda x, w: (pk.matmul(x, w),),
        [_spec((mm_m, mm_k)), _spec((mm_k, mm_n))],
        1,
        [_shape_entry((mm_m, mm_k)), _shape_entry((mm_k, mm_n))],
    )

    manifest = {
        "model": {
            "input_dim": cfg.input_dim,
            "hidden": list(cfg.hidden),
            "classes": cfg.classes,
            "batch": cfg.batch,
            "lr": cfg.lr,
            "n_layers": cfg.n_layers,
            "param_shapes": [list(s) for s in cfg.param_shapes()],
            "param_count": int(cfg.param_count()),
        },
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote manifest.json ({cfg.param_count()} params, "
          f"{cfg.n_layers} layers)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--input-dim", type=int, default=784)
    ap.add_argument("--hidden", type=int, nargs="*", default=[256, 256])
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.05)
    args = ap.parse_args()
    cfg = M.ModelConfig(
        input_dim=args.input_dim,
        hidden=tuple(args.hidden),
        classes=args.classes,
        batch=args.batch,
        lr=args.lr,
    )
    print(f"AOT-lowering MLP {args.input_dim}-{args.hidden}-{args.classes} "
          f"batch={args.batch} to {args.out_dir}")
    lower_all(cfg, args.out_dir)


if __name__ == "__main__":
    main()
