"""L1 — Pallas kernels for the paper's compute hot-spot (dense layers)."""

from . import matmul, ref  # noqa: F401
