"""Pure-jnp oracles for the Pallas kernels (correctness reference)."""

from __future__ import annotations

import jax.numpy as jnp


def matmul(x, w):
    out_dtype = jnp.promote_types(x.dtype, w.dtype)
    return jnp.matmul(
        x.astype(out_dtype), w.astype(out_dtype),
        preferred_element_type=out_dtype,
    )


def linear(x, w, b, *, activation: str = "none"):
    out_dtype = jnp.promote_types(jnp.promote_types(x.dtype, w.dtype), b.dtype)
    y = matmul(x, w).astype(out_dtype) + b.astype(out_dtype)[None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "tanh":
        y = jnp.tanh(y)
    else:
        assert activation == "none", activation
    return y
