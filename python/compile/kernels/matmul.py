"""L1 — Pallas tiled matmul kernels (the compute hot-spot of the DDL use case).

The MXDAG paper's end-to-end example (§4.1.1) is data-parallel distributed
deep learning; the compute MXTasks (FP_i / BP_i) are dominated by dense
matmuls. We express them as Pallas kernels tiled for TPU:

  * block sizes default to 128 so the inner tile feeds the 128x128 MXU
    systolic array directly;
  * the (bm, bk) + (bk, bn) + (bm, bn) f32 working set is kept well under
    VMEM (~16 MiB): 128^2 * 4B * 3 = 192 KiB per grid step;
  * the k dimension is walked by the innermost grid axis with an
    accumulate-into-output pattern (out_ref += partial), the standard
    Pallas TPU matmul schedule.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO for both testing and
the AOT artifacts. On a real TPU the same BlockSpecs compile natively;
DESIGN.md §Hardware-Adaptation and EXPERIMENTS.md §Perf carry the
VMEM/MXU analysis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (bm, bn) output tile; grid axis 2 walks k and accumulates."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, nsteps, activation):
    """Fused matmul + bias (+ activation) tile kernel."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )

    @pl.when(pl.program_id(2) == nsteps - 1)
    def _epilogue():
        acc = o_ref[...] + b_ref[...]
        if activation == "relu":
            acc = jnp.maximum(acc, 0.0)
        elif activation == "tanh":
            acc = jnp.tanh(acc)
        o_ref[...] = acc


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target (>=1). Keeps the grid
    exact without padding when possible."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def _pad2(a, bm, bn):
    m, n = a.shape
    pm = (-m) % bm
    pn = (-n) % bn
    if pm or pn:
        a = jnp.pad(a, ((0, pm), (0, pn)))
    return a


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def matmul(x, w, *, block_m: int = 128, block_n: int = 128, block_k: int = 128):
    """``x @ w`` via the Pallas tile kernel.

    Arbitrary (m, k) x (k, n) shapes are handled by zero-padding up to the
    block grid and slicing the result back; zero padding is exact for
    matmul.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    out_dtype = jnp.promote_types(x.dtype, w.dtype)

    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    bk = _pick_block(k, block_k)
    xp = _pad2(x.astype(out_dtype), bm, bk)
    wp = _pad2(w.astype(out_dtype), bk, bn)
    mp, kp = xp.shape
    _, np_ = wp.shape

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


@functools.partial(
    jax.jit, static_argnames=("activation", "block_m", "block_n", "block_k")
)
def linear(
    x,
    w,
    b,
    *,
    activation: str = "none",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
):
    """Fused ``act(x @ w + b)`` via a single Pallas kernel.

    ``activation`` in {"none", "relu", "tanh"}.
    """
    assert activation in ("none", "relu", "tanh"), activation
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,), (x.shape, w.shape, b.shape)
    out_dtype = jnp.promote_types(jnp.promote_types(x.dtype, w.dtype), b.dtype)

    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    bk = _pick_block(k, block_k)
    xp = _pad2(x.astype(out_dtype), bm, bk)
    wp = _pad2(w.astype(out_dtype), bk, bn)
    bp = _pad2(b.astype(out_dtype)[None, :], 1, bn)
    mp, kp = xp.shape
    _, np_ = wp.shape

    grid = (mp // bm, np_ // bn, kp // bk)
    kern = functools.partial(
        _linear_kernel, nsteps=grid[2], activation=activation
    )
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((1, bn), lambda i, j, s: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """Analytic VMEM working-set estimate for one grid step (perf model)."""
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)


def mxu_utilization(m: int, n: int, k: int, bm: int, bn: int, bk: int) -> float:
    """Fraction of MXU issue slots doing useful work for an (m,k)x(k,n)
    matmul padded up to the (bm,bn,bk) grid. 1.0 == perfectly tiled."""
    pm = ((m + bm - 1) // bm) * bm
    pn = ((n + bn - 1) // bn) * bn
    pk = ((k + bk - 1) // bk) * bk
    return (m * n * k) / float(pm * pn * pk)
