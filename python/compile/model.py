"""L2 — the DDL use-case model (§4.1.1): an MLP classifier in JAX.

Every dense layer goes through the Pallas ``linear`` kernel
(kernels/matmul.py), so the kernel lowers into the same HLO module that
the Rust coordinator executes via PJRT.

Exported computations (AOT-lowered by aot.py):
  * ``forward(params, x) -> logits``
  * ``loss_and_grads(params, x, y) -> (loss, *grads)``   # DDL worker step
  * ``train_step(params, x, y) -> (loss, *new_params)``  # fused single-host
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import matmul as pk
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """MLP configuration. Defaults: MNIST-like synthetic classification."""

    input_dim: int = 784
    hidden: Tuple[int, ...] = (256, 256)
    classes: int = 10
    batch: int = 64
    lr: float = 0.05
    seed: int = 0
    use_pallas: bool = True  # False => pure-jnp oracle layers (for tests)

    @property
    def dims(self) -> List[Tuple[int, int]]:
        sizes = (self.input_dim, *self.hidden, self.classes)
        return list(zip(sizes[:-1], sizes[1:]))

    @property
    def n_layers(self) -> int:
        return len(self.dims)

    def param_shapes(self) -> List[Tuple[int, ...]]:
        """Flat param list: [w0, b0, w1, b1, ...]."""
        shapes: List[Tuple[int, ...]] = []
        for din, dout in self.dims:
            shapes.append((din, dout))
            shapes.append((dout,))
        return shapes

    def param_count(self) -> int:
        return sum(int(jnp.prod(jnp.array(s))) for s in self.param_shapes())


def init_params(cfg: ModelConfig) -> List[jax.Array]:
    """He-initialised flat parameter list [w0, b0, w1, b1, ...]."""
    key = jax.random.PRNGKey(cfg.seed)
    params: List[jax.Array] = []
    for din, dout in cfg.dims:
        key, sub = jax.random.split(key)
        scale = jnp.sqrt(2.0 / din)
        params.append(jax.random.normal(sub, (din, dout), jnp.float32) * scale)
        params.append(jnp.zeros((dout,), jnp.float32))
    return params


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _pallas_linear(activation: str, x, w, b):
    """Fused Pallas linear with a hand-written VJP.

    The Pallas kernel uses ``pl.program_id`` grid accumulation, which JAX
    cannot JVP through; the backward pass is written explicitly — and is
    itself three Pallas matmuls, exactly how a TPU implementation would
    structure dgrad/wgrad.
    """
    return pk.linear(x, w, b, activation=activation)


def _pallas_linear_fwd(activation, x, w, b):
    y = pk.linear(x, w, b, activation=activation)
    return y, (x, w, y)


def _pallas_linear_bwd(activation, res, dy):
    x, w, y = res
    if activation == "relu":
        dz = dy * (y > 0).astype(dy.dtype)
    elif activation == "tanh":
        dz = dy * (1.0 - y * y)
    else:
        dz = dy
    dx = pk.matmul(dz, w.T)
    dw = pk.matmul(x.T, dz)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


_pallas_linear.defvjp(_pallas_linear_fwd, _pallas_linear_bwd)


def _linear(cfg: ModelConfig, x, w, b, activation: str):
    if cfg.use_pallas:
        return _pallas_linear(activation, x, w, b)
    return kref.linear(x, w, b, activation=activation)


def forward(cfg: ModelConfig, params: Sequence[jax.Array], x: jax.Array):
    """MLP forward pass; relu on hidden layers, raw logits out."""
    h = x
    nl = cfg.n_layers
    for i in range(nl):
        w, b = params[2 * i], params[2 * i + 1]
        act = "relu" if i < nl - 1 else "none"
        h = _linear(cfg, h, w, b, act)
    return h


def loss_fn(cfg: ModelConfig, params: Sequence[jax.Array], x, y):
    """Mean softmax cross-entropy; y is int32 class labels."""
    logits = forward(cfg, params, x)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - ll)


def loss_and_grads(cfg: ModelConfig, params: Sequence[jax.Array], x, y):
    """The DDL worker step: returns (loss, *grads) as a flat tuple.

    The Rust coordinator executes this artifact per worker, then runs the
    push/pull network MXTasks (gradient aggregation) itself.
    """
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, x, y)
    )(list(params))
    return (loss, *grads)


def train_step(cfg: ModelConfig, params: Sequence[jax.Array], x, y):
    """Fused single-host SGD step: returns (loss, *new_params)."""
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, x, y)
    )(list(params))
    new_params = [p - cfg.lr * g for p, g in zip(params, grads)]
    return (loss, *new_params)


def synthetic_batch(cfg: ModelConfig, step: int):
    """Deterministic synthetic classification data: class-dependent
    Gaussian blobs, learnable by an MLP (loss provably decreases)."""
    key = jax.random.PRNGKey(1000 + step)
    ky, kx = jax.random.split(key)
    y = jax.random.randint(ky, (cfg.batch,), 0, cfg.classes)
    centers = jax.random.normal(
        jax.random.PRNGKey(42), (cfg.classes, cfg.input_dim), jnp.float32
    )
    x = centers[y] + 0.3 * jax.random.normal(
        kx, (cfg.batch, cfg.input_dim), jnp.float32
    )
    return x, y
