//! Quickstart: build an MXDAG, run every scheduler on it, and (if
//! `make artifacts` has been run) execute a Pallas-kernel artifact
//! through the PJRT runtime.
//!
//!     cargo run --release --example quickstart

use mxdag::mxdag::MXDag;
use mxdag::runtime::{Engine, Tensor};
use mxdag::sched::{
    run, CoflowScheduler, FairScheduler, FifoScheduler, Grouping, MxScheduler,
    PackingScheduler, Scheduler,
};
use mxdag::sim::Cluster;
use mxdag::util::bench::Table;

fn main() -> anyhow::Result<()> {
    // --- 1. describe an application as an MXDAG ----------------------
    // ingest (host 0) fans out to two processing branches; results join
    // on host 3. Flows are explicit, first-class tasks.
    let mut b = MXDag::builder();
    let ingest = b.compute("ingest", 0, 1.0);
    let to_fast = b.flow("to_fast", 0, 1, 1.0);
    let fast = b.compute("fast_branch", 1, 1.0);
    let to_slow = b.flow_full("to_slow", 0, 2, 2.0, 0.5); // pipelineable
    let slow = b.compute_full("slow_branch", 2, 3.0, 0.75); // pipelineable
    let fast_out = b.flow("fast_out", 1, 3, 1.0);
    let slow_out = b.flow("slow_out", 2, 3, 1.0);
    let join = b.compute("join", 3, 0.5);
    b.dep(ingest, to_fast).dep(to_fast, fast).dep(fast, fast_out);
    b.dep(ingest, to_slow).dep(to_slow, slow).dep(slow, slow_out);
    b.dep(fast_out, join).dep(slow_out, join);
    let dag = b.finalize()?;

    // --- 2. compare schedulers on the fluid cluster substrate --------
    let cluster = Cluster::uniform(4);
    let schedulers: Vec<Box<dyn Scheduler>> = vec![
        Box::new(FairScheduler),
        Box::new(FifoScheduler),
        Box::new(PackingScheduler),
        Box::new(CoflowScheduler::new(Grouping::ByDst)),
        Box::new(MxScheduler::default()),
    ];
    let mut t = Table::new("quickstart: JCT by scheduler", &["JCT", "sim events"]);
    for s in &schedulers {
        let r = run(s.as_ref(), &dag, &cluster)?;
        t.row(
            s.name(),
            &[format!("{:.4}", r.makespan), format!("{}", r.events)],
        );
    }
    t.print();

    // --- 3. critical path analysis ------------------------------------
    let cpm = mxdag::mxdag::cpm(&dag);
    println!("\ncontention-free lower bound: {:.3}", cpm.makespan);
    let names: Vec<&str> = cpm
        .critical
        .iter()
        .map(|&t| dag.task(t).name.as_str())
        .collect();
    println!("critical path: {}", names.join(" -> "));

    // --- 4. run the Pallas matmul artifact through PJRT ---------------
    match Engine::load(std::path::Path::new("artifacts")) {
        Ok(engine) => {
            let spec = &engine
                .manifest
                .artifact("matmul")
                .map_err(anyhow::Error::msg)?
                .inputs;
            let (m, k) = (spec[0].shape[0], spec[0].shape[1]);
            let n = spec[1].shape[1];
            let x = Tensor::f32(&[m, k], (0..m * k).map(|i| (i % 7) as f32).collect());
            let w = Tensor::f32(&[k, n], (0..k * n).map(|i| (i % 5) as f32).collect());
            let out = engine.execute("matmul", &[x.clone(), w.clone()])?;
            // spot-check one element against a host-side dot product
            let host00: f32 = (0..k).map(|j| x.as_f32()[j] * w.as_f32()[j * n]).sum();
            println!(
                "\nPJRT matmul artifact: out[0,0]={} (host check {}), platform={}",
                out[0].as_f32()[0],
                host00,
                engine.platform()
            );
            assert!((out[0].as_f32()[0] - host00).abs() < 1e-2);
        }
        Err(e) => println!("\n(skipping PJRT demo — run `make artifacts` first: {e})"),
    }
    Ok(())
}
