//! §4.3 usages: what-if analysis (pipeline toggles, re-partitioning) and
//! runtime monitoring (host vs network straggler classification).
//!
//!     cargo run --release --example whatif_analysis

use mxdag::monitor::{detect_stragglers, replan_cpm};
use mxdag::sched::{evaluate, FairScheduler, Plan, Scheduler};
use mxdag::sim::{Annotations, Cluster, Policy};
use mxdag::util::bench::Table;
use mxdag::whatif::{pipeline_whatif, repartition};
use mxdag::workloads;

fn main() -> anyhow::Result<()> {
    // --- what-if: pipeline toggles on the Fig. 3 scenario --------------
    let (g, _) = workloads::fig3_dag();
    let cluster = workloads::figs::fig3_cluster();
    let base = Plan { ann: Annotations::default(), policy: Policy::fifo() };
    let (baseline, toggles) = pipeline_whatif(&g, &cluster, &base).unwrap();
    let mut t = Table::new(
        &format!("what-if: pipeline toggles (baseline JCT {baseline:.2})"),
        &["JCT", "delta"],
    );
    for w in &toggles {
        match &w.outcome {
            Ok((jct, delta)) => t.row_f64(&w.label, &[*jct, *delta]),
            Err(e) => t.row(&w.label, &[format!("failed: {e}"), String::new()]),
        }
    }
    t.print();

    // --- what-if: re-partition a monolithic compute task ----------------
    let mut b = mxdag::mxdag::MXDag::builder();
    let pre = b.compute("extract", 0, 0.5);
    let heavy = b.compute("transform", 0, 8.0);
    let post = b.compute("load", 0, 0.5);
    b.chain(&[pre, heavy, post]);
    let etl = b.finalize().unwrap();
    let cluster4 = Cluster::uniform(4);
    let mono = evaluate(&etl, &cluster4, &FairScheduler.plan(&etl, &cluster4))?.makespan;
    let mut t = Table::new("what-if: re-partition `transform`", &["JCT", "speedup"]);
    t.row_f64("monolithic", &[mono, 1.0]);
    for k in [2usize, 4] {
        let hosts: Vec<usize> = (0..k).collect();
        let split = repartition(&etl, heavy, &hosts, 0.2, 0.2).unwrap();
        let jct = evaluate(&split, &cluster4, &FairScheduler.plan(&split, &cluster4))?.makespan;
        t.row_f64(&format!("{k}-way shards"), &[jct, mono / jct]);
    }
    t.print();

    // --- monitoring: classify stragglers --------------------------------
    let g = workloads::fig1_dag();
    let plan = Plan::fair();
    let healthy = Cluster::uniform(3);
    let expected = evaluate(&g, &healthy, &plan)?;

    println!("\n== monitor: degraded uplink on host 1 ==");
    let mut bad = Cluster::uniform(3);
    bad.hosts[1].nic_up = 0.2;
    let observed = evaluate(&g, &bad, &plan)?;
    for s in detect_stragglers(&g, &expected, &observed, 1.5) {
        println!("  straggler: {} ({:?}) {:.1}x slower", s.name, s.kind, s.slowdown);
    }
    let replanned = replan_cpm(&g, &observed);
    println!(
        "  re-planned critical path length: {:.2} (was {:.2})",
        replanned.makespan,
        mxdag::mxdag::cpm(&g).makespan
    );

    println!("== monitor: degraded CPU on host 1 ==");
    let mut bad = Cluster::uniform(3);
    bad.hosts[1].cores = 0.2;
    let observed = evaluate(&g, &bad, &plan)?;
    for s in detect_stragglers(&g, &expected, &observed, 1.5) {
        println!("  straggler: {} ({:?}) {:.1}x slower", s.name, s.kind, s.slowdown);
    }
    Ok(())
}
