//! Multi-tenant scheduling (Principle 2): several map-reduce jobs share
//! a cluster; the altruistic MXDAG scheduler delays non-critical tasks
//! to their LST, accelerating other jobs' critical paths without
//! hurting anyone (Fig. 7 generalised).
//!
//!     cargo run --release --example mapreduce_altruistic

use mxdag::sched::altruistic::{merge, AltruisticScheduler, SelfishScheduler};
use mxdag::sched::evaluate;
use mxdag::sim::Cluster;
use mxdag::util::bench::Table;
use mxdag::workloads::{mapreduce_dag, MapReduceParams};

fn main() -> anyhow::Result<()> {
    // Fig. 7 generalised to three tenants: job 0 is a big job whose
    // critical branch lives on hosts 0/2 but holds a small straggler
    // branch on the shared host 1; jobs 1 and 2 are latency-sensitive
    // small jobs living entirely on host 1's compute + uplink.
    let big_job = {
        let (j1, _) = mxdag::workloads::fig7_jobs();
        j1
    };
    let small = |seed: u64, red_host: usize| {
        mapreduce_dag(&MapReduceParams {
            mappers: 2,
            reducers: 1,
            map_hosts: vec![1],
            red_hosts: vec![red_host],
            map_time: 0.5,
            red_time: 0.5,
            shuffle: 0.5,
            jitter: 0.2,
            seed,
            ..Default::default()
        })
        .0
    };
    let jobs = vec![big_job, small(41, 3), small(42, 3)];

    let multi = merge(&jobs);
    let cluster = Cluster::uniform(6);

    let selfish = evaluate(&multi.dag, &cluster, &SelfishScheduler.plan_multi(&multi))?;
    let altru = evaluate(&multi.dag, &cluster, &AltruisticScheduler.plan_multi_checked(&multi, &cluster))?;

    let mut t = Table::new(
        "3 map-reduce jobs on a shared cluster",
        &["selfish JCT", "altruistic JCT", "delta"],
    );
    let mut worse = 0;
    for j in 0..jobs.len() {
        let s = multi.jct(j, &selfish);
        let a = multi.jct(j, &altru);
        if a > s + 1e-6 {
            worse += 1;
        }
        t.row_f64(&format!("job {j}"), &[s, a, a - s]);
    }
    let avg_s = (0..jobs.len()).map(|j| multi.jct(j, &selfish)).sum::<f64>() / jobs.len() as f64;
    let avg_a = (0..jobs.len()).map(|j| multi.jct(j, &altru)).sum::<f64>() / jobs.len() as f64;
    t.row_f64("average", &[avg_s, avg_a, avg_a - avg_s]);
    t.print();

    println!(
        "\naverage JCT improvement: {:.1}% ({} job(s) regressed)",
        100.0 * (avg_s - avg_a) / avg_s,
        worse
    );
    assert!(avg_a <= avg_s + 1e-9, "altruism must not hurt average JCT");
    Ok(())
}
