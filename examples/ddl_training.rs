//! End-to-end driver: data-parallel training of the AOT-compiled MLP on
//! synthetic data, all three layers composing — Pallas kernels (L1)
//! inside the JAX model (L2) executed by the Rust coordinator (L3) over
//! PJRT-CPU, with layer-wise push/pull gradient synchronisation paced by
//! the NIC model and ordered by the MXDAG vs FIFO schedules (Fig. 6).
//!
//!     cargo run --release --example ddl_training
//!
//! Logs the loss curve (must decrease) and per-step latency for both
//! schedules. See EXPERIMENTS.md §E2E for recorded results.

use mxdag::coordinator::{train, DdlConfig, SyncSchedule};

fn main() -> anyhow::Result<()> {
    let steps = std::env::var("DDL_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);
    let workers = std::env::var("DDL_WORKERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);

    let mut reports = Vec::new();
    for schedule in [SyncSchedule::Fifo, SyncSchedule::Mxdag] {
        let cfg = DdlConfig {
            workers,
            steps,
            schedule,
            bandwidth: 25e6,
            time_scale: 1.0,
            fwd_reps: 2,
            log_every: 2,
            ..Default::default()
        };
        println!(
            "== schedule={} workers={} steps={} ==",
            schedule.label(),
            cfg.workers,
            cfg.steps
        );
        let r = train(&cfg)?;
        println!(
            "loss {:.4} -> {:.4} | mean steady step {:?} | total {:?}\n",
            r.first_loss(),
            r.last_loss(),
            r.mean_step_wall(),
            r.total
        );
        assert!(
            r.last_loss() < 0.5 * r.first_loss(),
            "training must make progress: {} -> {}",
            r.first_loss(),
            r.last_loss()
        );
        reports.push(r);
    }

    // both schedules compute identical numerics (synchronous SGD)
    let d = (reports[0].last_loss() - reports[1].last_loss()).abs();
    assert!(d < 1e-6, "schedules must be numerically identical, diff {d}");
    println!(
        "numerics identical across schedules (final loss diff {d:.2e}); \
         step-time ratio fifo/mxdag = {:.3}",
        reports[0].mean_step_wall().as_secs_f64() / reports[1].mean_step_wall().as_secs_f64()
    );
    println!("NOTE: on a single-core container compute cannot overlap compute; \
              the schedule effect on step time is carried by the fig6_ddl bench.");
    Ok(())
}
