//! Equivalence oracle for the fault-recovery layer (`sim/recovery.rs`):
//! host crashes (`DynAction::FailHost`) under `RecoveryPolicy::Retry`
//! kill in-flight work, re-enqueue it behind exponential-backoff gates
//! and quarantine terminally-stuck jobs — and every one of those paths
//! must hold to the same serial whole-set oracle the static engine,
//! the parallel fill and the dynamics layer already answer to, across
//! the full {Incremental, FullResort} × {Components, WholeSet} ×
//! {Eager, Anchored} × threads ∈ {1, 2, 4} matrix (eager corners
//! bitwise, anchored within `within_tolerance`). On top of the matrix:
//!
//! * `FailFast` + any timeline is bit-identical to spelling every
//!   `fail_host` as `slow_host { factor: 0 }` — the recovery layer off
//!   is exactly the pre-recovery engine;
//! * `Retry` + empty timeline is bit-identical to `FailFast` — the
//!   oracle-pairing convention for the fifth config axis;
//! * `DynTimeline::merge` preserves last-writer-wins order for
//!   same-timestamp events (the satellite determinism fix);
//! * a deterministic two-job scenario where one job's trunk death
//!   quarantines only that job while the other completes with its solo
//!   makespan, bitwise (capacity conservation: quarantine released
//!   every held slot).

use mxdag::sim::{
    simulate, within_tolerance, AllocKind, Cluster, DynAction, DynTimeline, HorizonKind,
    JobOutcome, LinkRef, Policy, QueueKind, RecoveryPolicy, SimConfig, SimDag, SimKind,
    SimResult, SimTask, StuckReason, Topology,
};
use mxdag::util::propcheck::{check, Config};
use mxdag::util::rng::Rng;
use mxdag::workloads::{random_dag, RandomParams};

fn gen_params(rng: &mut Rng) -> RandomParams {
    RandomParams {
        layers: rng.range(2, 5),
        width: rng.range(2, 5),
        hosts: rng.range(2, 8),
        edge_p: rng.range_f64(0.2, 0.9),
        pipe_frac: 0.0,
        min_size: 0.1,
        max_size: 3.0,
        seed: rng.next_u64(),
    }
}

/// The full configuration matrix; the first entry is the serial
/// whole-set baseline every other corner is compared against.
const MATRIX: [(QueueKind, AllocKind, HorizonKind); 8] = [
    (QueueKind::FullResort, AllocKind::WholeSet, HorizonKind::Eager),
    (QueueKind::Incremental, AllocKind::WholeSet, HorizonKind::Eager),
    (QueueKind::FullResort, AllocKind::Components, HorizonKind::Eager),
    (QueueKind::Incremental, AllocKind::Components, HorizonKind::Eager),
    (QueueKind::FullResort, AllocKind::WholeSet, HorizonKind::Anchored),
    (QueueKind::Incremental, AllocKind::WholeSet, HorizonKind::Anchored),
    (QueueKind::FullResort, AllocKind::Components, HorizonKind::Anchored),
    (QueueKind::Incremental, AllocKind::Components, HorizonKind::Anchored),
];

const THREADS: [usize; 3] = [1, 2, 4];

/// Run `sim` through the whole matrix with `timeline` and `recovery`
/// injected into every corner's `SimConfig`.
fn run_matrix(
    sim: &SimDag,
    cluster: &Cluster,
    policy: Policy,
    timeline: &DynTimeline,
    recovery: RecoveryPolicy,
) -> Result<Vec<Vec<SimResult>>, String> {
    MATRIX
        .iter()
        .map(|&(queue, alloc, horizon)| {
            THREADS
                .iter()
                .map(|&threads| {
                    simulate(
                        sim,
                        cluster,
                        &SimConfig {
                            policy,
                            queue,
                            alloc,
                            horizon,
                            threads,
                            dynamics: timeline.clone(),
                            recovery,
                            ..Default::default()
                        },
                    )
                    .map_err(|e| format!("{queue:?}/{alloc:?}/{horizon:?}/t{threads}: {e}"))
                })
                .collect()
        })
        .collect()
}

/// The standing agreement contract, extended to the recovery outputs:
/// corner serials against the whole-set baseline (value-equal for
/// eager, tolerance for anchored; NaN traces — quarantined chunks —
/// must be NaN everywhere), threaded runs against their own corner's
/// serial bitwise (eager) / tolerance (anchored). Retry accounting
/// (`retries`, per-job outcome kinds) is discrete and must agree
/// exactly wherever the comparison is bitwise.
fn assert_equivalent(tag: &str, results: &[Vec<SimResult>]) -> Result<(), String> {
    let base = &results[0][0];
    for (k, corner) in results.iter().enumerate() {
        let (queue, alloc, horizon) = MATRIX[k];
        let serial = &corner[0];
        let same = |x: f64, y: f64| match horizon {
            HorizonKind::Eager => (x - y).abs() <= 1e-9 || (x.is_nan() && y.is_nan()),
            HorizonKind::Anchored => {
                within_tolerance(x, y) || (x.is_nan() && y.is_nan())
            }
        };
        if k > 0 {
            let tag = format!("{tag} [{queue:?}/{alloc:?}/{horizon:?}]");
            if horizon == HorizonKind::Eager {
                if base.events != serial.events {
                    return Err(format!("{tag}: events {} vs {}", base.events, serial.events));
                }
                if base.retries != serial.retries {
                    return Err(format!(
                        "{tag}: retries {} vs {}",
                        base.retries, serial.retries
                    ));
                }
            }
            if !same(base.makespan, serial.makespan) {
                return Err(format!(
                    "{tag}: makespan {} vs {}",
                    base.makespan, serial.makespan
                ));
            }
            if base.jobs.len() != serial.jobs.len() {
                return Err(format!("{tag}: job count differs"));
            }
            for (j, (a, b)) in base.jobs.iter().zip(serial.jobs.iter()).enumerate() {
                if a.is_completed() != b.is_completed() {
                    return Err(format!("{tag}: job {j} outcome {a:?} vs {b:?}"));
                }
            }
            for (i, (a, b)) in base.trace.iter().zip(serial.trace.iter()).enumerate() {
                if !same(a.start, b.start) || !same(a.finish, b.finish) {
                    return Err(format!(
                        "{tag}: chunk {i} trace {:?}..{:?} vs {:?}..{:?}",
                        a.start, a.finish, b.start, b.finish
                    ));
                }
            }
        }
        for (j, r) in corner.iter().enumerate().skip(1) {
            let tag = format!("{tag} [{queue:?}/{alloc:?}/{horizon:?} t{}]", THREADS[j]);
            if serial.retries != r.retries {
                return Err(format!("{tag}: retries {} vs {}", serial.retries, r.retries));
            }
            if serial.jobs.len() != r.jobs.len() {
                return Err(format!("{tag}: job count differs"));
            }
            match horizon {
                HorizonKind::Eager => {
                    if serial.events != r.events {
                        return Err(format!("{tag}: events {} vs {}", serial.events, r.events));
                    }
                    if serial.makespan.to_bits() != r.makespan.to_bits() {
                        return Err(format!(
                            "{tag}: makespan bits {} vs {}",
                            serial.makespan, r.makespan
                        ));
                    }
                    for (i, (a, b)) in serial.trace.iter().zip(r.trace.iter()).enumerate() {
                        if a.start.to_bits() != b.start.to_bits()
                            || a.finish.to_bits() != b.finish.to_bits()
                        {
                            return Err(format!(
                                "{tag}: chunk {i} trace {:?}..{:?} vs {:?}..{:?}",
                                a.start, a.finish, b.start, b.finish
                            ));
                        }
                    }
                }
                HorizonKind::Anchored => {
                    if !within_tolerance(serial.makespan, r.makespan) {
                        return Err(format!(
                            "{tag}: makespan {} vs {}",
                            serial.makespan, r.makespan
                        ));
                    }
                    for (i, (a, b)) in serial.trace.iter().zip(r.trace.iter()).enumerate() {
                        let ok = |x: f64, y: f64| {
                            within_tolerance(x, y) || (x.is_nan() && y.is_nan())
                        };
                        if !ok(a.start, b.start) || !ok(a.finish, b.finish) {
                            return Err(format!(
                                "{tag}: chunk {i} trace {:?}..{:?} vs {:?}..{:?}",
                                a.start, a.finish, b.start, b.finish
                            ));
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

/// The headline recovery oracle: random DAGs with a crash/restore
/// cycle on a random host under `Retry` — in-flight victims lose
/// their progress, re-enter behind backoff gates and finish after the
/// restore — must keep all 24 matrix cells agreeing. Crash instants
/// are odd fractions so no task-completion boundary coincides with a
/// kill in one corner but not another.
#[test]
fn prop_retry_matrix_agrees() {
    check(
        "recovery-equivalence",
        &Config { cases: 8, ..Default::default() },
        gen_params,
        |p| {
            let g = random_dag(p);
            let cluster = Cluster::uniform(p.hosts);
            let victim = (p.seed % p.hosts as u64) as usize;
            let timeline = DynTimeline::new()
                .with(0.7731, DynAction::FailHost { host: victim })
                .with(1.3371, DynAction::RestoreHost { host: victim })
                .with(2.7713, DynAction::FailHost { host: victim })
                .with(3.1337, DynAction::RestoreHost { host: victim });
            let retry = RecoveryPolicy::Retry { max_attempts: 5, backoff: 0.25 };
            for policy in [Policy::fair(), Policy::priority()] {
                let sim = mxdag::sim::expand(&g, &Default::default());
                let results = run_matrix(&sim, &cluster, policy, &timeline, retry)?;
                assert_equivalent(&format!("{policy:?}"), &results)?;
                // the cycle must complete everything: the host comes
                // back before backoff gates expire a 5th time
                let base = &results[0][0];
                if !base.jobs.iter().all(|j| j.is_completed()) {
                    return Err(format!("jobs not completed: {:?}", base.jobs));
                }
            }
            Ok(())
        },
    );
}

/// Oracle-pairing convention, side one: under `FailFast` a
/// `fail_host` is *only* a capacity event — every corner (and thread
/// count) must be bit-identical to the same timeline with each crash
/// spelled `slow_host { factor: 0 }`, whether the run completes or
/// deadlocks.
#[test]
fn prop_failfast_crash_is_bitwise_slow_host_zero() {
    check(
        "recovery-failfast-corner",
        &Config { cases: 8, ..Default::default() },
        gen_params,
        |p| {
            let g = random_dag(p);
            let cluster = Cluster::uniform(p.hosts);
            let victim = (p.seed % p.hosts as u64) as usize;
            let crash = DynTimeline::new()
                .with(0.7731, DynAction::FailHost { host: victim })
                .with(2.3371, DynAction::RestoreHost { host: victim });
            let slow = DynTimeline::new()
                .with(0.7731, DynAction::SlowHost { host: victim, factor: 0.0 })
                .with(2.3371, DynAction::RestoreHost { host: victim });
            let sim = mxdag::sim::expand(&g, &Default::default());
            for &(queue, alloc, horizon) in MATRIX.iter() {
                for &threads in THREADS.iter() {
                    let cfg = |tl: &DynTimeline| SimConfig {
                        queue,
                        alloc,
                        horizon,
                        threads,
                        dynamics: tl.clone(),
                        recovery: RecoveryPolicy::FailFast,
                        ..Default::default()
                    };
                    let a = simulate(&sim, &cluster, &cfg(&crash));
                    let b = simulate(&sim, &cluster, &cfg(&slow));
                    let tag = format!("{queue:?}/{alloc:?}/{horizon:?}/t{threads}");
                    match (a, b) {
                        (Ok(ra), Ok(rb)) => {
                            if ra.makespan.to_bits() != rb.makespan.to_bits()
                                || ra.events != rb.events
                            {
                                return Err(format!(
                                    "{tag}: {} / {} vs {} / {}",
                                    ra.makespan, ra.events, rb.makespan, rb.events
                                ));
                            }
                            for (i, (x, y)) in
                                ra.trace.iter().zip(rb.trace.iter()).enumerate()
                            {
                                if x.start.to_bits() != y.start.to_bits()
                                    || x.finish.to_bits() != y.finish.to_bits()
                                {
                                    return Err(format!("{tag}: chunk {i} diverged"));
                                }
                            }
                        }
                        (Err(ea), Err(eb)) => {
                            if format!("{ea:?}") != format!("{eb:?}") {
                                return Err(format!("{tag}: {ea:?} vs {eb:?}"));
                            }
                        }
                        (x, y) => {
                            return Err(format!("{tag}: outcome kind diverged {x:?} vs {y:?}"))
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Oracle-pairing convention, side two: `Retry` with an *empty*
/// timeline takes the exact code path `FailFast` does (no crashes, no
/// victims, retry gates all zero) — bit-identical results on every
/// corner and thread count.
#[test]
fn prop_retry_with_empty_timeline_is_bitwise_failfast() {
    check(
        "recovery-empty-timeline-corner",
        &Config { cases: 8, ..Default::default() },
        gen_params,
        |p| {
            let g = random_dag(p);
            let cluster = Cluster::uniform(p.hosts);
            let sim = mxdag::sim::expand(&g, &Default::default());
            for &(queue, alloc, horizon) in MATRIX.iter() {
                for &threads in THREADS.iter() {
                    let cfg = |recovery| SimConfig {
                        queue,
                        alloc,
                        horizon,
                        threads,
                        recovery,
                        ..Default::default()
                    };
                    let ff = simulate(&sim, &cluster, &cfg(RecoveryPolicy::FailFast))
                        .map_err(|e| format!("failfast: {e}"))?;
                    let rt = simulate(&sim, &cluster, &cfg(RecoveryPolicy::retry_default()))
                        .map_err(|e| format!("retry: {e}"))?;
                    let tag = format!("{queue:?}/{alloc:?}/{horizon:?}/t{threads}");
                    if ff.makespan.to_bits() != rt.makespan.to_bits() || ff.events != rt.events
                    {
                        return Err(format!(
                            "{tag}: {} / {} vs {} / {}",
                            ff.makespan, ff.events, rt.makespan, rt.events
                        ));
                    }
                    for (i, (x, y)) in ff.trace.iter().zip(rt.trace.iter()).enumerate() {
                        if x.start.to_bits() != y.start.to_bits()
                            || x.finish.to_bits() != y.finish.to_bits()
                        {
                            return Err(format!("{tag}: chunk {i} diverged"));
                        }
                    }
                    if rt.retries != 0 || rt.lost_work != 0.0 {
                        return Err(format!("{tag}: phantom retries {}", rt.retries));
                    }
                }
            }
            Ok(())
        },
    );
}

/// The satellite determinism fix: `DynTimeline::merge` must preserve
/// last-writer-wins order for same-timestamp events. Two timelines
/// that collide on every instant (a degrade and its restore at the
/// same `at`) merge into exactly the individually-pushed spelling —
/// `PartialEq` on the event lists *and* bitwise on a simulation that
/// is sensitive to which same-instant writer survives.
#[test]
fn prop_merge_preserves_same_timestamp_order() {
    check(
        "dyn-merge-lww",
        &Config { cases: 12, ..Default::default() },
        |rng: &mut Rng| {
            let n_events = rng.range(1, 6);
            let mut ats = Vec::new();
            for _ in 0..n_events {
                ats.push(rng.range_f64(0.25, 3.0));
            }
            (rng.range_f64(0.1, 0.9), ats)
        },
        |(factor, ats)| {
            // a: degrade the uplink at each instant; b: restore it at
            // the same instants. merge(a, b) must leave every instant
            // restored (b wrote last); merge(b, a) must leave it
            // degraded.
            let link = LinkRef::NicUp(0);
            let mut a = DynTimeline::new();
            let mut b = DynTimeline::new();
            for &at in ats.iter() {
                a.push(at, DynAction::Degrade { link, factor: *factor });
                b.push(at, DynAction::Restore { link });
            }
            let mut merged = a.clone();
            merged.merge(&b);
            let mut reference = a.clone();
            for e in b.events() {
                reference.push(e.at, e.action);
            }
            if merged != reference {
                return Err(format!("merge != push-by-push: {merged:?} vs {reference:?}"));
            }
            // semantics: every instant nets out restored, so the flow
            // runs at full rate throughout — bitwise equal to no churn
            let sim = one_flow(0, 1, 4.0);
            let cluster = Cluster::uniform(2);
            let run = |tl: &DynTimeline| {
                simulate(
                    &sim,
                    &cluster,
                    &SimConfig { dynamics: tl.clone(), ..Default::default() },
                )
                .map_err(|e| e.to_string())
            };
            let with_merged = run(&merged)?;
            let clean = run(&DynTimeline::new())?;
            if with_merged.makespan.to_bits() != clean.makespan.to_bits() {
                return Err(format!(
                    "restore must win every instant: {} vs {}",
                    with_merged.makespan, clean.makespan
                ));
            }
            // and the reversed merge leaves the link degraded from the
            // first instant on — strictly slower
            let mut degraded = b.clone();
            degraded.merge(&a);
            let with_degraded = run(&degraded)?;
            if with_degraded.makespan <= with_merged.makespan + 1e-9 {
                return Err(format!(
                    "degrade must win when merged last: {} vs {}",
                    with_degraded.makespan, with_merged.makespan
                ));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Deterministic semantics: quarantine scope, capacity conservation,
// retry exhaustion.
// ---------------------------------------------------------------------

/// One flow `src -> dst` of `size`, as a bare `SimDag` (no dummies).
fn one_flow(src: usize, dst: usize, size: f64) -> SimDag {
    let mut d = SimDag::default();
    d.push(SimTask {
        orig: 0,
        chunk: (0, 1),
        kind: SimKind::Flow { src, dst },
        size,
        priority: 0,
        gate: 0.0,
        coflow: None,
    });
    d
}

fn push_compute(d: &mut SimDag, orig: usize, host: usize, size: f64) {
    d.push(SimTask {
        orig,
        chunk: (0, 1),
        kind: SimKind::Compute { host },
        size,
        priority: 0,
        gate: 0.0,
        coflow: None,
    });
}

/// The acceptance scenario: two independent jobs on a k = 1 parallel
/// fabric — job 0 is a flow pinned to the only trunk, job 1 is a
/// compute that never touches the fabric. The trunk dies mid-flow
/// with no survivor to reroute to; under `Retry` job 0 is quarantined
/// `Starved` on the trunk's arena slot while job 1 completes with its
/// solo makespan, bitwise, in every corner.
#[test]
fn trunk_death_quarantines_only_the_stranded_job() {
    let mut sim = one_flow(0, 1, 4.0);
    push_compute(&mut sim, 1, 2, 3.0);
    sim.job_of = vec![0, 1];
    let cluster = Cluster::parallel_fabrics(3, 1, 1.0);
    let trunk_slot = Topology::trunk(0, 3);
    let tl = DynTimeline::new()
        .with(1.0, DynAction::Degrade { link: LinkRef::Trunk(0), factor: 0.0 });

    // solo oracle: job 1's compute alone on the same cluster/timeline
    let mut solo = SimDag::default();
    push_compute(&mut solo, 1, 2, 3.0);

    for &(queue, alloc, horizon) in MATRIX.iter() {
        for &threads in THREADS.iter() {
            let cfg = SimConfig {
                queue,
                alloc,
                horizon,
                threads,
                dynamics: tl.clone(),
                recovery: RecoveryPolicy::retry_default(),
                ..Default::default()
            };
            let tag = format!("{queue:?}/{alloc:?}/{horizon:?}/t{threads}");
            let r = simulate(&sim, &cluster, &cfg)
                .unwrap_or_else(|e| panic!("{tag}: {e}"));
            assert_eq!(r.jobs.len(), 2, "{tag}");
            match r.jobs[0] {
                JobOutcome::Quarantined {
                    reason: StuckReason::Starved { resource: Some(res) },
                    at,
                } => {
                    assert_eq!(res, trunk_slot, "{tag}: must name the dead trunk");
                    assert!((at - 3.0).abs() < 1e-6, "{tag}: quarantined at {at}");
                }
                other => panic!("{tag}: job 0 should be starved-quarantined: {other:?}"),
            }
            assert!(r.jobs[1].is_completed(), "{tag}: survivor job");
            assert!(r.trace[0].finish.is_nan(), "{tag}: dead flow keeps a NaN trace");

            // capacity conservation: the survivor is bit-identical to
            // a fresh run without the quarantined job
            let solo_r = simulate(&solo, &cluster, &cfg)
                .unwrap_or_else(|e| panic!("{tag} solo: {e}"));
            assert_eq!(
                r.makespan.to_bits(),
                solo_r.makespan.to_bits(),
                "{tag}: survivor makespan {} vs solo {}",
                r.makespan,
                solo_r.makespan
            );
            assert_eq!(
                r.trace[1].start.to_bits(),
                solo_r.trace[0].start.to_bits(),
                "{tag}: survivor start"
            );
            assert_eq!(
                r.trace[1].finish.to_bits(),
                solo_r.trace[0].finish.to_bits(),
                "{tag}: survivor finish"
            );
        }
    }
}

/// Capacity conservation through a *crash* quarantine: host 1 dies and
/// takes job 0's long compute with it (`max_attempts: 1` — exhausted
/// on the first kill, quarantined in the same engine event). Job 1
/// later needs the very slots job 0 held — host 1's core after the
/// restore — so any cap leak would starve or slow it. The survivor
/// must match a fresh run of job 1 alone, bitwise, in every corner.
#[test]
fn crash_quarantine_releases_every_held_slot() {
    // job 0: a long compute on host 1, in flight at the crash.
    // job 1: compute on host 0 -> flow 0 -> 1 -> compute on host 1.
    let mut sim = SimDag::default();
    push_compute(&mut sim, 0, 1, 10.0);
    push_compute(&mut sim, 1, 0, 1.0);
    sim.push(SimTask {
        orig: 2,
        chunk: (0, 1),
        kind: SimKind::Flow { src: 0, dst: 1 },
        size: 1.0,
        priority: 0,
        gate: 0.0,
        coflow: None,
    });
    push_compute(&mut sim, 3, 1, 1.0);
    sim.dep(1, 2);
    sim.dep(2, 3);
    sim.job_of = vec![0, 1, 1, 1];

    let mut solo = SimDag::default();
    push_compute(&mut solo, 1, 0, 1.0);
    solo.push(SimTask {
        orig: 2,
        chunk: (0, 1),
        kind: SimKind::Flow { src: 0, dst: 1 },
        size: 1.0,
        priority: 0,
        gate: 0.0,
        coflow: None,
    });
    push_compute(&mut solo, 3, 1, 1.0);
    solo.dep(0, 1);
    solo.dep(1, 2);

    let cluster = Cluster::uniform(2);
    let tl = DynTimeline::new()
        .with(0.5, DynAction::FailHost { host: 1 })
        .with(0.75, DynAction::RestoreHost { host: 1 });
    let policy = RecoveryPolicy::Retry { max_attempts: 1, backoff: 1.0 };

    for &(queue, alloc, horizon) in MATRIX.iter() {
        for &threads in THREADS.iter() {
            let cfg = SimConfig {
                queue,
                alloc,
                horizon,
                threads,
                dynamics: tl.clone(),
                recovery: policy,
                ..Default::default()
            };
            let tag = format!("{queue:?}/{alloc:?}/{horizon:?}/t{threads}");
            let r = simulate(&sim, &cluster, &cfg)
                .unwrap_or_else(|e| panic!("{tag}: {e}"));
            match r.jobs[0] {
                JobOutcome::Exhausted { attempts } => {
                    assert_eq!(attempts, 1, "{tag}: one kill exhausts max_attempts: 1")
                }
                other => panic!("{tag}: job 0 should be exhausted: {other:?}"),
            }
            assert!(r.jobs[1].is_completed(), "{tag}: survivor job");
            assert!((r.lost_work - 0.5).abs() < 1e-6, "{tag}: lost {}", r.lost_work);
            assert_eq!(r.retries, 0, "{tag}: exhaustion is not a retry");

            let solo_r = simulate(&solo, &cluster, &cfg)
                .unwrap_or_else(|e| panic!("{tag} solo: {e}"));
            assert_eq!(
                r.makespan.to_bits(),
                solo_r.makespan.to_bits(),
                "{tag}: survivor makespan {} vs solo {}",
                r.makespan,
                solo_r.makespan
            );
            for (i, j) in [(1usize, 0usize), (2, 1), (3, 2)] {
                assert_eq!(
                    r.trace[i].start.to_bits(),
                    solo_r.trace[j].start.to_bits(),
                    "{tag}: chunk {i} start"
                );
                assert_eq!(
                    r.trace[i].finish.to_bits(),
                    solo_r.trace[j].finish.to_bits(),
                    "{tag}: chunk {i} finish"
                );
            }
        }
    }
}

/// Backoff is simulated time, not wall time, and progress lost to a
/// crash really is lost: a size-2 compute killed at t = 1 (1 unit of
/// work gone) re-enters at `1 + backoff` after the restore and runs
/// its full size again. With backoff 0.5 and an immediate restore the
/// finish lands at exactly 1 + 0.5 + 2 = 3.5 in every corner.
#[test]
fn retry_backoff_gates_in_simulated_time() {
    let mut sim = SimDag::default();
    push_compute(&mut sim, 0, 0, 2.0);
    let cluster = Cluster::uniform(1);
    let tl = DynTimeline::new()
        .with(1.0, DynAction::FailHost { host: 0 })
        .with(1.25, DynAction::RestoreHost { host: 0 });
    for &(queue, alloc, horizon) in MATRIX.iter() {
        for &threads in THREADS.iter() {
            let cfg = SimConfig {
                queue,
                alloc,
                horizon,
                threads,
                dynamics: tl.clone(),
                recovery: RecoveryPolicy::Retry { max_attempts: 3, backoff: 0.5 },
                ..Default::default()
            };
            let tag = format!("{queue:?}/{alloc:?}/{horizon:?}/t{threads}");
            let r = simulate(&sim, &cluster, &cfg)
                .unwrap_or_else(|e| panic!("{tag}: {e}"));
            assert!(
                (r.makespan - 3.5).abs() < 1e-6,
                "{tag}: makespan {} (expected 1 + 0.5 backoff + 2 rerun)",
                r.makespan
            );
            assert_eq!(r.retries, 1, "{tag}");
            assert!((r.lost_work - 1.0).abs() < 1e-6, "{tag}: lost {}", r.lost_work);
            assert!(r.jobs[0].is_completed(), "{tag}");
            // the trace keeps the *first* attempt's start
            assert_eq!(r.trace[0].start.to_bits(), 0.0f64.to_bits(), "{tag}");
        }
    }
}

/// A host that never comes back exhausts the victim's attempts one
/// backoff doubling at a time (1, 2, 4, ... simulated seconds), then
/// quarantines the job as `Exhausted` — no deadlock, makespan pinned
/// at the final kill.
#[test]
fn permanent_crash_exhausts_attempts_and_quarantines() {
    let mut sim = SimDag::default();
    push_compute(&mut sim, 0, 0, 10.0);
    push_compute(&mut sim, 1, 1, 2.0);
    sim.job_of = vec![0, 1];
    let cluster = Cluster::uniform(2);
    // two crashes: the first kills the running task (attempt 1), the
    // second kills the retried attempt (attempt 2 = max) -> exhausted
    let tl = DynTimeline::new()
        .with(1.0, DynAction::FailHost { host: 0 })
        .with(1.5, DynAction::RestoreHost { host: 0 })
        .with(3.0, DynAction::FailHost { host: 0 });
    for &(queue, alloc, horizon) in MATRIX.iter() {
        for &threads in THREADS.iter() {
            let cfg = SimConfig {
                queue,
                alloc,
                horizon,
                threads,
                dynamics: tl.clone(),
                recovery: RecoveryPolicy::Retry { max_attempts: 2, backoff: 1.0 },
                ..Default::default()
            };
            let tag = format!("{queue:?}/{alloc:?}/{horizon:?}/t{threads}");
            let r = simulate(&sim, &cluster, &cfg)
                .unwrap_or_else(|e| panic!("{tag}: {e}"));
            match r.jobs[0] {
                JobOutcome::Exhausted { attempts } => assert_eq!(attempts, 2, "{tag}"),
                other => panic!("{tag}: expected exhaustion, got {other:?}"),
            }
            assert!(r.jobs[1].is_completed(), "{tag}");
            assert_eq!(r.retries, 1, "{tag}: only the first kill re-enqueued");
            // attempt 1 runs [0, 1); retry gate 1 + 1 = 2; attempt 2
            // runs [2, 3) and dies at 3 -> 1 + 1 = 2 units destroyed
            assert!((r.lost_work - 2.0).abs() < 1e-6, "{tag}: lost {}", r.lost_work);
        }
    }
}
