//! Equivalence oracle for mid-simulation cluster dynamics: a seeded
//! random `DynTimeline` (degradations, restores, stragglers, host
//! churn) is injected into every corner of the {Incremental,
//! FullResort} × {Components, WholeSet} × {Eager, Anchored} ×
//! threads ∈ {1, 2, 4} matrix, with the serial FullResort/WholeSet
//! corner pinned as the oracle. The contract is the one
//! `prop_queue_equivalence` establishes for the static cluster and
//! churn must not weaken: eager corners agree bitwise (same event
//! boundaries — dynamics events split steps identically everywhere —
//! same makespan, same per-chunk traces), anchored corners within the
//! shared `mxdag::sim::within_tolerance` bound. On top of the matrix,
//! deterministic scenarios pin the *semantics*: a degraded link really
//! caps progress, a failed link carries zero flow until restored, a
//! restored link is re-eligible at the restore instant, a failed trunk
//! reroutes over the surviving parallel fabrics, and a stranded flow
//! deadlocks naming the dead link's arena slot.

use mxdag::sched::Plan;
use mxdag::sim::{
    simulate, within_tolerance, AllocKind, Cluster, DynAction, DynTimeline, HorizonKind,
    LinkRef, Policy, QueueKind, SimConfig, SimDag, SimError, SimKind, SimResult, SimTask,
    StuckReason,
};
use mxdag::util::propcheck::{check, Config};
use mxdag::util::rng::Rng;
use mxdag::workloads::{random_dag, RandomParams};

fn gen_params(rng: &mut Rng) -> RandomParams {
    RandomParams {
        layers: rng.range(2, 6),
        width: rng.range(2, 6),
        hosts: rng.range(2, 10),
        edge_p: rng.range_f64(0.2, 0.9),
        pipe_frac: 0.0,
        min_size: 0.1,
        max_size: 3.0,
        seed: rng.next_u64(),
    }
}

/// The full configuration matrix; the first entry is the serial
/// whole-set baseline every other corner is compared against.
const MATRIX: [(QueueKind, AllocKind, HorizonKind); 8] = [
    (QueueKind::FullResort, AllocKind::WholeSet, HorizonKind::Eager),
    (QueueKind::Incremental, AllocKind::WholeSet, HorizonKind::Eager),
    (QueueKind::FullResort, AllocKind::Components, HorizonKind::Eager),
    (QueueKind::Incremental, AllocKind::Components, HorizonKind::Eager),
    (QueueKind::FullResort, AllocKind::WholeSet, HorizonKind::Anchored),
    (QueueKind::Incremental, AllocKind::WholeSet, HorizonKind::Anchored),
    (QueueKind::FullResort, AllocKind::Components, HorizonKind::Anchored),
    (QueueKind::Incremental, AllocKind::Components, HorizonKind::Anchored),
];

/// Thread counts crossed with every corner; `threads = 1` is pinned
/// explicitly so a `MXDAG_TEST_THREADS` override cannot shift the
/// per-corner oracle.
const THREADS: [usize; 3] = [1, 2, 4];

/// Run `sim` through the whole matrix with `timeline` injected into
/// every corner's `SimConfig`.
fn run_matrix(
    sim: &SimDag,
    cluster: &Cluster,
    policy: Policy,
    timeline: &DynTimeline,
) -> Result<Vec<Vec<SimResult>>, String> {
    MATRIX
        .iter()
        .map(|&(queue, alloc, horizon)| {
            THREADS
                .iter()
                .map(|&threads| {
                    simulate(
                        sim,
                        cluster,
                        &SimConfig {
                            policy,
                            queue,
                            alloc,
                            horizon,
                            threads,
                            dynamics: timeline.clone(),
                            ..Default::default()
                        },
                    )
                    .map_err(|e| format!("{queue:?}/{alloc:?}/{horizon:?}/t{threads}: {e}"))
                })
                .collect()
        })
        .collect()
}

/// The `prop_queue_equivalence` agreement contract, verbatim: corner
/// serials against the whole-set baseline (bitwise-ish for eager,
/// tolerance for anchored), threaded runs against their own corner's
/// serial (bitwise for eager, tolerance for anchored).
fn assert_equivalent(tag: &str, results: &[Vec<SimResult>]) -> Result<(), String> {
    let base = &results[0][0];
    for (k, corner) in results.iter().enumerate() {
        let (queue, alloc, horizon) = MATRIX[k];
        let serial = &corner[0];
        let check_events = horizon == HorizonKind::Eager;
        let same = |x: f64, y: f64| match horizon {
            HorizonKind::Eager => (x - y).abs() <= 1e-9 || (x.is_nan() && y.is_nan()),
            HorizonKind::Anchored => within_tolerance(x, y),
        };
        if k > 0 {
            let tag = format!("{tag} [{queue:?}/{alloc:?}/{horizon:?}]");
            if check_events && base.events != serial.events {
                return Err(format!("{tag}: events {} vs {}", base.events, serial.events));
            }
            if !same(base.makespan, serial.makespan) {
                return Err(format!(
                    "{tag}: makespan {} vs {}",
                    base.makespan, serial.makespan
                ));
            }
            if base.trace.len() != serial.trace.len() {
                return Err(format!("{tag}: trace length differs"));
            }
            for (i, (a, b)) in base.trace.iter().zip(serial.trace.iter()).enumerate() {
                if !same(a.start, b.start) || !same(a.finish, b.finish) {
                    return Err(format!(
                        "{tag}: chunk {i} trace {:?}..{:?} vs {:?}..{:?}",
                        a.start, a.finish, b.start, b.finish
                    ));
                }
            }
        }
        for (j, r) in corner.iter().enumerate().skip(1) {
            let tag = format!("{tag} [{queue:?}/{alloc:?}/{horizon:?} t{}]", THREADS[j]);
            match horizon {
                HorizonKind::Eager => {
                    if serial.events != r.events {
                        return Err(format!("{tag}: events {} vs {}", serial.events, r.events));
                    }
                    if serial.makespan.to_bits() != r.makespan.to_bits() {
                        return Err(format!(
                            "{tag}: makespan bits {} vs {}",
                            serial.makespan, r.makespan
                        ));
                    }
                    for (i, (a, b)) in serial.trace.iter().zip(r.trace.iter()).enumerate() {
                        if a.start.to_bits() != b.start.to_bits()
                            || a.finish.to_bits() != b.finish.to_bits()
                        {
                            return Err(format!(
                                "{tag}: chunk {i} trace {:?}..{:?} vs {:?}..{:?}",
                                a.start, a.finish, b.start, b.finish
                            ));
                        }
                    }
                }
                HorizonKind::Anchored => {
                    if !within_tolerance(serial.makespan, r.makespan) {
                        return Err(format!(
                            "{tag}: makespan {} vs {}",
                            serial.makespan, r.makespan
                        ));
                    }
                    for (i, (a, b)) in serial.trace.iter().zip(r.trace.iter()).enumerate() {
                        if !within_tolerance(a.start, b.start)
                            || !within_tolerance(a.finish, b.finish)
                        {
                            return Err(format!(
                                "{tag}: chunk {i} trace {:?}..{:?} vs {:?}..{:?}",
                                a.start, a.finish, b.start, b.finish
                            ));
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

/// The headline churn oracle: random DAGs × random timelines (factors
/// in [0.1, 1.0] — no failures, so every corner completes) under every
/// static-plan policy family; all 24 matrix cells must keep agreeing
/// while links degrade, recover and hosts slow down mid-run.
#[test]
fn prop_random_churn_matrix_agrees() {
    check(
        "dynamics-equivalence",
        &Config { cases: 10, ..Default::default() },
        gen_params,
        |p| {
            let g = random_dag(p);
            let cluster = Cluster::uniform(p.hosts);
            let timeline = DynTimeline::random(p.seed ^ 0x9e37, &cluster, 6, 6.0);
            for policy in [Policy::fair(), Policy::fifo(), Policy::priority(), Policy::coflow()]
            {
                let plan = Plan { ann: Default::default(), policy };
                let sim = mxdag::sim::expand(&g, &plan.ann);
                let results = run_matrix(&sim, &cluster, policy, &timeline)?;
                assert_equivalent(&format!("{policy:?}"), &results)?;
            }
            Ok(())
        },
    );
}

/// Churn on parallel fabrics, including a full trunk failure and a
/// restore: rerouting over the surviving trunks must happen at the
/// same instant — with the same deterministic task order — in every
/// corner, and the restore must fold everyone back onto their static
/// path selection.
#[test]
fn prop_fabric_churn_with_reroute_agrees() {
    check(
        "dynamics-equivalence-fabrics",
        &Config { cases: 8, ..Default::default() },
        gen_params,
        |p| {
            let g = random_dag(p);
            let cluster = Cluster::parallel_fabrics(p.hosts.max(2), 2, 0.5);
            let timeline = DynTimeline::random(p.seed ^ 0x51ed, &cluster, 4, 6.0)
                .with(1.0, DynAction::Degrade { link: LinkRef::Trunk(0), factor: 0.0 })
                .with(3.0, DynAction::Restore { link: LinkRef::Trunk(0) });
            for policy in [Policy::fair(), Policy::priority(), Policy::coflow()] {
                let plan = Plan { ann: Default::default(), policy };
                let sim = mxdag::sim::expand(&g, &plan.ann);
                let results = run_matrix(&sim, &cluster, policy, &timeline)?;
                assert_equivalent(&format!("fabrics {policy:?}"), &results)?;
            }
            Ok(())
        },
    );
}

/// Flap storm: a NIC capacity that degrades/restores every quarter
/// time unit — far denser than the task event rate — so nearly every
/// engine step is a dynamics boundary. The matrix must still agree.
#[test]
fn flap_storm_matches_oracle() {
    let p = RandomParams {
        layers: 4,
        width: 4,
        hosts: 4,
        edge_p: 0.5,
        pipe_frac: 0.0,
        min_size: 0.5,
        max_size: 3.0,
        seed: 0xf1a9,
    };
    let g = random_dag(&p);
    let cluster = Cluster::uniform(p.hosts);
    let mut timeline = DynTimeline::flap(LinkRef::NicUp(0), 0.3, 0.25, 30.0);
    // a second flapping link, phase-shifted, so flaps overlap
    for e in DynTimeline::flap(LinkRef::NicDown(1), 0.5, 0.4, 30.0).events() {
        timeline.push(e.at, e.action);
    }
    for policy in [Policy::fair(), Policy::priority()] {
        let sim = mxdag::sim::expand(&g, &Default::default());
        let results = run_matrix(&sim, &cluster, policy, &timeline).unwrap();
        assert_equivalent(&format!("flap {policy:?}"), &results).unwrap();
    }
}

/// A timeline whose events all land after the last task finishes must
/// leave every corner bit-identical to the no-dynamics run: pending
/// events bound the step size from above but never shrink it below the
/// task horizon, and unapplied events are simply dropped at exit.
#[test]
fn post_completion_events_change_nothing() {
    let p = RandomParams {
        layers: 3,
        width: 3,
        hosts: 3,
        edge_p: 0.5,
        pipe_frac: 0.0,
        min_size: 0.5,
        max_size: 2.0,
        seed: 7,
    };
    let g = random_dag(&p);
    let cluster = Cluster::uniform(p.hosts);
    let sim = mxdag::sim::expand(&g, &Default::default());
    let late = DynTimeline::new()
        .with(1e6, DynAction::Degrade { link: LinkRef::NicUp(0), factor: 0.1 })
        .with(2e6, DynAction::SlowHost { host: 1, factor: 0.2 });
    let frozen = run_matrix(&sim, &cluster, Policy::fair(), &DynTimeline::new()).unwrap();
    let with_late = run_matrix(&sim, &cluster, Policy::fair(), &late).unwrap();
    for (k, (a_corner, b_corner)) in frozen.iter().zip(with_late.iter()).enumerate() {
        for (j, (a, b)) in a_corner.iter().zip(b_corner.iter()).enumerate() {
            assert_eq!(a.events, b.events, "corner {k} t{}", THREADS[j]);
            assert_eq!(
                a.makespan.to_bits(),
                b.makespan.to_bits(),
                "corner {k} t{}: {} vs {}",
                THREADS[j],
                a.makespan,
                b.makespan
            );
            for (i, (ta, tb)) in a.trace.iter().zip(b.trace.iter()).enumerate() {
                assert_eq!(ta.start.to_bits(), tb.start.to_bits(), "corner {k} chunk {i}");
                assert_eq!(ta.finish.to_bits(), tb.finish.to_bits(), "corner {k} chunk {i}");
            }
        }
    }
}

// ---------------------------------------------------------------------
// Deterministic semantics: capacity bounds, failure, restore, reroute.
// ---------------------------------------------------------------------

/// One flow `src -> dst` of `size`, as a bare `SimDag` (no dummies).
fn one_flow(src: usize, dst: usize, size: f64) -> SimDag {
    let mut d = SimDag::default();
    d.push(SimTask {
        orig: 0,
        chunk: (0, 1),
        kind: SimKind::Flow { src, dst },
        size,
        priority: 0,
        gate: 0.0,
        coflow: None,
    });
    d
}

fn run_all_corners(
    sim: &SimDag,
    cluster: &Cluster,
    timeline: &DynTimeline,
) -> Vec<Result<SimResult, SimError>> {
    MATRIX
        .iter()
        .map(|&(queue, alloc, horizon)| {
            simulate(
                sim,
                cluster,
                &SimConfig {
                    queue,
                    alloc,
                    horizon,
                    dynamics: timeline.clone(),
                    ..Default::default()
                },
            )
        })
        .collect()
}

/// No task may progress faster than the degraded capacity of a claimed
/// resource: a size-2 flow whose uplink drops to 0.25 at t = 1 has
/// exactly 1 byte left that now drains at 0.25 — finish at 5, in every
/// corner. Finishing any earlier would mean the flow ran above the
/// degraded cap.
#[test]
fn degraded_capacity_bounds_progress() {
    let sim = one_flow(0, 1, 2.0);
    let cluster = Cluster::uniform(2);
    let tl = DynTimeline::new()
        .with(1.0, DynAction::Degrade { link: LinkRef::NicUp(0), factor: 0.25 });
    for (k, r) in run_all_corners(&sim, &cluster, &tl).into_iter().enumerate() {
        let r = r.unwrap_or_else(|e| panic!("corner {k} failed: {e}"));
        assert!(
            (r.makespan - 5.0).abs() < 1e-6,
            "corner {k}: makespan {} (expected 5.0)",
            r.makespan
        );
    }
}

/// A failed link carries zero rated flow for the whole outage, and the
/// restored link is re-eligible at the restore instant: 1 byte moves
/// before the failure at t = 1, nothing during [1, 3], and the last
/// byte right after — finish at exactly 4.
#[test]
fn failed_link_carries_nothing_until_restore() {
    let sim = one_flow(0, 1, 2.0);
    let cluster = Cluster::uniform(2);
    let tl = DynTimeline::new()
        .with(1.0, DynAction::Degrade { link: LinkRef::NicUp(0), factor: 0.0 })
        .with(3.0, DynAction::Restore { link: LinkRef::NicUp(0) });
    for (k, r) in run_all_corners(&sim, &cluster, &tl).into_iter().enumerate() {
        let r = r.unwrap_or_else(|e| panic!("corner {k} failed: {e}"));
        assert!(
            (r.makespan - 4.0).abs() < 1e-6,
            "corner {k}: makespan {} (expected 4.0)",
            r.makespan
        );
    }
}

/// A straggler host throttles its compute slot: a size-2 compute task
/// on a host that slows to 0.5 at t = 1 finishes at 3.
#[test]
fn slow_host_throttles_compute() {
    let mut d = SimDag::default();
    d.push(SimTask {
        orig: 0,
        chunk: (0, 1),
        kind: SimKind::Compute { host: 0 },
        size: 2.0,
        priority: 0,
        gate: 0.0,
        coflow: None,
    });
    let cluster = Cluster::uniform(2);
    let tl = DynTimeline::new().with(1.0, DynAction::SlowHost { host: 0, factor: 0.5 });
    for (k, r) in run_all_corners(&d, &cluster, &tl).into_iter().enumerate() {
        let r = r.unwrap_or_else(|e| panic!("corner {k} failed: {e}"));
        assert!(
            (r.makespan - 3.0).abs() < 1e-6,
            "corner {k}: makespan {} (expected 3.0)",
            r.makespan
        );
    }
}

/// A permanent NIC failure with no pending recovery strands the flow:
/// every corner must report `Deadlock` whose sampled stuck task is
/// starved on exactly the dead uplink's arena slot.
#[test]
fn permanent_failure_deadlocks_naming_the_link() {
    let sim = one_flow(0, 1, 2.0);
    let cluster = Cluster::uniform(2);
    let dead = LinkRef::NicUp(0);
    let tl = DynTimeline::new().with(1.0, DynAction::Degrade { link: dead, factor: 0.0 });
    for (k, r) in run_all_corners(&sim, &cluster, &tl).into_iter().enumerate() {
        match r {
            Err(SimError::Deadlock { now, n_remaining, stuck, .. }) => {
                assert!((now - 1.0).abs() < 1e-6, "corner {k}: stuck at t={now}");
                assert_eq!(n_remaining, 1, "corner {k}");
                assert_eq!(
                    stuck,
                    Some((0, StuckReason::Starved { resource: Some(dead.slot(2)) })),
                    "corner {k}: deadlock must name the dead uplink"
                );
            }
            other => panic!("corner {k}: expected deadlock, got {other:?}"),
        }
    }
}

/// Failing the trunk a flow was hashed onto makes `ParallelFabrics`
/// re-select among the survivors: with k = 2 trunks of full capacity
/// the flow continues at rate 1 and still finishes at 2; a restore
/// mid-flight folds it back onto its static pick without a hiccup.
#[test]
fn trunk_failure_reroutes_to_survivor() {
    let sim = one_flow(0, 1, 2.0); // hash pick: trunk (0 + 1) % 2 = 1
    let cluster = Cluster::parallel_fabrics(2, 2, 1.0);
    let fail_only = DynTimeline::new()
        .with(1.0, DynAction::Degrade { link: LinkRef::Trunk(1), factor: 0.0 });
    let fail_restore = fail_only
        .clone()
        .with(1.5, DynAction::Restore { link: LinkRef::Trunk(1) });
    for tl in [&fail_only, &fail_restore] {
        for (k, r) in run_all_corners(&sim, &cluster, tl).into_iter().enumerate() {
            let r = r.unwrap_or_else(|e| panic!("corner {k} failed: {e}"));
            assert!(
                (r.makespan - 2.0).abs() < 1e-6,
                "corner {k}: makespan {} (expected 2.0 via surviving trunk)",
                r.makespan
            );
        }
    }
}

/// With a single fabric (k = 1) there is no survivor to reroute to:
/// the flow keeps its dead footprint and every corner deadlocks naming
/// the failed trunk's slot.
#[test]
fn stranded_flow_names_the_failed_trunk() {
    let sim = one_flow(0, 1, 2.0);
    let cluster = Cluster::parallel_fabrics(2, 1, 1.0);
    let dead = LinkRef::Trunk(0);
    let tl = DynTimeline::new().with(1.0, DynAction::Degrade { link: dead, factor: 0.0 });
    for (k, r) in run_all_corners(&sim, &cluster, &tl).into_iter().enumerate() {
        match r {
            Err(SimError::Deadlock { stuck, .. }) => {
                assert_eq!(
                    stuck,
                    Some((0, StuckReason::Starved { resource: Some(dead.slot(2)) })),
                    "corner {k}: deadlock must name the failed trunk"
                );
            }
            other => panic!("corner {k}: expected deadlock, got {other:?}"),
        }
    }
}
