//! Property-based invariants (util::propcheck) over random MXDAGs:
//! graph validity, simulator conservation laws, allocation feasibility,
//! Eq.(1)/(2) ordering, topology compatibility/monotonicity, and
//! schedule-independence of completion.

use mxdag::mxdag::{cpm, path, MXDag, TaskKind};
use mxdag::sched::{evaluate, Plan};
use mxdag::sim::{alloc, Cluster, Policy, SimDag, SimKind, SimTask, Topology};
use mxdag::util::propcheck::{check, Config};
use mxdag::util::rng::Rng;
use mxdag::workloads::{oversub, random_dag, RandomParams};

fn gen_params(rng: &mut Rng) -> RandomParams {
    RandomParams {
        layers: rng.range(2, 6),
        width: rng.range(2, 6),
        hosts: rng.range(2, 10),
        edge_p: rng.range_f64(0.2, 0.9),
        pipe_frac: rng.range_f64(0.0, 0.8),
        min_size: 0.1,
        max_size: 3.0,
        seed: rng.next_u64(),
    }
}

#[test]
fn prop_topo_order_valid() {
    check(
        "topo-order-valid",
        &Config { cases: 40, ..Default::default() },
        gen_params,
        |p| {
            let g = random_dag(p);
            let mut pos = vec![0usize; g.len()];
            for (i, &t) in g.topo().iter().enumerate() {
                pos[t] = i;
            }
            for u in 0..g.len() {
                for &v in g.succs(u) {
                    if pos[u] >= pos[v] {
                        return Err(format!("edge {u}->{v} violates topo"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simulation_conserves_and_bounds() {
    check(
        "sim-conservation",
        &Config { cases: 30, ..Default::default() },
        gen_params,
        |p| {
            let g = random_dag(p);
            let cluster = Cluster::uniform(p.hosts);
            let bound = cpm(&g).makespan;
            for policy in [Policy::fair(), Policy::fifo(), Policy::priority()] {
                let r = evaluate(&g, &cluster, &Plan { ann: Default::default(), policy })
                    .map_err(|e| e.to_string())?;
                if !r.makespan.is_finite() {
                    return Err("non-finite makespan".into());
                }
                if r.makespan < bound - 1e-6 {
                    return Err(format!("makespan {} beats CPM bound {bound}", r.makespan));
                }
                // work conservation-ish: every real task ran start<=finish
                for t in g.real_tasks() {
                    let (s, f) = (r.start_of(t), r.finish_of(t));
                    if !(s.is_finite() && f.is_finite() && f + 1e-9 >= s) {
                        return Err(format!("task {t} trace invalid: {s}..{f}"));
                    }
                    // deps respected at the logical level
                    for &pr in g.preds(t) {
                        if g.task(pr).kind.is_dummy() {
                            continue;
                        }
                        // pipelined preds may overlap; only whole-task
                        // deps are strict — check via CPM-free rule:
                        // finish of pred's FIRST chunk <= finish of t
                        if r.finish_of(t) + 1e-9 < r.start_of(pr) {
                            return Err(format!("task {t} finished before pred {pr} started"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_maxmin_allocation_feasible() {
    check(
        "maxmin-feasible",
        &Config { cases: 60, ..Default::default() },
        |rng| {
            let hosts = rng.range(2, 8);
            let n = rng.range(1, 12);
            let mut dag = SimDag::default();
            let mut ids = Vec::new();
            for _ in 0..n {
                let src = rng.below(hosts);
                let dst = (src + 1 + rng.below(hosts - 1)) % hosts;
                let kind = if rng.bool(0.5) {
                    SimKind::Flow { src, dst }
                } else {
                    SimKind::Compute { host: src }
                };
                ids.push(dag.push(SimTask {
                    orig: 0,
                    chunk: (0, 1),
                    kind,
                    size: 1.0,
                    priority: rng.below(5) as i64,
                    gate: 0.0,
                    coflow: None,
                }));
            }
            (hosts, dag, ids)
        },
        |(hosts, dag, ids)| {
            let cluster = Cluster::uniform(*hosts);
            for fill in [0usize, 1] {
                let mut caps = cluster.capacities();
                let mut rates = vec![0.0; ids.len()];
                if fill == 0 {
                    alloc::maxmin_fill(dag, ids, &mut caps, &mut rates);
                } else {
                    alloc::priority_fill(dag, ids, &mut caps, &mut rates);
                }
                // rates within [0,1]
                for &r in &rates {
                    if !(0.0 - 1e-9..=1.0 + 1e-9).contains(&r) {
                        return Err(format!("rate {r} out of range"));
                    }
                }
                // capacity feasibility: recompute usage
                let caps0 = cluster.capacities();
                let mut used = vec![0.0; caps0.len()];
                for (i, &t) in ids.iter().enumerate() {
                    for r in dag.tasks[t].kind.resources() {
                        used[r] += rates[i];
                    }
                }
                for (r, (&u, &c)) in used.iter().zip(&caps0).enumerate() {
                    if u > c + 1e-6 {
                        return Err(format!("resource {r} oversubscribed: {u} > {c}"));
                    }
                }
                // non-trivial: at least one task makes progress
                if !rates.iter().any(|&r| r > 1e-9) {
                    return Err("no task progresses".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_eq2_never_exceeds_eq1() {
    check(
        "eq2-le-eq1",
        &Config { cases: 80, ..Default::default() },
        |rng| {
            let n = rng.range(2, 6);
            let mut b = MXDag::builder();
            let mut prev = None;
            let mut ids = Vec::new();
            for i in 0..n {
                let size = rng.range_f64(0.5, 10.0);
                let unit = size / rng.range(1, 10) as f64;
                let t = if i % 2 == 0 {
                    b.compute_full(&format!("c{i}"), i, size, unit)
                } else {
                    b.flow_full(&format!("f{i}"), i - 1, i, size, unit)
                };
                if let Some(p) = prev {
                    b.dep(p, t);
                }
                prev = Some(t);
                ids.push(t);
            }
            (b.finalize().unwrap(), ids)
        },
        |(g, ids)| {
            let pipe = path::len_pipe(g, ids, &path::full_rsrc);
            let seq = path::len_seq(g, ids, &path::full_rsrc);
            if pipe > seq + 1e-9 {
                return Err(format!("Eq2 {pipe} > Eq1 {seq}"));
            }
            // Eq2 lower bound: the slowest stage
            let max_size = ids
                .iter()
                .map(|&t| g.task(t).size)
                .fold(0.0f64, f64::max);
            if pipe < max_size - 1e-9 {
                return Err(format!("Eq2 {pipe} beats slowest stage {max_size}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip_random_dags() {
    check(
        "dag-json-roundtrip",
        &Config { cases: 30, ..Default::default() },
        gen_params,
        |p| {
            let g = random_dag(p);
            let j = g.to_json();
            let g2 = MXDag::from_json(&j).map_err(|e| e.to_string())?;
            if g.len() != g2.len() || g.n_edges() != g2.n_edges() {
                return Err("structure changed".into());
            }
            for t in g.tasks() {
                if t.kind.is_dummy() {
                    continue;
                }
                let t2 = g2.task(g2.by_name(&t.name).ok_or("name lost")?);
                if t.size != t2.size || t.unit != t2.unit {
                    return Err(format!("task {} fields changed", t.name));
                }
                match (t.kind, t2.kind) {
                    (TaskKind::Compute { host: a }, TaskKind::Compute { host: b }) if a == b => {}
                    (TaskKind::Flow { src: a, dst: b }, TaskKind::Flow { src: c, dst: d })
                        if a == c && b == d => {}
                    _ => return Err(format!("kind changed for {}", t.name)),
                }
            }
            Ok(())
        },
    );
}

/// Topology invariant (a): the big switch is the `ratio → 0` limit of
/// the leaf/spine fabric. With a ratio so small the aggregation links
/// can never bind, every policy must reproduce the big-switch results
/// *exactly* on random DAGs — the refactor's bit-for-bit compatibility
/// check, run through the full engine.
#[test]
fn prop_bigswitch_equals_never_binding_fabric() {
    check(
        "bigswitch-vs-slack-fabric",
        &Config { cases: 20, ..Default::default() },
        gen_params,
        |p| {
            let g = random_dag(p);
            let big = Cluster::uniform(p.hosts);
            let slack = Cluster::uniform(p.hosts)
                .with_topology(Topology::Oversubscribed { racks: 2, ratio: 1e-6 });
            for policy in [Policy::fair(), Policy::fifo(), Policy::priority(), Policy::coflow()]
            {
                let plan = Plan { ann: Default::default(), policy };
                let a = evaluate(&g, &big, &plan).map_err(|e| e.to_string())?;
                let b = evaluate(&g, &slack, &plan).map_err(|e| e.to_string())?;
                if (a.makespan - b.makespan).abs() > 1e-9 {
                    return Err(format!(
                        "{policy:?}: bigswitch {} vs slack fabric {}",
                        a.makespan, b.makespan
                    ));
                }
                for t in g.real_tasks() {
                    if (a.finish_of(t) - b.finish_of(t)).abs() > 1e-9 {
                        return Err(format!("{policy:?}: task {t} trace diverged"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Topology invariant (b): on a cross-rack shuffle whose flows share
/// only the two aggregation links (one flow per host pair), the
/// fair-share makespan is monotone non-decreasing in the
/// oversubscription ratio — less fabric can never finish sooner.
#[test]
fn prop_makespan_monotone_in_oversubscription() {
    check(
        "oversub-monotone",
        &Config { cases: 30, ..Default::default() },
        |rng| {
            let per_rack = rng.range(2, 7);
            let n_flows = rng.range(1, per_rack + 1);
            let sizes: Vec<f64> =
                (0..n_flows).map(|_| rng.range_f64(0.5, 3.0)).collect();
            (per_rack, sizes)
        },
        |(per_rack, sizes)| {
            let g = oversub::cross_rack_flows(*per_rack, sizes);
            let mut prev = 0.0;
            for ratio in [1.0, 2.0, 4.0, 8.0, 16.0] {
                let cluster = oversub::two_rack_cluster(*per_rack, ratio);
                let r = evaluate(&g, &cluster, &Plan::fair()).map_err(|e| e.to_string())?;
                if !r.makespan.is_finite() {
                    return Err(format!("ratio {ratio}: non-finite makespan"));
                }
                if r.makespan + 1e-9 < prev {
                    return Err(format!(
                        "makespan shrank as the fabric tightened: {prev} -> {} at {ratio}",
                        r.makespan
                    ));
                }
                prev = r.makespan;
            }
            Ok(())
        },
    );
}

/// Every policy completes (finite makespan, valid traces) on an
/// oversubscribed fabric and on parallel fabrics — no deadlocks from
/// the added shared resources.
#[test]
fn prop_all_policies_complete_on_fabrics() {
    check(
        "fabrics-complete",
        &Config { cases: 15, ..Default::default() },
        gen_params,
        |p| {
            let g = random_dag(p);
            let clusters = [
                Cluster::uniform(p.hosts)
                    .with_topology(Topology::Oversubscribed { racks: 2, ratio: 4.0 }),
                Cluster::parallel_fabrics(p.hosts, 2, 0.5),
            ];
            for cluster in &clusters {
                for policy in
                    [Policy::fair(), Policy::fifo(), Policy::priority(), Policy::coflow()]
                {
                    let r = evaluate(&g, cluster, &Plan { ann: Default::default(), policy })
                        .map_err(|e| format!("{policy:?}: {e}"))?;
                    if !(r.makespan.is_finite() && r.makespan >= 0.0) {
                        return Err(format!("{policy:?}: bad makespan {}", r.makespan));
                    }
                    for t in g.real_tasks() {
                        if r.finish_of(t) + 1e-9 < r.start_of(t) {
                            return Err(format!("{policy:?}: task {t} finished before start"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_priorities_permutation_of_levels() {
    check(
        "cpm-priorities-levels",
        &Config { cases: 30, ..Default::default() },
        gen_params,
        |p| {
            let g = random_dag(p);
            let c = cpm(&g);
            let prios = c.priorities();
            // strictly smaller slack => strictly larger priority
            for a in 0..g.len() {
                for b in 0..g.len() {
                    if c.slack[a] + 1e-9 < c.slack[b] && prios[a] <= prios[b] {
                        return Err(format!(
                            "slack {} < {} but prio {} <= {}",
                            c.slack[a], c.slack[b], prios[a], prios[b]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}
