//! Property: crash-safe serve-mode recovery. A `serve::Service` killed
//! at arbitrary points (no drain, no final snapshot — the WAL tail is
//! all that survives) and resumed from its directory must land in
//! **bitwise-identical** engine state to a service that was never
//! interrupted, across engine thread counts and both recovery
//! policies. Also: resume tolerates a torn final WAL line (crash
//! mid-append), and snapshot compaction mid-stream does not change
//! outcomes.
//!
//! The fingerprint is `Service::state_text` — the full
//! `OpenLoop::state_json` dump with every f64 as raw bit hex, so equal
//! strings mean equal bits.

use std::io::Write;
use std::path::{Path, PathBuf};

use mxdag::mxdag::MXDag;
use mxdag::serve::{ServeConfig, Service};
use mxdag::sim::{poisson_arrivals, Cluster, RecoveryPolicy};
use mxdag::util::json::Json;
use mxdag::util::rng::Rng;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mxdag-psr-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// compute(host0) → flow(host0→host1) → compute(host1), all of `size`.
fn chain_spec(size: f64, tenant: &str) -> Json {
    let mut b = MXDag::builder();
    let a = b.compute("a", 0, size);
    let f = b.flow("f", 0, 1, size);
    let c = b.compute("c", 1, size * 0.5);
    b.dep(a, f).dep(f, c);
    let g = b.finalize().unwrap();
    Json::obj(vec![
        ("dag", g.to_json()),
        ("tenant", Json::Str(tenant.into())),
        ("deadline", Json::Num(50.0)),
    ])
}

/// One scripted operation: a submission or a clock tick.
enum Op {
    Submit(f64, Json),
    Tick(f64),
}

/// A seeded Poisson submission stream with interleaved ticks, sized to
/// overflow the watermark now and then (exercising deferral + shed).
fn script(seed: u64) -> Vec<Op> {
    let arrivals = poisson_arrivals(seed, 1.5, 10);
    let mut rng = Rng::new(seed ^ 0x9e3779b97f4a7c15);
    let mut ops = Vec::new();
    let mut t_prev = 0.0_f64;
    for (i, &at) in arrivals.iter().enumerate() {
        // a tick strictly between consecutive arrivals
        if at > t_prev + 0.2 {
            ops.push(Op::Tick(t_prev + (at - t_prev) * 0.5));
        }
        let size = rng.range_f64(0.4, 3.0);
        let tenant = *rng.choice(&["default", "gold", "bronze"]);
        ops.push(Op::Submit(at, chain_spec(size, tenant)));
        t_prev = at;
        if i == arrivals.len() / 2 {
            ops.push(Op::Tick(t_prev + 0.9));
        }
    }
    ops.push(Op::Tick(t_prev + 2.0));
    ops
}

fn config(threads: usize, recovery: RecoveryPolicy) -> ServeConfig {
    let mut cfg = ServeConfig::new(Cluster::uniform(3), "fair").unwrap();
    cfg.watermark = 6.0;
    cfg.defer_max = 0.8;
    cfg.snap_every = 5; // compact mid-stream, not just at drain
    cfg.engine.threads = threads;
    cfg.engine.recovery = recovery;
    cfg.weights.insert("gold".into(), 4);
    cfg.weights.insert("bronze".into(), 1);
    cfg
}

fn apply(svc: &mut Service, op: &Op) {
    match op {
        // admission refusals (Busy) are expected mid-overload; any
        // other refusal means the harness itself is broken
        Op::Submit(at, spec) => match svc.submit(spec, *at) {
            Ok(_) | Err(mxdag::serve::SubmitError::Busy { .. }) => {}
            Err(e) => panic!("submit failed: {e:?}"),
        },
        Op::Tick(at) => {
            svc.tick(*at).unwrap();
        }
    }
}

/// Run the whole script uninterrupted and return the fingerprint.
fn gold_run(dir: &Path, cfg: &ServeConfig, ops: &[Op]) -> String {
    let mut svc = Service::create(dir, cfg.clone()).unwrap();
    for op in ops {
        apply(&mut svc, op);
    }
    svc.drain().unwrap();
    svc.state_text()
}

/// Run with a kill+resume after operation `kill_at` (and again two
/// operations later — killing a resumed service must also work).
fn killed_run(dir: &Path, cfg: &ServeConfig, ops: &[Op], kill_at: usize) -> String {
    let mut svc = Service::create(dir, cfg.clone()).unwrap();
    for (i, op) in ops.iter().enumerate() {
        apply(&mut svc, op);
        if i == kill_at || i == kill_at + 2 {
            drop(svc); // crash: no drain, no final snapshot
            svc = Service::resume(dir, cfg.snap_every).unwrap();
        }
    }
    svc.drain().unwrap();
    svc.state_text()
}

#[test]
fn kill_resume_is_bitwise_across_threads_and_recovery() {
    for (threads, recovery) in [
        (1, RecoveryPolicy::FailFast),
        (4, RecoveryPolicy::FailFast),
        (1, RecoveryPolicy::retry_default()),
        (4, RecoveryPolicy::retry_default()),
    ] {
        let cfg = config(threads, recovery);
        let ops = script(42);
        let dir_gold = tmpdir(&format!("gold-{threads}-{}", recovery.label()));
        let gold = gold_run(&dir_gold, &cfg, &ops);
        // kill after a seeded sample of operations, early/middle/late
        let mut rng = Rng::new(1234);
        let mut kills = vec![0, ops.len() / 2, ops.len() - 1];
        kills.push(rng.below(ops.len()));
        kills.push(rng.below(ops.len()));
        for kill_at in kills {
            let dir = tmpdir(&format!("kill-{threads}-{}-{kill_at}", recovery.label()));
            let got = killed_run(&dir, &cfg, &ops, kill_at);
            assert_eq!(
                got, gold,
                "threads={threads} recovery={} kill_at={kill_at}: \
                 resumed state diverged from uninterrupted run",
                recovery.label()
            );
            let _ = std::fs::remove_dir_all(&dir);
        }
        let _ = std::fs::remove_dir_all(&dir_gold);
    }
}

/// Thread-count invariance of the *service* fingerprint itself: the
/// engine's parallel refill is bit-identical across `threads`, so two
/// services differing only in thread count must agree bitwise.
#[test]
fn fingerprint_is_thread_count_invariant() {
    let ops = script(7);
    let dir1 = tmpdir("t1");
    let a = gold_run(&dir1, &config(1, RecoveryPolicy::FailFast), &ops);
    let dir4 = tmpdir("t4");
    let b = gold_run(&dir4, &config(4, RecoveryPolicy::FailFast), &ops);
    assert_eq!(a, b, "threads=1 vs threads=4 diverged");
    let _ = std::fs::remove_dir_all(&dir1);
    let _ = std::fs::remove_dir_all(&dir4);
}

#[test]
fn resume_tolerates_a_torn_wal_tail() {
    let cfg = config(1, RecoveryPolicy::FailFast);
    let ops = script(11);
    // gold: uninterrupted
    let dir_gold = tmpdir("torn-gold");
    let gold = gold_run(&dir_gold, &cfg, &ops);
    // crash mid-append: run a prefix, then corrupt the final WAL line
    let dir = tmpdir("torn");
    let cut = ops.len() / 2;
    let mut svc = Service::create(&dir, cfg.clone()).unwrap();
    for op in &ops[..cut] {
        apply(&mut svc, op);
    }
    drop(svc);
    let wal = dir.join("wal.log");
    let bytes = std::fs::read(&wal).unwrap();
    // a torn tail only exists if the WAL has records post-compaction;
    // append half of a fake record either way
    let mut f = std::fs::OpenOptions::new().append(true).open(&wal).unwrap();
    f.write_all(b"{\"lsn\":999999,\"kind\":\"adv\",\"to\":\"40").unwrap();
    drop(f);
    assert!(std::fs::read(&wal).unwrap().len() > bytes.len());
    // resume must drop (and truncate) the torn record, then replay the
    // rest; a SECOND crash after new appends must still resume cleanly
    // — torn bytes left in place would read as mid-file corruption
    let mut svc = Service::resume(&dir, cfg.snap_every).unwrap();
    for (i, op) in ops[cut..].iter().enumerate() {
        apply(&mut svc, op);
        if i == 1 {
            drop(svc);
            svc = Service::resume(&dir, cfg.snap_every).unwrap();
        }
    }
    svc.drain().unwrap();
    assert_eq!(svc.state_text(), gold, "torn-tail resume diverged");
    let _ = std::fs::remove_dir_all(&dir_gold);
    let _ = std::fs::remove_dir_all(&dir);
}

/// After a drain, every submitted job is in a terminal state — resume
/// + report shows zero in-flight (lost) jobs. This is the same check
/// CI's serve-smoke job runs via `mxdag serve --resume DIR --check`.
#[test]
fn drained_directory_resumes_with_zero_lost_jobs() {
    let cfg = config(1, RecoveryPolicy::FailFast);
    let ops = script(3);
    let dir = tmpdir("drained");
    let n_submitted;
    {
        let mut svc = Service::create(&dir, cfg.clone()).unwrap();
        for op in &ops {
            apply(&mut svc, op);
        }
        svc.drain().unwrap();
        n_submitted = svc.n_jobs();
    }
    let svc = Service::resume(&dir, cfg.snap_every).unwrap();
    let rep = svc.report();
    assert_eq!(
        rep.get("jobs").unwrap().as_f64().unwrap() as usize,
        n_submitted
    );
    let states = rep.get("states").unwrap().as_obj().unwrap();
    let done = states
        .get("done")
        .map(|v| v.as_f64().unwrap() as usize)
        .unwrap_or(0);
    assert_eq!(done, n_submitted, "jobs lost across drain+resume: {rep}");
    let _ = std::fs::remove_dir_all(&dir);
}
