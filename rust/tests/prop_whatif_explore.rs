//! The parallel what-if equivalence oracle (batched plan-space engine).
//!
//! Two contracts, asserted over random layered DAGs, two base-plan
//! families and mixed hypothetical sets (single toggles, pair toggles,
//! valid/invalid/degenerate repartitions):
//!
//! 1. **Thread-count invariance** — `whatif::explore` at N workers is
//!    bit-identical to the serial sweep for every N: same baseline,
//!    same labels, same JCT/delta bits, same captured errors, same
//!    order. The workers' per-context caches are cost-only.
//! 2. **Context-reuse soundness** — every pipeline hypothetical's JCT
//!    equals a cold `sched::evaluate` of the same trial plan, bitwise
//!    (the `EvalContext` expansion/footprint/scratch reuse changes
//!    nothing observable).

use mxdag::mxdag::{TaskId, TaskKind};
use mxdag::sched::{evaluate, MxScheduler, Plan, Scheduler};
use mxdag::sim::{Cluster, Policy};
use mxdag::whatif::{explore, single_pipeline_toggles, Hypothetical, WhatIf};
use mxdag::workloads::{random_dag, RandomParams};

fn assert_whatif_bits(a: &WhatIf, b: &WhatIf) {
    assert_eq!(a.label, b.label);
    match (&a.outcome, &b.outcome) {
        (Ok((ja, da)), Ok((jb, db))) => {
            assert_eq!(ja.to_bits(), jb.to_bits(), "{}: jct", a.label);
            assert_eq!(da.to_bits(), db.to_bits(), "{}: delta", a.label);
        }
        (Err(ea), Err(eb)) => assert_eq!(ea, eb, "{}: error", a.label),
        (x, y) => panic!("{}: outcome kind diverged: {x:?} vs {y:?}", a.label),
    }
}

#[test]
fn explore_is_bit_identical_for_all_thread_counts() {
    for seed in [1u64, 4, 9] {
        let p = RandomParams {
            layers: 5,
            width: 4,
            hosts: 6,
            seed,
            pipe_frac: 0.5,
            ..Default::default()
        };
        let g = random_dag(&p);
        let cluster = Cluster::uniform(p.hosts);
        let bases = [
            Plan { ann: Default::default(), policy: Policy::fifo() },
            MxScheduler::without_pipelining().plan(&g, &cluster),
        ];
        for base in bases {
            let mut hypos = single_pipeline_toggles(&g, &base);
            let piped: Vec<TaskId> =
                g.real_tasks().filter(|&t| g.task(t).pipelineable()).collect();
            if piped.len() >= 2 {
                hypos.push(Hypothetical::Pipeline(vec![piped[0], piped[1]]));
                hypos.push(Hypothetical::Pipeline(vec![piped[1], piped[0]]));
            }
            let comp = g
                .real_tasks()
                .find(|&t| matches!(g.task(t).kind, TaskKind::Compute { .. }));
            if let Some(c) = comp {
                hypos.push(Hypothetical::Repartition {
                    target: c,
                    shard_hosts: vec![0, 1, 2],
                    scatter: 0.05,
                    gather: 0.05,
                });
                // degenerate: single shard — captured error, not abort
                hypos.push(Hypothetical::Repartition {
                    target: c,
                    shard_hosts: vec![0],
                    scatter: 0.05,
                    gather: 0.05,
                });
            }
            assert!(hypos.len() >= 4, "seed {seed}: want a non-trivial sweep");

            let serial = explore(&g, &cluster, &base, &hypos, 1).unwrap();
            assert_eq!(serial.results.len(), hypos.len());

            // contract 2: context reuse vs the cold path, bitwise
            for (h, w) in hypos.iter().zip(serial.results.iter()) {
                if let Hypothetical::Pipeline(ts) = h {
                    let mut trial = base.clone();
                    for &t in ts {
                        if !trial.ann.pipelined.contains(&t) {
                            trial.ann.pipelined.push(t);
                        }
                    }
                    match (evaluate(&g, &cluster, &trial), &w.outcome) {
                        (Ok(cold), Ok((jct, _))) => {
                            assert_eq!(cold.makespan.to_bits(), jct.to_bits(), "{}", w.label)
                        }
                        (Err(e), Err(we)) => assert_eq!(&e.to_string(), we),
                        (x, y) => {
                            panic!("{}: cold/context diverged: {:?} vs {y:?}", w.label, x.map(|r| r.makespan))
                        }
                    }
                }
            }

            // contract 1: thread-count invariance, bitwise
            for threads in [2usize, 3, 7, 32] {
                let par = explore(&g, &cluster, &base, &hypos, threads).unwrap();
                assert_eq!(
                    serial.baseline.to_bits(),
                    par.baseline.to_bits(),
                    "seed {seed} threads {threads}: baseline"
                );
                assert_eq!(serial.results.len(), par.results.len());
                for (a, b) in serial.results.iter().zip(par.results.iter()) {
                    assert_whatif_bits(a, b);
                }
            }
        }
    }
}
