//! Integration: scheduler-vs-scheduler guarantees over generated
//! workloads, and multi-DAG altruism invariants.

use mxdag::sched::altruistic::{merge, AltruisticScheduler, SelfishScheduler};
use mxdag::sched::{
    evaluate, run, CoflowScheduler, FairScheduler, FifoScheduler, Grouping, MxScheduler,
    PackingScheduler, Scheduler,
};
use mxdag::sim::Cluster;
use mxdag::workloads::{mapreduce_dag, random_dag, MapReduceParams, RandomParams};

/// The MXDAG scheduler (which guards against over-serialization by
/// checking the fair plan, §sched::mxsched) never loses to plain fair
/// sharing on any generated workload.
#[test]
fn mx_never_worse_than_fair() {
    for seed in 0..15u64 {
        let g = random_dag(&RandomParams { seed, ..Default::default() });
        let cluster = Cluster::uniform(8);
        let fair = run(&FairScheduler, &g, &cluster).unwrap().makespan;
        let mx = run(&MxScheduler::default(), &g, &cluster).unwrap().makespan;
        assert!(mx <= fair + 1e-6, "seed {seed}: mx {mx} vs fair {fair}");
    }
}

/// All schedulers produce valid executions on heterogeneous clusters.
#[test]
fn heterogeneous_cluster_support() {
    let g = random_dag(&RandomParams { seed: 23, hosts: 4, ..Default::default() });
    let mut cluster = Cluster::uniform(4);
    cluster.hosts[0].cores = 4.0; // beefy host
    cluster.hosts[1].nic_up = 0.5; // slow uplink
    cluster.hosts[2].nic_down = 2.0; // fast downlink
    let schedulers: Vec<Box<dyn Scheduler>> = vec![
        Box::new(FairScheduler),
        Box::new(FifoScheduler),
        Box::new(PackingScheduler),
        Box::new(CoflowScheduler::new(Grouping::ByDst)),
        Box::new(MxScheduler::default()),
    ];
    for s in schedulers {
        let r = run(s.as_ref(), &g, &cluster).unwrap();
        assert!(r.makespan.is_finite(), "{} failed", s.name());
    }
}

/// Altruism invariant (Principle 2): no job's JCT may regress vs selfish
/// scheduling, and at least one contended job should improve on the
/// Fig. 7 style workloads.
#[test]
fn altruism_pareto_on_contended_jobs() {
    // fig7-shaped jobs with randomized sizes: job 1 has a dominant branch
    // on host 0 (critical) and a small branch on the shared host 1; job 2
    // lives entirely on the shared resources.
    let mut improved = 0;
    for seed in 0..8u64 {
        let mut rng = mxdag::util::rng::Rng::new(seed);
        let big = 2.0 + rng.range_f64(0.0, 2.0);
        let small = 0.5 + rng.range_f64(0.0, 0.5);
        let j1 = {
            let mut b = mxdag::mxdag::MXDag::builder();
            let a = b.compute("a", 0, big);
            let bb = b.compute("b", 1, small);
            let f1 = b.flow("f1", 0, 2, big);
            let f2 = b.flow("f2", 1, 2, small);
            let r1 = b.compute("r1", 2, 1.0);
            b.dep(a, f1).dep(bb, f2).dep(f1, r1).dep(f2, r1);
            b.finalize().unwrap()
        };
        let j2 = mapreduce_dag(&MapReduceParams {
            mappers: 2,
            reducers: 1,
            map_hosts: vec![1],
            red_hosts: vec![3],
            map_time: small,
            shuffle: small,
            seed: seed + 50,
            ..Default::default()
        })
        .0;
        let multi = merge(&[j1, j2]);
        let cluster = Cluster::uniform(4);
        let s = evaluate(&multi.dag, &cluster, &SelfishScheduler.plan_multi(&multi)).unwrap();
        let al = evaluate(
            &multi.dag,
            &cluster,
            &AltruisticScheduler.plan_multi_checked(&multi, &cluster),
        )
        .unwrap();
        for j in 0..2 {
            assert!(
                multi.jct(j, &al) <= multi.jct(j, &s) + 1e-6,
                "seed {seed}: job {j} regressed {} -> {}",
                multi.jct(j, &s),
                multi.jct(j, &al)
            );
        }
        if multi.jct(1, &al) < multi.jct(1, &s) - 1e-9 {
            improved += 1;
        }
    }
    assert!(improved >= 1, "altruism (Pareto-checked) should help at least some contended cases: {improved}/8");
}

/// Merging N jobs preserves each job's own critical path length.
#[test]
fn merge_preserves_per_job_cpm() {
    let jobs: Vec<_> = (0..4u64)
        .map(|s| {
            mapreduce_dag(&MapReduceParams { seed: s, jitter: 0.3, ..Default::default() }).0
        })
        .collect();
    let multi = merge(&jobs);
    assert_eq!(multi.jobs.len(), 4);
    let total: usize = jobs.iter().map(|j| j.real_tasks().count()).sum();
    assert_eq!(multi.dag.real_tasks().count(), total);
}

/// Coflow grouping strategies give different groups on a shuffle — the
/// Fig. 2(b) definitional ambiguity, machine-checked.
#[test]
fn grouping_ambiguity_is_real() {
    let (g, _) = mapreduce_dag(&MapReduceParams::default());
    let by_dst = CoflowScheduler::new(Grouping::ByDst).groups(&g);
    let by_src = CoflowScheduler::new(Grouping::BySrc).groups(&g);
    let by_level = CoflowScheduler::new(Grouping::ByLevel).groups(&g);
    assert_ne!(by_dst.len(), by_level.len());
    assert_eq!(by_dst.len(), 2); // per reducer
    assert_eq!(by_src.len(), 4); // per mapper
    assert_eq!(by_level.len(), 1); // one shuffle stage
    // ...and they lead to different JCTs
    let cluster = Cluster::uniform(6);
    let jcts: Vec<f64> = [Grouping::ByDst, Grouping::BySrc, Grouping::ByLevel]
        .into_iter()
        .map(|gr| run(&CoflowScheduler::new(gr), &g, &cluster).unwrap().makespan)
        .collect();
    assert!(jcts.iter().all(|j| j.is_finite()));
}
