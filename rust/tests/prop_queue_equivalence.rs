//! Equivalence oracle for the engine's incremental machinery: on
//! randomized DAGs, under every policy a scheduler can emit, all eight
//! corners of the {Incremental, FullResort} queue ×
//! {Components, WholeSet} allocation × {Eager, Anchored} horizon
//! matrix must reproduce each other. The four **eager** corners agree
//! *exactly* — same event count (the engines take identical event
//! boundaries), same makespan and same per-chunk traces: level
//! membership is identical by construction, level allocation decomposes
//! bit-exactly over contention components, and clean components'
//! memoized rates equal what a whole-set reprice would recompute. The
//! four **anchored** corners are held to the documented tolerance
//! oracle instead — makespan and per-task trace times within 1e-6
//! relative of the eager baseline (event counts may differ: anchored
//! completes by predicted finish time, not by byte epsilon, and its
//! subtraction reorders float arithmetic — see `sim/horizon.rs`). Any
//! divergence beyond that means a dropped, reordered, stale-keyed,
//! stale-rated or stale-anchored ready task.

use mxdag::sched::{
    CoflowScheduler, FairScheduler, FifoScheduler, Grouping, MxScheduler, PackingScheduler,
    Plan, Scheduler,
};
use mxdag::sched::{evaluate, AltruisticScheduler, SelfishScheduler};
use mxdag::sim::{
    expand, simulate, within_tolerance, AllocKind, Cluster, HorizonKind, Policy, QueueKind,
    SimConfig, SimDag, SimKind, SimResult, SimTask,
};
use mxdag::util::propcheck::{check, Config};
use mxdag::util::rng::Rng;
use mxdag::workloads::{self, random_dag, wide_fanout, FanoutParams, RandomParams};

fn gen_params(rng: &mut Rng) -> RandomParams {
    RandomParams {
        layers: rng.range(2, 6),
        width: rng.range(2, 6),
        hosts: rng.range(2, 10),
        edge_p: rng.range_f64(0.2, 0.9),
        pipe_frac: rng.range_f64(0.0, 0.8),
        min_size: 0.1,
        max_size: 3.0,
        seed: rng.next_u64(),
    }
}

/// The full configuration matrix; the first entry is the pre-refactor
/// baseline every other corner is compared against (bitwise for the
/// eager corners, within tolerance for the anchored ones).
const MATRIX: [(QueueKind, AllocKind, HorizonKind); 8] = [
    (QueueKind::FullResort, AllocKind::WholeSet, HorizonKind::Eager),
    (QueueKind::Incremental, AllocKind::WholeSet, HorizonKind::Eager),
    (QueueKind::FullResort, AllocKind::Components, HorizonKind::Eager),
    (QueueKind::Incremental, AllocKind::Components, HorizonKind::Eager),
    (QueueKind::FullResort, AllocKind::WholeSet, HorizonKind::Anchored),
    (QueueKind::Incremental, AllocKind::WholeSet, HorizonKind::Anchored),
    (QueueKind::FullResort, AllocKind::Components, HorizonKind::Anchored),
    (QueueKind::Incremental, AllocKind::Components, HorizonKind::Anchored),
];

/// Thread counts crossed with every corner. `threads = 1` is the
/// serial oracle (pinned explicitly so a `MXDAG_TEST_THREADS` override
/// cannot shift the baseline); higher counts fan component refills
/// across workers and must reproduce the oracle — bit-for-bit on the
/// eager corners, within the documented tolerance on anchored.
const THREADS: [usize; 3] = [1, 2, 4];

fn run_matrix(
    plan: &Plan,
    dag: &mxdag::mxdag::MXDag,
    cluster: &Cluster,
) -> Result<Vec<Vec<SimResult>>, String> {
    let sim = expand(dag, &plan.ann);
    MATRIX
        .iter()
        .map(|&(queue, alloc, horizon)| {
            THREADS
                .iter()
                .map(|&threads| {
                    simulate(
                        &sim,
                        cluster,
                        &SimConfig {
                            policy: plan.policy,
                            queue,
                            alloc,
                            horizon,
                            threads,
                            ..Default::default()
                        },
                    )
                    .map_err(|e| format!("{queue:?}/{alloc:?}/{horizon:?}/t{threads}: {e}"))
                })
                .collect()
        })
        .collect()
}

fn assert_equivalent(tag: &str, results: &[Vec<SimResult>]) -> Result<(), String> {
    let base = &results[0][0];
    for (k, corner) in results.iter().enumerate() {
        let (queue, alloc, horizon) = MATRIX[k];
        let serial = &corner[0];
        // eager corners replay the baseline's event boundaries exactly;
        // anchored corners legitimately group completions differently
        // and are compared on times only, through the shared
        // `mxdag::sim::within_tolerance` contract
        let check_events = horizon == HorizonKind::Eager;
        let same = |x: f64, y: f64| match horizon {
            HorizonKind::Eager => (x - y).abs() <= 1e-9 || (x.is_nan() && y.is_nan()),
            HorizonKind::Anchored => within_tolerance(x, y),
        };
        if k > 0 {
            let tag = format!("{tag} [{queue:?}/{alloc:?}/{horizon:?}]");
            if check_events && base.events != serial.events {
                return Err(format!("{tag}: events {} vs {}", base.events, serial.events));
            }
            if !same(base.makespan, serial.makespan) {
                return Err(format!(
                    "{tag}: makespan {} vs {}",
                    base.makespan, serial.makespan
                ));
            }
            if base.trace.len() != serial.trace.len() {
                return Err(format!("{tag}: trace length differs"));
            }
            for (i, (a, b)) in base.trace.iter().zip(serial.trace.iter()).enumerate() {
                if !same(a.start, b.start) || !same(a.finish, b.finish) {
                    return Err(format!(
                        "{tag}: chunk {i} trace {:?}..{:?} vs {:?}..{:?}",
                        a.start, a.finish, b.start, b.finish
                    ));
                }
            }
        }
        // the parallel loop is judged against its own corner's serial
        // run: eager corners must not change a single bit (same event
        // boundaries, same float payloads), anchored corners are held
        // to the tolerance contract
        for (j, r) in corner.iter().enumerate().skip(1) {
            let tag = format!("{tag} [{queue:?}/{alloc:?}/{horizon:?} t{}]", THREADS[j]);
            match horizon {
                HorizonKind::Eager => {
                    if serial.events != r.events {
                        return Err(format!(
                            "{tag}: events {} vs {}",
                            serial.events, r.events
                        ));
                    }
                    if serial.makespan.to_bits() != r.makespan.to_bits() {
                        return Err(format!(
                            "{tag}: makespan bits {} vs {}",
                            serial.makespan, r.makespan
                        ));
                    }
                    for (i, (a, b)) in serial.trace.iter().zip(r.trace.iter()).enumerate() {
                        if a.start.to_bits() != b.start.to_bits()
                            || a.finish.to_bits() != b.finish.to_bits()
                        {
                            return Err(format!(
                                "{tag}: chunk {i} trace {:?}..{:?} vs {:?}..{:?}",
                                a.start, a.finish, b.start, b.finish
                            ));
                        }
                    }
                }
                HorizonKind::Anchored => {
                    if !within_tolerance(serial.makespan, r.makespan) {
                        return Err(format!(
                            "{tag}: makespan {} vs {}",
                            serial.makespan, r.makespan
                        ));
                    }
                    for (i, (a, b)) in serial.trace.iter().zip(r.trace.iter()).enumerate() {
                        if !within_tolerance(a.start, b.start)
                            || !within_tolerance(a.finish, b.finish)
                        {
                            return Err(format!(
                                "{tag}: chunk {i} trace {:?}..{:?} vs {:?}..{:?}",
                                a.start, a.finish, b.start, b.finish
                            ));
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

/// The headline oracle: all five policy families (fair, fifo, packing
/// priorities, SEBF coflow, mxdag critical-path priorities) take the
/// same event path through every (queue, alloc) configuration.
#[test]
fn prop_matrix_agrees_all_policies() {
    check(
        "queue-alloc-equivalence",
        &Config { cases: 15, ..Default::default() },
        gen_params,
        |p| {
            let g = random_dag(p);
            let cluster = Cluster::uniform(p.hosts);
            let schedulers: Vec<Box<dyn Scheduler>> = vec![
                Box::new(FairScheduler),
                Box::new(FifoScheduler),
                Box::new(PackingScheduler),
                Box::new(CoflowScheduler::new(Grouping::ByDst)),
                Box::new(MxScheduler::without_pipelining()),
            ];
            for s in &schedulers {
                let plan = s.plan(&g, &cluster);
                let results = run_matrix(&plan, &g, &cluster)?;
                assert_equivalent(s.name(), &results)?;
            }
            Ok(())
        },
    );
}

/// Same oracle on a non-trivial topology: fabric links widen task
/// resource footprints, which both the saturation early-exit and the
/// component partition (cross-rack flows bridge racks into one
/// component) must respect.
#[test]
fn prop_matrix_agrees_on_oversubscribed_fabric() {
    check(
        "queue-alloc-equivalence-oversub",
        &Config { cases: 8, ..Default::default() },
        gen_params,
        |p| {
            let g = random_dag(p);
            let cluster = Cluster::oversubscribed(p.hosts.max(2), 2, 4.0);
            for policy in [Policy::fair(), Policy::fifo(), Policy::priority(), Policy::coflow()]
            {
                let plan = Plan { ann: Default::default(), policy };
                let results = run_matrix(&plan, &g, &cluster)?;
                assert_equivalent(&format!("{policy:?}"), &results)?;
            }
            Ok(())
        },
    );
}

/// And on parallel fabrics, where hash-selected trunks glue otherwise
/// unrelated flows into shared components.
#[test]
fn prop_matrix_agrees_on_parallel_fabrics() {
    check(
        "queue-alloc-equivalence-fabrics",
        &Config { cases: 8, ..Default::default() },
        gen_params,
        |p| {
            let g = random_dag(p);
            let cluster = Cluster::parallel_fabrics(p.hosts.max(2), 2, 0.5);
            for policy in [Policy::fair(), Policy::fifo(), Policy::priority(), Policy::coflow()]
            {
                let plan = Plan { ann: Default::default(), policy };
                let results = run_matrix(&plan, &g, &cluster)?;
                assert_equivalent(&format!("{policy:?}"), &results)?;
            }
            Ok(())
        },
    );
}

/// Gated plans (Principle-2 altruism) exercise the gate heap: delayed
/// tasks must re-enter the ready stream in their original live order,
/// and a gate expiry must dirty exactly the components it feeds.
#[test]
fn gated_altruistic_plan_is_equivalent() {
    let (j1, j2) = workloads::fig7_jobs();
    let multi = mxdag::sched::altruistic::merge(&[j1, j2]);
    let cluster = Cluster::uniform(4);
    let plan = AltruisticScheduler.plan_multi(&multi);
    assert!(!plan.ann.gates.is_empty(), "altruistic multi-plan must gate tasks");
    let results = run_matrix(&plan, &multi.dag, &cluster).unwrap();
    assert_equivalent("altruistic-multi", &results).unwrap();
    // and the checked variant still honours the Pareto guarantee when
    // served from the incremental queue + component-wise allocation
    let checked = AltruisticScheduler.plan_multi_checked(&multi, &cluster);
    let r = evaluate(&multi.dag, &cluster, &checked).unwrap();
    assert!(r.makespan.is_finite());
    let selfish = evaluate(&multi.dag, &cluster, &SelfishScheduler.plan_multi(&multi)).unwrap();
    for j in 0..multi.jobs.len() {
        assert!(multi.jct(j, &r) <= multi.jct(j, &selfish) + 1e-9);
    }
}

/// Numeric-drift regression: on a long run (≥ 10k events) the anchored
/// horizon's reordered float arithmetic must not accumulate — makespan
/// and every per-task finish stay within 1e-6 relative of the eager
/// integration sweep. A drift that compounds per event would blow well
/// past the bound at this scale long before it shows on small DAGs.
#[test]
fn anchored_drift_bounded_on_long_run() {
    let hosts = 16;
    let cluster = Cluster::uniform(hosts);
    let p = FanoutParams { branches: 3_400, hosts, seed: 42, ..Default::default() };
    let g = wide_fanout(&p);
    let plan = MxScheduler::without_pipelining().plan(&g, &cluster);
    let sim = expand(&g, &plan.ann);
    let mk = |horizon| SimConfig { policy: plan.policy, horizon, ..Default::default() };
    let eager = simulate(&sim, &cluster, &mk(HorizonKind::Eager)).unwrap();
    let anch = simulate(&sim, &cluster, &mk(HorizonKind::Anchored)).unwrap();
    assert!(
        eager.events >= 10_000,
        "regression workload shrank: only {} events",
        eager.events
    );
    let close = within_tolerance;
    assert!(
        close(eager.makespan, anch.makespan),
        "makespan drift: {} vs {}",
        eager.makespan,
        anch.makespan
    );
    let mut worst = 0.0f64;
    for (i, (a, b)) in eager.trace.iter().zip(anch.trace.iter()).enumerate() {
        assert!(
            close(a.finish, b.finish) && close(a.start, b.start),
            "chunk {i} drifted: {:?}..{:?} vs {:?}..{:?}",
            a.start,
            a.finish,
            b.start,
            b.finish
        );
        worst = worst.max((a.finish - b.finish).abs() / a.finish.abs().max(1.0));
    }
    println!(
        "anchored drift over {} events: worst relative finish drift {worst:.3e}",
        eager.events
    );
}

/// Parameters for the merge/split storm: alternating waves of flows
/// over disjoint host pairs (many small components) and gated bridge
/// flows that straddle neighbouring pairs (components merge as bridges
/// arrive, re-split as they drain). The widest waves exceed the
/// parallel fill threshold, so `threads > 1` runs take the fan-out
/// path — not the inline fallback — through every merge and split.
#[derive(Debug, Clone, Copy)]
struct StormParams {
    pairs: usize,
    per_pair: usize,
    waves: usize,
    seed: u64,
}

fn storm_dag(p: &StormParams) -> (SimDag, Cluster) {
    let hosts = 2 * p.pairs;
    let mut rng = Rng::new(p.seed);
    let mut d = SimDag::default();
    let flow = |src: usize, dst: usize, size: f64, coflow: Option<usize>| SimTask {
        orig: 0,
        chunk: (0, 1),
        kind: SimKind::Flow { src, dst },
        size,
        priority: 0,
        gate: 0.0,
        coflow,
    };
    // prev[p] holds the previous wave's tasks touching host pair p
    let mut prev: Vec<Vec<usize>> = vec![Vec::new(); p.pairs];
    for w in 0..p.waves {
        let mut next: Vec<Vec<usize>> = vec![Vec::new(); p.pairs];
        if w % 2 == 0 {
            // split wave: flows stay inside their own pair, so any
            // components the previous bridge wave glued together fall
            // apart again as it drains
            for pair in 0..p.pairs {
                for _ in 0..p.per_pair {
                    let mut t = flow(
                        2 * pair,
                        2 * pair + 1,
                        rng.range_f64(0.5, 3.0),
                        None,
                    );
                    t.orig = d.len();
                    let id = d.push(t);
                    for &g in prev[pair].iter() {
                        d.dep(g, id);
                    }
                    next[pair].push(id);
                }
            }
        } else {
            // bridge wave: each flow straddles two neighbouring pairs
            // and is gated on both, arriving exactly when the engine
            // must merge their components; shared coflow tags pull the
            // grouped SEBF re-key path into the storm as well
            for pair in 0..p.pairs - 1 {
                let mut t = flow(
                    2 * pair + 1,
                    2 * pair + 2,
                    rng.range_f64(0.5, 2.0),
                    Some(pair / 2),
                );
                t.orig = d.len();
                let id = d.push(t);
                if let Some(&g) = prev[pair].last() {
                    d.dep(g, id);
                }
                if let Some(&g) = prev[pair + 1].first() {
                    d.dep(g, id);
                }
                next[pair].push(id);
                next[pair + 1].push(id);
            }
        }
        prev = next;
    }
    (d, Cluster::uniform(hosts))
}

/// The dedicated merge/split storm: adversarial arrivals repeatedly
/// bridge and re-split components while every corner of the
/// (queue, alloc, horizon, threads) matrix must keep agreeing.
#[test]
fn prop_merge_split_storm_agrees() {
    check(
        "merge-split-storm",
        &Config { cases: 6, ..Default::default() },
        |rng: &mut Rng| StormParams {
            pairs: rng.range(8, 33),
            per_pair: rng.range(4, 11),
            waves: rng.range(3, 7),
            seed: rng.next_u64(),
        },
        |p| {
            let (d, cluster) = storm_dag(p);
            for policy in [Policy::fair(), Policy::priority(), Policy::coflow()] {
                let results: Vec<Vec<SimResult>> = MATRIX
                    .iter()
                    .map(|&(queue, alloc, horizon)| {
                        THREADS
                            .iter()
                            .map(|&threads| {
                                simulate(
                                    &d,
                                    &cluster,
                                    &SimConfig {
                                        policy,
                                        queue,
                                        alloc,
                                        horizon,
                                        threads,
                                        ..Default::default()
                                    },
                                )
                                .map_err(|e| {
                                    format!("{queue:?}/{alloc:?}/{horizon:?}/t{threads}: {e}")
                                })
                            })
                            .collect::<Result<Vec<_>, _>>()
                    })
                    .collect::<Result<Vec<_>, _>>()?;
                assert_equivalent(&format!("storm {policy:?}"), &results)?;
            }
            Ok(())
        },
    );
}
