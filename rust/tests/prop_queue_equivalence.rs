//! Equivalence oracle for the incremental ready-queue engine: on
//! randomized DAGs, under every policy a scheduler can emit, the
//! incremental bucket queue must reproduce the full re-sort baseline
//! *exactly* — same event count (the engines take identical event
//! boundaries), same makespan and same per-chunk traces. Level
//! membership is identical by construction and level allocation is
//! order-independent, so any divergence here means the incremental
//! path dropped, reordered or stale-keyed a ready task.

use mxdag::sched::{
    CoflowScheduler, FairScheduler, FifoScheduler, Grouping, MxScheduler, PackingScheduler,
    Plan, Scheduler,
};
use mxdag::sched::{evaluate, AltruisticScheduler, SelfishScheduler};
use mxdag::sim::{expand, simulate, Cluster, Policy, QueueKind, SimConfig, SimResult};
use mxdag::util::propcheck::{check, Config};
use mxdag::util::rng::Rng;
use mxdag::workloads::{self, random_dag, RandomParams};

fn gen_params(rng: &mut Rng) -> RandomParams {
    RandomParams {
        layers: rng.range(2, 6),
        width: rng.range(2, 6),
        hosts: rng.range(2, 10),
        edge_p: rng.range_f64(0.2, 0.9),
        pipe_frac: rng.range_f64(0.0, 0.8),
        min_size: 0.1,
        max_size: 3.0,
        seed: rng.next_u64(),
    }
}

fn run_both(
    plan: &Plan,
    dag: &mxdag::mxdag::MXDag,
    cluster: &Cluster,
) -> Result<(SimResult, SimResult), String> {
    let sim = expand(dag, &plan.ann);
    let mk = |queue: QueueKind| SimConfig { policy: plan.policy, queue, ..Default::default() };
    let full = simulate(&sim, cluster, &mk(QueueKind::FullResort))
        .map_err(|e| format!("full-resort: {e}"))?;
    let inc = simulate(&sim, cluster, &mk(QueueKind::Incremental))
        .map_err(|e| format!("incremental: {e}"))?;
    Ok((full, inc))
}

fn assert_equivalent(tag: &str, full: &SimResult, inc: &SimResult) -> Result<(), String> {
    if full.events != inc.events {
        return Err(format!("{tag}: events {} vs {}", full.events, inc.events));
    }
    if (full.makespan - inc.makespan).abs() > 1e-9 {
        return Err(format!("{tag}: makespan {} vs {}", full.makespan, inc.makespan));
    }
    if full.trace.len() != inc.trace.len() {
        return Err(format!("{tag}: trace length differs"));
    }
    for (i, (a, b)) in full.trace.iter().zip(inc.trace.iter()).enumerate() {
        let same = |x: f64, y: f64| (x - y).abs() <= 1e-9 || (x.is_nan() && y.is_nan());
        if !same(a.start, b.start) || !same(a.finish, b.finish) {
            return Err(format!(
                "{tag}: chunk {i} trace {:?}..{:?} vs {:?}..{:?}",
                a.start, a.finish, b.start, b.finish
            ));
        }
    }
    Ok(())
}

/// The headline oracle: all five policy families (fair, fifo, packing
/// priorities, SEBF coflow, mxdag critical-path priorities) pop ready
/// tasks in exactly the same order on both queue implementations.
#[test]
fn prop_incremental_matches_full_resort_all_policies() {
    check(
        "queue-equivalence",
        &Config { cases: 20, ..Default::default() },
        gen_params,
        |p| {
            let g = random_dag(p);
            let cluster = Cluster::uniform(p.hosts);
            let schedulers: Vec<Box<dyn Scheduler>> = vec![
                Box::new(FairScheduler),
                Box::new(FifoScheduler),
                Box::new(PackingScheduler),
                Box::new(CoflowScheduler::new(Grouping::ByDst)),
                Box::new(MxScheduler::without_pipelining()),
            ];
            for s in &schedulers {
                let plan = s.plan(&g, &cluster);
                let (full, inc) = run_both(&plan, &g, &cluster)?;
                assert_equivalent(s.name(), &full, &inc)?;
            }
            Ok(())
        },
    );
}

/// Same oracle on a non-trivial topology (fabric links widen task
/// resource footprints, which the saturation early-exit must respect).
#[test]
fn prop_equivalence_holds_on_oversubscribed_fabric() {
    check(
        "queue-equivalence-oversub",
        &Config { cases: 10, ..Default::default() },
        gen_params,
        |p| {
            let g = random_dag(p);
            let cluster = Cluster::oversubscribed(p.hosts.max(2), 2, 4.0);
            for policy in [Policy::fair(), Policy::fifo(), Policy::priority(), Policy::coflow()]
            {
                let plan = Plan { ann: Default::default(), policy };
                let (full, inc) = run_both(&plan, &g, &cluster)?;
                assert_equivalent(&format!("{policy:?}"), &full, &inc)?;
            }
            Ok(())
        },
    );
}

/// Gated plans (Principle-2 altruism) exercise the gate heap: delayed
/// tasks must re-enter the ready stream in their original live order.
#[test]
fn gated_altruistic_plan_is_equivalent() {
    let (j1, j2) = workloads::fig7_jobs();
    let multi = mxdag::sched::altruistic::merge(&[j1, j2]);
    let cluster = Cluster::uniform(4);
    let plan = AltruisticScheduler.plan_multi(&multi);
    assert!(!plan.ann.gates.is_empty(), "altruistic multi-plan must gate tasks");
    let (full, inc) = run_both(&plan, &multi.dag, &cluster).unwrap();
    assert_equivalent("altruistic-multi", &full, &inc).unwrap();
    // and the checked variant still honours the Pareto guarantee when
    // served from the incremental queue
    let checked = AltruisticScheduler.plan_multi_checked(&multi, &cluster);
    let r = evaluate(&multi.dag, &cluster, &checked).unwrap();
    assert!(r.makespan.is_finite());
    let selfish = evaluate(&multi.dag, &cluster, &SelfishScheduler.plan_multi(&multi)).unwrap();
    for j in 0..multi.jobs.len() {
        assert!(multi.jct(j, &r) <= multi.jct(j, &selfish) + 1e-9);
    }
}
