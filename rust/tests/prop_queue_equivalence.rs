//! Equivalence oracle for the engine's incremental machinery: on
//! randomized DAGs, under every policy a scheduler can emit, all four
//! corners of the {Incremental, FullResort} queue ×
//! {Components, WholeSet} allocation matrix must reproduce each other
//! *exactly* — same event count (the engines take identical event
//! boundaries), same makespan and same per-chunk traces. Level
//! membership is identical by construction, level allocation decomposes
//! bit-exactly over contention components, and clean components'
//! memoized rates equal what a whole-set reprice would recompute — so
//! any divergence here means a dropped, reordered, stale-keyed or
//! stale-rated ready task.

use mxdag::sched::{
    CoflowScheduler, FairScheduler, FifoScheduler, Grouping, MxScheduler, PackingScheduler,
    Plan, Scheduler,
};
use mxdag::sched::{evaluate, AltruisticScheduler, SelfishScheduler};
use mxdag::sim::{
    expand, simulate, AllocKind, Cluster, Policy, QueueKind, SimConfig, SimResult,
};
use mxdag::util::propcheck::{check, Config};
use mxdag::util::rng::Rng;
use mxdag::workloads::{self, random_dag, RandomParams};

fn gen_params(rng: &mut Rng) -> RandomParams {
    RandomParams {
        layers: rng.range(2, 6),
        width: rng.range(2, 6),
        hosts: rng.range(2, 10),
        edge_p: rng.range_f64(0.2, 0.9),
        pipe_frac: rng.range_f64(0.0, 0.8),
        min_size: 0.1,
        max_size: 3.0,
        seed: rng.next_u64(),
    }
}

/// The full configuration matrix; the first entry is the pre-refactor
/// baseline every other corner is compared against.
const MATRIX: [(QueueKind, AllocKind); 4] = [
    (QueueKind::FullResort, AllocKind::WholeSet),
    (QueueKind::Incremental, AllocKind::WholeSet),
    (QueueKind::FullResort, AllocKind::Components),
    (QueueKind::Incremental, AllocKind::Components),
];

fn run_matrix(
    plan: &Plan,
    dag: &mxdag::mxdag::MXDag,
    cluster: &Cluster,
) -> Result<Vec<SimResult>, String> {
    let sim = expand(dag, &plan.ann);
    MATRIX
        .iter()
        .map(|&(queue, alloc)| {
            simulate(
                &sim,
                cluster,
                &SimConfig { policy: plan.policy, queue, alloc, ..Default::default() },
            )
            .map_err(|e| format!("{queue:?}/{alloc:?}: {e}"))
        })
        .collect()
}

fn assert_equivalent(tag: &str, results: &[SimResult]) -> Result<(), String> {
    let base = &results[0];
    for (k, r) in results.iter().enumerate().skip(1) {
        let (queue, alloc) = MATRIX[k];
        let tag = format!("{tag} [{queue:?}/{alloc:?}]");
        if base.events != r.events {
            return Err(format!("{tag}: events {} vs {}", base.events, r.events));
        }
        if (base.makespan - r.makespan).abs() > 1e-9 {
            return Err(format!("{tag}: makespan {} vs {}", base.makespan, r.makespan));
        }
        if base.trace.len() != r.trace.len() {
            return Err(format!("{tag}: trace length differs"));
        }
        for (i, (a, b)) in base.trace.iter().zip(r.trace.iter()).enumerate() {
            let same = |x: f64, y: f64| (x - y).abs() <= 1e-9 || (x.is_nan() && y.is_nan());
            if !same(a.start, b.start) || !same(a.finish, b.finish) {
                return Err(format!(
                    "{tag}: chunk {i} trace {:?}..{:?} vs {:?}..{:?}",
                    a.start, a.finish, b.start, b.finish
                ));
            }
        }
    }
    Ok(())
}

/// The headline oracle: all five policy families (fair, fifo, packing
/// priorities, SEBF coflow, mxdag critical-path priorities) take the
/// same event path through every (queue, alloc) configuration.
#[test]
fn prop_matrix_agrees_all_policies() {
    check(
        "queue-alloc-equivalence",
        &Config { cases: 15, ..Default::default() },
        gen_params,
        |p| {
            let g = random_dag(p);
            let cluster = Cluster::uniform(p.hosts);
            let schedulers: Vec<Box<dyn Scheduler>> = vec![
                Box::new(FairScheduler),
                Box::new(FifoScheduler),
                Box::new(PackingScheduler),
                Box::new(CoflowScheduler::new(Grouping::ByDst)),
                Box::new(MxScheduler::without_pipelining()),
            ];
            for s in &schedulers {
                let plan = s.plan(&g, &cluster);
                let results = run_matrix(&plan, &g, &cluster)?;
                assert_equivalent(s.name(), &results)?;
            }
            Ok(())
        },
    );
}

/// Same oracle on a non-trivial topology: fabric links widen task
/// resource footprints, which both the saturation early-exit and the
/// component partition (cross-rack flows bridge racks into one
/// component) must respect.
#[test]
fn prop_matrix_agrees_on_oversubscribed_fabric() {
    check(
        "queue-alloc-equivalence-oversub",
        &Config { cases: 8, ..Default::default() },
        gen_params,
        |p| {
            let g = random_dag(p);
            let cluster = Cluster::oversubscribed(p.hosts.max(2), 2, 4.0);
            for policy in [Policy::fair(), Policy::fifo(), Policy::priority(), Policy::coflow()]
            {
                let plan = Plan { ann: Default::default(), policy };
                let results = run_matrix(&plan, &g, &cluster)?;
                assert_equivalent(&format!("{policy:?}"), &results)?;
            }
            Ok(())
        },
    );
}

/// And on parallel fabrics, where hash-selected trunks glue otherwise
/// unrelated flows into shared components.
#[test]
fn prop_matrix_agrees_on_parallel_fabrics() {
    check(
        "queue-alloc-equivalence-fabrics",
        &Config { cases: 8, ..Default::default() },
        gen_params,
        |p| {
            let g = random_dag(p);
            let cluster = Cluster::parallel_fabrics(p.hosts.max(2), 2, 0.5);
            for policy in [Policy::fair(), Policy::fifo(), Policy::priority(), Policy::coflow()]
            {
                let plan = Plan { ann: Default::default(), policy };
                let results = run_matrix(&plan, &g, &cluster)?;
                assert_equivalent(&format!("{policy:?}"), &results)?;
            }
            Ok(())
        },
    );
}

/// Gated plans (Principle-2 altruism) exercise the gate heap: delayed
/// tasks must re-enter the ready stream in their original live order,
/// and a gate expiry must dirty exactly the components it feeds.
#[test]
fn gated_altruistic_plan_is_equivalent() {
    let (j1, j2) = workloads::fig7_jobs();
    let multi = mxdag::sched::altruistic::merge(&[j1, j2]);
    let cluster = Cluster::uniform(4);
    let plan = AltruisticScheduler.plan_multi(&multi);
    assert!(!plan.ann.gates.is_empty(), "altruistic multi-plan must gate tasks");
    let results = run_matrix(&plan, &multi.dag, &cluster).unwrap();
    assert_equivalent("altruistic-multi", &results).unwrap();
    // and the checked variant still honours the Pareto guarantee when
    // served from the incremental queue + component-wise allocation
    let checked = AltruisticScheduler.plan_multi_checked(&multi, &cluster);
    let r = evaluate(&multi.dag, &cluster, &checked).unwrap();
    assert!(r.makespan.is_finite());
    let selfish = evaluate(&multi.dag, &cluster, &SelfishScheduler.plan_multi(&multi)).unwrap();
    for j in 0..multi.jobs.len() {
        assert!(multi.jct(j, &r) <= multi.jct(j, &selfish) + 1e-9);
    }
}
