//! End-to-end smoke tests for `mxdag serve` over the real TCP surface:
//! spawn the binary, drive raw HTTP/1.1 through `TcpStream`, SIGTERM
//! it, and assert a clean drain (exit 0) plus zero lost jobs on
//! `--resume --check`. These are the same motions CI's serve-smoke job
//! performs with curl.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use mxdag::mxdag::MXDag;
use mxdag::util::json::Json;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mxdag-http-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// A 2-host chain DAG in the submission wire format.
fn job_body() -> String {
    let mut b = MXDag::builder();
    let c = b.compute("c", 0, 0.5);
    let f = b.flow("f", 0, 1, 0.5);
    b.dep(c, f);
    let dag = b.finalize().unwrap().to_json();
    Json::obj(vec![("dag", dag), ("deadline", Json::Num(60.0))]).to_string()
}

struct Server {
    child: Child,
    addr: String,
    dir: PathBuf,
}

impl Server {
    /// Boot `mxdag serve` on an ephemeral port and wait for the
    /// addr-file handshake.
    fn spawn(tag: &str, extra: &[&str]) -> Server {
        let dir = tmpdir(tag);
        let addr_file = dir.with_extension("addr");
        let _ = std::fs::remove_file(&addr_file);
        let child = Command::new(env!("CARGO_BIN_EXE_mxdag"))
            .args([
                "serve",
                "--dir",
                dir.to_str().unwrap(),
                "--addr-file",
                addr_file.to_str().unwrap(),
                "--port",
                "0",
                "--hosts",
                "2",
                "--scheduler",
                "fair",
                // 20 virtual seconds per wall second: jobs finish fast
                "--time-scale",
                "20",
                "--tick-ms",
                "20",
            ])
            .args(extra)
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn mxdag serve");
        let deadline = Instant::now() + Duration::from_secs(20);
        let addr = loop {
            if let Ok(s) = std::fs::read_to_string(&addr_file) {
                let s = s.trim().to_string();
                if !s.is_empty() {
                    break s;
                }
            }
            assert!(
                Instant::now() < deadline,
                "server never wrote its addr file"
            );
            std::thread::sleep(Duration::from_millis(20));
        };
        Server { child, addr, dir }
    }

    /// One HTTP exchange (the server always answers Connection: close).
    /// Returns (status, body).
    fn request(&self, raw: &[u8]) -> (u16, String) {
        let mut s = TcpStream::connect(&self.addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.write_all(raw).expect("send request");
        read_response(&mut s)
    }

    fn get(&self, path: &str) -> (u16, String) {
        self.request(format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes())
    }

    fn post(&self, path: &str, body: &str) -> (u16, String) {
        self.request(
            format!(
                "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
    }

    /// SIGTERM, then wait (bounded) for the drain to finish.
    fn terminate(mut self) -> i32 {
        let ok = Command::new("kill")
            .arg(self.child.id().to_string())
            .status()
            .expect("run kill")
            .success();
        assert!(ok, "kill failed");
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            if let Some(st) = self.child.try_wait().expect("try_wait") {
                return st.code().expect("no exit code (killed by signal?)");
            }
            if Instant::now() >= deadline {
                let _ = self.child.kill();
                panic!("server did not drain within 30s of SIGTERM");
            }
            std::thread::sleep(Duration::from_millis(25));
        }
    }
}

fn read_response(s: &mut TcpStream) -> (u16, String) {
    let mut buf = Vec::new();
    let _ = s.read_to_end(&mut buf); // until server-side close
    let text = String::from_utf8_lossy(&buf).to_string();
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .and_then(|w| w.parse().ok())
        .unwrap_or_else(|| panic!("unparseable response: {text:?}"));
    let body = text
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    (status, body)
}

#[test]
fn submit_poll_drain_resume_roundtrip() {
    let srv = Server::spawn("roundtrip", &[]);

    let (st, body) = srv.get("/healthz");
    assert_eq!(st, 200, "healthz: {body}");
    let h = Json::parse(&body).unwrap();
    assert_eq!(h.get("draining").unwrap().as_bool().unwrap(), false);

    // submit two jobs; seqs are assigned in order
    let (st, body) = srv.post("/jobs", &job_body());
    assert_eq!(st, 202, "submit: {body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("seq").unwrap().as_f64().unwrap(), 0.0);
    let (st, _) = srv.post("/jobs", &job_body());
    assert_eq!(st, 202);

    // poll until job 0 completes (virtual time runs 20x wall)
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let (st, body) = srv.get("/jobs/0");
        assert_eq!(st, 200, "poll: {body}");
        let j = Json::parse(&body).unwrap();
        if j.get("state").unwrap().as_str().unwrap() == "done" {
            assert_eq!(j.get("outcome").unwrap().as_str().unwrap(), "completed");
            assert_eq!(j.get("deadline_met").unwrap().as_bool().unwrap(), true);
            break;
        }
        assert!(Instant::now() < deadline, "job 0 never finished: {body}");
        std::thread::sleep(Duration::from_millis(50));
    }

    let (st, _) = srv.get("/jobs/99");
    assert_eq!(st, 404);
    let (st, body) = srv.get("/metrics");
    assert_eq!(st, 200);
    assert!(body.contains("http_requests"), "metrics: {body}");
    let (st, _) = srv.get("/nope");
    assert_eq!(st, 404);
    let (st, _) = srv.request(b"DELETE /jobs HTTP/1.1\r\nHost: x\r\n\r\n");
    assert_eq!(st, 405);

    // graceful drain on SIGTERM: exit 0, nothing lost
    let dir = srv.dir.clone();
    assert_eq!(srv.terminate(), 0, "SIGTERM drain must exit 0");

    // resume + check: every submitted job is terminal
    let out = Command::new(env!("CARGO_BIN_EXE_mxdag"))
        .args(["serve", "--resume", dir.to_str().unwrap(), "--check"])
        .output()
        .expect("run --check");
    assert!(out.status.success(), "--check failed: {out:?}");
    let rep = Json::parse(&String::from_utf8_lossy(&out.stdout)).unwrap();
    assert_eq!(rep.get("jobs").unwrap().as_f64().unwrap(), 2.0);
    let done = rep
        .get("states")
        .unwrap()
        .get("done")
        .unwrap()
        .as_f64()
        .unwrap();
    assert_eq!(done, 2.0, "jobs lost across drain+resume: {rep}");
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_file(dir.with_extension("addr"));
}

#[test]
fn malformed_oversized_and_stalled_requests_never_kill_the_server() {
    let srv = Server::spawn(
        "hostile",
        &["--max-body", "4096", "--read-timeout-ms", "400"],
    );

    // malformed JSON body → 400, server stays up
    let (st, _) = srv.post("/jobs", "this is not json");
    assert_eq!(st, 400);
    // valid JSON, invalid submission → 400
    let (st, body) = srv.post("/jobs", "{\"dag\": 12}");
    assert_eq!(st, 400, "bad dag: {body}");
    // a DAG naming a host beyond the 2-host cluster → 400
    let mut b = MXDag::builder();
    let c = b.compute("c", 0, 1.0);
    let f = b.flow("f", 0, 7, 1.0);
    b.dep(c, f);
    let spec = Json::obj(vec![("dag", b.finalize().unwrap().to_json())]).to_string();
    let (st, body) = srv.post("/jobs", &spec);
    assert_eq!(st, 400, "bad host: {body}");

    // oversized: Content-Length above --max-body → 413 without reading
    let (st, _) = srv.request(
        b"POST /jobs HTTP/1.1\r\nHost: x\r\nContent-Length: 999999\r\n\r\n",
    );
    assert_eq!(st, 413);

    // slow loris: open, send half a request line, stall past the read
    // timeout → 408
    let mut s = TcpStream::connect(&srv.addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(b"GET /healthz HT").unwrap();
    let (st, _) = read_response(&mut s);
    assert_eq!(st, 408);

    // chunked transfer encoding is unsupported → 501
    let (st, _) = srv.request(
        b"POST /jobs HTTP/1.1\r\nHost: x\r\nTransfer-Encoding: chunked\r\n\r\n",
    );
    assert_eq!(st, 501);

    // after all that abuse, the server still serves
    let (st, _) = srv.get("/healthz");
    assert_eq!(st, 200);
    let (st, body) = srv.post("/jobs", &job_body());
    assert_eq!(st, 202, "post-abuse submit: {body}");

    let dir = srv.dir.clone();
    assert_eq!(srv.terminate(), 0);
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_file(dir.with_extension("addr"));
}
