//! Integration: abstraction + expansion + simulator across modules.

use mxdag::mxdag::{cpm, path, MXDag};
use mxdag::sched::{evaluate, Plan};
use mxdag::sim::{expand, simulate, Annotations, Cluster, Policy, SimConfig};
use mxdag::workloads::{self, DdlParams, MapReduceParams, RandomParams};

/// The simulated makespan can never beat the contention-free CPM bound.
#[test]
fn makespan_never_beats_cpm_bound() {
    for seed in 0..10 {
        let g = workloads::random_dag(&RandomParams { seed, ..Default::default() });
        let cluster = Cluster::uniform(8);
        let bound = cpm(&g).makespan;
        for plan in [
            Plan::fair(),
            Plan { ann: Default::default(), policy: Policy::fifo() },
            Plan { ann: Default::default(), policy: Policy::priority() },
        ] {
            let r = evaluate(&g, &cluster, &plan).unwrap();
            assert!(
                r.makespan >= bound - 1e-6,
                "seed {seed}: {} < bound {bound}",
                r.makespan
            );
        }
    }
}

/// Single-task-per-resource DAGs hit the CPM bound exactly (no contention).
#[test]
fn no_contention_hits_cpm_bound() {
    let mut b = MXDag::builder();
    let a = b.compute("a", 0, 1.5);
    let f = b.flow("f", 0, 1, 2.5);
    let c = b.compute("c", 1, 0.5);
    b.chain(&[a, f, c]);
    let g = b.finalize().unwrap();
    let bound = cpm(&g).makespan;
    let r = evaluate(&g, &Cluster::uniform(2), &Plan::fair()).unwrap();
    assert!((r.makespan - bound).abs() < 1e-9);
}

/// Eq. (2) vs chunk-level simulation across a parameter sweep.
///
/// With *aligned* chunk counts the closed form is exact; with mismatched
/// counts the chunked execution quantizes the hand-off, so the sim may
/// exceed Eq.(2) by at most one (largest) unit — never undershoot it.
#[test]
fn eq2_matches_simulation_sweep() {
    let cluster = Cluster::uniform(2);
    for (s1, k1) in [(4.0, 4usize), (6.0, 3), (9.0, 9)] {
        for (s2, k2) in [(4.0, 4usize), (8.0, 8), (3.0, 3)] {
            let u1 = s1 / k1 as f64;
            let u2 = s2 / k2 as f64;
            let mut b = MXDag::builder();
            let a = b.compute_full("a", 0, s1, u1);
            let f = b.flow_full("f", 0, 1, s2, u2);
            b.dep(a, f);
            let g = b.finalize().unwrap();
            let eq2 = path::len_pipe(&g, &[a, f], &path::full_rsrc);
            let ann = Annotations { pipelined: vec![a, f], ..Default::default() };
            let sim = simulate(&expand(&g, &ann), &cluster, &SimConfig::default())
                .unwrap()
                .makespan;
            let ctx = format!("S=({s1},{s2}) K=({k1},{k2}): eq2 {eq2} vs sim {sim}");
            if k1 == k2 {
                assert!((eq2 - sim).abs() < 1e-9, "aligned chunks must be exact: {ctx}");
            } else {
                assert!(sim >= eq2 - 1e-9, "sim can't beat the fluid bound: {ctx}");
                assert!(
                    sim <= eq2 + u1.max(u2) + 1e-9,
                    "quantization is at most one unit: {ctx}"
                );
            }
        }
    }
}

/// Pipelining a full chain can never be slower than the analytic Eq (1)
/// sequential bound on an uncontended cluster.
#[test]
fn pipeline_bounded_by_sequential() {
    let mut b = MXDag::builder();
    let a = b.compute_full("a", 0, 6.0, 1.0);
    let f = b.flow_full("f", 0, 1, 4.0, 1.0);
    let c = b.compute_full("c", 1, 5.0, 1.0);
    b.chain(&[a, f, c]);
    let g = b.finalize().unwrap();
    let seq = path::len_seq(&g, &[a, f, c], &path::full_rsrc);
    let ann = Annotations { pipelined: vec![a, f, c], ..Default::default() };
    let piped = simulate(&expand(&g, &ann), &Cluster::uniform(2), &SimConfig::default())
        .unwrap()
        .makespan;
    assert!(piped <= seq + 1e-9, "pipelined {piped} vs sequential {seq}");
    // and it should actually help here
    assert!(piped < seq - 1.0);
}

/// Coflow all-or-nothing + MADD vs per-flow: per-flow never loses on the
/// paper's scenarios.
#[test]
fn coflow_never_beats_mx_on_figures() {
    use mxdag::sched::{run, CoflowScheduler, Grouping, MxScheduler};
    // fig2a at several asymmetries
    for t1 in [1.0, 2.0, 4.0] {
        let (g, flows) = workloads::fig2a_dag(t1, 1.0);
        let cluster = Cluster::uniform(4);
        let mx = run(&MxScheduler::without_pipelining(), &g, &cluster)
            .unwrap()
            .makespan;
        let co = run(
            &CoflowScheduler::new(Grouping::Explicit(vec![
                vec![flows[0], flows[1]],
                vec![flows[2], flows[3]],
            ])),
            &g,
            &cluster,
        )
        .unwrap()
        .makespan;
        assert!(mx <= co + 1e-9, "t1={t1}: mx {mx} vs coflow {co}");
    }
}

/// DDL: MXDAG ≥ parity with FIFO across depth and comm ratio.
#[test]
fn ddl_sweep_mx_never_loses() {
    use mxdag::sched::{run, FifoScheduler, MxScheduler};
    let cluster = Cluster::with_cores(2, 2.0);
    for layers in [2usize, 4, 8] {
        for comm in [0.5, 1.0, 2.0] {
            let (g, _) = workloads::ddl_dag(&DdlParams { layers, comm, ..Default::default() });
            let fifo = run(&FifoScheduler, &g, &cluster).unwrap().makespan;
            let mx = run(&MxScheduler::without_pipelining(), &g, &cluster)
                .unwrap()
                .makespan;
            assert!(
                mx <= fifo + 1e-9,
                "layers={layers} comm={comm}: mx {mx} vs fifo {fifo}"
            );
        }
    }
}

/// The full scheduler pipeline handles a jittered shuffle end to end.
#[test]
fn shuffle_all_policies_complete() {
    let (g, _) = workloads::mapreduce_dag(&MapReduceParams {
        mappers: 6,
        reducers: 3,
        map_hosts: vec![0, 1, 2],
        red_hosts: vec![3, 4, 5],
        jitter: 0.4,
        seed: 17,
        ..Default::default()
    });
    let cluster = Cluster::uniform(6);
    for policy in [Policy::fair(), Policy::fifo(), Policy::priority(), Policy::coflow()] {
        let r = evaluate(&g, &cluster, &Plan { ann: Default::default(), policy }).unwrap();
        assert!(r.makespan.is_finite() && r.makespan > 0.0);
        // every task finished after it started
        for t in g.real_tasks() {
            assert!(r.finish_of(t) >= r.start_of(t) - 1e-12);
        }
    }
}

/// Gates (altruism) delay starts but never deadlock the DAG.
#[test]
fn gates_respected_without_deadlock() {
    let g = workloads::fig1_dag();
    let mut ann = Annotations::default();
    let f3 = g.by_name("f3").unwrap();
    ann.gates.insert(f3, 2.5);
    let r = evaluate(
        &g,
        &Cluster::uniform(3),
        &Plan { ann, policy: Policy::priority() },
    )
    .unwrap();
    assert!(r.start_of(f3) >= 2.5 - 1e-9);
}

/// The CLI documents deadlock => exit 2 and event-limit => exit 3, for
/// `simulate`, `simulate --open` and `serve` alike.
/// `SimError::kind_str`/`exit_code` are the single source of that
/// mapping — this pins both failure classes to their documented codes.
#[test]
fn sim_error_kinds_map_to_documented_exit_codes() {
    use mxdag::sim::SimError;
    // deadlock: a flow into a dead uplink can never make progress
    let mut b = MXDag::builder();
    b.flow("f", 0, 1, 1.0);
    let g = b.finalize().unwrap();
    let sim = expand(&g, &Annotations::default());
    let mut cluster = Cluster::uniform(2);
    cluster.hosts[0].nic_up = 0.0;
    let e = simulate(&sim, &cluster, &SimConfig::default()).unwrap_err();
    assert!(matches!(e, SimError::Deadlock { .. }), "{e}");
    assert_eq!(e.kind_str(), "deadlock");
    assert_eq!(e.exit_code(), 2);

    // event limit: a healthy sequential chain, but only one event
    let mut b = MXDag::builder();
    let a = b.compute("a", 0, 1.0);
    let f = b.flow("f", 0, 1, 1.0);
    let c = b.compute("c", 1, 1.0);
    b.chain(&[a, f, c]);
    let g = b.finalize().unwrap();
    let sim = expand(&g, &Annotations::default());
    let cfg = SimConfig { max_events: 1, ..SimConfig::default() };
    let e = simulate(&sim, &Cluster::uniform(2), &cfg).unwrap_err();
    assert!(matches!(e, SimError::EventLimit(_)), "{e}");
    assert_eq!(e.kind_str(), "event_limit");
    assert_eq!(e.exit_code(), 3);
}
