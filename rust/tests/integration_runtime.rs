//! Integration over the PJRT runtime: load real artifacts, check
//! numerics against host-side references, and run a short end-to-end
//! training burst. Tests skip (with a notice) when `make artifacts`
//! hasn't been run.

use std::path::Path;

use mxdag::coordinator::{train, DdlConfig, SyncSchedule};
use mxdag::runtime::{Engine, Tensor};

fn engine() -> Option<Engine> {
    match Engine::load(Path::new("artifacts")) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            None
        }
    }
}

#[test]
fn matmul_artifact_matches_host() {
    let Some(engine) = engine() else { return };
    let spec = &engine.manifest.artifact("matmul").unwrap().inputs;
    let (m, k) = (spec[0].shape[0], spec[0].shape[1]);
    let n = spec[1].shape[1];
    let x: Vec<f32> = (0..m * k).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
    let w: Vec<f32> = (0..k * n).map(|i| ((i % 11) as f32 - 5.0) * 0.1).collect();
    let out = engine
        .execute("matmul", &[Tensor::f32(&[m, k], x.clone()), Tensor::f32(&[k, n], w.clone())])
        .unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape(), &[m, n]);
    // full host-side check
    let o = out[0].as_f32();
    for i in [0usize, m / 2, m - 1] {
        for j in [0usize, n / 2, n - 1] {
            let want: f32 = (0..k).map(|p| x[i * k + p] * w[p * n + j]).sum();
            assert!(
                (o[i * n + j] - want).abs() < 1e-3,
                "({i},{j}): {} vs {}",
                o[i * n + j],
                want
            );
        }
    }
}

#[test]
fn layer_forwards_compose_into_full_forward() {
    let Some(engine) = engine() else { return };
    let m = engine.manifest.clone();
    let params = mxdag::coordinator::ddl::init_params(&m.model.param_shapes, 3);
    let gen = mxdag::coordinator::ddl::DataGen::new(
        m.model.input_dim,
        m.model.classes,
        m.model.batch,
        3,
    );
    let (x, _) = gen.batch(0, 0);

    // layer-by-layer
    let mut h = x.clone();
    for l in 0..m.model.n_layers {
        h = engine
            .execute(
                &format!("layer_fwd_{l}"),
                &[h, params[2 * l].clone(), params[2 * l + 1].clone()],
            )
            .unwrap()
            .pop()
            .unwrap();
    }
    // fused forward
    let mut inputs = params.clone();
    inputs.push(x);
    let logits = engine.execute("forward", &inputs).unwrap().pop().unwrap();

    assert_eq!(h.shape(), logits.shape());
    for (a, b) in h.as_f32().iter().zip(logits.as_f32()) {
        assert!((a - b).abs() < 1e-3, "{a} vs {b}");
    }
}

#[test]
fn grad_step_loss_matches_train_step() {
    let Some(engine) = engine() else { return };
    let m = engine.manifest.clone();
    let params = mxdag::coordinator::ddl::init_params(&m.model.param_shapes, 5);
    let gen = mxdag::coordinator::ddl::DataGen::new(
        m.model.input_dim,
        m.model.classes,
        m.model.batch,
        5,
    );
    let (x, y) = gen.batch(1, 0);
    let mut inputs = params.clone();
    inputs.push(x);
    inputs.push(y);
    let g = engine.execute("grad_step", &inputs).unwrap();
    let t = engine.execute("train_step", &inputs).unwrap();
    assert_eq!(g.len(), 1 + params.len());
    assert_eq!(t.len(), 1 + params.len());
    assert!((g[0].scalar_f32() - t[0].scalar_f32()).abs() < 1e-5);
    // train_step == params - lr * grads
    let lr = m.model.lr as f32;
    for i in 0..params.len() {
        let mut want = params[i].clone();
        want.axpy_neg(lr, &g[1 + i]);
        for (a, b) in want.as_f32().iter().zip(t[1 + i].as_f32()) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}

#[test]
fn shape_mismatch_rejected() {
    let Some(engine) = engine() else { return };
    let bad = vec![Tensor::zeros(&[1, 1]), Tensor::zeros(&[1, 1])];
    assert!(engine.execute("matmul", &bad).is_err());
    assert!(engine.execute("matmul", &bad[..1]).is_err());
    assert!(engine.execute("nonexistent", &[]).is_err());
}

/// Short end-to-end burst: loss decreases and both schedules agree.
#[test]
fn e2e_training_loss_decreases() {
    if engine().is_none() {
        return;
    }
    let mut finals = Vec::new();
    for schedule in [SyncSchedule::Fifo, SyncSchedule::Mxdag] {
        let cfg = DdlConfig {
            workers: 2,
            steps: 4,
            schedule,
            time_scale: 0.0, // don't sleep in tests
            log_every: 0,
            fwd_reps: 1,
            ..Default::default()
        };
        let r = train(&cfg).unwrap();
        assert!(
            r.last_loss() < r.first_loss(),
            "{}: {} -> {}",
            schedule.label(),
            r.first_loss(),
            r.last_loss()
        );
        finals.push(r.last_loss());
    }
    assert!(
        (finals[0] - finals[1]).abs() < 1e-6,
        "synchronous SGD must be schedule-invariant: {finals:?}"
    );
}
