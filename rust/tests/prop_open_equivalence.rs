//! Equivalence oracle for the open-system streaming driver
//! (`sim/openloop.rs`). The driver chains closed engine runs era by
//! era, so its correctness contract is stated *against* the closed
//! engine:
//!
//! * **closed-mode identity** — every arrival at `t = 0` with an
//!   infinite watermark collapses to exactly one era over the
//!   [`concat_jobs`] concatenation. The open run and the closed run of
//!   that DAG are the *same computation* (same DAG bits, same config),
//!   so events, makespan and per-task traces must agree bitwise on
//!   every corner of the {queue} × {alloc} × {horizon} matrix ×
//!   threads ∈ {1, 2, 4} × recovery ∈ {failfast, retry}, anchored
//!   corners included (the 1e-6 tolerance pairing is a *cross*-corner
//!   contract; open-vs-closed on one corner is identity).
//! * **solo-stream identity** — jobs spaced so wide that the live set
//!   never holds two jobs must each reproduce their solo closed run
//!   shifted by their arrival instant, bitwise per task.
//! * **thread determinism under load** — a contended stream with a
//!   finite watermark and deferral window must produce the identical
//!   admitted/rejected set, admission instants, outcomes and JCTs for
//!   every thread count, per corner.
//! * **bounded memory** — streaming 10× more jobs through a reused
//!   [`SimScratch`] must not grow its footprint once the live-set
//!   high-water mark is reached (the epoch GC satellite).
//! * **shedding accounting** — rejected jobs never enter the engine:
//!   distinct [`JobOutcome::Rejected`], empty traces, and zero
//!   `lost_work` contribution.

use mxdag::sim::{
    concat_jobs, expand, poisson_arrivals, run_open, run_open_in, simulate, AllocKind, Cluster,
    DynAction, DynTimeline, HorizonKind, JobOutcome, OpenConfig, OpenJob, QueueKind,
    RecoveryPolicy, SimConfig, SimDag, SimKind, SimScratch, SimTask,
};
use mxdag::util::propcheck::{check, Config};
use mxdag::util::rng::Rng;
use mxdag::workloads::{random_dag, RandomParams};

const MATRIX: [(QueueKind, AllocKind, HorizonKind); 8] = [
    (QueueKind::FullResort, AllocKind::WholeSet, HorizonKind::Eager),
    (QueueKind::Incremental, AllocKind::WholeSet, HorizonKind::Eager),
    (QueueKind::FullResort, AllocKind::Components, HorizonKind::Eager),
    (QueueKind::Incremental, AllocKind::Components, HorizonKind::Eager),
    (QueueKind::FullResort, AllocKind::WholeSet, HorizonKind::Anchored),
    (QueueKind::Incremental, AllocKind::WholeSet, HorizonKind::Anchored),
    (QueueKind::FullResort, AllocKind::Components, HorizonKind::Anchored),
    (QueueKind::Incremental, AllocKind::Components, HorizonKind::Anchored),
];

const THREADS: [usize; 3] = [1, 2, 4];

/// A stream of 2–4 random job DAGs on a shared host pool.
#[derive(Debug)]
struct StreamCase {
    dags: Vec<SimDag>,
    hosts: usize,
    seed: u64,
}

fn gen_stream(rng: &mut Rng) -> StreamCase {
    let hosts = rng.range(2, 6);
    let n_jobs = rng.range(2, 5);
    let seed = rng.next_u64();
    let dags = (0..n_jobs)
        .map(|j| {
            let p = RandomParams {
                layers: rng.range(2, 4),
                width: rng.range(2, 4),
                hosts,
                edge_p: rng.range_f64(0.2, 0.9),
                pipe_frac: 0.0,
                min_size: 0.1,
                max_size: 3.0,
                seed: seed.wrapping_add(j as u64),
            };
            expand(&random_dag(&p), &Default::default())
        })
        .collect();
    StreamCase { dags, hosts, seed }
}

fn cfg_of(
    (queue, alloc, horizon): (QueueKind, AllocKind, HorizonKind),
    threads: usize,
    timeline: &DynTimeline,
    recovery: RecoveryPolicy,
) -> SimConfig {
    SimConfig {
        queue,
        alloc,
        horizon,
        threads,
        dynamics: timeline.clone(),
        recovery,
        ..Default::default()
    }
}

/// Closed-mode identity: open-at-t0 with an infinite watermark is the
/// closed run of the concatenation, bit for bit, on every matrix
/// corner × thread count × recovery policy — with a recoverable
/// crash/restore cycle folded in under `Retry` so the kill/backoff
/// machinery crosses the era build too.
#[test]
fn prop_open_at_t0_is_bitwise_closed() {
    check(
        "open-closed-identity",
        &Config { cases: 6, ..Default::default() },
        gen_stream,
        |case| {
            let cluster = Cluster::uniform(case.hosts);
            let jobs: Vec<OpenJob> = case
                .dags
                .iter()
                .map(|d| OpenJob { at: 0.0, dag: d.clone(), deadline: None, weight: 1 })
                .collect();
            let concat = concat_jobs(&jobs);
            let victim = (case.seed % case.hosts as u64) as usize;
            let cycle = DynTimeline::new()
                .with(0.7731, DynAction::FailHost { host: victim })
                .with(1.3371, DynAction::RestoreHost { host: victim });
            let regimes: [(&str, DynTimeline, RecoveryPolicy); 2] = [
                ("failfast", DynTimeline::new(), RecoveryPolicy::FailFast),
                ("retry", cycle, RecoveryPolicy::Retry { max_attempts: 5, backoff: 0.25 }),
            ];
            for (rname, timeline, recovery) in regimes.iter() {
                for &corner in MATRIX.iter() {
                    for &threads in THREADS.iter() {
                        let cfg = cfg_of(corner, threads, timeline, *recovery);
                        let tag = format!("{corner:?} t{threads} {rname}");
                        let closed = simulate(&concat, &cluster, &cfg)
                            .map_err(|e| format!("{tag}: closed {e}"))?;
                        let open = run_open(
                            &jobs,
                            &cluster,
                            &OpenConfig { engine: cfg, ..OpenConfig::default() },
                        )
                        .map_err(|e| format!("{tag}: open {e}"))?;
                        if open.eras != 1 {
                            return Err(format!("{tag}: {} eras, expected 1", open.eras));
                        }
                        if open.admitted != jobs.len() || open.rejected != 0 {
                            return Err(format!(
                                "{tag}: admitted {}/{} rejected {}",
                                open.admitted,
                                jobs.len(),
                                open.rejected
                            ));
                        }
                        if closed.events != open.events {
                            return Err(format!(
                                "{tag}: events {} vs {}",
                                closed.events, open.events
                            ));
                        }
                        if closed.retries != open.retries {
                            return Err(format!(
                                "{tag}: retries {} vs {}",
                                closed.retries, open.retries
                            ));
                        }
                        if closed.lost_work.to_bits() != open.lost_work.to_bits() {
                            return Err(format!(
                                "{tag}: lost_work {} vs {}",
                                closed.lost_work, open.lost_work
                            ));
                        }
                        if closed.makespan.to_bits() != open.makespan.to_bits() {
                            return Err(format!(
                                "{tag}: makespan {} vs {}",
                                closed.makespan, open.makespan
                            ));
                        }
                        let mut base = 0usize;
                        for (j, jr) in open.jobs.iter().enumerate() {
                            if jr.admitted_at != Some(0.0) {
                                return Err(format!("{tag}: job {j} not admitted at 0"));
                            }
                            for (k, t) in jr.trace.iter().enumerate() {
                                let c = &closed.trace[base + k];
                                let same_bits = |x: f64, y: f64| {
                                    x.to_bits() == y.to_bits() || (x.is_nan() && y.is_nan())
                                };
                                if !same_bits(c.start, t.start) || !same_bits(c.finish, t.finish)
                                {
                                    return Err(format!(
                                        "{tag}: job {j} task {k}: {:?}..{:?} vs {:?}..{:?}",
                                        c.start, c.finish, t.start, t.finish
                                    ));
                                }
                            }
                            base += jr.trace.len();
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Solo-stream identity: arrivals spaced past each job's solo
/// makespan never contend, so each job's absolute trace is its solo
/// closed trace shifted by its arrival — bitwise, since the era run is
/// the identical computation and the absolute rebase performs the same
/// `arrival + t` addition the test does.
#[test]
fn prop_spaced_stream_matches_solo_runs() {
    check(
        "open-solo-stream",
        &Config { cases: 6, ..Default::default() },
        gen_stream,
        |case| {
            let cluster = Cluster::uniform(case.hosts);
            let fast = SimConfig {
                queue: QueueKind::Incremental,
                alloc: AllocKind::Components,
                ..Default::default()
            };
            let solos: Vec<_> = case
                .dags
                .iter()
                .map(|d| simulate(d, &cluster, &fast))
                .collect::<Result<_, _>>()
                .map_err(|e| format!("solo: {e}"))?;
            // arrivals: each job lands strictly after its predecessor
            // fully drained
            let mut jobs = Vec::new();
            let mut at = 0.0f64;
            for (d, solo) in case.dags.iter().zip(solos.iter()) {
                jobs.push(OpenJob { at, dag: d.clone(), deadline: None, weight: 1 });
                at += solo.makespan * 1.5 + 1.0;
            }
            let open = run_open(
                &jobs,
                &cluster,
                &OpenConfig { engine: fast.clone(), ..OpenConfig::default() },
            )
            .map_err(|e| format!("open: {e}"))?;
            if open.completed != jobs.len() {
                return Err(format!("completed {}/{}", open.completed, jobs.len()));
            }
            for (j, (jr, solo)) in open.jobs.iter().zip(solos.iter()).enumerate() {
                let at = jobs[j].at;
                for (k, (t, s)) in jr.trace.iter().zip(solo.trace.iter()).enumerate() {
                    if t.start.to_bits() != (at + s.start).to_bits()
                        || t.finish.to_bits() != (at + s.finish).to_bits()
                    {
                        return Err(format!(
                            "job {j} task {k}: {:?}..{:?} vs shifted solo {:?}..{:?}",
                            t.start,
                            t.finish,
                            at + s.start,
                            at + s.finish
                        ));
                    }
                }
                let jct = jr.jct.ok_or_else(|| format!("job {j} has no jct"))?;
                if (jct - solo.makespan).abs() > 1e-9 {
                    return Err(format!("job {j} jct {jct} vs solo {}", solo.makespan));
                }
            }
            Ok(())
        },
    );
}

/// Thread determinism under load: a contended Poisson stream with a
/// finite watermark and a deferral window must reproduce the identical
/// admitted/rejected set, admission instants, per-job outcomes and
/// JCTs at every thread count, on every corner — thread count shards
/// the refill, never the semantics.
#[test]
fn prop_contended_stream_is_thread_deterministic() {
    check(
        "open-thread-determinism",
        &Config { cases: 4, ..Default::default() },
        gen_stream,
        |case| {
            let cluster = Cluster::uniform(case.hosts);
            let fast = SimConfig {
                queue: QueueKind::Incremental,
                alloc: AllocKind::Components,
                ..Default::default()
            };
            let solo = simulate(&case.dags[0], &cluster, &fast)
                .map_err(|e| format!("solo: {e}"))?
                .makespan;
            // arrivals dense enough to overlap; watermark low enough
            // that shedding is plausible but solo jobs still pass
            let arrivals = poisson_arrivals(case.seed, 2.0 / solo.max(1e-3), case.dags.len());
            let jobs: Vec<OpenJob> = case
                .dags
                .iter()
                .zip(arrivals.iter())
                .map(|(d, &at)| OpenJob { at, dag: d.clone(), deadline: Some(solo * 4.0), weight: 1 })
                .collect();
            for &corner in MATRIX.iter() {
                let run_at = |threads: usize| {
                    run_open(
                        &jobs,
                        &cluster,
                        &OpenConfig {
                            watermark: solo * 1.5,
                            defer_max: solo * 0.5,
                            engine: cfg_of(
                                corner,
                                threads,
                                &DynTimeline::new(),
                                RecoveryPolicy::FailFast,
                            ),
                        },
                    )
                };
                let base = run_at(1).map_err(|e| format!("{corner:?} t1: {e}"))?;
                for &threads in THREADS[1..].iter() {
                    let r = run_at(threads).map_err(|e| format!("{corner:?} t{threads}: {e}"))?;
                    let tag = format!("{corner:?} t{threads}");
                    if base.admitted != r.admitted
                        || base.rejected != r.rejected
                        || base.eras != r.eras
                        || base.events != r.events
                        || base.makespan.to_bits() != r.makespan.to_bits()
                    {
                        return Err(format!(
                            "{tag}: counters diverged ({}/{}/{} vs {}/{}/{})",
                            base.admitted, base.rejected, base.eras, r.admitted, r.rejected,
                            r.eras
                        ));
                    }
                    for (j, (a, b)) in base.jobs.iter().zip(r.jobs.iter()).enumerate() {
                        if a.admitted_at.map(f64::to_bits) != b.admitted_at.map(f64::to_bits) {
                            return Err(format!("{tag}: job {j} admission instant"));
                        }
                        if a.jct.map(f64::to_bits) != b.jct.map(f64::to_bits) {
                            return Err(format!("{tag}: job {j} jct"));
                        }
                        if std::mem::discriminant(&a.outcome)
                            != std::mem::discriminant(&b.outcome)
                        {
                            return Err(format!(
                                "{tag}: job {j} outcome {:?} vs {:?}",
                                a.outcome, b.outcome
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// One compute task of `size` on `host`.
fn one_task_job(at: f64, host: usize, size: f64) -> OpenJob {
    let mut d = SimDag::default();
    d.push(SimTask {
        orig: 0,
        chunk: (0, 1),
        kind: SimKind::Compute { host },
        size,
        priority: 0,
        gate: 0.0,
        coflow: None,
    });
    OpenJob { at, dag: d, deadline: None, weight: 1 }
}

/// The bounded-memory satellite: after the scratch has seen a 1k-job
/// stream, pushing a 10k-job stream through the *same* scratch must
/// not grow its footprint — per-era state is sized by the live set
/// (which this stream caps at a handful of jobs), not by the stream
/// length. The arena, `CompSet` and `FinHeap` capacities all feed
/// `SimScratch::footprint()`.
#[test]
fn scratch_footprint_plateaus_over_ten_thousand_jobs() {
    let cluster = Cluster::uniform(4);
    let mk_stream = |n: usize| -> Vec<OpenJob> {
        (0..n).map(|i| one_task_job(i as f64 * 0.5, i % 4, 1.0)).collect()
    };
    let cfg = OpenConfig::default();
    let mut scratch = SimScratch::default();

    let warm = run_open_in(&mk_stream(1_000), &cluster, &cfg, &mut scratch).unwrap();
    assert_eq!(warm.completed, 1_000, "warm stream completes");
    let high_water = scratch.footprint();
    assert!(high_water > 0, "footprint must be measurable");

    let long = run_open_in(&mk_stream(10_000), &cluster, &cfg, &mut scratch).unwrap();
    assert_eq!(long.completed, 10_000, "long stream completes");
    assert_eq!(
        scratch.footprint(),
        high_water,
        "10x the stream must not grow the scratch: the live set, not the \
         stream, sizes the memory"
    );
}

/// The shedding satellite: rejected jobs never enter the engine. A
/// two-job burst over a watermark that only fits one must shed the
/// second with the distinct `Rejected` outcome, an empty trace, no
/// admission instant — and `lost_work` stays exactly zero (shedding
/// is not a crash; nothing was started, nothing was destroyed).
#[test]
fn rejected_jobs_are_excluded_from_lost_work_and_traces() {
    let cluster = Cluster::uniform(1);
    let jobs = vec![one_task_job(0.0, 0, 4.0), one_task_job(1.0, 0, 4.0)];
    let r = run_open(
        &jobs,
        &cluster,
        &OpenConfig { watermark: 5.0, defer_max: 0.0, ..OpenConfig::default() },
    )
    .unwrap();
    assert_eq!((r.admitted, r.rejected, r.completed), (1, 1, 1));
    assert_eq!(r.lost_work, 0.0, "shedding must not count as destroyed work");
    match r.jobs[1].outcome {
        JobOutcome::Rejected { at } => assert_eq!(at, 1.0, "shed at its arrival instant"),
        ref other => panic!("expected Rejected, got {other:?}"),
    }
    assert!(r.jobs[1].trace.is_empty(), "shed jobs have no trace");
    assert_eq!(r.jobs[1].admitted_at, None);
    assert_eq!(r.jobs[1].jct, None);
    // the admitted job is untouched by the shed one
    assert_eq!(r.jobs[0].jct, Some(4.0));
}

/// The dynamics-vs-GC satellite regression: a restore landing *after*
/// every job that experienced the degradation has departed must still
/// lift the factor for later arrivals — link factor state lives on the
/// timeline fold, not on any job the GC reclaimed. (The same scenario
/// is unit-tested inside `sim/openloop.rs`; this copy pins it at the
/// integration surface with a second, disjoint-host stream.)
#[test]
fn restore_after_departure_still_lifts_the_cap() {
    let cluster = Cluster::uniform(3);
    let mut cfg = OpenConfig::default();
    cfg.engine.dynamics = DynTimeline::new()
        .with(0.5, DynAction::SlowHost { host: 0, factor: 0.5 })
        // by t = 6 the only job that ever saw the slowdown is long gone
        .with(6.0, DynAction::RestoreHost { host: 0 });
    let jobs = vec![
        // runs [0, 0.5) at full rate, then at 0.5x: finishes at 3.5
        one_task_job(0.0, 0, 2.0),
        // never touches host 0 and finishes at 5.0 — so no live job
        // witnesses the t = 6 restore when it fires
        one_task_job(4.0, 1, 1.0),
        // admitted after the restore: must see host 0 at full rate
        one_task_job(10.0, 0, 2.0),
    ];
    let r = run_open(&jobs, &cluster, &cfg).unwrap();
    assert_eq!(r.completed, 3);
    let jct = |i: usize| r.jobs[i].jct.unwrap();
    assert!((jct(0) - 3.5).abs() < 1e-9, "job 0 pays the slowdown: {}", jct(0));
    assert!((jct(1) - 1.0).abs() < 1e-9, "job 1 is on another host: {}", jct(1));
    assert!(
        (jct(2) - 2.0).abs() < 1e-9,
        "job 2 must see the restored host even though the restore fired in an \
         idle gap after job 0 departed: {}",
        jct(2)
    );
}
