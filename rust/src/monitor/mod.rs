//! Monitoring & debugging (§4.3): compare the planned execution against
//! an observed one, classify *host* vs *network* stragglers — which a
//! traditional DAG cannot distinguish — and re-derive the critical path
//! from observed progress for runtime re-planning.

use crate::mxdag::{cpm_with, Cpm, MXDag, TaskId, TaskKind};
use crate::sim::SimResult;

/// A detected straggler.
#[derive(Debug, Clone)]
pub struct Straggler {
    pub task: TaskId,
    pub name: String,
    pub kind: StragglerKind,
    /// observed duration / expected duration.
    pub slowdown: f64,
}

/// The distinction MXDAG makes possible (§4.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StragglerKind {
    /// A compute MXTask ran slow: the *host* (CPU/GPU contention, thermal…)
    Host { host: usize },
    /// A network MXTask ran slow: the *path* src→dst is congested.
    Network { src: usize, dst: usize },
}

/// Compare expected and observed per-task durations; report tasks slower
/// than `threshold`× their expectation. `expected`/`observed` give
/// (start, finish) per logical task.
pub fn detect_stragglers(
    dag: &MXDag,
    expected: &SimResult,
    observed: &SimResult,
    threshold: f64,
) -> Vec<Straggler> {
    let mut out = Vec::new();
    for t in dag.real_tasks() {
        let task = dag.task(t);
        let exp = expected.finish_of(t) - expected.start_of(t);
        let obs = observed.finish_of(t) - observed.start_of(t);
        if exp <= 0.0 {
            continue;
        }
        let slowdown = obs / exp;
        if slowdown > threshold {
            let kind = match task.kind {
                TaskKind::Compute { host } => StragglerKind::Host { host },
                TaskKind::Flow { src, dst } => StragglerKind::Network { src, dst },
                _ => continue,
            };
            out.push(Straggler { task: t, name: task.name.clone(), kind, slowdown });
        }
    }
    out.sort_by(|a, b| b.slowdown.partial_cmp(&a.slowdown).unwrap());
    out
}

/// Re-derive the critical path using *observed* durations for finished
/// tasks and planned sizes for the rest — the §4.3 runtime re-planning
/// input ("determine the new critical paths to optimize the scheduling
/// plan at runtime").
pub fn replan_cpm(dag: &MXDag, observed: &SimResult) -> Cpm {
    let dur: Vec<f64> = dag
        .tasks()
        .iter()
        .map(|t| {
            if t.kind.is_dummy() {
                return 0.0;
            }
            let obs = observed.finish_of(t.id) - observed.start_of(t.id);
            if obs.is_finite() && obs > 0.0 {
                obs
            } else {
                t.size
            }
        })
        .collect();
    cpm_with(dag, &dur)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{evaluate, Plan};
    use crate::sim::{Cluster, Host};
    use crate::workloads;

    /// Run fig1 on a healthy cluster and one with a degraded NIC; the
    /// monitor must finger the network straggler, not the hosts.
    #[test]
    fn network_straggler_classified() {
        let g = workloads::fig1_dag();
        let healthy = Cluster::uniform(3);
        let mut degraded = Cluster::uniform(3);
        degraded.hosts[1] = Host { nic_up: 0.25, ..Host::default() }; // B's uplink
        let plan = Plan::fair();
        let exp = evaluate(&g, &healthy, &plan).unwrap();
        let obs = evaluate(&g, &degraded, &plan).unwrap();
        let s = detect_stragglers(&g, &exp, &obs, 1.5);
        assert!(!s.is_empty());
        assert_eq!(s[0].name, "f2"); // the flow out of B
        assert!(matches!(s[0].kind, StragglerKind::Network { src: 1, dst: 2 }));
    }

    #[test]
    fn host_straggler_classified() {
        let g = workloads::fig1_dag();
        let healthy = Cluster::uniform(3);
        let mut degraded = Cluster::uniform(3);
        degraded.hosts[1].cores = 0.25; // B computes 4x slower
        let plan = Plan::fair();
        let exp = evaluate(&g, &healthy, &plan).unwrap();
        let obs = evaluate(&g, &degraded, &plan).unwrap();
        let s = detect_stragglers(&g, &exp, &obs, 1.5);
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].name, "B");
        assert!(matches!(s[0].kind, StragglerKind::Host { host: 1 }));
        assert!((s[0].slowdown - 4.0).abs() < 1e-6);
    }

    #[test]
    fn healthy_run_reports_nothing() {
        let g = workloads::fig1_dag();
        let cluster = Cluster::uniform(3);
        let plan = Plan::fair();
        let exp = evaluate(&g, &cluster, &plan).unwrap();
        assert!(detect_stragglers(&g, &exp, &exp, 1.1).is_empty());
    }

    #[test]
    fn replan_shifts_critical_path() {
        // a -> f_fast -> b   (healthy critical path: 0.1 + 1 + 1 = 2.1)
        // a -> f_slow -> c   (healthy: 0.1 + 1 + 0.5 = 1.6, has slack)
        let mut bld = crate::mxdag::MXDag::builder();
        let a = bld.compute("a", 0, 0.1);
        let f_fast = bld.flow("f_fast", 0, 1, 1.0);
        let b = bld.compute("b", 1, 1.0);
        let f_slow = bld.flow("f_slow", 0, 2, 1.0);
        let c = bld.compute("c", 2, 0.5);
        bld.dep(a, f_fast).dep(f_fast, b).dep(a, f_slow).dep(f_slow, c);
        let g = bld.finalize().unwrap();

        let plan = Plan::fair();
        let exp = evaluate(&g, &Cluster::uniform(3), &plan).unwrap();
        let c0 = replan_cpm(&g, &exp);
        assert!(!c0.is_critical(f_slow), "healthy: f_slow has slack");
        assert!(c0.is_critical(f_fast));

        // degrade ONLY host 2's downlink: f_slow runs at 0.2 => dur 5
        let mut degraded = Cluster::uniform(3);
        degraded.hosts[2].nic_down = 0.2;
        let obs = evaluate(&g, &degraded, &plan).unwrap();
        let c1 = replan_cpm(&g, &obs);
        assert!(c1.makespan > c0.makespan);
        assert!(c1.is_critical(f_slow), "replan must flip the critical path");
        assert!(!c1.is_critical(f_fast));
    }
}
