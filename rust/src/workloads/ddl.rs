//! The distributed-deep-learning MXDAG of Fig. 6 (§4.1.1).
//!
//! Layer-wise parameter synchronisation between a worker and a parameter
//! server: per layer i, `BP_i → push_i → pull_i → FP_i`; BP runs top
//! layer first (L-1 … 0), FP bottom first (0 … L-1). All pushes share
//! the worker's uplink, all pulls its downlink — the scheduling question
//! is the tensor transmission *order* (ByteScheduler's insight, which
//! the MXDAG analysis recovers via critical-path priority).

use crate::mxdag::{MXDag, TaskId};

#[derive(Debug, Clone)]
pub struct DdlParams {
    pub layers: usize,
    /// Back-propagation compute time per layer.
    pub bp: f64,
    /// Forward-propagation compute time per layer.
    pub fp: f64,
    /// Transfer time per layer's parameters (push and pull each).
    pub comm: f64,
    /// Worker host id; parameter server is `worker + 1`.
    pub worker: usize,
}

impl Default for DdlParams {
    fn default() -> Self {
        // FP-heavy regime: reordering tensor transmission lets lower-layer
        // pulls hide behind the FP chain (the ByteScheduler sweet spot).
        DdlParams { layers: 4, bp: 0.5, fp: 2.0, comm: 1.0, worker: 0 }
    }
}

/// Task handles for one layer.
#[derive(Debug, Clone, Copy)]
pub struct DdlLayer {
    pub bp: TaskId,
    pub push: TaskId,
    pub pull: TaskId,
    pub fp: TaskId,
}

/// Build the Fig. 6 DAG. Returns (dag, layer handles bottom-up).
pub fn ddl_dag(p: &DdlParams) -> (MXDag, Vec<DdlLayer>) {
    let w = p.worker;
    let ps = p.worker + 1;
    let mut b = MXDag::builder();
    let mut layers = Vec::with_capacity(p.layers);
    for i in 0..p.layers {
        let bp = b.compute(&format!("BP{i}"), w, p.bp);
        let push = b.flow(&format!("push{i}"), w, ps, p.comm);
        let pull = b.flow(&format!("pull{i}"), ps, w, p.comm);
        let fp = b.compute(&format!("FP{i}"), w, p.fp);
        b.dep(bp, push).dep(push, pull).dep(pull, fp);
        layers.push(DdlLayer { bp, push, pull, fp });
    }
    // BP chain: top layer first (L-1 -> ... -> 0)
    for i in (1..p.layers).rev() {
        b.dep(layers[i].bp, layers[i - 1].bp);
    }
    // FP chain: bottom layer first (0 -> ... -> L-1)
    for i in 1..p.layers {
        b.dep(layers[i - 1].fp, layers[i].fp);
    }
    (b.finalize().unwrap(), layers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mxdag::cpm;
    use crate::sched::{run, FifoScheduler, MxScheduler};
    use crate::sim::Cluster;

    #[test]
    fn structure_bp_reverse_fp_forward() {
        let (g, layers) = ddl_dag(&DdlParams::default());
        // BP3 has no real preds; BP0 is last in the BP chain
        assert_eq!(g.preds(layers[3].bp), &[g.start()]);
        assert!(g.preds(layers[0].bp).contains(&layers[1].bp));
        assert!(g.preds(layers[3].fp).contains(&layers[2].fp));
    }

    #[test]
    fn critical_path_goes_through_lowest_layer() {
        let (g, layers) = ddl_dag(&DdlParams::default());
        let c = cpm(&g);
        assert!(c.is_critical(layers[0].push), "push0 is critical");
        assert!(!c.is_critical(layers[3].push), "push3 has slack");
    }

    /// Fig. 6 headline: layer-priority (MXDAG) beats FIFO tensor order.
    #[test]
    fn mxdag_beats_fifo_transmission_order() {
        let p = DdlParams::default();
        let (g, _) = ddl_dag(&p);
        let cluster = Cluster::with_cores(2, 2.0);
        let fifo = run(&FifoScheduler, &g, &cluster).unwrap().makespan;
        let mx = run(&MxScheduler::without_pipelining(), &g, &cluster)
            .unwrap()
            .makespan;
        assert!(mx < fifo - 1e-9, "mx {mx} must beat fifo {fifo}");
    }

    #[test]
    fn mx_never_loses_across_comm_sweep() {
        let cluster = Cluster::with_cores(2, 2.0);
        for comm in [0.25, 0.5, 1.0, 2.0, 4.0] {
            let (g, _) = ddl_dag(&DdlParams { comm, ..Default::default() });
            let fifo = run(&FifoScheduler, &g, &cluster).unwrap().makespan;
            let mx = run(&MxScheduler::without_pipelining(), &g, &cluster)
                .unwrap()
                .makespan;
            assert!(mx <= fifo + 1e-9, "comm={comm}: mx {mx} vs fifo {fifo}");
        }
    }
}
