//! The concrete scenarios of Figures 1, 2(a), 3 and 7.
//!
//! Sizes are chosen so that every qualitative claim in the paper holds
//! in the fluid model and is *checked by tests/benches*:
//! who wins, in which direction, and where the crossovers sit.

use crate::mxdag::{MXDag, TaskId};

/// Fig. 1: host A sends flow 1 to B (which computes, then sends flow 2
/// to C) and flow 3 directly to C. Fair sharing of A's uplink delays the
/// critical flow 1; co-scheduling prioritises it.
pub fn fig1_dag() -> MXDag {
    let mut b = MXDag::builder();
    let a = b.compute("A", 0, 0.0);
    let f1 = b.flow("f1", 0, 1, 1.0);
    let bt = b.compute("B", 1, 1.0);
    let f2 = b.flow("f2", 1, 2, 1.0);
    let f3 = b.flow("f3", 0, 2, 1.0);
    let c = b.compute("C", 2, 1.0);
    b.chain(&[a, f1, bt, f2, c]);
    b.dep(a, f3).dep(f3, c);
    b.finalize().unwrap()
}

/// Fig. 2(a): symmetric diamond topology with *asymmetric compute times*
/// `t1 != t2`. Returns (dag, [f1, f2, f3, f4]) — the flows the coflow
/// baseline groups as {f1,f2} and {f3,f4}.
pub fn fig2a_dag(t1: f64, t2: f64) -> (MXDag, [TaskId; 4]) {
    let mut b = MXDag::builder();
    let a = b.compute("A", 0, 0.5);
    let f1 = b.flow("f1", 0, 1, 1.0);
    let f2 = b.flow("f2", 0, 2, 1.0);
    let bt = b.compute("B", 1, t1);
    let ct = b.compute("C", 2, t2);
    let f3 = b.flow("f3", 1, 3, 1.0);
    let f4 = b.flow("f4", 2, 3, 1.0);
    let d = b.compute("D", 3, 0.5);
    b.dep(a, f1).dep(a, f2);
    b.dep(f1, bt).dep(f2, ct);
    b.dep(bt, f3).dep(ct, f4);
    b.dep(f3, d).dep(f4, d);
    (b.finalize().unwrap(), [f1, f2, f3, f4])
}

/// Fig. 3: 4-node DAG with critical path A→B→C. D is off the critical
/// path. Flows f1 (A→B), f2 (B→C), f3 (A→C), f4 (D→C).
///
/// Returns (dag, names->ids of [A, f1, B, f2, f3, D, f4, C]).
pub fn fig3_dag() -> (MXDag, [TaskId; 8]) {
    let mut b = MXDag::builder();
    let a = b.compute_full("A", 0, 4.0, 1.0);
    let f1 = b.flow_full("f1", 0, 1, 6.0, 1.5);
    let bt = b.compute("B", 1, 2.0);
    let f2 = b.flow("f2", 1, 2, 2.0);
    let f3 = b.flow_full("f3", 0, 2, 4.0, 1.0);
    let d = b.compute_full("D", 3, 2.0, 0.5);
    let f4 = b.flow_full("f4", 3, 2, 1.0, 0.25);
    let c = b.compute("C", 2, 2.0);
    b.chain(&[a, f1, bt, f2, c]);
    b.dep(a, f3).dep(f3, c);
    b.dep(d, f4).dep(f4, c);
    (b.finalize().unwrap(), [a, f1, bt, f2, f3, d, f4, c])
}

/// Cluster for the Fig. 3 scenario: 4 uniform hosts, but C (host 2) has
/// a wide ingress so the analysis isolates the contention the paper
/// reasons about — A's *uplink* shared by f1 and f3.
pub fn fig3_cluster() -> crate::sim::Cluster {
    let mut c = crate::sim::Cluster::uniform(4);
    c.hosts[2].nic_down = 3.0;
    c
}

/// The four pipelineability choices of Fig. 3(b–e):
/// baseline (no pipeline), case 1 (off-critical D+f4), case 2 (+A,f1 on
/// the critical path), case 3 (+f3, which contends with f1 on A's NIC).
pub fn fig3_pipeline_sets() -> Vec<(&'static str, Vec<&'static str>)> {
    vec![
        ("baseline(no pipeline)", vec![]),
        ("case1(+D,f4 off-critical)", vec!["D", "f4"]),
        ("case2(+A,f1 critical)", vec!["D", "f4", "A", "f1"]),
        ("case3(+f3 contends)", vec!["D", "f4", "A", "f1", "f3"]),
    ]
}

/// Fig. 7: two map-reduce jobs sharing host 1's compute slot (tasks b, d)
/// and host 1's uplink (flows f2, f3).
///
/// Job 1: a(h0,2), b(h1,1), f1:h0→h2(2), f2:h1→h2(1), r1(h2,1).
/// Job 2: d(h1,1), f3:h1→h3(1), r2(h3,1).
pub fn fig7_jobs() -> (MXDag, MXDag) {
    let j1 = {
        let mut b = MXDag::builder();
        let a = b.compute("a", 0, 2.0);
        let bb = b.compute("b", 1, 1.0);
        let f1 = b.flow("f1", 0, 2, 2.0);
        let f2 = b.flow("f2", 1, 2, 1.0);
        let r1 = b.compute("r1", 2, 1.0);
        b.dep(a, f1).dep(bb, f2).dep(f1, r1).dep(f2, r1);
        b.finalize().unwrap()
    };
    let j2 = {
        let mut b = MXDag::builder();
        let d = b.compute("d", 1, 1.0);
        let f3 = b.flow("f3", 1, 3, 1.0);
        let r2 = b.compute("r2", 3, 1.0);
        b.dep(d, f3).dep(f3, r2);
        b.finalize().unwrap()
    };
    (j1, j2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mxdag::cpm;
    use crate::sched::{evaluate, run, FairScheduler, MxScheduler, Plan, Scheduler};
    use crate::sim::{Annotations, Cluster, Policy};

    #[test]
    fn fig1_t2_beats_t1() {
        let g = fig1_dag();
        let cluster = Cluster::uniform(3);
        let t1 = run(&FairScheduler, &g, &cluster).unwrap().makespan;
        let t2 = run(&MxScheduler::without_pipelining(), &g, &cluster)
            .unwrap()
            .makespan;
        assert!(t2 < t1 - 1e-9, "T2 {t2} must beat T1 {t1}");
        assert!((t1 - 5.0).abs() < 1e-9);
        assert!((t2 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn fig2a_asymmetric_compute_times() {
        let (g, _) = fig2a_dag(3.0, 1.0);
        let c = cpm(&g);
        // critical path goes through the long compute B
        assert!(c.is_critical(g.by_name("B").unwrap()));
        assert!(!c.is_critical(g.by_name("C").unwrap()));
    }

    #[test]
    fn fig3_critical_path_is_abc() {
        let (g, _) = fig3_dag();
        let c = cpm(&g);
        for name in ["A", "f1", "B", "f2", "C"] {
            assert!(c.is_critical(g.by_name(name).unwrap()), "{name} critical");
        }
        assert!(!c.is_critical(g.by_name("D").unwrap()));
        assert!(!c.is_critical(g.by_name("f4").unwrap()));
    }

    /// The headline Fig. 3 series under the FIFO runtime:
    /// baseline == case1, case2 < baseline, case3 > baseline.
    #[test]
    fn fig3_cases_ordering() {
        let (g, _) = fig3_dag();
        let cluster = super::fig3_cluster();
        let mut results = Vec::new();
        for (name, pipes) in fig3_pipeline_sets() {
            let pipelined = pipes.iter().map(|n| g.by_name(n).unwrap()).collect();
            let plan = Plan {
                ann: Annotations { pipelined, ..Default::default() },
                policy: Policy::fifo(),
            };
            let r = evaluate(&g, &cluster, &plan).unwrap();
            results.push((name, r.makespan));
        }
        let base = results[0].1;
        let case1 = results[1].1;
        let case2 = results[2].1;
        let case3 = results[3].1;
        assert!((case1 - base).abs() < 1e-9, "case1 {case1} == base {base}");
        assert!(case2 < base - 1e-9, "case2 {case2} < base {base}");
        assert!(case3 > base + 1e-9, "case3 {case3} > base {base}");
    }

    #[test]
    fn fig7_jobs_share_resources() {
        let (j1, j2) = fig7_jobs();
        // b and d on host 1 compute; f2 and f3 on host 1 uplink
        assert!(j1.by_name("b").is_some() && j2.by_name("d").is_some());
        let c1 = cpm(&j1);
        assert!((c1.makespan - 5.0).abs() < 1e-9); // a->f1->r1
        let c2 = cpm(&j2);
        assert!((c2.makespan - 3.0).abs() < 1e-9);
    }

    #[test]
    fn mx_scheduler_handles_fig3() {
        // The full MXDAG scheduler (priority + pipeline search) must be at
        // least as good as the best hand-picked case under FIFO.
        let (g, _) = fig3_dag();
        let cluster = super::fig3_cluster();
        let mx = run(&MxScheduler::default(), &g, &cluster).unwrap();
        let case2 = {
            let pipelined = ["D", "f4", "A", "f1"]
                .iter()
                .map(|n| g.by_name(n).unwrap())
                .collect();
            let plan = Plan {
                ann: Annotations { pipelined, ..Default::default() },
                policy: Policy::fifo(),
            };
            evaluate(&g, &cluster, &plan).unwrap()
        };
        assert!(
            mx.makespan <= case2.makespan + 1e-9,
            "mx {} vs best-fifo-case {}",
            mx.makespan,
            case2.makespan
        );
        let _ = MxScheduler::default().name();
    }
}
