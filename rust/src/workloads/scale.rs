//! Wide-fanout scale workload: the ready-queue hot path.
//!
//! `branches` independent `compute → flow → compute` chains fan out
//! from the implicit `v_S`, so thousands of tasks are ready
//! simultaneously and the engine's per-event scheduling cost — not the
//! DAG structure — dominates. Sources are spread uniformly over the
//! hosts and every flow goes to the next host on a ring (the
//! neighbour-exchange / ring-allreduce pattern), so each uplink
//! saturates together with its paired downlink and every core and NIC
//! stays contended for most of the run — which is what lets the
//! incremental ready queue's saturation early exit stop after
//! `O(resources)` levels instead of walking all `O(tasks)` of them.
//! Used by `benches/sched_scaling.rs` at 1k / 5k / 10k tasks.

use crate::mxdag::MXDag;
use crate::util::rng::Rng;

/// Parameters for [`wide_fanout`].
#[derive(Debug, Clone)]
pub struct FanoutParams {
    /// Number of `compute → flow → compute` chains (3 real tasks each).
    pub branches: usize,
    /// Hosts the endpoints are spread over (≥ 2).
    pub hosts: usize,
    /// Minimum task size.
    pub min_size: f64,
    /// Maximum task size (sizes are uniform in `[min_size, max_size)`;
    /// distinct sizes keep critical-path priorities mostly distinct,
    /// which is the worst case for a sort-based scheduler).
    pub max_size: f64,
    /// PRNG seed (generation is fully deterministic per seed).
    pub seed: u64,
}

impl Default for FanoutParams {
    fn default() -> Self {
        FanoutParams { branches: 64, hosts: 16, min_size: 0.5, max_size: 2.0, seed: 11 }
    }
}

/// Number of branches that yields roughly `tasks` real tasks.
pub fn branches_for_tasks(tasks: usize) -> usize {
    (tasks / 3).max(1)
}

/// Generate the wide-fanout DAG (3 × `branches` real tasks).
pub fn wide_fanout(p: &FanoutParams) -> MXDag {
    assert!(p.hosts >= 2 && p.branches >= 1, "need hosts >= 2 and branches >= 1");
    let mut rng = Rng::new(p.seed);
    let mut b = MXDag::builder();
    for i in 0..p.branches {
        let src = rng.below(p.hosts);
        let dst = (src + 1) % p.hosts; // ring neighbour: up/down saturate in pairs
        let a = b.compute(&format!("a{i}"), src, rng.range_f64(p.min_size, p.max_size));
        let f = b.flow(&format!("f{i}"), src, dst, rng.range_f64(p.min_size, p.max_size));
        let c = b.compute(&format!("c{i}"), dst, rng.range_f64(p.min_size, p.max_size));
        b.dep(a, f);
        b.dep(f, c);
    }
    b.finalize().expect("independent chains cannot form a cycle")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mxdag::TaskKind;
    use crate::sched::{run, FairScheduler, FifoScheduler, MxScheduler};
    use crate::sim::Cluster;

    #[test]
    fn task_count_and_determinism() {
        let p = FanoutParams { branches: 40, ..Default::default() };
        let g1 = wide_fanout(&p);
        let g2 = wide_fanout(&p);
        assert_eq!(g1.real_tasks().count(), 120);
        assert_eq!(g1.len(), g2.len());
        assert_eq!(g1.n_edges(), g2.n_edges());
        assert_eq!(branches_for_tasks(10_000), 3333);
        assert_eq!(branches_for_tasks(1), 1);
    }

    #[test]
    fn flows_connect_distinct_hosts_in_range() {
        let p = FanoutParams { branches: 200, hosts: 7, ..Default::default() };
        let g = wide_fanout(&p);
        for t in g.tasks() {
            if let TaskKind::Flow { src, dst } = t.kind {
                assert_ne!(src, dst);
                assert!(src < 7 && dst < 7);
            }
        }
    }

    #[test]
    fn schedulers_complete_fanout() {
        let p = FanoutParams { branches: 50, hosts: 4, seed: 3, ..Default::default() };
        let g = wide_fanout(&p);
        let cluster = Cluster::uniform(p.hosts);
        for r in [
            run(&FairScheduler, &g, &cluster),
            run(&FifoScheduler, &g, &cluster),
            run(&MxScheduler::without_pipelining(), &g, &cluster),
        ] {
            let r = r.unwrap();
            assert!(r.makespan.is_finite() && r.makespan > 0.0);
        }
    }
}
