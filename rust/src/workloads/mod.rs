//! Workload generators: the paper's figure scenarios (Figs. 1–3, 6, 7),
//! the Wukong DAG of Fig. 2(b), oversubscribed-fabric scenarios, plus
//! general map-reduce / DDL / random / wide-fanout DAG generators used
//! by benches and property tests.

pub mod ddl;
pub mod figs;
pub mod mapreduce;
pub mod oversub;
pub mod random;
pub mod scale;
pub mod wukong;

pub use ddl::{ddl_dag, DdlParams};
pub use figs::{fig1_dag, fig2a_dag, fig3_dag, fig3_pipeline_sets, fig7_jobs};
pub use mapreduce::{mapreduce_dag, MapReduceParams};
pub use oversub::{cross_rack_flows, incast_with_chain, two_rack_cluster};
pub use random::{random_dag, RandomParams};
pub use scale::{branches_for_tasks, wide_fanout, FanoutParams};
pub use wukong::{wukong_dag, WukongCoflows};
