//! The asymmetric-topology DAG of Fig. 2(b), adopted from Wukong, and
//! its three candidate coflow abstractions (b1, b2, b3).
//!
//! Tasks A..F on hosts 0..5; flows
//!   f1: A→B, f2: B→E, f3: C→D, f4: C→E, f5: D→F, f6: E→F.
//! The asymmetry: B→D is absent, and D's compute is heavier, so the
//! C→f3→D→f5→F path is critical. The optimal schedule delays f4 on C's
//! uplink and, as a cascading effect, f5/f6 do not share F's downlink.

use crate::mxdag::{MXDag, TaskId};

/// The three coflow definitions a programmer could commit to (§2.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WukongCoflows {
    /// b1: broadcast from C {f3,f4} + aggregation at F {f5,f6}.
    B1,
    /// b2: aggregation at E {f2,f4}.
    B2,
    /// b3: all flows between {B,C} and {D,E}: {f2,f3,f4}.
    B3,
}

/// Build the Fig. 2(b) DAG. Returns (dag, [f1..f6]).
pub fn wukong_dag() -> (MXDag, [TaskId; 6]) {
    let mut b = MXDag::builder();
    let a = b.compute("A", 0, 1.0);
    let bt = b.compute("B", 1, 1.0);
    let c = b.compute("C", 2, 1.0);
    let d = b.compute("D", 3, 4.0); // heavier: makes the f3 path critical
    let e = b.compute("E", 4, 1.0);
    let f = b.compute("F", 5, 1.0);
    let f1 = b.flow("f1", 0, 1, 1.0);
    let f2 = b.flow("f2", 1, 4, 1.0);
    let f3 = b.flow("f3", 2, 3, 1.0);
    let f4 = b.flow("f4", 2, 4, 1.0);
    let f5 = b.flow("f5", 3, 5, 1.0);
    let f6 = b.flow("f6", 4, 5, 1.0);
    b.dep(a, f1).dep(f1, bt);
    b.dep(bt, f2).dep(f2, e);
    b.dep(c, f3).dep(f3, d);
    b.dep(c, f4).dep(f4, e);
    b.dep(d, f5).dep(f5, f);
    b.dep(e, f6).dep(f6, f);
    (b.finalize().unwrap(), [f1, f2, f3, f4, f5, f6])
}

impl WukongCoflows {
    pub fn groups(&self, flows: &[TaskId; 6]) -> Vec<Vec<TaskId>> {
        let [_, f2, f3, f4, f5, f6] = *flows;
        match self {
            WukongCoflows::B1 => vec![vec![f3, f4], vec![f5, f6]],
            WukongCoflows::B2 => vec![vec![f2, f4]],
            WukongCoflows::B3 => vec![vec![f2, f3, f4]],
        }
    }
    pub fn all() -> [WukongCoflows; 3] {
        [WukongCoflows::B1, WukongCoflows::B2, WukongCoflows::B3]
    }
    pub fn label(&self) -> &'static str {
        match self {
            WukongCoflows::B1 => "coflow-b1{f3,f4}{f5,f6}",
            WukongCoflows::B2 => "coflow-b2{f2,f4}",
            WukongCoflows::B3 => "coflow-b3{f2,f3,f4}",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mxdag::cpm;
    use crate::sched::{run, CoflowScheduler, Grouping, MxScheduler};
    use crate::sim::Cluster;

    #[test]
    fn topology_is_asymmetric() {
        let (g, _) = wukong_dag();
        // B sends only to E; C sends to both D and E — no B→D edge.
        let c = g.by_name("C").unwrap();
        let b = g.by_name("B").unwrap();
        assert_eq!(g.succs(c).len(), 2);
        assert_eq!(g.succs(b).len(), 1);
    }

    #[test]
    fn critical_path_through_d() {
        let (g, _) = wukong_dag();
        let r = cpm(&g);
        assert!(r.is_critical(g.by_name("f3").unwrap()));
        assert!(r.is_critical(g.by_name("D").unwrap()));
        assert!(!r.is_critical(g.by_name("f4").unwrap()));
        assert_eq!(r.makespan, 8.0); // C f3 D f5 F = 1+1+4+1+1
    }

    /// Fig. 2(d): the MXDAG schedule beats *all three* coflow groupings.
    #[test]
    fn mxdag_beats_every_coflow_grouping() {
        let (g, flows) = wukong_dag();
        let cluster = Cluster::uniform(6);
        let mx = run(&MxScheduler::without_pipelining(), &g, &cluster)
            .unwrap()
            .makespan;
        for variant in WukongCoflows::all() {
            let s = CoflowScheduler::new(Grouping::Explicit(variant.groups(&flows)));
            let co = run(&s, &g, &cluster).unwrap().makespan;
            assert!(
                mx < co - 1e-9,
                "mxdag {mx} must beat {} with {co}",
                variant.label()
            );
        }
    }

    #[test]
    fn mxdag_delays_f4_behind_f3() {
        let (g, flows) = wukong_dag();
        let cluster = Cluster::uniform(6);
        let r = run(&MxScheduler::without_pipelining(), &g, &cluster).unwrap();
        let [_, _, f3, f4, ..] = flows;
        // f3 owns C's uplink first; f4 follows
        assert!(r.finish_of(f3) <= r.start_of(f4) + 1e-9);
    }
}
