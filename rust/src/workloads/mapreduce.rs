//! General map-reduce MXDAG generator (maps → shuffle flows → reduces).

use crate::util::rng::Rng;
use crate::mxdag::{MXDag, TaskId};

#[derive(Debug, Clone)]
pub struct MapReduceParams {
    pub mappers: usize,
    pub reducers: usize,
    /// Host of mapper i = `map_hosts[i % len]`; likewise reducers.
    pub map_hosts: Vec<usize>,
    pub red_hosts: Vec<usize>,
    pub map_time: f64,
    pub red_time: f64,
    /// Shuffle bytes (time at full NIC) per mapper→reducer pair.
    pub shuffle: f64,
    /// ± jitter fraction applied to task sizes (heterogeneity, §2.2).
    pub jitter: f64,
    pub seed: u64,
}

impl Default for MapReduceParams {
    fn default() -> Self {
        MapReduceParams {
            mappers: 4,
            reducers: 2,
            map_hosts: vec![0, 1, 2, 3],
            red_hosts: vec![4, 5],
            map_time: 1.0,
            red_time: 1.0,
            shuffle: 0.5,
            jitter: 0.0,
            seed: 1,
        }
    }
}

/// Handles into a generated map-reduce DAG.
#[derive(Debug, Clone)]
pub struct MapReduceDag {
    pub maps: Vec<TaskId>,
    pub reduces: Vec<TaskId>,
    /// `flows[m][r]` = shuffle flow mapper m → reducer r.
    pub flows: Vec<Vec<TaskId>>,
}

pub fn mapreduce_dag(p: &MapReduceParams) -> (MXDag, MapReduceDag) {
    assert!(!p.map_hosts.is_empty() && !p.red_hosts.is_empty());
    let mut rng = Rng::new(p.seed);
    let jit = |base: f64, rng: &mut Rng| {
        if p.jitter > 0.0 {
            base * (1.0 + rng.range_f64(-p.jitter, p.jitter))
        } else {
            base
        }
    };
    let mut b = MXDag::builder();
    let maps: Vec<TaskId> = (0..p.mappers)
        .map(|m| {
            let host = p.map_hosts[m % p.map_hosts.len()];
            let size = jit(p.map_time, &mut rng);
            b.compute(&format!("map{m}"), host, size)
        })
        .collect();
    let reduces: Vec<TaskId> = (0..p.reducers)
        .map(|r| {
            let host = p.red_hosts[r % p.red_hosts.len()];
            let size = jit(p.red_time, &mut rng);
            b.compute(&format!("red{r}"), host, size)
        })
        .collect();
    let mut flows = vec![vec![0; p.reducers]; p.mappers];
    for m in 0..p.mappers {
        let src = p.map_hosts[m % p.map_hosts.len()];
        for r in 0..p.reducers {
            let dst = p.red_hosts[r % p.red_hosts.len()];
            let size = jit(p.shuffle, &mut rng);
            let f = b.flow(&format!("sh{m}_{r}"), src, dst, size);
            b.dep(maps[m], f);
            b.dep(f, reduces[r]);
            flows[m][r] = f;
        }
    }
    (b.finalize().unwrap(), MapReduceDag { maps, reduces, flows })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{run, FairScheduler, MxScheduler};
    use crate::sim::Cluster;

    #[test]
    fn shape_is_bipartite_shuffle() {
        let (g, h) = mapreduce_dag(&MapReduceParams::default());
        assert_eq!(h.maps.len(), 4);
        assert_eq!(h.reduces.len(), 2);
        assert_eq!(g.real_tasks().count(), 4 + 2 + 8);
        // every reduce depends on a flow from every mapper
        for &r in &h.reduces {
            assert_eq!(g.preds(r).len(), 4);
        }
    }

    #[test]
    fn jitter_changes_sizes_deterministically() {
        let p = MapReduceParams { jitter: 0.5, seed: 9, ..Default::default() };
        let (g1, h1) = mapreduce_dag(&p);
        let (g2, _) = mapreduce_dag(&p);
        assert_eq!(g1.task(h1.maps[0]).size, g2.task(h1.maps[0]).size);
        let (g3, h3) = mapreduce_dag(&MapReduceParams { seed: 10, ..p });
        assert_ne!(g1.task(h1.maps[0]).size, g3.task(h3.maps[0]).size);
    }

    #[test]
    fn schedulers_complete_shuffle() {
        let p = MapReduceParams { jitter: 0.3, ..Default::default() };
        let (g, _) = mapreduce_dag(&p);
        let cluster = Cluster::uniform(6);
        let fair = run(&FairScheduler, &g, &cluster).unwrap();
        let mx = run(&MxScheduler::without_pipelining(), &g, &cluster).unwrap();
        assert!(mx.makespan <= fair.makespan + 1e-6);
    }
}
