//! Random layered MXDAG generator — scale/property-test workloads.

use crate::util::rng::Rng;
use crate::mxdag::{MXDag, TaskId};

#[derive(Debug, Clone)]
pub struct RandomParams {
    pub layers: usize,
    pub width: usize,
    pub hosts: usize,
    /// Probability of an edge between adjacent-layer tasks.
    pub edge_p: f64,
    /// Fraction of tasks that are pipelineable (unit = size / 4).
    pub pipe_frac: f64,
    pub min_size: f64,
    pub max_size: f64,
    pub seed: u64,
}

impl Default for RandomParams {
    fn default() -> Self {
        RandomParams {
            layers: 4,
            width: 4,
            hosts: 8,
            edge_p: 0.5,
            pipe_frac: 0.25,
            min_size: 0.5,
            max_size: 2.0,
            seed: 7,
        }
    }
}

/// Generate a layered DAG: alternating compute layers and flow layers.
/// Every flow's endpoints match its adjacent computes' hosts, so the
/// graph is physically realisable.
pub fn random_dag(p: &RandomParams) -> MXDag {
    assert!(p.hosts >= 2 && p.layers >= 1 && p.width >= 1);
    let mut rng = Rng::new(p.seed);
    let mut b = MXDag::builder();
    let mut prev: Vec<(TaskId, usize)> = Vec::new(); // (task, host)

    for layer in 0..p.layers {
        let mut cur: Vec<(TaskId, usize)> = Vec::new();
        for wi in 0..p.width {
            let host = rng.below(p.hosts);
            let size = rng.range_f64(p.min_size, p.max_size);
            let unit = if rng.bool(p.pipe_frac) { size / 4.0 } else { size };
            let t = b.compute_full(&format!("c{layer}_{wi}"), host, size, unit);
            cur.push((t, host));
        }
        if layer > 0 {
            let mut any = vec![false; cur.len()];
            for (pi, &(pt, ph)) in prev.iter().enumerate() {
                for (ci, &(ct, ch)) in cur.iter().enumerate() {
                    if rng.bool(p.edge_p) {
                        any[ci] = true;
                        if ph == ch {
                            b.dep(pt, ct); // same host: no flow needed
                        } else {
                            let size = rng.range_f64(p.min_size, p.max_size);
                            let unit = if rng.bool(p.pipe_frac) { size / 4.0 } else { size };
                            let f = b.flow_full(
                                &format!("f{layer}_{pi}_{ci}"),
                                ph,
                                ch,
                                size,
                                unit,
                            );
                            b.dep(pt, f);
                            b.dep(f, ct);
                        }
                    }
                }
            }
            // keep the graph connected layer-to-layer
            for (ci, &(ct, ch)) in cur.iter().enumerate() {
                if !any[ci] {
                    let &(pt, ph) = rng.choice(&prev);
                    if ph == ch {
                        b.dep(pt, ct);
                    } else {
                        let f = b.flow(&format!("fx{layer}_{ci}"), ph, ch, 1.0);
                        b.dep(pt, f);
                        b.dep(f, ct);
                    }
                }
            }
        }
        prev = cur;
    }
    b.finalize().expect("layered generator cannot create cycles")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{run, FairScheduler, FifoScheduler, MxScheduler, PackingScheduler};
    use crate::sim::Cluster;

    #[test]
    fn deterministic_by_seed() {
        let p = RandomParams::default();
        let g1 = random_dag(&p);
        let g2 = random_dag(&p);
        assert_eq!(g1.len(), g2.len());
        assert_eq!(g1.n_edges(), g2.n_edges());
    }

    #[test]
    fn flows_connect_distinct_hosts() {
        let g = random_dag(&RandomParams { seed: 3, ..Default::default() });
        for t in g.tasks() {
            if let crate::mxdag::TaskKind::Flow { src, dst } = t.kind {
                assert_ne!(src, dst, "flow {} loops", t.name);
            }
        }
    }

    #[test]
    fn all_schedulers_complete_random_dags() {
        for seed in 0..5 {
            let p = RandomParams { seed, ..Default::default() };
            let g = random_dag(&p);
            let cluster = Cluster::uniform(p.hosts);
            for r in [
                run(&FairScheduler, &g, &cluster),
                run(&FifoScheduler, &g, &cluster),
                run(&PackingScheduler, &g, &cluster),
                run(&MxScheduler::without_pipelining(), &g, &cluster),
            ] {
                let r = r.unwrap();
                assert!(r.makespan.is_finite() && r.makespan > 0.0);
            }
        }
    }

    #[test]
    fn scales_to_hundreds_of_tasks() {
        let p = RandomParams { layers: 10, width: 10, hosts: 16, seed: 11, ..Default::default() };
        let g = random_dag(&p);
        assert!(g.real_tasks().count() > 100);
        let r = run(&FairScheduler, &g, &Cluster::uniform(16)).unwrap();
        assert!(r.makespan.is_finite());
    }
}
