//! Oversubscribed-fabric workloads: the scenarios where compute/network
//! co-scheduling diverges most from DAG-only and coflow-only baselines,
//! because the scarce resource is a *shared* aggregation link rather
//! than a private NIC.
//!
//! Pair these DAGs with [`Cluster::oversubscribed`] so that rack
//! boundaries line up: `cross_rack_flows(per_rack, ..)` assumes hosts
//! `0..per_rack` form rack 0 and `per_rack..2*per_rack` rack 1 (the
//! block partition `Topology::Oversubscribed` uses with 2 racks).

use crate::mxdag::{MXDag, TaskId};
use crate::sim::Cluster;

/// `sizes.len()` independent cross-rack flows on distinct host pairs:
/// flow `i` goes `i → per_rack + i` with size `sizes[i]`. All flows are
/// ready at t=0 and share only the two rack aggregation links, which
/// makes fair-share completion provably monotone in the
/// oversubscription ratio (a single effective bottleneck).
pub fn cross_rack_flows(per_rack: usize, sizes: &[f64]) -> MXDag {
    assert!(
        sizes.len() <= per_rack,
        "one flow per host pair: need sizes.len() <= per_rack"
    );
    let mut b = MXDag::builder();
    for (i, &s) in sizes.iter().enumerate() {
        b.flow(&format!("x{i}"), i, per_rack + i, s);
    }
    b.finalize().expect("flows only: acyclic")
}

/// The matching 2-rack cluster for [`cross_rack_flows`].
pub fn two_rack_cluster(per_rack: usize, ratio: f64) -> Cluster {
    Cluster::oversubscribed(2 * per_rack, 2, ratio)
}

/// Incast with a critical chain on a 2-rack / 4-host fabric:
///
/// * chain: `A@0 (0.5) → fc: 0→2 (1.0) → C@2 (3.0)` — the job;
/// * `side_flows` unit background flows `1 → 3`, ready at t=0, which
///   contend with `fc` only on the rack aggregation links.
///
/// On a big switch the chain never waits (disjoint NICs). The more the
/// fabric is oversubscribed, the more a schedule that fair-shares (or
/// coflow-groups) the aggregation link delays the critical flow — while
/// a co-scheduler that prioritizes `fc` keeps the chain's JCT at
/// `0.5 + 1/min(1, cap) + 3.0`. Returns `(dag, id of C, side flow ids)`.
pub fn incast_with_chain(side_flows: usize) -> (MXDag, TaskId, Vec<TaskId>) {
    let mut b = MXDag::builder();
    let a = b.compute("A", 0, 0.5);
    let fc = b.flow("fc", 0, 2, 1.0);
    let c = b.compute("C", 2, 3.0);
    b.chain(&[a, fc, c]);
    let sides: Vec<TaskId> = (0..side_flows)
        .map(|i| b.flow(&format!("s{i}"), 1, 3, 1.0))
        .collect();
    (b.finalize().unwrap(), c, sides)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{run, FairScheduler, MxScheduler};

    #[test]
    fn cross_rack_flows_span_racks() {
        let g = cross_rack_flows(3, &[1.0, 2.0]);
        assert_eq!(g.real_tasks().count(), 2);
        for t in g.tasks() {
            if let crate::mxdag::TaskKind::Flow { src, dst } = t.kind {
                assert!(src < 3 && dst >= 3, "flow {} must cross racks", t.name);
            }
        }
    }

    /// Acceptance-criterion check in miniature: as the fabric gets more
    /// oversubscribed, the co-scheduler's lead over fair sharing on the
    /// chain's JCT grows, because fair sharing splits the scarce
    /// aggregation link among all background flows.
    #[test]
    fn cosched_advantage_grows_with_ratio() {
        let (g, c, _) = incast_with_chain(6);
        let mut prev_gap = f64::NEG_INFINITY;
        for ratio in [1.0, 4.0, 8.0] {
            let cluster = two_rack_cluster(2, ratio);
            let mx = run(&MxScheduler::without_pipelining(), &g, &cluster).unwrap();
            let fair = run(&FairScheduler, &g, &cluster).unwrap();
            let gap = fair.finish_of(c) - mx.finish_of(c);
            assert!(gap >= -1e-9, "ratio {ratio}: mx must not lose, gap {gap}");
            assert!(
                gap >= prev_gap - 1e-9,
                "advantage must widen with ratio: {prev_gap} -> {gap} at {ratio}"
            );
            prev_gap = gap;
        }
        assert!(prev_gap > 1.0, "at 8:1 the gap should be substantial: {prev_gap}");
    }

    /// At heavy oversubscription the exact chain JCTs are analyzable:
    /// agg capacity = 2/ratio; the prioritized critical flow takes
    /// 1/cap, fair sharing takes (sides+1)/cap.
    #[test]
    fn incast_chain_jct_matches_analysis_at_ratio_4() {
        let (g, c, _) = incast_with_chain(6);
        let cluster = two_rack_cluster(2, 4.0); // agg cap 0.5
        let mx = run(&MxScheduler::without_pipelining(), &g, &cluster).unwrap();
        // A 0→0.5, fc at rate 0.5 → 2.5, C → 5.5
        assert!((mx.finish_of(c) - 5.5).abs() < 1e-6, "mx {}", mx.finish_of(c));
        let fair = run(&FairScheduler, &g, &cluster).unwrap();
        assert!(
            fair.finish_of(c) > 12.0,
            "fair share must pay for the whole incast: {}",
            fair.finish_of(c)
        );
    }
}
