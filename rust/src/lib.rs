//! # MXDAG — a hybrid abstraction for cluster applications
//!
//! Reproduction of Wang et al., *"MXDAG: A Hybrid Abstraction for
//! Cluster Applications"* (2021). Compute **and** network tasks are both
//! first-class nodes of a DAG (`MXTask`s with `Size`/`Unit`), enabling
//! explicit co-scheduling of CPU/GPU slots and NIC bandwidth.
//!
//! Layer map (DESIGN.md §2):
//! * [`mxdag`] — the abstraction: graphs, Copaths, Eqs. (1)/(2), CPM;
//! * [`sim`] — fluid cluster substrate with fair/priority/FIFO/coflow
//!   bandwidth sharing and chunk-level pipelining;
//! * [`sched`] — the co-scheduler (Principles 1 & 2) and all baselines;
//! * [`workloads`] — the paper's figure scenarios + generators;
//! * [`whatif`], [`monitor`] — §4.3 usages;
//! * [`runtime`], [`coordinator`] — the real execution path: PJRT-CPU
//!   executes AOT-compiled JAX/Pallas artifacts under MXDAG scheduling;
//! * [`serve`] — crash-safe service mode: a WAL-backed long-lived
//!   multi-tenant coordinator over the open-system driver;
//! * [`util`] — substrates built in-repo (JSON, RNG, CLI, bench, propcheck).

pub mod coordinator;
pub mod monitor;
pub mod mxdag;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod sim;
pub mod util;
pub mod whatif;
pub mod workloads;
