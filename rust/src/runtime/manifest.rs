//! The artifact manifest emitted by `python/compile/aot.py`.
//!
//! Describes every AOT-lowered HLO module: file name, ordered input
//! shapes/dtypes (flattened params first), and output arity. The Rust
//! side is driven entirely by this file — no Python at runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DType {
    F32,
    S32,
}

impl DType {
    fn parse(s: &str) -> Result<DType, String> {
        match s {
            "f32" => Ok(DType::F32),
            "s32" => Ok(DType::S32),
            other => Err(format!("unsupported dtype `{other}`")),
        }
    }
}

/// One input tensor spec.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One AOT artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub n_outputs: usize,
}

/// The model section (layer/param layout of the DDL example).
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub input_dim: usize,
    pub hidden: Vec<usize>,
    pub classes: usize,
    pub batch: usize,
    pub lr: f64,
    pub n_layers: usize,
    pub param_shapes: Vec<Vec<usize>>,
    pub param_count: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelMeta,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    /// Load `manifest.json` from the artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| format!("read manifest: {e}"))?;
        let j = Json::parse(&text).map_err(|e| format!("parse manifest: {e}"))?;
        Self::from_json(&j, dir)
    }

    pub fn from_json(j: &Json, dir: &Path) -> Result<Manifest, String> {
        let e = |x: crate::util::json::JsonError| x.to_string();
        let m = j.get("model").map_err(e)?;
        let model = ModelMeta {
            input_dim: m.get("input_dim").map_err(e)?.as_usize().map_err(e)?,
            hidden: m.get("hidden").map_err(e)?.usize_vec().map_err(e)?,
            classes: m.get("classes").map_err(e)?.as_usize().map_err(e)?,
            batch: m.get("batch").map_err(e)?.as_usize().map_err(e)?,
            lr: m.get("lr").map_err(e)?.as_f64().map_err(e)?,
            n_layers: m.get("n_layers").map_err(e)?.as_usize().map_err(e)?,
            param_shapes: m
                .get("param_shapes")
                .map_err(e)?
                .as_arr()
                .map_err(e)?
                .iter()
                .map(|s| s.usize_vec().map_err(e))
                .collect::<Result<_, _>>()?,
            param_count: m.get("param_count").map_err(e)?.as_usize().map_err(e)?,
        };
        let mut artifacts = BTreeMap::new();
        for (name, a) in j.get("artifacts").map_err(e)?.as_obj().map_err(e)? {
            let inputs = a
                .get("inputs")
                .map_err(e)?
                .as_arr()
                .map_err(e)?
                .iter()
                .map(|i| {
                    Ok(TensorSpec {
                        shape: i.get("shape").map_err(e)?.usize_vec().map_err(e)?,
                        dtype: DType::parse(i.get("dtype").map_err(e)?.as_str().map_err(e)?)?,
                    })
                })
                .collect::<Result<Vec<_>, String>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    file: dir.join(a.get("file").map_err(e)?.as_str().map_err(e)?),
                    inputs,
                    n_outputs: a.get("n_outputs").map_err(e)?.as_usize().map_err(e)?,
                },
            );
        }
        Ok(Manifest { model, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta, String> {
        self.artifacts
            .get(name)
            .ok_or_else(|| format!("unknown artifact `{name}`"))
    }

    /// Bytes moved for layer `i`'s parameters (push or pull) — drives the
    /// network MXTask sizes of the DDL coordinator.
    pub fn layer_param_bytes(&self, layer: usize) -> usize {
        // params are [w0, b0, w1, b1, ...]; each f32 = 4 bytes
        let w = &self.model.param_shapes[2 * layer];
        let b = &self.model.param_shapes[2 * layer + 1];
        4 * (w.iter().product::<usize>() + b.iter().product::<usize>())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Json {
        Json::parse(
            r#"{
              "model": {"input_dim": 16, "hidden": [8], "classes": 4,
                        "batch": 4, "lr": 0.1, "n_layers": 2,
                        "param_shapes": [[16,8],[8],[8,4],[4]],
                        "param_count": 172},
              "artifacts": {
                "forward": {"file": "forward.hlo.txt",
                  "inputs": [{"shape":[16,8],"dtype":"f32"},
                             {"shape":[8],"dtype":"f32"},
                             {"shape":[8,4],"dtype":"f32"},
                             {"shape":[4],"dtype":"f32"},
                             {"shape":[4,16],"dtype":"f32"}],
                  "n_outputs": 1}
              }
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_model_and_artifacts() {
        let m = Manifest::from_json(&sample(), Path::new("/tmp/a")).unwrap();
        assert_eq!(m.model.param_count, 172);
        assert_eq!(m.model.param_shapes.len(), 4);
        let f = m.artifact("forward").unwrap();
        assert_eq!(f.inputs.len(), 5);
        assert_eq!(f.inputs[0].elements(), 128);
        assert_eq!(f.n_outputs, 1);
        assert_eq!(f.file, Path::new("/tmp/a/forward.hlo.txt"));
    }

    #[test]
    fn layer_bytes() {
        let m = Manifest::from_json(&sample(), Path::new("/tmp")).unwrap();
        assert_eq!(m.layer_param_bytes(0), 4 * (16 * 8 + 8));
        assert_eq!(m.layer_param_bytes(1), 4 * (8 * 4 + 4));
    }

    #[test]
    fn unknown_artifact_errors() {
        let m = Manifest::from_json(&sample(), Path::new("/tmp")).unwrap();
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn bad_dtype_rejected() {
        let j = Json::parse(
            r#"{"model": {"input_dim":1,"hidden":[],"classes":1,"batch":1,
                "lr":0.1,"n_layers":1,"param_shapes":[[1]],"param_count":1},
               "artifacts": {"x": {"file":"x","inputs":[{"shape":[1],"dtype":"c64"}],"n_outputs":1}}}"#,
        )
        .unwrap();
        assert!(Manifest::from_json(&j, Path::new("/")).is_err());
    }
}
