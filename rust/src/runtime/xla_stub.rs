//! Compile-time stand-in for the vendored `xla` (PJRT) bindings.
//!
//! Built when the `pjrt` cargo feature is **off** (the default): it
//! mirrors exactly the API surface `runtime::engine`/`runtime::tensor`
//! use, and every fallible entry point returns [`Unavailable`]. The
//! effect is that `Engine::load` fails cleanly, so every
//! artifact-dependent test and bench skips with a notice instead of the
//! whole tree failing to build on machines without the PJRT toolchain.

use std::fmt;

/// Error returned by every stubbed PJRT entry point.
#[derive(Debug, Clone, Copy)]
pub struct Unavailable;

impl fmt::Display for Unavailable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(
            "PJRT backend unavailable (this binary was built without the `pjrt` \
             feature; enable it and the vendored `xla` crate to execute artifacts)",
        )
    }
}

impl std::error::Error for Unavailable {}

pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Unavailable> {
        Err(Unavailable)
    }
    pub fn array_shape(&self) -> Result<ArrayShape, Unavailable> {
        Err(Unavailable)
    }
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Unavailable> {
        Err(Unavailable)
    }
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Unavailable> {
        Err(Unavailable)
    }
    pub fn to_literal_sync(&self) -> Result<Literal, Unavailable> {
        Err(Unavailable)
    }
}

pub struct ArrayShape;

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &[]
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Unavailable> {
        Err(Unavailable)
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Unavailable> {
        Err(Unavailable)
    }
    pub fn platform_name(&self) -> String {
        "pjrt-unavailable".to_string()
    }
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Unavailable> {
        Err(Unavailable)
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<Literal>>, Unavailable> {
        Err(Unavailable)
    }
}
