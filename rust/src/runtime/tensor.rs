//! Host-side tensors: the plain-`Vec<f32>` values the coordinator moves
//! between workers, converted to/from PJRT `Literal`s at execute time.

use crate::util::error::Result;

#[cfg(not(feature = "pjrt"))]
use super::xla_stub as xla;

use super::manifest::{DType, TensorSpec};

/// A host tensor (f32 or i32), shape-carrying.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    S32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product::<usize>().max(1);
        Tensor::F32 { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        Tensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn s32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        Tensor::S32 { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::S32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::S32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes occupied (both dtypes are 4-byte).
    pub fn bytes(&self) -> usize {
        4 * self.len()
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            Tensor::F32 { data, .. } => data,
            _ => panic!("not an f32 tensor"),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match self {
            Tensor::F32 { data, .. } => data,
            _ => panic!("not an f32 tensor"),
        }
    }

    pub fn scalar_f32(&self) -> f32 {
        assert_eq!(self.len(), 1, "not a scalar");
        self.as_f32()[0]
    }

    pub fn matches(&self, spec: &TensorSpec) -> bool {
        let dt_ok = matches!(
            (self, &spec.dtype),
            (Tensor::F32 { .. }, DType::F32) | (Tensor::S32 { .. }, DType::S32)
        );
        dt_ok && self.shape() == spec.shape.as_slice()
    }

    /// In-place `self -= lr * other` (the coordinator-side SGD update).
    pub fn axpy_neg(&mut self, lr: f32, other: &Tensor) {
        let a = self.as_f32_mut();
        let b = other.as_f32();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter_mut().zip(b) {
            *x -= lr * *y;
        }
    }

    /// In-place `self += other` (gradient accumulation).
    pub fn add_assign(&mut self, other: &Tensor) {
        let a = self.as_f32_mut();
        let b = other.as_f32();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter_mut().zip(b) {
            *x += *y;
        }
    }

    /// In-place scale.
    pub fn scale(&mut self, s: f32) {
        for x in self.as_f32_mut() {
            *x *= s;
        }
    }
}

/// Convert to an XLA literal.
pub fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    let lit = match t {
        Tensor::F32 { data, .. } => xla::Literal::vec1(data.as_slice()).reshape(&dims)?,
        Tensor::S32 { data, .. } => xla::Literal::vec1(data.as_slice()).reshape(&dims)?,
    };
    Ok(lit)
}

/// Convert back from an XLA literal (f32 only — all our outputs are f32).
pub fn from_literal_f32(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Ok(Tensor::f32(&dims, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = Tensor::f32(&[2, 3], vec![1.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.bytes(), 24);
        let z = Tensor::zeros(&[4]);
        assert_eq!(z.as_f32(), &[0.0; 4]);
    }

    #[test]
    #[should_panic]
    fn shape_data_mismatch_panics() {
        Tensor::f32(&[2, 2], vec![0.0; 3]);
    }

    #[test]
    fn sgd_update() {
        let mut p = Tensor::f32(&[3], vec![1.0, 2.0, 3.0]);
        let g = Tensor::f32(&[3], vec![1.0, 1.0, 1.0]);
        p.axpy_neg(0.5, &g);
        assert_eq!(p.as_f32(), &[0.5, 1.5, 2.5]);
    }

    #[test]
    fn accumulate_and_scale() {
        let mut a = Tensor::f32(&[2], vec![1.0, 2.0]);
        a.add_assign(&Tensor::f32(&[2], vec![3.0, 4.0]));
        a.scale(0.5);
        assert_eq!(a.as_f32(), &[2.0, 3.0]);
    }

    #[test]
    fn spec_matching() {
        use crate::runtime::manifest::{DType, TensorSpec};
        let t = Tensor::f32(&[2, 3], vec![0.0; 6]);
        assert!(t.matches(&TensorSpec { shape: vec![2, 3], dtype: DType::F32 }));
        assert!(!t.matches(&TensorSpec { shape: vec![3, 2], dtype: DType::F32 }));
        assert!(!t.matches(&TensorSpec { shape: vec![2, 3], dtype: DType::S32 }));
        let y = Tensor::s32(&[2], vec![0, 1]);
        assert!(y.matches(&TensorSpec { shape: vec![2], dtype: DType::S32 }));
    }

    #[test]
    fn scalar_accessor() {
        assert_eq!(Tensor::f32(&[], vec![7.5]).scalar_f32(), 7.5);
    }
}
