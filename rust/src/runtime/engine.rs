//! The PJRT execution engine: loads `artifacts/*.hlo.txt` (HLO **text**
//! — see DESIGN.md §2 for why not serialized protos), compiles each once
//! on the CPU PJRT client, and executes them from the coordinator's hot
//! path. Python never runs here.

use std::path::Path;
use std::collections::BTreeMap;

use crate::util::error::{anyhow, Context, Result};

#[cfg(not(feature = "pjrt"))]
use super::xla_stub as xla;

use super::manifest::Manifest;
use super::tensor::{from_literal_f32, to_literal, Tensor};

/// A loaded artifact set, ready to execute.
pub struct Engine {
    client: xla::PjRtClient,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    pub manifest: Manifest,
}

impl Engine {
    /// Load + compile every artifact in `dir` on the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu()?;
        let mut executables = BTreeMap::new();
        for (name, meta) in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(
                meta.file
                    .to_str()
                    .ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text for `{name}`"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling `{name}`"))?;
            executables.insert(name.clone(), exe);
        }
        Ok(Engine { client, executables, manifest })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }

    /// Execute artifact `name` with shape-checked inputs; returns the
    /// untupled outputs as host tensors.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let meta = self.manifest.artifact(name).map_err(|e| anyhow!(e))?;
        if inputs.len() != meta.inputs.len() {
            return Err(anyhow!(
                "`{name}` wants {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            ));
        }
        for (i, (t, spec)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if !t.matches(spec) {
                return Err(anyhow!(
                    "`{name}` input {i}: shape/dtype mismatch (got {:?}, want {:?})",
                    t.shape(),
                    spec.shape
                ));
            }
        }
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("artifact `{name}` not loaded"))?;
        let lits: Vec<xla::Literal> =
            inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        // lowered with return_tuple=True: always a tuple
        let parts = result.to_tuple()?;
        if parts.len() != meta.n_outputs {
            return Err(anyhow!(
                "`{name}` returned {} outputs, manifest says {}",
                parts.len(),
                meta.n_outputs
            ));
        }
        parts.iter().map(from_literal_f32).collect()
    }
}

#[cfg(test)]
mod tests {
    //! Unit tests needing real artifacts live in
    //! `rust/tests/integration_runtime.rs`; here we only check error paths
    //! that don't require a compiled artifact.

    use super::*;

    #[test]
    fn load_missing_dir_fails() {
        assert!(Engine::load(Path::new("/nonexistent-artifacts")).is_err());
    }
}
