//! Runtime layer: PJRT-CPU execution of the AOT-compiled JAX/Pallas
//! artifacts. `Engine::load` parses HLO text, compiles once, and the
//! coordinator calls `Engine::execute` on its hot path — Python is
//! compile-time only.

pub mod engine;
pub mod manifest;
pub mod tensor;

/// Stand-in for the `xla` crate when the `pjrt` feature is off: the
/// same API surface, every entry point failing with a clear message.
#[cfg(not(feature = "pjrt"))]
pub(crate) mod xla_stub;

pub use engine::Engine;
pub use manifest::{ArtifactMeta, DType, Manifest, ModelMeta, TensorSpec};
pub use tensor::{from_literal_f32, to_literal, Tensor};
