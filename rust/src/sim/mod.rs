//! Cluster substrate: a fluid (rate-based) discrete-event simulator of
//! hosts, full-duplex NICs and a pluggable network topology (big switch,
//! oversubscribed leaf/spine, parallel fabrics), with pluggable sharing
//! policies served from an incremental ready-queue (`ready`), a
//! component-wise rate allocator with memoized rates (`components`,
//! `alloc`), and anchored time advance over a finish-time heap
//! (`horizon`), plus mid-simulation cluster dynamics — fabric churn,
//! stragglers, reroute — folded into the event loop (`dynamics`), and a
//! fault-recovery layer — task retry with exponential backoff, per-job
//! quarantine and outcome reporting (`recovery`) — and an open-system
//! streaming driver chaining closed runs era by era with admission
//! control, overload shedding and bounded-memory epoch GC
//! (`openloop`). This is
//! the testbed every scheduler in `sched/` is evaluated on (DESIGN.md §5
//! records why a fluid model preserves the paper's comparisons;
//! `docs/ARCHITECTURE.md` documents the engine ↔ scheduler contract).

pub mod alloc;
pub mod components;
pub mod dynamics;
pub mod engine;
pub mod expand;
pub mod horizon;
pub mod openloop;
pub mod ready;
pub mod recovery;
pub mod spec;
pub mod topology;

pub use alloc::{AllocScratch, TaskRes};
pub use components::{AllocKind, CompSet};
pub use dynamics::{DynAction, DynEvent, DynState, DynTimeline, LinkRef};
pub use engine::{
    simulate, simulate_in, simulate_with_footprints, QueueKind, SimConfig, SimError, SimResult,
    SimScratch, StopState, StuckReason, TaskTrace,
};
pub use horizon::{within_tolerance, FinHeap, HorizonKind, TOLERANCE_REL};
pub use expand::{apply_annotations, expand, Annotations};
pub use openloop::{
    concat_jobs, poisson_arrivals, run_open, run_open_in, OpenConfig, OpenCounters, OpenJob,
    OpenJobResult, OpenLoop, OpenResult, OpenSpec,
};
pub use recovery::{retry_backoff, JobOutcome, RecoveryPolicy};
pub use ready::{BucketQueue, Keying, PrioKey, QueueDiscipline, ReadyQueue, ResortQueue};
pub use spec::{Cluster, CpuPolicy, Host, NetPolicy, Policy, SimDag, SimKind, SimTask};
pub use topology::{PathSelect, Topology};
