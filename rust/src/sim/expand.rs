//! Pipeline expansion: logical MXDAG → physical SimDag.
//!
//! A task selected for pipelining with `Size S`, `Unit U` becomes
//! `K = ⌈S/U⌉` chunk tasks of size `S/K` chained in order. Along an edge
//! u→v where *both* ends are pipelined, chunk `j` of `v` depends on the
//! chunk of `u` that produces data fraction `(j+1)/K_v` — so the
//! downstream task starts as soon as the first unit is available
//! (Fig. 5). For any non-pipelined end the edge binds to the whole task
//! (last chunk of `u` → first chunk of `v`).

use std::collections::BTreeMap;

use super::spec::{SimDag, SimKind, SimTask};
use crate::mxdag::{MXDag, TaskId, TaskKind};

/// Scheduling annotations applied during expansion.
#[derive(Debug, Clone, Default)]
pub struct Annotations {
    /// Per logical task: priority (higher = first). Missing = 0.
    pub priorities: BTreeMap<TaskId, i64>,
    /// Per logical task: earliest start gate. Missing = 0.
    pub gates: BTreeMap<TaskId, f64>,
    /// Logical tasks to execute in pipeline (chunk-expanded).
    pub pipelined: Vec<TaskId>,
    /// Coflow groups over logical *flow* tasks (must not be pipelined).
    pub coflows: Vec<Vec<TaskId>>,
    /// Owning job per logical task — the quarantine / per-job-outcome
    /// unit of the fault-recovery layer (`sim/recovery.rs`). Missing =
    /// job 0; empty map = single-job DAG (`SimDag::job_of` stays
    /// empty).
    pub jobs: BTreeMap<TaskId, usize>,
}

fn kind_of(dag: &MXDag, t: TaskId) -> SimKind {
    match dag.task(t).kind {
        TaskKind::Start | TaskKind::End => SimKind::Dummy,
        TaskKind::Compute { host } => SimKind::Compute { host },
        TaskKind::Flow { src, dst } => SimKind::Flow { src, dst },
    }
}

/// Apply the *per-task* annotation fields — priority, start gate,
/// coflow tag — to an already-expanded `SimDag`, in place. These fields
/// are plain value rewrites: the chunk structure depends solely on the
/// pipelined set, so [`expand`] calls this once on a fresh expansion
/// and [`crate::sched::EvalContext`] re-calls it on a *cached*
/// expansion when scoring another plan with the same pipelined set —
/// one definition of the field semantics for both paths. Gates bind to
/// a task's first chunk only (later chunks are released by the chunk
/// chain); priorities and coflow tags cover every chunk.
pub fn apply_annotations(sim: &mut SimDag, ann: &Annotations) {
    let mut coflow_of: BTreeMap<TaskId, usize> = BTreeMap::new();
    for (g, members) in ann.coflows.iter().enumerate() {
        for &m in members {
            coflow_of.insert(m, g);
        }
    }
    for task in sim.tasks.iter_mut() {
        task.priority = ann.priorities.get(&task.orig).copied().unwrap_or(0);
        task.gate = if task.chunk.0 == 0 {
            ann.gates.get(&task.orig).copied().unwrap_or(0.0)
        } else {
            0.0
        };
        task.coflow = coflow_of.get(&task.orig).copied();
    }
    // the job map is another value rewrite keyed by `orig`, so cached
    // expansions pick up job ownership the same way; no map keeps the
    // cheap single-job default (an empty `job_of`)
    let mut job_of = std::mem::take(&mut sim.job_of);
    job_of.clear();
    if !ann.jobs.is_empty() {
        job_of.extend(sim.tasks.iter().map(|t| ann.jobs.get(&t.orig).copied().unwrap_or(0)));
    }
    sim.job_of = job_of;
}

/// Expand `dag` into a physical SimDag under `ann`.
pub fn expand(dag: &MXDag, ann: &Annotations) -> SimDag {
    let n = dag.len();
    let piped: Vec<bool> = {
        let mut v = vec![false; n];
        for &t in &ann.pipelined {
            if dag.task(t).pipelineable() {
                v[t] = true;
            }
        }
        v
    };
    #[cfg(debug_assertions)]
    for members in ann.coflows.iter() {
        for &m in members {
            debug_assert!(
                !piped[m],
                "coflow semantics are defined on unpipelined flows"
            );
        }
    }

    let mut out = SimDag::default();
    // logical task -> its chunk ids (in order)
    let mut chunks: Vec<Vec<usize>> = vec![Vec::new(); n];

    // Create chunks in *task-id* (insertion) order — not topo order — so
    // that FIFO tie-breaking between same-instant-ready tasks follows the
    // order the application issued them (the NIC send-queue semantics the
    // Fig. 3 baseline assumes). Per-task annotation fields are applied
    // by `apply_annotations` below.
    for t in 0..n {
        let task = dag.task(t);
        let k = if piped[t] { task.chunks() } else { 1 };
        let chunk_size = if k == 0 { 0.0 } else { task.size / k as f64 };
        for j in 0..k {
            let id = out.push(SimTask {
                orig: t,
                chunk: (j, k),
                kind: kind_of(dag, t),
                size: chunk_size,
                priority: 0,
                gate: 0.0,
                coflow: None,
            });
            chunks[t].push(id);
            if j > 0 {
                let prev = chunks[t][j - 1];
                out.dep(prev, id);
            }
        }
    }
    apply_annotations(&mut out, ann);

    // cross edges
    for u in 0..n {
        for &v in dag.succs(u) {
            let ku = chunks[u].len();
            let kv = chunks[v].len();
            if piped[u] && piped[v] && ku > 1 && kv > 1 {
                // chunk j of v needs input fraction (j+1)/kv from u
                for j in 0..kv {
                    let frac = (j + 1) as f64 / kv as f64;
                    let need = ((ku as f64 * frac).ceil() as usize).clamp(1, ku) - 1;
                    out.dep(chunks[u][need], chunks[v][j]);
                }
            } else {
                // whole-task dependency
                out.dep(*chunks[u].last().unwrap(), chunks[v][0]);
            }
        }
    }
    out
}

/// Chunk ids of a logical task inside the expanded DAG (test helper).
pub fn chunk_ids(sim: &SimDag, orig: TaskId) -> Vec<usize> {
    sim.tasks
        .iter()
        .enumerate()
        .filter(|(_, t)| t.orig == orig)
        .map(|(i, _)| i)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::{simulate, SimConfig};
    use crate::sim::spec::Cluster;
    use crate::mxdag::path;

    /// Two pipelineable tasks in a chain (Fig. 5 setup).
    fn two_stage(s1: f64, u1: f64, s2: f64, u2: f64) -> (MXDag, TaskId, TaskId) {
        let mut b = MXDag::builder();
        let a = b.compute_full("a", 0, s1, u1);
        let f = b.flow_full("f", 0, 1, s2, u2);
        b.dep(a, f);
        (b.finalize().unwrap(), a, f)
    }

    #[test]
    fn no_pipeline_single_chunks() {
        let (g, a, f) = two_stage(4.0, 1.0, 4.0, 1.0);
        let sim = expand(&g, &Annotations::default());
        assert_eq!(chunk_ids(&sim, a).len(), 1);
        assert_eq!(chunk_ids(&sim, f).len(), 1);
        let r = simulate(&sim, &Cluster::uniform(2), &SimConfig::default()).unwrap();
        assert!((r.makespan - 8.0).abs() < 1e-9);
    }

    #[test]
    fn pipeline_matches_eq2_equal_units() {
        let (g, a, f) = two_stage(4.0, 1.0, 4.0, 1.0);
        let ann = Annotations { pipelined: vec![a, f], ..Default::default() };
        let sim = expand(&g, &ann);
        assert_eq!(chunk_ids(&sim, a).len(), 4);
        let r = simulate(&sim, &Cluster::uniform(2), &SimConfig::default()).unwrap();
        // Eq2: (1+1) + max(4,4) - max(1,1) = 5
        let eq2 = path::len_pipe(&g, &[a, f], &path::full_rsrc);
        assert!((r.makespan - eq2).abs() < 1e-9, "sim {} vs eq2 {}", r.makespan, eq2);
    }

    #[test]
    fn pipeline_dominated_by_slow_stage() {
        // slow producer: consumer waits per chunk
        let (g, a, f) = two_stage(8.0, 2.0, 4.0, 1.0);
        let ann = Annotations { pipelined: vec![a, f], ..Default::default() };
        let sim = expand(&g, &ann);
        let r = simulate(&sim, &Cluster::uniform(2), &SimConfig::default()).unwrap();
        // Eq2: (2+1) + 8 - 2 = 9
        let eq2 = path::len_pipe(&g, &[a, f], &path::full_rsrc);
        assert!((r.makespan - eq2).abs() < 1e-9, "sim {} vs eq2 {}", r.makespan, eq2);
    }

    #[test]
    fn one_sided_pipeline_binds_whole_task() {
        let (g, a, f) = two_stage(4.0, 1.0, 4.0, 4.0); // f not pipelineable
        let ann = Annotations { pipelined: vec![a, f], ..Default::default() };
        let sim = expand(&g, &ann);
        assert_eq!(chunk_ids(&sim, f).len(), 1);
        let r = simulate(&sim, &Cluster::uniform(2), &SimConfig::default()).unwrap();
        assert!((r.makespan - 8.0).abs() < 1e-9); // no overlap possible
    }

    #[test]
    fn annotations_propagate() {
        let (g, a, f) = two_stage(4.0, 1.0, 4.0, 1.0);
        let mut ann = Annotations::default();
        ann.priorities.insert(f, 7);
        ann.gates.insert(a, 2.0);
        let sim = expand(&g, &ann);
        for id in chunk_ids(&sim, f) {
            assert_eq!(sim.tasks[id].priority, 7);
        }
        let a0 = chunk_ids(&sim, a)[0];
        assert_eq!(sim.tasks[a0].gate, 2.0);
        let r = simulate(&sim, &Cluster::uniform(2), &SimConfig::default()).unwrap();
        assert!(r.start_of(a) >= 2.0 - 1e-9);
    }

    /// The cached-expansion path: re-applying different field
    /// annotations to an existing expansion must equal a fresh
    /// expansion with those annotations (same structure, new fields).
    #[test]
    fn apply_annotations_rewrites_cached_structure() {
        let (g, a, f) = two_stage(4.0, 1.0, 4.0, 1.0);
        let ann1 = Annotations { pipelined: vec![a, f], ..Default::default() };
        let mut sim = expand(&g, &ann1);
        let mut ann2 = ann1.clone();
        ann2.priorities.insert(f, 7);
        ann2.gates.insert(a, 2.0);
        apply_annotations(&mut sim, &ann2);
        let fresh = expand(&g, &ann2);
        assert_eq!(sim.len(), fresh.len());
        for (x, y) in sim.tasks.iter().zip(fresh.tasks.iter()) {
            assert_eq!(x.priority, y.priority);
            assert_eq!(x.gate.to_bits(), y.gate.to_bits());
            assert_eq!(x.coflow, y.coflow);
        }
    }

    #[test]
    fn job_map_propagates_to_every_chunk() {
        let (g, a, f) = two_stage(4.0, 1.0, 4.0, 1.0);
        let mut ann = Annotations { pipelined: vec![a, f], ..Default::default() };
        // no jobs annotated: the cheap single-job default
        let sim = expand(&g, &ann);
        assert!(sim.job_of.is_empty());
        assert_eq!(sim.n_jobs(), 1);
        // annotated: every chunk inherits its logical task's job, and
        // re-applying to a cached expansion matches a fresh one
        ann.jobs.insert(f, 1);
        let fresh = expand(&g, &ann);
        assert_eq!(fresh.job_of.len(), fresh.len());
        assert_eq!(fresh.n_jobs(), 2);
        for id in chunk_ids(&fresh, f) {
            assert_eq!(fresh.job(id), 1);
        }
        for id in chunk_ids(&fresh, a) {
            assert_eq!(fresh.job(id), 0);
        }
        let mut cached = sim;
        apply_annotations(&mut cached, &ann);
        assert_eq!(cached.job_of, fresh.job_of);
    }

    #[test]
    fn coflow_group_ids_assigned() {
        let mut b = MXDag::builder();
        let f1 = b.flow("f1", 0, 1, 1.0);
        let f2 = b.flow("f2", 0, 2, 1.0);
        let g = {
            let _ = (f1, f2);
            b.finalize().unwrap()
        };
        let ann = Annotations { coflows: vec![vec![f1, f2]], ..Default::default() };
        let sim = expand(&g, &ann);
        assert_eq!(sim.tasks[chunk_ids(&sim, f1)[0]].coflow, Some(0));
        assert_eq!(sim.tasks[chunk_ids(&sim, f2)[0]].coflow, Some(0));
    }

    #[test]
    fn mismatched_chunk_counts_align_by_fraction() {
        // ku=2, kv=4: v chunks 0,1 need u chunk 0; v chunks 2,3 need u chunk 1
        let (g, a, f) = two_stage(4.0, 2.0, 4.0, 1.0);
        let ann = Annotations { pipelined: vec![a, f], ..Default::default() };
        let sim = expand(&g, &ann);
        let ua = chunk_ids(&sim, a);
        let uf = chunk_ids(&sim, f);
        assert_eq!(ua.len(), 2);
        assert_eq!(uf.len(), 4);
        assert!(sim.preds[uf[0]].contains(&ua[0]));
        assert!(sim.preds[uf[1]].contains(&ua[0]));
        assert!(sim.preds[uf[2]].contains(&ua[1]));
        assert!(sim.preds[uf[3]].contains(&ua[1]));
    }

    #[test]
    fn expansion_preserves_logical_semantics() {
        // whatever we pipeline, a topological execution completes
        let (g, a, f) = two_stage(6.0, 1.5, 3.0, 1.0);
        for pipe in [vec![], vec![a], vec![f], vec![a, f]] {
            let ann = Annotations { pipelined: pipe, ..Default::default() };
            let sim = expand(&g, &ann);
            let r = simulate(&sim, &Cluster::uniform(2), &SimConfig::default()).unwrap();
            assert!(r.makespan > 0.0);
            // pipelining never violates: f cannot finish before a's first chunk
            assert!(r.finish_of(f) >= r.start_of(a));
        }
    }
}
