//! Contention components: an incremental partition of the *queued*
//! tasks into connected components of the resource-sharing graph.
//!
//! Tasks only interact through shared resources (the structure MXDAG
//! itself exposes: a task's footprint is a handful of arena slots), so
//! the rates of tasks in disjoint components cannot change when an
//! event touches another component. The engine exploits this via
//! [`AllocKind::Components`]: it re-runs the fluid fill only for
//! components an event *touched* — task arrival, completion, gate
//! expiry, or an SEBF key going stale — while clean components keep
//! their memoized rates. An event in one rack no longer reprices flows
//! in another.
//!
//! ## How the partition is maintained
//!
//! * **Insert** (a task enters the ready queue): the task's resources
//!   are looked up in the resource→component map; every distinct owning
//!   component is merged into the most populous one (union by size),
//!   the task joins it, and the result is marked dirty.
//! * **Remove** (completion): the task leaves its component's member
//!   list and the component is marked dirty. The component is *not*
//!   split eagerly — decremental connectivity is expensive — it is
//!   rebuilt lazily.
//! * **Rebuild** (at refill time, engine-driven): a dirty component
//!   re-derives exact connectivity among its remaining members with a
//!   scratch union-find, retires its slot, and emerges as one fresh
//!   component per connectivity class. Splits therefore cost
//!   `O(component)` exactly when the component is being refilled anyway.
//!
//! Between a merge/removal and the next rebuild the partition may be
//! *coarser* than true connectivity (stale resource claims can glue
//! unrelated tasks together for one event). That is deliberately safe:
//! the fills themselves re-decompose their inputs exactly
//! ([`alloc::maxmin_fill_res_in`](super::alloc::maxmin_fill_res_in)),
//! so a coarse component only means slightly more refill work — never a
//! different allocation. Coflow groups are kept atomic by linking all
//! members of group `g` through a *virtual* resource (arena id
//! `n_res + g`), because MADD couples their rates even when their flows
//! share no physical link.
//!
//! Component slots are a slab with generation counters: the
//! resource→component map stores `(slot, gen)` claims, so retiring a
//! slot invalidates every claim to it in O(1) and slots can be reused
//! without scanning the arena.
//!
//! ## Dirty ⇒ re-anchor (anchored time advance)
//!
//! Under [`HorizonKind::Anchored`](super::horizon::HorizonKind) the
//! dirty worklist carries a second duty: it is the *only* trigger for
//! materializing remaining bytes. When the engine pops a dirty
//! component it first re-anchors every member at `now`
//! (`rem = rem_anchor − rate · (now − anchor)`), removes their stale
//! finish-time heap entries, refreshes SEBF keys from the re-anchored
//! bytes, and only then rebuilds and refills. A clean component is
//! never iterated per event — its memoized rates are immutable between
//! the events that touch it (the invariant above), so its members'
//! anchors and heap entries stay valid by construction. The dirty
//! rules therefore double as the anchor-consistency rules: anything
//! that can change a member's rate (arrival, completion, gate expiry,
//! SEBF drift at refill) marks the component dirty *before* the next
//! refill reads its bytes.
//!
//! ## Disjointness ⇒ shard ownership (parallel event loop)
//!
//! The rebuild contract is also what makes the engine's parallel
//! refill sound: the fresh components a drain emits are pairwise
//! disjoint in **both members and resources** (each is one exact
//! connectivity class over the drained members, and a resource claim
//! names at most one live component). `SimConfig.threads > 1` fans
//! the refills of those fresh components across worker threads — each
//! worker's writes are confined to state derived from its own
//! component, so no synchronisation is needed inside the fan-out and
//! a serial replay of the outputs reproduces the serial engine
//! exactly. Merge and split transitions never happen concurrently
//! with refills: insert/remove/rebuild all run in the engine's serial
//! event phases (see "Parallel event loop" in `docs/ARCHITECTURE.md`).

use super::alloc::{find, TaskRes, MAX_TASK_RES};

/// Which allocation strategy the engine runs per event
/// (`SimConfig::alloc`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocKind {
    /// Re-run the fluid fill only for contention components touched
    /// since the last event; clean components keep their memoized rates
    /// (default).
    Components,
    /// Re-price the whole active set every event — the pre-refactor
    /// *cost profile*, kept as the equivalence oracle
    /// (`tests/prop_queue_equivalence.rs`) and benchmark baseline.
    /// Results are bit-for-bit identical to [`AllocKind::Components`].
    /// Note it runs the *same* component-decomposed fill arithmetic as
    /// everything else in this revision (that sharing is exactly what
    /// makes the oracle bitwise); it is not a frozen bitstream of the
    /// previous revision's global progressive filling, whose increments
    /// mixed across disjoint components.
    WholeSet,
}

impl AllocKind {
    /// Parse the CLI / scenario-JSON spelling (`components` |
    /// `wholeset`).
    pub fn parse(s: &str) -> Result<AllocKind, String> {
        match s {
            "components" => Ok(AllocKind::Components),
            "wholeset" => Ok(AllocKind::WholeSet),
            other => Err(format!("unknown alloc kind `{other}` (components|wholeset)")),
        }
    }
}

const NONE: usize = usize::MAX;

/// The incremental component partition (see the module docs).
///
/// Task ids index `0..n_tasks`; resource ids index the flat arena
/// `0..n_res` *including* any virtual coflow-group slots appended by the
/// caller. A task is a member of at most one component while queued.
#[derive(Debug, Default)]
pub struct CompSet {
    // per task
    task_comp: Vec<usize>,
    pos: Vec<usize>,
    // per resource: claiming slot, valid while the generation matches
    owner: Vec<usize>,
    owner_gen: Vec<u32>,
    // component slab
    members: Vec<Vec<usize>>,
    res: Vec<Vec<usize>>,
    gen_of: Vec<u32>,
    alive: Vec<bool>,
    dirty_flag: Vec<bool>,
    free: Vec<usize>,
    live: Vec<usize>,
    live_pos: Vec<usize>,
    dirty: Vec<usize>,
    // rebuild scratch
    parent: Vec<usize>,
    seen_res: Vec<usize>,
    seen_epoch: Vec<u64>,
    epoch: u64,
    root_comp: Vec<usize>,
    /// Retired member buffers, recycled by [`CompSet::alloc_slot`] so
    /// rebuilds stay allocation-free once capacities are warm.
    spare: Vec<Vec<usize>>,
}

impl CompSet {
    /// Partition over task ids `0..n_tasks` and resource ids `0..n_res`
    /// (physical arena plus virtual coflow-group slots).
    pub fn new(n_tasks: usize, n_res: usize) -> CompSet {
        CompSet {
            task_comp: vec![NONE; n_tasks],
            pos: vec![NONE; n_tasks],
            owner: vec![NONE; n_res],
            owner_gen: vec![0; n_res],
            members: Vec::new(),
            res: Vec::new(),
            gen_of: Vec::new(),
            alive: Vec::new(),
            dirty_flag: Vec::new(),
            free: Vec::new(),
            live: Vec::new(),
            live_pos: Vec::new(),
            dirty: Vec::new(),
            parent: Vec::new(),
            seen_res: vec![0; n_res],
            seen_epoch: vec![0; n_res],
            epoch: 0,
            root_comp: Vec::new(),
            spare: Vec::new(),
        }
    }

    /// Reset to an empty partition over `n_tasks` task ids and `n_res`
    /// resource ids — the between-runs reuse hook
    /// ([`SimScratch`](crate::sim::SimScratch)): every slot is retired
    /// to the free list with its member/resource buffers kept, all
    /// claims are dropped. The free list is ordered so slot ids are
    /// handed out lowest-first again, exactly as from a fresh
    /// [`CompSet::new`].
    pub fn reset(&mut self, n_tasks: usize, n_res: usize) {
        self.task_comp.clear();
        self.task_comp.resize(n_tasks, NONE);
        self.pos.clear();
        self.pos.resize(n_tasks, NONE);
        self.owner.clear();
        self.owner.resize(n_res, NONE);
        self.owner_gen.clear();
        self.owner_gen.resize(n_res, 0);
        for c in 0..self.members.len() {
            self.members[c].clear();
            self.res[c].clear();
            self.alive[c] = false;
            self.dirty_flag[c] = false;
            self.live_pos[c] = NONE;
        }
        self.live.clear();
        self.dirty.clear();
        self.free.clear();
        self.free.extend((0..self.members.len()).rev());
        self.seen_res.clear();
        self.seen_res.resize(n_res, 0);
        self.seen_epoch.clear();
        self.seen_epoch.resize(n_res, 0);
        self.epoch = 0;
        // parent/root_comp are per-rebuild scratch; `spare` buffers and
        // `gen_of` stamps carry over (claims are owner-side, all dropped)
    }

    /// Total reserved slots across every internal buffer (outer vectors
    /// plus the per-component member/resource/spare inner vectors) — the
    /// memory high-water mark across every run this set has served.
    /// Read by the open-loop bounded-memory oracle: with epoch GC the
    /// partition sizes to the largest concurrent live set, never to the
    /// stream total.
    pub fn capacity(&self) -> usize {
        let inner = |v: &Vec<Vec<usize>>| -> usize {
            v.capacity() + v.iter().map(|i| i.capacity()).sum::<usize>()
        };
        self.task_comp.capacity()
            + self.pos.capacity()
            + self.owner.capacity()
            + self.owner_gen.capacity()
            + inner(&self.members)
            + inner(&self.res)
            + self.gen_of.capacity()
            + self.alive.capacity()
            + self.dirty_flag.capacity()
            + self.free.capacity()
            + self.live.capacity()
            + self.live_pos.capacity()
            + self.dirty.capacity()
            + self.parent.capacity()
            + self.seen_res.capacity()
            + self.seen_epoch.capacity()
            + self.root_comp.capacity()
            + inner(&self.spare)
    }

    /// The component currently owning resource `r`, if any. Claims by
    /// retired slots are invalid (generation mismatch).
    fn owner_of(&self, r: usize) -> Option<usize> {
        let c = self.owner[r];
        if c != NONE && self.owner_gen[r] == self.gen_of[c] && self.alive[c] {
            Some(c)
        } else {
            None
        }
    }

    fn claim(&mut self, r: usize, c: usize) {
        self.owner[r] = c;
        self.owner_gen[r] = self.gen_of[c];
    }

    fn alloc_slot(&mut self) -> usize {
        let c = match self.free.pop() {
            Some(c) => c,
            None => {
                self.members.push(self.spare.pop().unwrap_or_default());
                self.res.push(Vec::new());
                self.gen_of.push(0);
                self.alive.push(false);
                self.dirty_flag.push(false);
                self.live_pos.push(NONE);
                self.members.len() - 1
            }
        };
        if self.members[c].capacity() == 0 {
            // the slot whose member buffer a rebuild took: re-arm it from
            // the spare pool so refills stay allocation-free
            if let Some(v) = self.spare.pop() {
                self.members[c] = v;
            }
        }
        debug_assert!(self.members[c].is_empty() && self.res[c].is_empty());
        self.alive[c] = true;
        self.dirty_flag[c] = false;
        self.live_pos[c] = self.live.len();
        self.live.push(c);
        c
    }

    fn retire(&mut self, c: usize) {
        self.alive[c] = false;
        self.gen_of[c] = self.gen_of[c].wrapping_add(1); // invalidate claims
        self.members[c].clear();
        self.res[c].clear();
        let i = self.live_pos[c];
        self.live.swap_remove(i);
        if i < self.live.len() {
            let moved = self.live[i];
            self.live_pos[moved] = i;
        }
        self.live_pos[c] = NONE;
        self.free.push(c);
    }

    /// Mark component `c` dirty (idempotent).
    pub fn mark_dirty(&mut self, c: usize) {
        if !self.dirty_flag[c] {
            self.dirty_flag[c] = true;
            self.dirty.push(c);
        }
    }

    /// Mark the component containing queued task `t` dirty (no-op if
    /// `t` is not queued).
    pub fn mark_task_dirty(&mut self, t: usize) {
        let c = self.task_comp[t];
        if c != NONE {
            self.mark_dirty(c);
        }
    }

    /// Pop one dirty live component id, or `None` when the worklist is
    /// drained. Entries for components that were merged away or already
    /// processed are skipped.
    pub fn pop_dirty(&mut self) -> Option<usize> {
        while let Some(c) = self.dirty.pop() {
            if self.alive[c] && self.dirty_flag[c] {
                self.dirty_flag[c] = false;
                return Some(c);
            }
        }
        None
    }

    /// Add queued task `t` with physical footprint `tr` (plus an
    /// optional virtual coflow-group resource), merging every component
    /// it bridges. The resulting component is marked dirty.
    pub fn insert(&mut self, t: usize, tr: &TaskRes, virt: Option<usize>) {
        debug_assert_eq!(self.task_comp[t], NONE, "task {t} already tracked");
        // distinct live components already owning any of t's resources
        let mut found = [NONE; MAX_TASK_RES + 1];
        let mut nf = 0usize;
        for r in tr.iter().chain(virt) {
            if let Some(c) = self.owner_of(r) {
                if !found[..nf].contains(&c) {
                    found[nf] = c;
                    nf += 1;
                }
            }
        }
        let target = if nf == 0 {
            self.alloc_slot()
        } else {
            let mut tgt = found[0];
            for &c in &found[1..nf] {
                if self.members[c].len() > self.members[tgt].len() {
                    tgt = c;
                }
            }
            for &c in &found[..nf] {
                if c != tgt {
                    self.merge_into(c, tgt);
                }
            }
            tgt
        };
        self.task_comp[t] = target;
        self.pos[t] = self.members[target].len();
        self.members[target].push(t);
        for r in tr.iter().chain(virt) {
            self.claim(r, target);
            self.res[target].push(r);
        }
        self.mark_dirty(target);
    }

    fn merge_into(&mut self, src: usize, tgt: usize) {
        debug_assert!(self.alive[src] && self.alive[tgt] && src != tgt);
        let moved = std::mem::take(&mut self.members[src]);
        for &m in &moved {
            self.task_comp[m] = tgt;
            self.pos[m] = self.members[tgt].len();
            self.members[tgt].push(m);
        }
        let res = std::mem::take(&mut self.res[src]);
        for &r in &res {
            // re-claim only what src still owns; stale entries may
            // legitimately belong to another live component by now
            if self.owner[r] == src && self.owner_gen[r] == self.gen_of[src] {
                self.claim(r, tgt);
            }
        }
        self.res[tgt].extend_from_slice(&res);
        // hand the buffers back so the slab slot reuses the allocations
        self.members[src] = moved;
        self.members[src].clear();
        self.res[src] = res;
        self.res[src].clear();
        self.retire(src);
    }

    /// Remove task `t` (completion). Its component is marked dirty; the
    /// possible split is deferred to [`CompSet::rebuild`].
    pub fn remove(&mut self, t: usize) {
        let c = self.task_comp[t];
        if c == NONE {
            return;
        }
        self.task_comp[t] = NONE;
        let i = self.pos[t];
        self.members[c].swap_remove(i);
        if i < self.members[c].len() {
            let m = self.members[c][i];
            self.pos[m] = i;
        }
        self.pos[t] = NONE;
        self.mark_dirty(c);
    }

    /// Re-derive exact connectivity among `c`'s members, retire `c`,
    /// and create one fresh component per connectivity class (ids
    /// appended to `out`, none of them dirty — the caller refills them
    /// immediately). `virt[t]` is the task's virtual coflow-group
    /// resource, if any. The caller must release `c`'s capacity
    /// ([`CompSet::res_of`]) *before* calling this.
    pub fn rebuild(
        &mut self,
        c: usize,
        task_res: &[TaskRes],
        virt: &[Option<usize>],
        out: &mut Vec<usize>,
    ) {
        debug_assert!(self.alive[c]);
        let mut mem = std::mem::take(&mut self.members[c]);
        let m = mem.len();
        // union-find over member positions via shared resources
        self.epoch += 1;
        self.parent.clear();
        self.parent.extend(0..m);
        for (i, &t) in mem.iter().enumerate() {
            for r in task_res[t].iter().chain(virt[t]) {
                if self.seen_epoch[r] == self.epoch {
                    let j = self.seen_res[r];
                    let (ri, rj) = (find(&mut self.parent, i), find(&mut self.parent, j));
                    if ri != rj {
                        self.parent[ri] = rj;
                    }
                } else {
                    self.seen_epoch[r] = self.epoch;
                    self.seen_res[r] = i;
                }
            }
        }
        self.retire(c);
        // one fresh component per root, in order of first appearance
        self.root_comp.clear();
        self.root_comp.resize(m, NONE);
        for (i, &t) in mem.iter().enumerate() {
            let root = find(&mut self.parent, i);
            let slot = if self.root_comp[root] == NONE {
                let s = self.alloc_slot();
                self.root_comp[root] = s;
                out.push(s);
                s
            } else {
                self.root_comp[root]
            };
            self.task_comp[t] = slot;
            self.pos[t] = self.members[slot].len();
            self.members[slot].push(t);
            for r in task_res[t].iter().chain(virt[t]) {
                self.claim(r, slot);
                self.res[slot].push(r);
            }
        }
        // recycle the taken member buffer (slot `c` may already be
        // reused by one of the new components, so it goes to the pool,
        // not back to `c`)
        mem.clear();
        self.spare.push(mem);
    }

    /// Component of queued task `t`.
    pub fn comp_of(&self, t: usize) -> Option<usize> {
        if self.task_comp[t] == NONE {
            None
        } else {
            Some(self.task_comp[t])
        }
    }

    /// Member tasks of live component `c`.
    pub fn members(&self, c: usize) -> &[usize] {
        &self.members[c]
    }

    /// Resources component `c` may have drawn on since its last rebuild
    /// (a superset: duplicates and resources of since-removed members
    /// are possible — exactly what a capacity release must cover).
    pub fn res_of(&self, c: usize) -> &[usize] {
        &self.res[c]
    }

    /// Live component ids (arbitrary but deterministic order).
    pub fn live_slots(&self) -> &[usize] {
        &self.live
    }

    /// Whether slot `c` currently holds a live component.
    pub fn is_alive(&self, c: usize) -> bool {
        self.alive[c]
    }

    /// Upper bound on slot ids (for parallel engine-side arrays).
    pub fn slot_bound(&self) -> usize {
        self.members.len()
    }

    /// Number of live components.
    pub fn n_live(&self) -> usize {
        self.live.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::alloc::{
        coflow_fill_res, coflow_fill_res_in, maxmin_fill_res, maxmin_fill_res_in,
        priority_fill_res, priority_fill_res_in, AllocScratch, MAX_TASK_RES,
    };
    use crate::util::propcheck::{check, Config};
    use crate::util::rng::Rng;

    fn tr(res: &[usize]) -> TaskRes {
        let mut t = TaskRes::default();
        for &r in res {
            t.push(r);
        }
        t
    }

    #[test]
    fn insert_merges_on_shared_resource() {
        let mut cs = CompSet::new(8, 8);
        cs.insert(0, &tr(&[0, 1]), None);
        cs.insert(1, &tr(&[2, 3]), None);
        assert_eq!(cs.n_live(), 2);
        assert_ne!(cs.comp_of(0), cs.comp_of(1));
        // task 2 bridges both components
        cs.insert(2, &tr(&[1, 2]), None);
        assert_eq!(cs.n_live(), 1);
        assert_eq!(cs.comp_of(0), cs.comp_of(1));
        assert_eq!(cs.comp_of(0), cs.comp_of(2));
        let c = cs.comp_of(0).unwrap();
        let mut m = cs.members(c).to_vec();
        m.sort_unstable();
        assert_eq!(m, vec![0, 1, 2]);
    }

    #[test]
    fn remove_then_rebuild_splits() {
        // chain 0 -[r1]- 1 -[r2]- 2; removing the middle task splits
        let mut cs = CompSet::new(8, 8);
        cs.insert(0, &tr(&[0, 1]), None);
        cs.insert(1, &tr(&[1, 2]), None);
        cs.insert(2, &tr(&[2, 3]), None);
        assert_eq!(cs.n_live(), 1);
        cs.remove(1);
        let task_res: Vec<TaskRes> = vec![tr(&[0, 1]), tr(&[1, 2]), tr(&[2, 3])];
        let virt = vec![None; 3];
        let mut out = Vec::new();
        while let Some(c) = cs.pop_dirty() {
            cs.rebuild(c, &task_res, &virt, &mut out);
        }
        assert_eq!(cs.n_live(), 2);
        assert_eq!(out.len(), 2);
        assert_ne!(cs.comp_of(0), cs.comp_of(2));
        assert_eq!(cs.comp_of(1), None);
    }

    #[test]
    fn virtual_group_resource_keeps_coflow_atomic() {
        // two flows on disjoint NICs, same coflow group => one component
        let mut cs = CompSet::new(4, 10);
        cs.insert(0, &tr(&[0, 1]), Some(8));
        cs.insert(1, &tr(&[2, 3]), Some(8));
        assert_eq!(cs.n_live(), 1);
        assert_eq!(cs.comp_of(0), cs.comp_of(1));
        // a third, ungrouped flow stays apart
        cs.insert(2, &tr(&[4, 5]), None);
        assert_eq!(cs.n_live(), 2);
    }

    #[test]
    fn rebuild_releases_orphaned_resources() {
        let mut cs = CompSet::new(4, 8);
        cs.insert(0, &tr(&[0, 1]), None);
        cs.insert(1, &tr(&[1, 2]), None);
        cs.remove(0);
        let task_res: Vec<TaskRes> = vec![tr(&[0, 1]), tr(&[1, 2])];
        let virt = vec![None; 2];
        let mut out = Vec::new();
        while let Some(c) = cs.pop_dirty() {
            cs.rebuild(c, &task_res, &virt, &mut out);
        }
        // resource 0 belonged only to the removed task: a new task on it
        // must get a fresh singleton component, not join task 1's
        cs.insert(2, &tr(&[0]), None);
        assert_eq!(cs.n_live(), 2);
        assert_ne!(cs.comp_of(1), cs.comp_of(2));
    }

    #[test]
    fn dirty_worklist_dedups_and_skips_retired() {
        let mut cs = CompSet::new(8, 8);
        cs.insert(0, &tr(&[0]), None);
        cs.insert(1, &tr(&[1]), None);
        cs.mark_task_dirty(0);
        cs.mark_task_dirty(0); // duplicate mark
        // merging retires one of the two slots while both are dirty
        cs.insert(2, &tr(&[0, 1]), None);
        let mut seen = Vec::new();
        while let Some(c) = cs.pop_dirty() {
            assert!(cs.is_alive(c));
            seen.push(c);
        }
        // exactly the surviving merged component is yielded, once
        assert_eq!(seen.len(), 1);
        assert_eq!(Some(seen[0]), cs.comp_of(2));
    }

    // ---------------- property: component-wise == whole-set ----------

    #[derive(Debug, Clone)]
    struct Case {
        n_res: usize,
        tasks: Vec<TaskRes>,
        prios: Vec<i64>,
        coflow: Vec<Option<usize>>,
        remaining: Vec<f64>,
        caps: Vec<f64>,
    }

    fn gen_case(rng: &mut Rng) -> Case {
        let n_res = rng.range(4, 12);
        let n = rng.range(1, 20);
        let mut tasks = Vec::with_capacity(n);
        for _ in 0..n {
            let k = rng.range(1, (MAX_TASK_RES).min(n_res) + 1);
            let mut t = TaskRes::default();
            while (t.n as usize) < k {
                let r = rng.below(n_res);
                if !t.iter().any(|x| x == r) {
                    t.push(r);
                }
            }
            tasks.push(t);
        }
        let prios: Vec<i64> = (0..n).map(|_| rng.range(0, 4) as i64).collect();
        let n_groups = rng.range(1, 4);
        let coflow: Vec<Option<usize>> = (0..n)
            .map(|_| if rng.bool(0.6) { Some(rng.below(n_groups)) } else { None })
            .collect();
        let remaining: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 3.0)).collect();
        let caps: Vec<f64> = (0..n_res)
            .map(|_| if rng.bool(0.1) { 0.0 } else { rng.range_f64(0.3, 2.0) })
            .collect();
        Case { n_res, tasks, prios, coflow, remaining, caps }
    }

    /// Partition the case's tasks with a `CompSet` (virtual group
    /// resources included), exercising rebuild, and return the
    /// components as sorted member lists.
    fn partition(case: &Case, with_groups: bool) -> Vec<Vec<usize>> {
        let n = case.tasks.len();
        let virt: Vec<Option<usize>> = (0..n)
            .map(|i| if with_groups { case.coflow[i].map(|g| case.n_res + g) } else { None })
            .collect();
        let mut cs = CompSet::new(n, case.n_res + 4);
        for i in 0..n {
            cs.insert(i, &case.tasks[i], virt[i]);
        }
        let mut out = Vec::new();
        while let Some(c) = cs.pop_dirty() {
            cs.rebuild(c, &case.tasks, &virt, &mut out);
        }
        let mut comps: Vec<Vec<usize>> = cs
            .live_slots()
            .iter()
            .map(|&c| {
                let mut m = cs.members(c).to_vec();
                m.sort_unstable();
                m
            })
            .collect();
        comps.sort();
        comps
    }

    fn assert_rates_eq(tag: &str, whole: &[f64], comp: &[f64]) -> Result<(), String> {
        for (i, (a, b)) in whole.iter().zip(comp.iter()).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!("{tag}: task {i} rate {a} vs {b}"));
            }
        }
        Ok(())
    }

    /// Component-wise fills must equal whole-set fills *bit for bit*
    /// under all three allocators — the invariant the engine's
    /// `AllocKind` oracle pairing rests on.
    #[test]
    fn prop_component_fills_match_whole_set() {
        check(
            "component-fill-equivalence",
            &Config { cases: 60, ..Default::default() },
            gen_case,
            |case| {
                let n = case.tasks.len();
                // --- max-min fair ---
                let mut caps_w = case.caps.clone();
                let mut rates_w = vec![0.0; n];
                let mut users = vec![0.0; case.n_res];
                maxmin_fill_res(&case.tasks, &mut caps_w, &mut rates_w, &mut users);
                let mut caps_c = case.caps.clone();
                let mut rates_c = vec![0.0; n];
                let mut s = AllocScratch::default();
                for comp in partition(case, false) {
                    let sub: Vec<TaskRes> = comp.iter().map(|&i| case.tasks[i]).collect();
                    let mut sub_rates = vec![0.0; sub.len()];
                    maxmin_fill_res_in(&sub, &mut caps_c, &mut sub_rates, &mut users, &mut s);
                    for (j, &i) in comp.iter().enumerate() {
                        rates_c[i] = sub_rates[j];
                    }
                }
                assert_rates_eq("maxmin", &rates_w, &rates_c)?;

                // --- strict priority ---
                let mut caps_w = case.caps.clone();
                let mut rates_w = vec![0.0; n];
                priority_fill_res(&case.tasks, &case.prios, &mut caps_w, &mut rates_w, &mut users);
                let mut caps_c = case.caps.clone();
                let mut rates_c = vec![0.0; n];
                for comp in partition(case, false) {
                    let sub: Vec<TaskRes> = comp.iter().map(|&i| case.tasks[i]).collect();
                    let prios: Vec<i64> = comp.iter().map(|&i| case.prios[i]).collect();
                    let mut sub_rates = vec![0.0; sub.len()];
                    priority_fill_res_in(&sub, &prios, &mut caps_c, &mut sub_rates, &mut users, &mut s);
                    for (j, &i) in comp.iter().enumerate() {
                        rates_c[i] = sub_rates[j];
                    }
                }
                assert_rates_eq("priority", &rates_w, &rates_c)?;

                // --- coflow (groups atomic via virtual resources) ---
                let mut caps_w = case.caps.clone();
                let mut rates_w = vec![0.0; n];
                coflow_fill_res(
                    &case.tasks,
                    &case.coflow,
                    &case.remaining,
                    &case.caps,
                    &mut caps_w,
                    &mut rates_w,
                );
                let mut caps_c = case.caps.clone();
                let mut rates_c = vec![0.0; n];
                for comp in partition(case, true) {
                    let sub: Vec<TaskRes> = comp.iter().map(|&i| case.tasks[i]).collect();
                    let coflow: Vec<Option<usize>> =
                        comp.iter().map(|&i| case.coflow[i]).collect();
                    let rem: Vec<f64> = comp.iter().map(|&i| case.remaining[i]).collect();
                    let mut sub_rates = vec![0.0; sub.len()];
                    coflow_fill_res_in(
                        &sub,
                        &coflow,
                        &rem,
                        &case.caps,
                        &mut caps_c,
                        &mut sub_rates,
                        &mut s,
                    );
                    for (j, &i) in comp.iter().enumerate() {
                        rates_c[i] = sub_rates[j];
                    }
                }
                assert_rates_eq("coflow", &rates_w, &rates_c)?;
                Ok(())
            },
        );
    }
}
