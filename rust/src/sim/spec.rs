//! Simulator input spec: the cluster substrate and the *physical* DAG
//! (MXDAG after pipeline expansion) that the fluid engine executes.

use crate::mxdag::TaskId;

/// One host: compute slots plus a full-duplex NIC.
///
/// Rates are normalised: a compute task at full resource runs at rate 1
/// (occupying one core); a flow at full NIC runs at rate 1.
#[derive(Debug, Clone)]
pub struct Host {
    pub cores: f64,
    pub nic_up: f64,
    pub nic_down: f64,
}

impl Default for Host {
    fn default() -> Self {
        Host { cores: 1.0, nic_up: 1.0, nic_down: 1.0 }
    }
}

/// The cluster: a set of hosts.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub hosts: Vec<Host>,
}

impl Cluster {
    /// `n` identical single-core hosts with unit NICs.
    pub fn uniform(n: usize) -> Cluster {
        Cluster { hosts: vec![Host::default(); n] }
    }

    pub fn with_cores(n: usize, cores: f64) -> Cluster {
        Cluster { hosts: vec![Host { cores, ..Host::default() }; n] }
    }

    pub fn n_hosts(&self) -> usize {
        self.hosts.len()
    }

    /// Resource vector layout: [core_0, up_0, down_0, core_1, ...].
    pub fn capacities(&self) -> Vec<f64> {
        let mut caps = Vec::with_capacity(self.hosts.len() * 3);
        for h in &self.hosts {
            caps.push(h.cores);
            caps.push(h.nic_up);
            caps.push(h.nic_down);
        }
        caps
    }
}

/// Resource index helpers (see [`Cluster::capacities`]).
pub fn res_core(h: usize) -> usize {
    3 * h
}
pub fn res_up(h: usize) -> usize {
    3 * h + 1
}
pub fn res_down(h: usize) -> usize {
    3 * h + 2
}

/// Physical task kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimKind {
    Compute { host: usize },
    Flow { src: usize, dst: usize },
    /// Zero-cost synchronisation node (dummy start/end).
    Dummy,
}

impl SimKind {
    /// Resources this task draws from (0, 1 or 2 entries).
    pub fn resources(&self) -> Vec<usize> {
        match *self {
            SimKind::Compute { host } => vec![res_core(host)],
            SimKind::Flow { src, dst } => vec![res_up(src), res_down(dst)],
            SimKind::Dummy => vec![],
        }
    }
    pub fn is_flow(&self) -> bool {
        matches!(self, SimKind::Flow { .. })
    }
}

/// One physical (possibly chunk-level) task.
#[derive(Debug, Clone)]
pub struct SimTask {
    /// Originating MXTask in the logical MXDAG.
    pub orig: TaskId,
    /// (chunk index, total chunks) of the originating task.
    pub chunk: (usize, usize),
    pub kind: SimKind,
    pub size: f64,
    /// Higher = scheduled first under the Priority/Fifo policies.
    pub priority: i64,
    /// Earliest start time (scheduler gate; Principle 2 altruism).
    pub gate: f64,
    /// Coflow group id (flows only; all-or-nothing + MADD semantics).
    pub coflow: Option<usize>,
}

/// The physical DAG the engine executes.
#[derive(Debug, Clone, Default)]
pub struct SimDag {
    pub tasks: Vec<SimTask>,
    pub preds: Vec<Vec<usize>>,
    pub succs: Vec<Vec<usize>>,
}

impl SimDag {
    pub fn push(&mut self, t: SimTask) -> usize {
        let id = self.tasks.len();
        self.tasks.push(t);
        self.preds.push(Vec::new());
        self.succs.push(Vec::new());
        id
    }

    pub fn dep(&mut self, a: usize, b: usize) {
        debug_assert!(a != b);
        self.succs[a].push(b);
        self.preds[b].push(a);
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }
}

/// Bandwidth-sharing policy for network flows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetPolicy {
    /// Max-min fair progressive filling (network-aware DAG baseline).
    Fair,
    /// Strict priority by `SimTask::priority`, fair within a level.
    Priority,
    /// Per-NIC FIFO: ready-order strict priority (plain-DAG baseline).
    Fifo,
    /// Varys-style coflow: SEBF ordering + MADD rates + all-or-nothing.
    Coflow,
}

/// Compute-slot sharing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CpuPolicy {
    Fair,
    Priority,
    Fifo,
}

#[derive(Debug, Clone, Copy)]
pub struct Policy {
    pub net: NetPolicy,
    pub cpu: CpuPolicy,
}

impl Policy {
    pub fn fair() -> Policy {
        Policy { net: NetPolicy::Fair, cpu: CpuPolicy::Fair }
    }
    pub fn priority() -> Policy {
        Policy { net: NetPolicy::Priority, cpu: CpuPolicy::Priority }
    }
    pub fn fifo() -> Policy {
        Policy { net: NetPolicy::Fifo, cpu: CpuPolicy::Fifo }
    }
    pub fn coflow() -> Policy {
        Policy { net: NetPolicy::Coflow, cpu: CpuPolicy::Fair }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacities_layout() {
        let c = Cluster::uniform(2);
        assert_eq!(c.capacities(), vec![1.0; 6]);
        assert_eq!(res_core(1), 3);
        assert_eq!(res_up(1), 4);
        assert_eq!(res_down(1), 5);
    }

    #[test]
    fn kind_resources() {
        assert_eq!(SimKind::Compute { host: 2 }.resources(), vec![6]);
        assert_eq!(SimKind::Flow { src: 0, dst: 1 }.resources(), vec![1, 5]);
        assert!(SimKind::Dummy.resources().is_empty());
    }

    #[test]
    fn dag_push_dep() {
        let mut d = SimDag::default();
        let a = d.push(SimTask {
            orig: 0,
            chunk: (0, 1),
            kind: SimKind::Dummy,
            size: 0.0,
            priority: 0,
            gate: 0.0,
            coflow: None,
        });
        let b = d.push(SimTask {
            orig: 1,
            chunk: (0, 1),
            kind: SimKind::Compute { host: 0 },
            size: 1.0,
            priority: 0,
            gate: 0.0,
            coflow: None,
        });
        d.dep(a, b);
        assert_eq!(d.succs[a], vec![b]);
        assert_eq!(d.preds[b], vec![a]);
    }

    #[test]
    fn cluster_with_cores() {
        let c = Cluster::with_cores(1, 4.0);
        assert_eq!(c.capacities()[0], 4.0);
    }
}
