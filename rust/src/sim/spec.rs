//! Simulator input spec: the cluster substrate and the *physical* DAG
//! (MXDAG after pipeline expansion) that the fluid engine executes.

use crate::mxdag::TaskId;
use crate::util::json::{Json, JsonError};

use super::alloc::TaskRes;
use super::ready::{Keying, QueueDiscipline};
use super::topology::Topology;

/// One host: compute slots plus a full-duplex NIC.
///
/// Rates are normalised: a compute task at full resource runs at rate 1
/// (occupying one core); a flow at full NIC runs at rate 1.
#[derive(Debug, Clone)]
pub struct Host {
    pub cores: f64,
    pub nic_up: f64,
    pub nic_down: f64,
}

impl Default for Host {
    fn default() -> Self {
        Host { cores: 1.0, nic_up: 1.0, nic_down: 1.0 }
    }
}

/// The cluster: a set of hosts wired together by a [`Topology`].
///
/// The default topology is [`Topology::BigSwitch`], which reproduces the
/// pre-topology semantics bit-for-bit (flows touch only their endpoint
/// NICs, and the resource vector is exactly `3 × hosts` long).
#[derive(Debug, Clone)]
pub struct Cluster {
    pub hosts: Vec<Host>,
    pub topology: Topology,
}

impl Cluster {
    /// `n` identical single-core hosts with unit NICs on a big switch.
    pub fn uniform(n: usize) -> Cluster {
        Cluster { hosts: vec![Host::default(); n], topology: Topology::BigSwitch }
    }

    pub fn with_cores(n: usize, cores: f64) -> Cluster {
        Cluster {
            hosts: vec![Host { cores, ..Host::default() }; n],
            topology: Topology::BigSwitch,
        }
    }

    /// Builder-style topology override.
    pub fn with_topology(mut self, topology: Topology) -> Cluster {
        self.topology = topology;
        self
    }

    /// `n` uniform hosts on a two-tier leaf/spine fabric with `racks`
    /// leaves oversubscribed `ratio : 1`.
    pub fn oversubscribed(n: usize, racks: usize, ratio: f64) -> Cluster {
        assert!(racks >= 1 && ratio > 0.0, "racks >= 1 and ratio > 0 required");
        Cluster::uniform(n).with_topology(Topology::Oversubscribed { racks, ratio })
    }

    /// `n` uniform hosts behind `k` parallel fabrics of capacity `trunk`
    /// each, with hash-based path selection.
    pub fn parallel_fabrics(n: usize, k: usize, trunk: f64) -> Cluster {
        assert!(k >= 1 && trunk > 0.0, "k >= 1 and trunk > 0 required");
        Cluster::uniform(n).with_topology(Topology::ParallelFabrics {
            k,
            select: super::topology::PathSelect::Hash,
            trunk,
        })
    }

    pub fn n_hosts(&self) -> usize {
        self.hosts.len()
    }

    /// Total resources: `3 × hosts` per-host slots plus fabric extras.
    pub fn n_resources(&self) -> usize {
        3 * self.hosts.len() + self.topology.n_extra(self.hosts.len())
    }

    /// Resource vector layout: `[core_0, up_0, down_0, core_1, ...]`
    /// followed by the topology's fabric resources (aggregation links or
    /// parallel trunks).
    pub fn capacities(&self) -> Vec<f64> {
        let n = self.hosts.len();
        let mut caps = Vec::with_capacity(self.n_resources());
        for h in &self.hosts {
            caps.push(h.cores);
            caps.push(h.nic_up);
            caps.push(h.nic_down);
        }
        match &self.topology {
            Topology::BigSwitch => {}
            Topology::Oversubscribed { racks, ratio } => {
                // one pass over hosts, accumulating per-rack NIC sums
                let mut up = vec![0.0; *racks];
                let mut down = vec![0.0; *racks];
                for (h, host) in self.hosts.iter().enumerate() {
                    let r = self.topology.rack_of(h, n).unwrap();
                    up[r] += host.nic_up;
                    down[r] += host.nic_down;
                }
                for r in 0..*racks {
                    caps.push(up[r] / ratio);
                    caps.push(down[r] / ratio);
                }
            }
            Topology::ParallelFabrics { k, trunk, .. } => {
                for _ in 0..*k {
                    caps.push(*trunk);
                }
            }
        }
        caps
    }

    /// Resource footprint of a physical task under this topology.
    pub fn task_res(&self, kind: &SimKind) -> TaskRes {
        let mut tr = TaskRes::default();
        match *kind {
            SimKind::Compute { host } => tr.push(res_core(host)),
            SimKind::Flow { src, dst } => {
                tr.push(res_up(src));
                tr.push(res_down(dst));
                self.topology.push_flow_extras(src, dst, self.hosts.len(), &mut tr);
            }
            SimKind::Dummy => {}
        }
        tr
    }

    /// Resource indices of a task (allocating convenience form).
    pub fn resources_of(&self, kind: &SimKind) -> Vec<usize> {
        self.task_res(kind).iter().collect()
    }

    /// Rate the task runs at when alone in the cluster: `min(1,
    /// bottleneck capacity along its resources)`. This is the per-path
    /// bottleneck bandwidth schedulers cost critical paths with.
    pub fn solo_rate(&self, kind: &SimKind) -> f64 {
        let caps = self.capacities();
        self.solo_rate_with(&caps, kind)
    }

    /// As [`Cluster::solo_rate`], reusing a precomputed capacity vector.
    pub fn solo_rate_with(&self, caps: &[f64], kind: &SimKind) -> f64 {
        let mut rate: f64 = 1.0;
        for r in self.task_res(kind).iter() {
            rate = rate.min(caps[r]);
        }
        rate.max(0.0)
    }

    /// JSON form: `{"hosts": N | [{cores, nic_up, nic_down}...],
    /// "topology": {...}}` (both keys optional on parse).
    pub fn to_json(&self) -> Json {
        let hosts: Vec<Json> = self
            .hosts
            .iter()
            .map(|h| {
                Json::obj(vec![
                    ("cores", Json::Num(h.cores)),
                    ("nic_up", Json::Num(h.nic_up)),
                    ("nic_down", Json::Num(h.nic_down)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("hosts", Json::Arr(hosts)),
            ("topology", self.topology.to_json()),
        ])
    }

    /// Parse the JSON form of [`Cluster::to_json`]. `"hosts"` may be a
    /// count (uniform hosts) or an array of host objects; missing host
    /// fields default to 1.0; missing `"topology"` means big switch.
    pub fn from_json(j: &Json) -> Result<Cluster, JsonError> {
        let obj = j.as_obj()?;
        let hosts = match obj.get("hosts") {
            None => Vec::new(),
            Some(Json::Num(n)) => {
                if !(n.is_finite() && *n >= 0.0 && *n <= 1e6 && n.fract() == 0.0) {
                    return Err(JsonError::Type { want: "host count (integer 0..=1e6)", got: "number" });
                }
                vec![Host::default(); *n as usize]
            }
            Some(v) => v
                .as_arr()?
                .iter()
                .map(|h| {
                    let field = |k: &str| -> Result<f64, JsonError> {
                        let v = match h.as_obj()?.get(k) {
                            Some(v) => v.as_f64()?,
                            None => 1.0,
                        };
                        if !(v.is_finite() && v >= 0.0) {
                            return Err(JsonError::Type { want: "finite non-negative host capacity", got: "number" });
                        }
                        Ok(v)
                    };
                    Ok(Host {
                        cores: field("cores")?,
                        nic_up: field("nic_up")?,
                        nic_down: field("nic_down")?,
                    })
                })
                .collect::<Result<Vec<Host>, JsonError>>()?,
        };
        let topology = match obj.get("topology") {
            None => Topology::BigSwitch,
            Some(t) => Topology::from_json(t)?,
        };
        Ok(Cluster { hosts, topology })
    }
}

/// Resource index helpers (see [`Cluster::capacities`]).
pub fn res_core(h: usize) -> usize {
    3 * h
}
pub fn res_up(h: usize) -> usize {
    3 * h + 1
}
pub fn res_down(h: usize) -> usize {
    3 * h + 2
}
/// Whether arena slot `r` is a compute core (vs NIC/fabric). The
/// classifier lives here, next to the layout it encodes, so engine-side
/// resource-class logic cannot drift from [`Cluster::capacities`].
pub fn is_core_slot(r: usize, n_hosts: usize) -> bool {
    r < 3 * n_hosts && r % 3 == 0
}

/// Physical task kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimKind {
    Compute { host: usize },
    Flow { src: usize, dst: usize },
    /// Zero-cost synchronisation node (dummy start/end).
    Dummy,
}

impl SimKind {
    /// Resources this task draws from (0, 1 or 2 entries) **on a big
    /// switch**. Topology-aware callers should use
    /// [`Cluster::resources_of`] / [`Cluster::task_res`], which add the
    /// fabric resources a flow crosses.
    pub fn resources(&self) -> Vec<usize> {
        match *self {
            SimKind::Compute { host } => vec![res_core(host)],
            SimKind::Flow { src, dst } => vec![res_up(src), res_down(dst)],
            SimKind::Dummy => vec![],
        }
    }
    pub fn is_flow(&self) -> bool {
        matches!(self, SimKind::Flow { .. })
    }
}

/// One physical (possibly chunk-level) task.
#[derive(Debug, Clone)]
pub struct SimTask {
    /// Originating MXTask in the logical MXDAG.
    pub orig: TaskId,
    /// (chunk index, total chunks) of the originating task.
    pub chunk: (usize, usize),
    pub kind: SimKind,
    pub size: f64,
    /// Higher = scheduled first under the Priority/Fifo policies.
    pub priority: i64,
    /// Earliest start time (scheduler gate; Principle 2 altruism).
    pub gate: f64,
    /// Coflow group id (flows only; all-or-nothing + MADD semantics).
    pub coflow: Option<usize>,
}

/// The physical DAG the engine executes.
#[derive(Debug, Clone, Default)]
pub struct SimDag {
    pub tasks: Vec<SimTask>,
    pub preds: Vec<Vec<usize>>,
    pub succs: Vec<Vec<usize>>,
    /// Owning *job* per task, parallel to `tasks` — the quarantine unit
    /// of the fault-recovery layer (`sim/recovery.rs`) and the grouping
    /// key for `SimResult` per-job outcomes. Left empty (the default,
    /// and what `push` maintains) every task belongs to the implicit
    /// job `0`; multi-job planners populate it through
    /// `Annotations::jobs`.
    pub job_of: Vec<usize>,
}

impl SimDag {
    pub fn push(&mut self, t: SimTask) -> usize {
        let id = self.tasks.len();
        self.tasks.push(t);
        self.preds.push(Vec::new());
        self.succs.push(Vec::new());
        id
    }

    pub fn dep(&mut self, a: usize, b: usize) {
        debug_assert!(a != b);
        self.succs[a].push(b);
        self.preds[b].push(a);
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Owning job of task `t` (`0` when no job map is annotated).
    pub fn job(&self, t: usize) -> usize {
        self.job_of.get(t).copied().unwrap_or(0)
    }

    /// Append every task of `other` as job `job`, remapping the edges,
    /// shifting `orig` logical ids by `orig_offset` and coflow groups
    /// by `coflow_offset` so concatenated jobs cannot collide on either
    /// namespace. Returns the index `other`'s task 0 landed at. The
    /// open-loop era rebuild (`sim/openloop.rs`) concatenates the live
    /// jobs of each epoch with this.
    pub fn append_job(
        &mut self,
        other: &SimDag,
        job: usize,
        orig_offset: TaskId,
        coflow_offset: usize,
    ) -> usize {
        let base = self.tasks.len();
        // densify the implicit job map before a multi-job append
        if self.job_of.len() < base {
            self.job_of.resize(base, 0);
        }
        for t in &other.tasks {
            self.tasks.push(SimTask {
                orig: t.orig + orig_offset,
                coflow: t.coflow.map(|c| c + coflow_offset),
                ..t.clone()
            });
            self.job_of.push(job);
        }
        for p in &other.preds {
            self.preds.push(p.iter().map(|&x| x + base).collect());
        }
        for s in &other.succs {
            self.succs.push(s.iter().map(|&x| x + base).collect());
        }
        base
    }

    /// Number of jobs — at least 1 (the implicit job `0`).
    pub fn n_jobs(&self) -> usize {
        self.job_of.iter().copied().max().map_or(1, |m| m + 1)
    }
}

/// Bandwidth-sharing policy for network flows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetPolicy {
    /// Max-min fair progressive filling (network-aware DAG baseline).
    Fair,
    /// Strict priority by `SimTask::priority`, fair within a level.
    Priority,
    /// Per-NIC FIFO: ready-order strict priority (plain-DAG baseline).
    Fifo,
    /// Varys-style coflow: SEBF ordering + MADD rates + all-or-nothing.
    Coflow,
}

/// Compute-slot sharing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CpuPolicy {
    Fair,
    Priority,
    Fifo,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Policy {
    pub net: NetPolicy,
    pub cpu: CpuPolicy,
}

impl Policy {
    pub fn fair() -> Policy {
        Policy { net: NetPolicy::Fair, cpu: CpuPolicy::Fair }
    }
    pub fn priority() -> Policy {
        Policy { net: NetPolicy::Priority, cpu: CpuPolicy::Priority }
    }
    pub fn fifo() -> Policy {
        Policy { net: NetPolicy::Fifo, cpu: CpuPolicy::Fifo }
    }
    pub fn coflow() -> Policy {
        Policy { net: NetPolicy::Coflow, cpu: CpuPolicy::Fair }
    }

    /// How this policy keys the engine's ready queues — the concrete
    /// half of the scheduler ↔ engine contract (see
    /// [`QueueDiscipline`] and `Scheduler::disciplines`).
    pub fn discipline(&self) -> QueueDiscipline {
        QueueDiscipline {
            cpu: match self.cpu {
                CpuPolicy::Fair => Keying::SingleLevel,
                CpuPolicy::Priority => Keying::StaticPriority,
                CpuPolicy::Fifo => Keying::FifoArrival,
            },
            net: match self.net {
                NetPolicy::Fair => Keying::SingleLevel,
                NetPolicy::Priority => Keying::StaticPriority,
                NetPolicy::Fifo => Keying::FifoArrival,
                NetPolicy::Coflow => Keying::SebfGroups,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacities_layout() {
        let c = Cluster::uniform(2);
        assert_eq!(c.capacities(), vec![1.0; 6]);
        assert_eq!(res_core(1), 3);
        assert_eq!(res_up(1), 4);
        assert_eq!(res_down(1), 5);
    }

    #[test]
    fn kind_resources() {
        assert_eq!(SimKind::Compute { host: 2 }.resources(), vec![6]);
        assert_eq!(SimKind::Flow { src: 0, dst: 1 }.resources(), vec![1, 5]);
        assert!(SimKind::Dummy.resources().is_empty());
    }

    #[test]
    fn dag_push_dep() {
        let mut d = SimDag::default();
        let a = d.push(SimTask {
            orig: 0,
            chunk: (0, 1),
            kind: SimKind::Dummy,
            size: 0.0,
            priority: 0,
            gate: 0.0,
            coflow: None,
        });
        let b = d.push(SimTask {
            orig: 1,
            chunk: (0, 1),
            kind: SimKind::Compute { host: 0 },
            size: 1.0,
            priority: 0,
            gate: 0.0,
            coflow: None,
        });
        d.dep(a, b);
        assert_eq!(d.succs[a], vec![b]);
        assert_eq!(d.preds[b], vec![a]);
    }

    #[test]
    fn append_job_remaps_ids_edges_and_coflows() {
        let task = |orig: usize, host: usize, coflow: Option<usize>| SimTask {
            orig,
            chunk: (0, 1),
            kind: SimKind::Compute { host },
            size: 1.0,
            priority: 0,
            gate: 0.0,
            coflow,
        };
        let mut a = SimDag::default();
        let t0 = a.push(task(0, 0, None));
        let mut b = SimDag::default();
        let u0 = b.push(task(0, 1, Some(0)));
        let u1 = b.push(task(1, 1, Some(1)));
        b.dep(u0, u1);
        let base = a.append_job(&b, 1, 10, 5);
        assert_eq!(base, 1);
        assert_eq!(a.len(), 3);
        assert_eq!(a.job(t0), 0);
        assert_eq!(a.job(base + u1), 1);
        assert_eq!(a.n_jobs(), 2);
        assert_eq!(a.tasks[base].orig, 10);
        assert_eq!(a.tasks[base + 1].orig, 11);
        assert_eq!(a.tasks[base].coflow, Some(5));
        assert_eq!(a.tasks[base + 1].coflow, Some(6));
        assert_eq!(a.succs[base], vec![base + 1]);
        assert_eq!(a.preds[base + 1], vec![base]);
    }

    #[test]
    fn policy_disciplines_match_constants() {
        assert_eq!(Policy::fair().discipline(), QueueDiscipline::FAIR);
        assert_eq!(Policy::priority().discipline(), QueueDiscipline::PRIORITY);
        assert_eq!(Policy::fifo().discipline(), QueueDiscipline::FIFO);
        assert_eq!(Policy::coflow().discipline(), QueueDiscipline::COFLOW);
    }

    #[test]
    fn cluster_with_cores() {
        let c = Cluster::with_cores(1, 4.0);
        assert_eq!(c.capacities()[0], 4.0);
    }

    #[test]
    fn oversub_capacities_appended() {
        // 4 uniform hosts, 2 racks, ratio 2: per-host slots unchanged,
        // then agg_up/agg_down per rack at 2 (hosts) / 2 (ratio) = 1.
        let c = Cluster::oversubscribed(4, 2, 2.0);
        let caps = c.capacities();
        assert_eq!(caps.len(), 16);
        assert_eq!(&caps[..12], &[1.0; 12]);
        assert_eq!(&caps[12..], &[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(c.n_resources(), 16);
    }

    #[test]
    fn fabrics_capacities_appended() {
        let c = Cluster::parallel_fabrics(2, 3, 0.5);
        let caps = c.capacities();
        assert_eq!(caps.len(), 9);
        assert_eq!(&caps[6..], &[0.5, 0.5, 0.5]);
    }

    #[test]
    fn task_res_topology_aware() {
        let c = Cluster::oversubscribed(4, 2, 4.0);
        // intra-rack flow: NICs only (identical to the big switch)
        let intra: Vec<usize> = c.resources_of(&SimKind::Flow { src: 0, dst: 1 });
        assert_eq!(intra, vec![res_up(0), res_down(1)]);
        // cross-rack flow: NICs + agg_up(rack 0) + agg_down(rack 1)
        let cross: Vec<usize> = c.resources_of(&SimKind::Flow { src: 0, dst: 3 });
        assert_eq!(cross, vec![res_up(0), res_down(3), 12, 15]);
        // computes never touch the fabric
        assert_eq!(c.resources_of(&SimKind::Compute { host: 2 }), vec![res_core(2)]);
    }

    #[test]
    fn solo_rate_reflects_bottleneck() {
        let big = Cluster::uniform(4);
        assert_eq!(big.solo_rate(&SimKind::Flow { src: 0, dst: 3 }), 1.0);
        // ratio 4 on 2-host racks: agg capacity 2/4 = 0.5 bottlenecks
        let over = Cluster::oversubscribed(4, 2, 4.0);
        assert_eq!(over.solo_rate(&SimKind::Flow { src: 0, dst: 3 }), 0.5);
        assert_eq!(over.solo_rate(&SimKind::Flow { src: 0, dst: 1 }), 1.0);
        // degraded core caps the compute rate
        let mut deg = Cluster::uniform(2);
        deg.hosts[1].cores = 0.25;
        assert_eq!(deg.solo_rate(&SimKind::Compute { host: 1 }), 0.25);
        // beefy resources never push the rate above 1
        let beefy = Cluster::with_cores(1, 8.0);
        assert_eq!(beefy.solo_rate(&SimKind::Compute { host: 0 }), 1.0);
    }

    #[test]
    fn cluster_json_roundtrip() {
        let mut c = Cluster::oversubscribed(3, 2, 4.0);
        c.hosts[1].nic_up = 0.5;
        let j = c.to_json();
        let back = Cluster::from_json(&j).unwrap();
        assert_eq!(back.n_hosts(), 3);
        assert_eq!(back.hosts[1].nic_up, 0.5);
        assert_eq!(back.topology, c.topology);
        assert_eq!(back.capacities(), c.capacities());
    }

    #[test]
    fn cluster_json_host_count_form() {
        let j = Json::parse(r#"{"hosts": 4, "topology": {"kind": "bigswitch"}}"#).unwrap();
        let c = Cluster::from_json(&j).unwrap();
        assert_eq!(c.n_hosts(), 4);
        assert_eq!(c.capacities(), vec![1.0; 12]);
    }

    #[test]
    fn cluster_json_rejects_bad_host_counts() {
        for bad in [r#"{"hosts": 1e18}"#, r#"{"hosts": -3}"#, r#"{"hosts": 2.7}"#] {
            let j = Json::parse(bad).unwrap();
            assert!(Cluster::from_json(&j).is_err(), "must reject {bad}");
        }
    }

    #[test]
    fn cluster_json_rejects_bad_host_fields() {
        for bad in [
            r#"{"hosts": [{"nic_up": -1}]}"#,
            r#"{"hosts": [{"cores": 1e999}]}"#,
            r#"{"hosts": [{"nic_down": "fast"}]}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(Cluster::from_json(&j).is_err(), "must reject {bad}");
        }
    }
}
