//! Mid-simulation cluster dynamics: fabric churn as first-class events.
//!
//! A [`DynTimeline`] is a deterministic, time-sorted list of
//! [`DynEvent`]s — link capacity degradation/restore, full link failure
//! (with `ParallelFabrics` path re-selection in the engine), host
//! slowdowns/stragglers, and host churn (a host leaving is a slowdown
//! to zero; rejoining restores it). The engine folds the timeline into
//! its event loop as a new event class: when simulated time reaches the
//! next entry, effective base capacities are rescaled, touched
//! contention components are dirtied, failed-trunk flows are rerouted,
//! and the finish-time horizon is re-armed (see `sim/engine.rs` step 0).
//!
//! Semantics are *absolute*, not cumulative: `Degrade { factor }` sets
//! the link's capacity multiplier to `factor` (so a second degrade of
//! the same link overwrites the first rather than compounding), and
//! `Restore` sets it back to `1.0`. This makes capacity flaps
//! (degrade/restore cycles) exact round trips: after a restore the
//! effective capacity is bit-identical to the pre-failure value.
//!
//! [`DynState`] is the engine-side cursor: per-slot link factors,
//! per-host factors, and the index of the next pending event. It lives
//! in `SimScratch` so warm re-runs reuse its buffers.

use crate::sim::spec::Cluster;
use crate::sim::topology::Topology;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// A named capacity-bearing resource slot: per-host slots by role, or a
/// fabric extra (aggregation link / parallel-fabric trunk).
///
/// String spelling (CLI / scenario JSON): `core:H`, `up:H`, `down:H`,
/// `agg_up:R`, `agg_down:R`, `trunk:J`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkRef {
    /// Host `h`'s compute slot.
    Core(usize),
    /// Host `h`'s NIC uplink.
    NicUp(usize),
    /// Host `h`'s NIC downlink.
    NicDown(usize),
    /// Rack `r`'s aggregation uplink (leaf/spine topologies only).
    AggUp(usize),
    /// Rack `r`'s aggregation downlink (leaf/spine topologies only).
    AggDown(usize),
    /// Parallel fabric `j`'s trunk (`ParallelFabrics` only).
    Trunk(usize),
}

impl LinkRef {
    /// Flat arena slot of this link for a cluster with `n_hosts` hosts.
    pub fn slot(&self, n_hosts: usize) -> usize {
        match *self {
            LinkRef::Core(h) => 3 * h,
            LinkRef::NicUp(h) => 3 * h + 1,
            LinkRef::NicDown(h) => 3 * h + 2,
            LinkRef::AggUp(r) => Topology::agg_up(r, n_hosts),
            LinkRef::AggDown(r) => Topology::agg_down(r, n_hosts),
            LinkRef::Trunk(j) => Topology::trunk(j, n_hosts),
        }
    }

    /// Stable string spelling, inverse of [`LinkRef::parse`].
    pub fn label(&self) -> String {
        match *self {
            LinkRef::Core(h) => format!("core:{h}"),
            LinkRef::NicUp(h) => format!("up:{h}"),
            LinkRef::NicDown(h) => format!("down:{h}"),
            LinkRef::AggUp(r) => format!("agg_up:{r}"),
            LinkRef::AggDown(r) => format!("agg_down:{r}"),
            LinkRef::Trunk(j) => format!("trunk:{j}"),
        }
    }

    /// Parse a `kind:index` spelling (see type docs).
    pub fn parse(s: &str) -> Result<LinkRef, String> {
        let (kind, idx) = s
            .split_once(':')
            .ok_or_else(|| format!("link `{s}`: expected kind:index"))?;
        let i: usize = idx
            .parse()
            .map_err(|_| format!("link `{s}`: bad index `{idx}`"))?;
        match kind {
            "core" => Ok(LinkRef::Core(i)),
            "up" => Ok(LinkRef::NicUp(i)),
            "down" => Ok(LinkRef::NicDown(i)),
            "agg_up" => Ok(LinkRef::AggUp(i)),
            "agg_down" => Ok(LinkRef::AggDown(i)),
            "trunk" => Ok(LinkRef::Trunk(i)),
            _ => Err(format!(
                "link `{s}`: unknown kind `{kind}` (core|up|down|agg_up|agg_down|trunk)"
            )),
        }
    }

    /// Check the reference resolves to a real slot of `cluster`.
    pub fn validate(&self, cluster: &Cluster) -> Result<(), String> {
        let n = cluster.n_hosts();
        match *self {
            LinkRef::Core(h) | LinkRef::NicUp(h) | LinkRef::NicDown(h) => {
                if h >= n {
                    return Err(format!(
                        "link `{}`: host {h} out of range (n_hosts = {n})",
                        self.label()
                    ));
                }
            }
            LinkRef::AggUp(r) | LinkRef::AggDown(r) => match cluster.topology {
                Topology::Oversubscribed { racks, .. } if r < racks => {}
                Topology::Oversubscribed { racks, .. } => {
                    return Err(format!(
                        "link `{}`: rack {r} out of range (racks = {racks})",
                        self.label()
                    ));
                }
                _ => {
                    return Err(format!(
                        "link `{}`: topology has no aggregation links",
                        self.label()
                    ));
                }
            },
            LinkRef::Trunk(j) => match cluster.topology {
                Topology::ParallelFabrics { k, .. } if j < k => {}
                Topology::ParallelFabrics { k, .. } => {
                    return Err(format!(
                        "link `{}`: fabric {j} out of range (k = {k})",
                        self.label()
                    ));
                }
                _ => {
                    return Err(format!(
                        "link `{}`: topology has no parallel-fabric trunks",
                        self.label()
                    ));
                }
            },
        }
        Ok(())
    }
}

/// One cluster-state mutation. Factors are absolute multipliers on the
/// link's or host's base capacity (`0.0` = failed/offline, `1.0` =
/// healthy); they overwrite rather than compound.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DynAction {
    /// Set `link`'s capacity multiplier to `factor` (`0.0` = failure).
    Degrade { link: LinkRef, factor: f64 },
    /// Set `link`'s multiplier back to `1.0`.
    Restore { link: LinkRef },
    /// Scale all three of `host`'s slots (core, NIC up, NIC down) by
    /// `factor` — a straggler (`0 < factor < 1`) or a departure (`0.0`).
    SlowHost { host: usize, factor: f64 },
    /// Set `host`'s multiplier back to `1.0` (a churned host rejoins).
    RestoreHost { host: usize },
    /// `host` crashes: capacity-wise identical to
    /// `SlowHost { factor: 0.0 }`, but under
    /// [`RecoveryPolicy::Retry`](crate::sim::recovery::RecoveryPolicy)
    /// the engine additionally *kills* every in-flight task whose
    /// footprint touches the host — progress is lost, bytes reset to
    /// full, and the task re-enters behind an exponential-backoff gate
    /// (see `sim/recovery.rs`). Under `FailFast` the two are
    /// indistinguishable. A later [`DynAction::RestoreHost`] brings the
    /// host back.
    FailHost { host: usize },
}

/// A [`DynAction`] scheduled at simulated time `at`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DynEvent {
    pub at: f64,
    pub action: DynAction,
}

/// A time-sorted sequence of [`DynEvent`]s. Equal-time events keep
/// insertion order (applied in that order within one engine event).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DynTimeline {
    events: Vec<DynEvent>,
}

impl DynTimeline {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// The sorted event list.
    pub fn events(&self) -> &[DynEvent] {
        &self.events
    }

    /// Insert an event, keeping the list sorted by time (stable: an
    /// event lands after existing events with the same `at`).
    pub fn push(&mut self, at: f64, action: DynAction) {
        let i = self.events.partition_point(|e| e.at <= at);
        self.events.insert(i, DynEvent { at, action });
    }

    /// Chainable [`DynTimeline::push`].
    pub fn with(mut self, at: f64, action: DynAction) -> Self {
        self.push(at, action);
        self
    }

    /// Merge `other`'s events into `self`, preserving **last-writer-wins
    /// order for same-timestamp events**: every event keeps its relative
    /// order within its source timeline, and at any shared timestamp
    /// `other`'s events land *after* `self`'s — exactly as if they had
    /// been [`push`](DynTimeline::push)ed one by one in `other`'s order.
    /// Factors are absolute (they overwrite, not compound), and the
    /// engine applies all same-instant events atomically in list order,
    /// so this ordering guarantee is what makes a merged timeline replay
    /// bit-identically to the individually-pushed spelling — flap storms
    /// routinely put a degrade and a restore of the same link on the
    /// same instant, where any reordering would flip the surviving
    /// factor (prop-tested in `tests/prop_recovery_equivalence.rs`).
    pub fn merge(&mut self, other: &DynTimeline) {
        for e in other.events.iter() {
            self.push(e.at, e.action);
        }
    }

    /// A capacity flap: degrade `link` to `factor` at `period`,
    /// restore at `2 * period`, degrade again at `3 * period`, … while
    /// the event time stays `< until`.
    pub fn flap(link: LinkRef, factor: f64, period: f64, until: f64) -> Self {
        let mut tl = Self::new();
        let mut t = period;
        let mut down = true;
        while t < until {
            let action = if down {
                DynAction::Degrade { link, factor }
            } else {
                DynAction::Restore { link }
            };
            tl.push(t, action);
            down = !down;
            t += period;
        }
        tl
    }

    /// A seeded random timeline over `cluster`'s links: `n_events`
    /// degrade/restore/slow-host events with factors in
    /// `[0.1, 1.0]` (never a full failure — callers that want failures
    /// add them explicitly), times in `(0, t_max)`. Deterministic in
    /// `seed`; used by the equivalence property tests and the bench.
    pub fn random(seed: u64, cluster: &Cluster, n_events: usize, t_max: f64) -> Self {
        let mut rng = Rng::new(seed);
        let n = cluster.n_hosts();
        let mut tl = Self::new();
        for _ in 0..n_events {
            let at = rng.range_f64(0.0, t_max).max(1e-3);
            let roll = rng.below(8);
            let action = match roll {
                0 => DynAction::SlowHost {
                    host: rng.below(n),
                    factor: rng.range_f64(0.1, 1.0),
                },
                1 => DynAction::RestoreHost { host: rng.below(n) },
                2 | 3 => DynAction::Restore {
                    link: Self::random_link(&mut rng, cluster),
                },
                _ => DynAction::Degrade {
                    link: Self::random_link(&mut rng, cluster),
                    factor: rng.range_f64(0.1, 1.0),
                },
            };
            tl.push(at, action);
        }
        tl
    }

    fn random_link(rng: &mut Rng, cluster: &Cluster) -> LinkRef {
        let n = cluster.n_hosts();
        match cluster.topology {
            Topology::BigSwitch => match rng.below(3) {
                0 => LinkRef::Core(rng.below(n)),
                1 => LinkRef::NicUp(rng.below(n)),
                _ => LinkRef::NicDown(rng.below(n)),
            },
            Topology::Oversubscribed { racks, .. } => match rng.below(5) {
                0 => LinkRef::Core(rng.below(n)),
                1 => LinkRef::NicUp(rng.below(n)),
                2 => LinkRef::NicDown(rng.below(n)),
                3 => LinkRef::AggUp(rng.below(racks)),
                _ => LinkRef::AggDown(rng.below(racks)),
            },
            Topology::ParallelFabrics { k, .. } => match rng.below(5) {
                0 => LinkRef::Core(rng.below(n)),
                1 => LinkRef::NicUp(rng.below(n)),
                2 => LinkRef::NicDown(rng.below(n)),
                _ => LinkRef::Trunk(rng.below(k)),
            },
        }
    }

    /// Check every event against `cluster`: link references must
    /// resolve, times and factors must be finite and non-negative.
    pub fn validate(&self, cluster: &Cluster) -> Result<(), String> {
        let n = cluster.n_hosts();
        for (i, e) in self.events.iter().enumerate() {
            if !e.at.is_finite() || e.at < 0.0 {
                return Err(format!("dynamics[{i}]: bad time {}", e.at));
            }
            match e.action {
                DynAction::Degrade { link, factor } => {
                    link.validate(cluster)?;
                    if !factor.is_finite() || factor < 0.0 {
                        return Err(format!("dynamics[{i}]: bad factor {factor}"));
                    }
                }
                DynAction::Restore { link } => link.validate(cluster)?,
                DynAction::SlowHost { host, factor } => {
                    if host >= n {
                        return Err(format!(
                            "dynamics[{i}]: host {host} out of range (n_hosts = {n})"
                        ));
                    }
                    if !factor.is_finite() || factor < 0.0 {
                        return Err(format!("dynamics[{i}]: bad factor {factor}"));
                    }
                }
                DynAction::RestoreHost { host } | DynAction::FailHost { host } => {
                    if host >= n {
                        return Err(format!(
                            "dynamics[{i}]: host {host} out of range (n_hosts = {n})"
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Parse a JSON array of event objects:
    ///
    /// ```json
    /// [{"at": 2.0, "kind": "degrade", "link": "trunk:1", "factor": 0.5},
    ///  {"at": 3.0, "kind": "fail",    "link": "up:0"},
    ///  {"at": 4.0, "kind": "restore", "link": "trunk:1"},
    ///  {"at": 1.0, "kind": "slow_host",    "host": 3, "factor": 0.25},
    ///  {"at": 2.5, "kind": "fail_host",    "host": 3},
    ///  {"at": 5.0, "kind": "restore_host", "host": 3}]
    /// ```
    ///
    /// `fail` is shorthand for `degrade` with factor `0.0`; `fail_host`
    /// is a crash that kills in-flight work under retry recovery (see
    /// [`DynAction::FailHost`]).
    pub fn from_json(j: &Json) -> Result<DynTimeline, String> {
        let arr = j.as_arr().map_err(|e| format!("dynamics: {e}"))?;
        let mut tl = DynTimeline::new();
        for (i, ev) in arr.iter().enumerate() {
            let ctx = |e: &dyn std::fmt::Display| format!("dynamics[{i}]: {e}");
            let at = ev.get("at").and_then(|v| v.as_f64()).map_err(|e| ctx(&e))?;
            let kind = ev.get("kind").and_then(|v| v.as_str()).map_err(|e| ctx(&e))?;
            let link = |key: &str| -> Result<LinkRef, String> {
                let s = ev.get(key).and_then(|v| v.as_str()).map_err(|e| ctx(&e))?;
                LinkRef::parse(s).map_err(|e| ctx(&e))
            };
            let host = || ev.get("host").and_then(|v| v.as_usize()).map_err(|e| ctx(&e));
            let factor = || ev.get("factor").and_then(|v| v.as_f64()).map_err(|e| ctx(&e));
            let action = match kind {
                "degrade" => DynAction::Degrade { link: link("link")?, factor: factor()? },
                "fail" => DynAction::Degrade { link: link("link")?, factor: 0.0 },
                "restore" => DynAction::Restore { link: link("link")? },
                "slow_host" => DynAction::SlowHost { host: host()?, factor: factor()? },
                "restore_host" => DynAction::RestoreHost { host: host()? },
                "fail_host" => DynAction::FailHost { host: host()? },
                _ => {
                    return Err(format!(
                        "dynamics[{i}]: unknown kind `{kind}` \
                         (degrade|fail|restore|slow_host|fail_host|restore_host)"
                    ))
                }
            };
            tl.push(at, action);
        }
        Ok(tl)
    }

    /// Serialize to the [`DynTimeline::from_json`] format.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.events
                .iter()
                .map(|e| match e.action {
                    DynAction::Degrade { link, factor } => Json::obj(vec![
                        ("at", Json::Num(e.at)),
                        ("kind", Json::Str("degrade".into())),
                        ("link", Json::Str(link.label())),
                        ("factor", Json::Num(factor)),
                    ]),
                    DynAction::Restore { link } => Json::obj(vec![
                        ("at", Json::Num(e.at)),
                        ("kind", Json::Str("restore".into())),
                        ("link", Json::Str(link.label())),
                    ]),
                    DynAction::SlowHost { host, factor } => Json::obj(vec![
                        ("at", Json::Num(e.at)),
                        ("kind", Json::Str("slow_host".into())),
                        ("host", Json::Num(host as f64)),
                        ("factor", Json::Num(factor)),
                    ]),
                    DynAction::RestoreHost { host } => Json::obj(vec![
                        ("at", Json::Num(e.at)),
                        ("kind", Json::Str("restore_host".into())),
                        ("host", Json::Num(host as f64)),
                    ]),
                    DynAction::FailHost { host } => Json::obj(vec![
                        ("at", Json::Num(e.at)),
                        ("kind", Json::Str("fail_host".into())),
                        ("host", Json::Num(host as f64)),
                    ]),
                })
                .collect(),
        )
    }
}

/// Engine-side cursor over a [`DynTimeline`]: the current per-slot link
/// factors, per-host factors, and the next pending event index. Owned
/// by `SimScratch` so its buffers survive across warm runs.
#[derive(Debug, Default)]
pub struct DynState {
    /// Per-resource-slot capacity multiplier (fabric extras included).
    link_factor: Vec<f64>,
    /// Per-host multiplier, applied on top of the three host slots.
    host_factor: Vec<f64>,
    /// Index of the next unapplied timeline event.
    cursor: usize,
}

impl DynState {
    /// Reset to the healthy state (all factors `1.0`, cursor at 0).
    pub fn reset(&mut self, n_res: usize, n_hosts: usize) {
        self.link_factor.clear();
        self.link_factor.resize(n_res, 1.0);
        self.host_factor.clear();
        self.host_factor.resize(n_hosts, 1.0);
        self.cursor = 0;
    }

    /// Time of the next unapplied event, if any.
    pub fn next_at(&self, tl: &DynTimeline) -> Option<f64> {
        tl.events.get(self.cursor).map(|e| e.at)
    }

    /// Effective multiplier for slot `r`: the link factor, times the
    /// host factor when `r` is one of the `3 * n_hosts` host slots.
    pub fn factor_of(&self, r: usize, n_hosts: usize) -> f64 {
        let f = self.link_factor[r];
        if r < 3 * n_hosts {
            f * self.host_factor[r / 3]
        } else {
            f
        }
    }

    /// Whether the fabric link occupying slot `r` is up (host factors
    /// do not apply to fabric extras).
    pub fn link_alive(&self, r: usize) -> bool {
        self.link_factor[r] > 0.0
    }

    /// Apply every event with `at <= now + eps`, rescaling
    /// `caps0[r] = base[r] * factor_of(r)` for each touched slot.
    /// Touched slots are recorded in `touched`/`touched_list`
    /// (deduplicated; the caller clears the marks after consuming the
    /// list). Hosts crashed by a due [`DynAction::FailHost`] are
    /// appended to `failed_hosts` (not deduplicated — one entry per
    /// crash event) so the engine's retry layer can kill their
    /// in-flight work. Returns `true` if any fabric-extra slot (`r >=
    /// 3 * n_hosts`) was touched — the signal that `ParallelFabrics`
    /// path re-selection must re-run.
    #[allow(clippy::too_many_arguments)]
    pub fn apply_due(
        &mut self,
        tl: &DynTimeline,
        now: f64,
        eps: f64,
        n_hosts: usize,
        base: &[f64],
        caps0: &mut [f64],
        touched: &mut [bool],
        touched_list: &mut Vec<usize>,
        failed_hosts: &mut Vec<usize>,
    ) -> bool {
        let mut extra_touched = false;
        let mut touch = |r: usize,
                         touched: &mut [bool],
                         touched_list: &mut Vec<usize>| {
            if !touched[r] {
                touched[r] = true;
                touched_list.push(r);
            }
            if r >= 3 * n_hosts {
                extra_touched = true;
            }
        };
        while let Some(e) = tl.events.get(self.cursor) {
            if e.at > now + eps {
                break;
            }
            self.cursor += 1;
            match e.action {
                DynAction::Degrade { link, factor } => {
                    let r = link.slot(n_hosts);
                    self.link_factor[r] = factor;
                    touch(r, touched, touched_list);
                }
                DynAction::Restore { link } => {
                    let r = link.slot(n_hosts);
                    self.link_factor[r] = 1.0;
                    touch(r, touched, touched_list);
                }
                DynAction::SlowHost { host, factor } => {
                    self.host_factor[host] = factor;
                    for r in 3 * host..3 * host + 3 {
                        touch(r, touched, touched_list);
                    }
                }
                DynAction::RestoreHost { host } => {
                    self.host_factor[host] = 1.0;
                    for r in 3 * host..3 * host + 3 {
                        touch(r, touched, touched_list);
                    }
                }
                DynAction::FailHost { host } => {
                    self.host_factor[host] = 0.0;
                    for r in 3 * host..3 * host + 3 {
                        touch(r, touched, touched_list);
                    }
                    failed_hosts.push(host);
                }
            }
        }
        for &r in touched_list.iter() {
            caps0[r] = base[r] * self.factor_of(r, n_hosts);
        }
        extra_touched
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_ref_parse_label_round_trip() {
        for s in ["core:0", "up:3", "down:7", "agg_up:1", "agg_down:0", "trunk:2"] {
            let l = LinkRef::parse(s).unwrap();
            assert_eq!(l.label(), s);
        }
        assert!(LinkRef::parse("nope:1").is_err());
        assert!(LinkRef::parse("trunk").is_err());
        assert!(LinkRef::parse("up:x").is_err());
    }

    #[test]
    fn link_ref_slots_match_arena_layout() {
        let n = 4;
        assert_eq!(LinkRef::Core(2).slot(n), 6);
        assert_eq!(LinkRef::NicUp(2).slot(n), 7);
        assert_eq!(LinkRef::NicDown(2).slot(n), 8);
        assert_eq!(LinkRef::AggUp(1).slot(n), Topology::agg_up(1, n));
        assert_eq!(LinkRef::Trunk(0).slot(n), Topology::trunk(0, n));
    }

    #[test]
    fn link_ref_validate_checks_topology_kind() {
        let big = Cluster::uniform(4);
        assert!(LinkRef::NicUp(3).validate(&big).is_ok());
        assert!(LinkRef::NicUp(4).validate(&big).is_err());
        assert!(LinkRef::Trunk(0).validate(&big).is_err());
        assert!(LinkRef::AggUp(0).validate(&big).is_err());

        let fab = Cluster::parallel_fabrics(4, 2, 1.5);
        assert!(LinkRef::Trunk(1).validate(&fab).is_ok());
        assert!(LinkRef::Trunk(2).validate(&fab).is_err());
        assert!(LinkRef::AggUp(0).validate(&fab).is_err());

        let over = Cluster::oversubscribed(4, 2, 2.0);
        assert!(LinkRef::AggDown(1).validate(&over).is_ok());
        assert!(LinkRef::AggDown(2).validate(&over).is_err());
    }

    #[test]
    fn timeline_push_keeps_sorted_and_stable() {
        let mut tl = DynTimeline::new();
        tl.push(2.0, DynAction::Restore { link: LinkRef::NicUp(0) });
        tl.push(1.0, DynAction::Degrade { link: LinkRef::NicUp(0), factor: 0.5 });
        tl.push(2.0, DynAction::Restore { link: LinkRef::NicUp(1) });
        let ats: Vec<f64> = tl.events().iter().map(|e| e.at).collect();
        assert_eq!(ats, vec![1.0, 2.0, 2.0]);
        // equal-time events keep insertion order
        assert_eq!(
            tl.events()[1].action,
            DynAction::Restore { link: LinkRef::NicUp(0) }
        );
        assert_eq!(
            tl.events()[2].action,
            DynAction::Restore { link: LinkRef::NicUp(1) }
        );
    }

    #[test]
    fn flap_alternates_degrade_restore() {
        let tl = DynTimeline::flap(LinkRef::Trunk(0), 0.5, 1.0, 4.5);
        assert_eq!(tl.len(), 4);
        assert!(matches!(tl.events()[0].action, DynAction::Degrade { .. }));
        assert!(matches!(tl.events()[1].action, DynAction::Restore { .. }));
        assert!(matches!(tl.events()[2].action, DynAction::Degrade { .. }));
        assert_eq!(tl.events()[3].at, 4.0);
    }

    #[test]
    fn json_round_trip() {
        let tl = DynTimeline::new()
            .with(1.0, DynAction::Degrade { link: LinkRef::Trunk(1), factor: 0.25 })
            .with(2.0, DynAction::SlowHost { host: 3, factor: 0.5 })
            .with(2.5, DynAction::FailHost { host: 2 })
            .with(3.0, DynAction::Restore { link: LinkRef::Trunk(1) })
            .with(4.0, DynAction::RestoreHost { host: 3 });
        let j = tl.to_json();
        let back = DynTimeline::from_json(&j).unwrap();
        assert_eq!(back, tl);
        // `fail` parses as a zero-factor degrade
        let j = Json::parse(r#"[{"at": 1.5, "kind": "fail", "link": "up:0"}]"#).unwrap();
        let tl = DynTimeline::from_json(&j).unwrap();
        assert_eq!(
            tl.events()[0].action,
            DynAction::Degrade { link: LinkRef::NicUp(0), factor: 0.0 }
        );
        assert!(DynTimeline::from_json(
            &Json::parse(r#"[{"at": 1, "kind": "warp", "link": "up:0"}]"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn validate_rejects_out_of_range_and_bad_factors() {
        let fab = Cluster::parallel_fabrics(4, 2, 1.5);
        let ok = DynTimeline::new()
            .with(1.0, DynAction::Degrade { link: LinkRef::Trunk(0), factor: 0.5 });
        assert!(ok.validate(&fab).is_ok());
        let bad_link = DynTimeline::new()
            .with(1.0, DynAction::Restore { link: LinkRef::Trunk(9) });
        assert!(bad_link.validate(&fab).is_err());
        let bad_factor = DynTimeline::new()
            .with(1.0, DynAction::SlowHost { host: 0, factor: -1.0 });
        assert!(bad_factor.validate(&fab).is_err());
        let bad_host = DynTimeline::new()
            .with(1.0, DynAction::RestoreHost { host: 4 });
        assert!(bad_host.validate(&fab).is_err());
        let bad_time = DynTimeline::new()
            .with(f64::NAN, DynAction::RestoreHost { host: 0 });
        assert!(bad_time.validate(&fab).is_err());
    }

    #[test]
    fn apply_due_rescales_and_marks_touched() {
        let fab = Cluster::parallel_fabrics(2, 2, 1.5);
        let n = fab.n_hosts();
        let base = fab.capacities();
        let mut caps0 = base.clone();
        let tl = DynTimeline::new()
            .with(1.0, DynAction::Degrade { link: LinkRef::Trunk(0), factor: 0.5 })
            .with(1.0, DynAction::SlowHost { host: 1, factor: 0.25 })
            .with(5.0, DynAction::Restore { link: LinkRef::Trunk(0) });
        let mut st = DynState::default();
        st.reset(fab.n_resources(), n);
        let mut touched = vec![false; fab.n_resources()];
        let mut list = Vec::new();
        let mut failed = Vec::new();

        // nothing due before t = 1
        assert!(!st.apply_due(
            &tl, 0.5, 1e-9, n, &base, &mut caps0, &mut touched, &mut list, &mut failed
        ));
        assert!(list.is_empty());
        assert_eq!(st.next_at(&tl), Some(1.0));

        // both t = 1 events land atomically; trunk touch reported
        let extra = st.apply_due(
            &tl, 1.0, 1e-9, n, &base, &mut caps0, &mut touched, &mut list, &mut failed
        );
        assert!(extra);
        let trunk0 = Topology::trunk(0, n);
        assert_eq!(caps0[trunk0], base[trunk0] * 0.5);
        for r in 3..6 {
            assert_eq!(caps0[r], base[r] * 0.25);
        }
        assert!(st.link_alive(trunk0)); // degraded but not failed
        assert_eq!(list.len(), 4); // trunk + 3 host slots, deduped
        assert_eq!(st.next_at(&tl), Some(5.0));
        for &r in &list {
            touched[r] = false;
        }
        list.clear();

        // restore is an exact round trip; nothing crashed along the way
        st.apply_due(&tl, 5.0, 1e-9, n, &base, &mut caps0, &mut touched, &mut list, &mut failed);
        assert_eq!(caps0[trunk0].to_bits(), base[trunk0].to_bits());
        assert_eq!(st.next_at(&tl), None);
        assert!(failed.is_empty());
    }

    #[test]
    fn fail_host_zeroes_slots_and_reports_the_crash() {
        let fab = Cluster::parallel_fabrics(2, 2, 1.5);
        let n = fab.n_hosts();
        let base = fab.capacities();
        let mut caps0 = base.clone();
        let tl = DynTimeline::new()
            .with(1.0, DynAction::FailHost { host: 1 })
            .with(3.0, DynAction::RestoreHost { host: 1 });
        let mut st = DynState::default();
        st.reset(fab.n_resources(), n);
        let mut touched = vec![false; fab.n_resources()];
        let mut list = Vec::new();
        let mut failed = Vec::new();

        st.apply_due(&tl, 1.0, 1e-9, n, &base, &mut caps0, &mut touched, &mut list, &mut failed);
        assert_eq!(failed, vec![1]);
        for r in 3..6 {
            assert_eq!(caps0[r], 0.0);
        }
        for &r in &list {
            touched[r] = false;
        }
        list.clear();
        failed.clear();

        // the rejoin is a bit-exact round trip and reports no crash
        st.apply_due(&tl, 3.0, 1e-9, n, &base, &mut caps0, &mut touched, &mut list, &mut failed);
        assert!(failed.is_empty());
        for r in 3..6 {
            assert_eq!(caps0[r].to_bits(), base[r].to_bits());
        }
    }

    #[test]
    fn merge_preserves_last_writer_wins_at_equal_times() {
        let up = LinkRef::NicUp(0);
        let mut a = DynTimeline::new()
            .with(1.0, DynAction::Degrade { link: up, factor: 0.5 })
            .with(2.0, DynAction::Degrade { link: up, factor: 0.25 });
        let b = DynTimeline::new()
            .with(2.0, DynAction::Restore { link: up })
            .with(3.0, DynAction::FailHost { host: 1 });
        a.merge(&b);
        // sorted, and at t = 2 `b`'s restore lands AFTER `a`'s degrade,
        // so the restore is the surviving writer at that instant
        let ats: Vec<f64> = a.events().iter().map(|e| e.at).collect();
        assert_eq!(ats, vec![1.0, 2.0, 2.0, 3.0]);
        assert_eq!(a.events()[1].action, DynAction::Degrade { link: up, factor: 0.25 });
        assert_eq!(a.events()[2].action, DynAction::Restore { link: up });
        // merged == individually pushed in the same order
        let pushed = DynTimeline::new()
            .with(1.0, DynAction::Degrade { link: up, factor: 0.5 })
            .with(2.0, DynAction::Degrade { link: up, factor: 0.25 })
            .with(2.0, DynAction::Restore { link: up })
            .with(3.0, DynAction::FailHost { host: 1 });
        assert_eq!(a, pushed);
    }

    #[test]
    fn random_timeline_is_deterministic_and_valid() {
        let fab = Cluster::parallel_fabrics(6, 3, 1.5);
        let a = DynTimeline::random(42, &fab, 20, 10.0);
        let b = DynTimeline::random(42, &fab, 20, 10.0);
        assert_eq!(a, b);
        assert_eq!(a.len(), 20);
        a.validate(&fab).unwrap();
        let c = DynTimeline::random(43, &fab, 20, 10.0);
        assert_ne!(a, c);
        // sorted
        for w in a.events().windows(2) {
            assert!(w[0].at <= w[1].at);
        }
    }
}
