//! Rate allocation: who gets how much of each NIC / core / fabric link
//! right now.
//!
//! All policies operate on the same fluid model: every active task draws
//! on a small set of resources (a core; or src-NIC-up + dst-NIC-down
//! plus whatever fabric links the [`Topology`](super::topology::Topology)
//! routes it through) and can run at rate ≤ 1. Policies differ in how
//! contended capacity is divided:
//!
//! * **max-min fair** — progressive filling (the network-aware baseline);
//! * **strict priority** — higher priority first, fair within a level
//!   (how the MXDAG co-scheduler expresses critical-path preference);
//! * **coflow (Varys)** — SEBF group ordering + MADD rates so all flows
//!   of a coflow finish together (the abstraction Fig. 2 critiques).
//!
//! Hot path note (§Perf): these run on every simulator event, so they
//! work on flat precomputed resource arrays ([`TaskRes`]) — no maps, no
//! per-iteration allocation, no task cloning. A task's footprint is
//! variable-arity but bounded by [`MAX_TASK_RES`] so it stays `Copy`.

use std::collections::BTreeMap;

use super::spec::{SimDag, SimKind};

const EPS: f64 = 1e-12;

/// Maximum resources one task can touch (core | up + down + agg_up +
/// agg_down is the widest current footprint).
pub const MAX_TASK_RES: usize = 4;

/// Precomputed resource footprint of one task (≤ [`MAX_TASK_RES`]
/// resources: endpoint NICs plus up to two fabric links).
#[derive(Debug, Clone, Copy, Default)]
pub struct TaskRes {
    pub res: [usize; MAX_TASK_RES],
    pub n: u8,
}

impl TaskRes {
    /// Big-switch footprint (endpoint NICs only). Topology-aware callers
    /// use [`Cluster::task_res`](super::spec::Cluster::task_res).
    pub fn of(kind: &SimKind) -> TaskRes {
        let mut tr = TaskRes::default();
        match *kind {
            SimKind::Compute { host } => tr.push(super::spec::res_core(host)),
            SimKind::Flow { src, dst } => {
                tr.push(super::spec::res_up(src));
                tr.push(super::spec::res_down(dst));
            }
            SimKind::Dummy => {}
        }
        tr
    }

    /// Append a resource index (panics past [`MAX_TASK_RES`]).
    #[inline]
    pub fn push(&mut self, r: usize) {
        self.res[self.n as usize] = r;
        self.n += 1;
    }

    #[inline]
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.res[..self.n as usize].iter().copied()
    }
}

/// Max-min progressive filling. `tasks[i]` are the active tasks'
/// resource footprints; `caps` is mutated to residuals; `rates[i]` is
/// written per active index. `users` is caller-provided scratch of
/// `caps.len()` (reset internally).
pub fn maxmin_fill_res(
    tasks: &[TaskRes],
    caps: &mut [f64],
    rates: &mut [f64],
    users: &mut [f64],
) {
    debug_assert_eq!(users.len(), caps.len());
    let n = tasks.len();
    let mut frozen: Vec<bool> = tasks.iter().map(|t| t.n == 0).collect();
    loop {
        // count unfrozen users per resource
        for u in users.iter_mut() {
            *u = 0.0;
        }
        let mut n_unfrozen = 0usize;
        for (i, t) in tasks.iter().enumerate() {
            if frozen[i] {
                continue;
            }
            n_unfrozen += 1;
            for r in t.iter() {
                users[r] += 1.0;
            }
        }
        if n_unfrozen == 0 {
            break;
        }
        // largest uniform increment bounded by residual/users and
        // per-task headroom to rate 1
        let mut delta = f64::INFINITY;
        for (i, t) in tasks.iter().enumerate() {
            if frozen[i] {
                continue;
            }
            delta = delta.min(1.0 - rates[i]);
            for r in t.iter() {
                delta = delta.min(caps[r].max(0.0) / users[r]);
            }
        }
        if delta > EPS {
            for (i, t) in tasks.iter().enumerate() {
                if frozen[i] {
                    continue;
                }
                rates[i] += delta;
                for r in t.iter() {
                    caps[r] -= delta;
                }
            }
        }
        // freeze saturated / capped tasks; stop when nothing moves
        let mut any_unfrozen = false;
        let mut any_frozen_now = false;
        for (i, t) in tasks.iter().enumerate() {
            if frozen[i] {
                continue;
            }
            let at_cap = rates[i] >= 1.0 - EPS;
            let starved = t.iter().any(|r| caps[r] <= EPS);
            if at_cap || starved {
                frozen[i] = true;
                any_frozen_now = true;
            } else {
                any_unfrozen = true;
            }
        }
        if !any_unfrozen {
            break;
        }
        if delta <= EPS && !any_frozen_now {
            break; // numerically stuck
        }
        let _ = n;
    }
}

/// Strict priority: levels high→low, max-min within a level on residuals.
pub fn priority_fill_res(
    tasks: &[TaskRes],
    prios: &[i64],
    caps: &mut [f64],
    rates: &mut [f64],
    users: &mut [f64],
) {
    let n = tasks.len();
    debug_assert_eq!(prios.len(), n);
    // sort indices by priority descending (small n: simple sort)
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(prios[i]));
    let mut level_tasks: Vec<TaskRes> = Vec::with_capacity(n);
    let mut level_idx: Vec<usize> = Vec::with_capacity(n);
    let mut level_rates: Vec<f64> = Vec::with_capacity(n);
    let mut k = 0;
    while k < n {
        let p = prios[order[k]];
        level_tasks.clear();
        level_idx.clear();
        while k < n && prios[order[k]] == p {
            level_idx.push(order[k]);
            level_tasks.push(tasks[order[k]]);
            k += 1;
        }
        level_rates.clear();
        level_rates.resize(level_tasks.len(), 0.0);
        maxmin_fill_res(&level_tasks, caps, &mut level_rates, users);
        for (j, &i) in level_idx.iter().enumerate() {
            rates[i] = level_rates[j];
        }
    }
}

/// Varys-style coflow allocation over the active *flows*: SEBF group
/// ordering + MADD rates on residual capacity. Ungrouped flows are
/// singleton groups. `remaining[i]` per active index. `caps0` holds the
/// *full* capacities: the SEBF bottleneck of a group is its completion
/// lower bound `max_r load_r / caps0[r]`, so narrow fabric links (e.g.
/// an oversubscribed aggregation uplink) correctly dominate wide NICs.
///
/// This whole-active-set form is the *reference implementation*: the
/// engine's incremental path keeps the same bounds as ready-queue keys
/// (`engine::sebf_bound_single` / `engine::sebf_bound_group`) and runs
/// the identical MADD per queue level — a semantic change here must be
/// mirrored there (the `prop_queue_equivalence` suite and the engine's
/// coflow tests guard the pairing).
pub fn coflow_fill_res(
    tasks: &[TaskRes],
    coflow: &[Option<usize>],
    remaining: &[f64],
    caps0: &[f64],
    caps: &mut [f64],
    rates: &mut [f64],
) {
    let mut groups: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
    for i in 0..tasks.len() {
        let key = match coflow[i] {
            Some(g) => (0usize, g),
            None => (1usize, i),
        };
        groups.entry(key).or_default().push(i);
    }

    // SEBF: smallest bottleneck-completion-bound first (on full capacity)
    let mut ordered: Vec<(f64, Vec<usize>)> = groups
        .into_values()
        .map(|members| {
            let mut per_res: BTreeMap<usize, f64> = BTreeMap::new();
            let mut max_rem: f64 = 0.0;
            for &i in &members {
                max_rem = max_rem.max(remaining[i]);
                for r in tasks[i].iter() {
                    *per_res.entry(r).or_insert(0.0) += remaining[i];
                }
            }
            let bottleneck = per_res
                .iter()
                .map(|(&r, &load)| {
                    if caps0[r] <= EPS {
                        f64::INFINITY
                    } else {
                        load / caps0[r]
                    }
                })
                .fold(max_rem, f64::max);
            (bottleneck, members)
        })
        .collect();
    ordered.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

    for (_, members) in ordered {
        // MADD: all members finish at the same τ, feasible on residuals
        let mut tau: f64 = 0.0;
        let mut per_res: BTreeMap<usize, f64> = BTreeMap::new();
        for &i in &members {
            tau = tau.max(remaining[i]); // rate ≤ 1 per flow
            for r in tasks[i].iter() {
                *per_res.entry(r).or_insert(0.0) += remaining[i];
            }
        }
        for (&r, &load) in &per_res {
            if caps[r] <= EPS {
                tau = f64::INFINITY;
            } else {
                tau = tau.max(load / caps[r]);
            }
        }
        if !tau.is_finite() || tau <= EPS {
            continue;
        }
        for &i in &members {
            let rate = remaining[i] / tau;
            rates[i] = rate;
            for r in tasks[i].iter() {
                caps[r] = (caps[r] - rate).max(0.0);
            }
        }
    }
}

// ------------------------------------------------------------------
// Compatibility wrappers over &SimDag + task-id subsets (tests, tools).
// ------------------------------------------------------------------

fn subset_res(dag: &SimDag, active: &[usize]) -> Vec<TaskRes> {
    active.iter().map(|&t| TaskRes::of(&dag.tasks[t].kind)).collect()
}

/// Max-min fair over a task-id subset (wrapper; see `maxmin_fill_res`).
pub fn maxmin_fill(dag: &SimDag, active: &[usize], caps: &mut [f64], rates: &mut [f64]) {
    let tasks = subset_res(dag, active);
    let mut users = vec![0.0; caps.len()];
    maxmin_fill_res(&tasks, caps, rates, &mut users);
}

/// Strict priority over a task-id subset (wrapper).
pub fn priority_fill(dag: &SimDag, active: &[usize], caps: &mut [f64], rates: &mut [f64]) {
    let tasks = subset_res(dag, active);
    let prios: Vec<i64> = active.iter().map(|&t| dag.tasks[t].priority).collect();
    let mut users = vec![0.0; caps.len()];
    priority_fill_res(&tasks, &prios, caps, rates, &mut users);
}

/// Coflow allocation over a task-id subset (wrapper). `remaining` is
/// indexed by *task id* here (engine-internal layout); `caps` must hold
/// the full capacities on entry (they double as the SEBF reference).
pub fn coflow_fill(
    dag: &SimDag,
    active: &[usize],
    remaining: &[f64],
    caps: &mut [f64],
    rates: &mut [f64],
) {
    let tasks = subset_res(dag, active);
    let coflow: Vec<Option<usize>> = active.iter().map(|&t| dag.tasks[t].coflow).collect();
    let rem: Vec<f64> = active.iter().map(|&t| remaining[t]).collect();
    let caps0 = caps.to_vec();
    coflow_fill_res(&tasks, &coflow, &rem, &caps0, caps, rates);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::spec::{SimDag, SimKind, SimTask};

    fn flow(dag: &mut SimDag, src: usize, dst: usize, prio: i64, coflow: Option<usize>) -> usize {
        dag.push(SimTask {
            orig: 0,
            chunk: (0, 1),
            kind: SimKind::Flow { src, dst },
            size: 1.0,
            priority: prio,
            gate: 0.0,
            coflow,
        })
    }

    #[test]
    fn fair_shares_common_nic() {
        let mut d = SimDag::default();
        let a = flow(&mut d, 0, 1, 0, None);
        let b = flow(&mut d, 0, 2, 0, None);
        let mut caps = vec![1.0; 9];
        let mut rates = vec![0.0; 2];
        maxmin_fill(&d, &[a, b], &mut caps, &mut rates);
        assert!((rates[0] - 0.5).abs() < 1e-9);
        assert!((rates[1] - 0.5).abs() < 1e-9);
    }

    #[test]
    fn fair_no_contention_full_rate() {
        let mut d = SimDag::default();
        let a = flow(&mut d, 0, 1, 0, None);
        let b = flow(&mut d, 2, 1, 0, None); // shares only dst downlink
        let mut caps = vec![1.0; 9];
        caps[5] = 2.0; // beefy downlink on host 1
        let mut rates = vec![0.0; 2];
        maxmin_fill(&d, &[a, b], &mut caps, &mut rates);
        assert!((rates[0] - 1.0).abs() < 1e-9);
        assert!((rates[1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn fair_three_way_bottleneck() {
        let mut d = SimDag::default();
        let ids: Vec<usize> = (1..4).map(|dst| flow(&mut d, 0, dst, 0, None)).collect();
        let mut caps = vec![1.0; 12];
        let mut rates = vec![0.0; 3];
        maxmin_fill(&d, &ids, &mut caps, &mut rates);
        for r in rates {
            assert!((r - 1.0 / 3.0).abs() < 1e-9);
        }
    }

    #[test]
    fn priority_starves_lower_level() {
        let mut d = SimDag::default();
        let hi = flow(&mut d, 0, 1, 10, None);
        let lo = flow(&mut d, 0, 2, 1, None);
        let mut caps = vec![1.0; 9];
        let mut rates = vec![0.0; 2];
        priority_fill(&d, &[hi, lo], &mut caps, &mut rates);
        assert!((rates[0] - 1.0).abs() < 1e-9);
        assert!(rates[1] < 1e-9);
    }

    #[test]
    fn priority_equal_level_is_fair() {
        let mut d = SimDag::default();
        let a = flow(&mut d, 0, 1, 5, None);
        let b = flow(&mut d, 0, 2, 5, None);
        let mut caps = vec![1.0; 9];
        let mut rates = vec![0.0; 2];
        priority_fill(&d, &[a, b], &mut caps, &mut rates);
        assert!((rates[0] - 0.5).abs() < 1e-9);
        assert!((rates[1] - 0.5).abs() < 1e-9);
    }

    #[test]
    fn priority_lower_uses_leftover() {
        let mut d = SimDag::default();
        let hi = flow(&mut d, 0, 1, 10, None); // up0 + down1
        let lo = flow(&mut d, 2, 1, 1, None); // up2 + down1 (shared down)
        let mut caps = vec![1.0; 9];
        caps[5] = 1.5; // down1
        let mut rates = vec![0.0; 2];
        priority_fill(&d, &[hi, lo], &mut caps, &mut rates);
        assert!((rates[0] - 1.0).abs() < 1e-9);
        assert!((rates[1] - 0.5).abs() < 1e-9);
    }

    #[test]
    fn coflow_madd_finishes_together() {
        let mut d = SimDag::default();
        let a = flow(&mut d, 0, 1, 0, Some(0));
        let b = flow(&mut d, 0, 2, 0, Some(0));
        let mut caps = vec![1.0; 9];
        let mut rates = vec![0.0; 2];
        let mut remaining = vec![0.0; d.len()];
        remaining[a] = 2.0;
        remaining[b] = 1.0;
        coflow_fill(&d, &[a, b], &remaining, &mut caps, &mut rates);
        assert!((rates[0] - 2.0 / 3.0).abs() < 1e-9);
        assert!((rates[1] - 1.0 / 3.0).abs() < 1e-9);
        assert!((remaining[a] / rates[0] - remaining[b] / rates[1]).abs() < 1e-9);
    }

    #[test]
    fn coflow_sebf_orders_small_group_first() {
        let mut d = SimDag::default();
        let small = flow(&mut d, 0, 1, 0, Some(0));
        let big = flow(&mut d, 0, 2, 0, Some(1));
        let mut remaining = vec![0.0; d.len()];
        remaining[small] = 1.0;
        remaining[big] = 10.0;
        let mut caps = vec![1.0; 9];
        let mut rates = vec![0.0; 2];
        coflow_fill(&d, &[small, big], &remaining, &mut caps, &mut rates);
        assert!((rates[0] - 1.0).abs() < 1e-9);
        assert!(rates[1] < 1e-9);
    }

    #[test]
    fn compute_tasks_share_cores() {
        let mut d = SimDag::default();
        let mk = |d: &mut SimDag| {
            d.push(SimTask {
                orig: 0,
                chunk: (0, 1),
                kind: SimKind::Compute { host: 0 },
                size: 1.0,
                priority: 0,
                gate: 0.0,
                coflow: None,
            })
        };
        let a = mk(&mut d);
        let b = mk(&mut d);
        let mut caps = vec![1.0, 1.0, 1.0];
        let mut rates = vec![0.0; 2];
        maxmin_fill(&d, &[a, b], &mut caps, &mut rates);
        assert!((rates[0] - 0.5).abs() < 1e-9);
        assert!((rates[1] - 0.5).abs() < 1e-9);

        let mut caps = vec![2.0, 1.0, 1.0];
        let mut rates = vec![0.0; 2];
        maxmin_fill(&d, &[a, b], &mut caps, &mut rates);
        assert!((rates[0] - 1.0).abs() < 1e-9);
        assert!((rates[1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn task_res_footprints() {
        assert_eq!(TaskRes::of(&SimKind::Dummy).n, 0);
        let c = TaskRes::of(&SimKind::Compute { host: 2 });
        assert_eq!((c.n, c.res[0]), (1, 6));
        let f = TaskRes::of(&SimKind::Flow { src: 0, dst: 1 });
        assert_eq!((f.n, f.res[0], f.res[1]), (2, 1, 5));
    }

    #[test]
    fn task_res_push_variable_arity() {
        let mut tr = TaskRes::default();
        for r in [3, 9, 12, 15] {
            tr.push(r);
        }
        assert_eq!(tr.n as usize, MAX_TASK_RES);
        assert_eq!(tr.iter().collect::<Vec<_>>(), vec![3, 9, 12, 15]);
    }

    #[test]
    fn maxmin_k_resource_task() {
        // one 4-resource task: rate bounded by its narrowest resource
        let tasks = [{
            let mut tr = TaskRes::default();
            for r in 0..4 {
                tr.push(r);
            }
            tr
        }];
        let mut caps = vec![1.0, 0.25, 1.0, 0.5];
        let mut rates = vec![0.0];
        let mut users = vec![0.0; caps.len()];
        maxmin_fill_res(&tasks, &mut caps, &mut rates, &mut users);
        assert!((rates[0] - 0.25).abs() < 1e-9);
    }

    #[test]
    fn sebf_bottleneck_normalized_by_capacity() {
        // Two singleton groups with equal remaining bytes, but group B's
        // flow crosses a narrow shared link (capacity 0.25): its
        // completion bound is 4x worse, so SEBF must serve A first.
        // separate NIC pairs so only the narrow link distinguishes them
        let a = {
            let mut tr = TaskRes::default();
            tr.push(2);
            tr.push(3);
            tr
        };
        let b = {
            let mut tr = TaskRes::default();
            tr.push(0);
            tr.push(1);
            tr.push(4); // the narrow shared link
            tr
        };
        let tasks = [a, b];
        let coflow = [Some(0), Some(1)];
        let remaining = [1.0, 1.0];
        let caps0 = vec![1.0, 1.0, 1.0, 1.0, 0.25];
        let mut caps = caps0.clone();
        let mut rates = vec![0.0; 2];
        coflow_fill_res(&tasks, &coflow, &remaining, &caps0, &mut caps, &mut rates);
        // A (bound 1.0) ordered before B (bound 4.0); both can still run
        // (disjoint resources), but B is pinned to the narrow link rate.
        assert!((rates[0] - 1.0).abs() < 1e-9);
        assert!((rates[1] - 0.25).abs() < 1e-9);
    }
}
