//! Rate allocation: who gets how much of each NIC / core / fabric link
//! right now.
//!
//! All policies operate on the same fluid model: every active task draws
//! on a small set of resources (a core; or src-NIC-up + dst-NIC-down
//! plus whatever fabric links the [`Topology`](super::topology::Topology)
//! routes it through) and can run at rate ≤ 1. Policies differ in how
//! contended capacity is divided:
//!
//! * **max-min fair** — progressive filling (the network-aware baseline);
//! * **strict priority** — higher priority first, fair within a level
//!   (how the MXDAG co-scheduler expresses critical-path preference);
//! * **coflow (Varys)** — SEBF group ordering + MADD rates so all flows
//!   of a coflow finish together (the abstraction Fig. 2 critiques).
//!
//! Hot path note (§Perf): these run on every simulator event, so they
//! work on flat precomputed resource arrays ([`TaskRes`]) and reusable
//! caller-owned scratch ([`AllocScratch`]) — no maps, no per-call
//! allocation, no task cloning. A task's footprint is variable-arity but
//! bounded by [`MAX_TASK_RES`] so it stays `Copy`.
//!
//! ## Contention components
//!
//! Tasks only interact through shared resources, so progressive filling
//! decomposes exactly over the connected components of the
//! resource-sharing graph. [`maxmin_fill_res_in`] exploits this: it
//! partitions its input with a scratch union-find and fills each
//! component independently. This matters twice over. It is faster (the
//! per-round uniform increment converges per component instead of being
//! throttled by the globally tightest bottleneck), and it is what makes
//! the engine's component-wise allocation
//! ([`AllocKind::Components`](super::components::AllocKind)) **bit-for-bit
//! identical** to the whole-active-set oracle: whichever superset of
//! tasks a caller passes, each component's rates are computed by the
//! same arithmetic on the same operands. The engine-level partition
//! lives in [`CompSet`](super::components::CompSet); this module only
//! guarantees the fill itself is component-local.

use super::spec::{SimDag, SimKind};

const EPS: f64 = 1e-12;

/// Maximum resources one task can touch (core | up + down + agg_up +
/// agg_down is the widest current footprint).
pub const MAX_TASK_RES: usize = 4;

/// Precomputed resource footprint of one task (≤ [`MAX_TASK_RES`]
/// resources: endpoint NICs plus up to two fabric links).
#[derive(Debug, Clone, Copy, Default)]
pub struct TaskRes {
    pub res: [usize; MAX_TASK_RES],
    pub n: u8,
}

impl TaskRes {
    /// Big-switch footprint (endpoint NICs only). Topology-aware callers
    /// use [`Cluster::task_res`](super::spec::Cluster::task_res).
    pub fn of(kind: &SimKind) -> TaskRes {
        let mut tr = TaskRes::default();
        match *kind {
            SimKind::Compute { host } => tr.push(super::spec::res_core(host)),
            SimKind::Flow { src, dst } => {
                tr.push(super::spec::res_up(src));
                tr.push(super::spec::res_down(dst));
            }
            SimKind::Dummy => {}
        }
        tr
    }

    /// Append a resource index (panics past [`MAX_TASK_RES`]).
    #[inline]
    pub fn push(&mut self, r: usize) {
        self.res[self.n as usize] = r;
        self.n += 1;
    }

    #[inline]
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.res[..self.n as usize].iter().copied()
    }
}

/// Reusable scratch for the allocation hot path. One instance lives in
/// the engine and is threaded through every fill of a simulation, so
/// per-event allocation cost is amortised to zero (the buffers grow to
/// high-water marks once). The compatibility wrappers construct a fresh
/// one per call; hot callers must not.
#[derive(Debug, Default)]
pub struct AllocScratch {
    // progressive filling
    frozen: Vec<bool>,
    touched: Vec<usize>,
    // connected-component decomposition (per fill call)
    parent: Vec<usize>,
    res_seen: Vec<usize>,
    res_epoch: Vec<u64>,
    epoch: u64,
    comp_of: Vec<usize>,
    roots: Vec<usize>,
    comp_start: Vec<usize>,
    comp_cursor: Vec<usize>,
    comp_tasks: Vec<usize>,
    // strict-priority levels
    order: Vec<usize>,
    level_tasks: Vec<TaskRes>,
    level_idx: Vec<usize>,
    level_rates: Vec<f64>,
    // coflow grouping
    keys: Vec<(usize, usize, usize)>,
    group_span: Vec<(usize, usize)>,
    group_bounds: Vec<(f64, u32)>,
    load: Vec<f64>,
    load_seen: Vec<u64>,
    load_epoch: u64,
    load_touched: Vec<usize>,
}

impl AllocScratch {
    fn ensure(&mut self, n_tasks: usize, n_res: usize) {
        if self.frozen.len() < n_tasks {
            self.frozen.resize(n_tasks, false);
            self.parent.resize(n_tasks, 0);
            self.comp_of.resize(n_tasks, 0);
            self.roots.resize(n_tasks, usize::MAX);
        }
        if self.res_seen.len() < n_res {
            self.res_seen.resize(n_res, 0);
            self.res_epoch.resize(n_res, 0);
            self.load.resize(n_res, 0.0);
            self.load_seen.resize(n_res, 0);
        }
    }
}

/// Path-halving union-find lookup, shared by the fill-internal
/// decomposition here and [`CompSet`](super::components::CompSet)'s
/// rebuild — both partitions must agree on connectivity.
pub(crate) fn find(parent: &mut [usize], mut x: usize) -> usize {
    while parent[x] != x {
        parent[x] = parent[parent[x]];
        x = parent[x];
    }
    x
}

/// Progressive filling restricted to the task indices in `sub`, which
/// must be *resource-closed* against the rest of the call (no task
/// outside `sub` shares a resource with one inside). The arithmetic is
/// identical to the classic whole-set loop run on `sub` alone; the
/// round order over tasks does not affect the result bit-wise (counts
/// are exact integers in `f64`, the increment is a min-reduction, and
/// per-resource subtraction repeats the same operand).
fn fill_subset(
    tasks: &[TaskRes],
    sub: &[usize],
    caps: &mut [f64],
    rates: &mut [f64],
    users: &mut [f64],
    frozen: &mut [bool],
    touched: &mut Vec<usize>,
) {
    // distinct-enough resource list for cheap per-round resets
    // (duplicates are harmless: zeroing twice is zeroing)
    touched.clear();
    for &i in sub {
        for r in tasks[i].iter() {
            touched.push(r);
        }
    }
    loop {
        // count unfrozen users per resource
        for &r in touched.iter() {
            users[r] = 0.0;
        }
        let mut n_unfrozen = 0usize;
        for &i in sub {
            if frozen[i] {
                continue;
            }
            n_unfrozen += 1;
            for r in tasks[i].iter() {
                users[r] += 1.0;
            }
        }
        if n_unfrozen == 0 {
            break;
        }
        // largest uniform increment bounded by residual/users and
        // per-task headroom to rate 1
        let mut delta = f64::INFINITY;
        for &i in sub {
            if frozen[i] {
                continue;
            }
            delta = delta.min(1.0 - rates[i]);
            for r in tasks[i].iter() {
                delta = delta.min(caps[r].max(0.0) / users[r]);
            }
        }
        if delta > EPS {
            for &i in sub {
                if frozen[i] {
                    continue;
                }
                rates[i] += delta;
                for r in tasks[i].iter() {
                    caps[r] -= delta;
                }
            }
        }
        // freeze saturated / capped tasks; stop when nothing moves
        let mut any_unfrozen = false;
        let mut any_frozen_now = false;
        for &i in sub {
            if frozen[i] {
                continue;
            }
            let at_cap = rates[i] >= 1.0 - EPS;
            let starved = tasks[i].iter().any(|r| caps[r] <= EPS);
            if at_cap || starved {
                frozen[i] = true;
                any_frozen_now = true;
            } else {
                any_unfrozen = true;
            }
        }
        if !any_unfrozen {
            break;
        }
        if delta <= EPS && !any_frozen_now {
            break; // numerically stuck
        }
    }
}

/// Max-min progressive filling, decomposed over contention components
/// (see the module docs). `tasks[i]` are the active tasks' resource
/// footprints; `caps` is mutated to residuals; `rates[i]` is written per
/// active index. `users` is caller-provided scratch of `caps.len()`
/// (reset internally); `scratch` is the reusable allocation scratch.
pub fn maxmin_fill_res_in(
    tasks: &[TaskRes],
    caps: &mut [f64],
    rates: &mut [f64],
    users: &mut [f64],
    s: &mut AllocScratch,
) {
    debug_assert_eq!(users.len(), caps.len());
    let n = tasks.len();
    if n == 0 {
        return;
    }
    s.ensure(n, caps.len());
    for i in 0..n {
        s.frozen[i] = tasks[i].n == 0;
        s.parent[i] = i;
        s.roots[i] = usize::MAX;
    }
    // union tasks sharing a resource (epoch-tagged, no clearing)
    s.epoch += 1;
    for (i, t) in tasks.iter().enumerate() {
        for r in t.iter() {
            if s.res_epoch[r] == s.epoch {
                let j = s.res_seen[r];
                let (ri, rj) = (find(&mut s.parent, i), find(&mut s.parent, j));
                if ri != rj {
                    s.parent[ri] = rj;
                }
            } else {
                s.res_epoch[r] = s.epoch;
                s.res_seen[r] = i;
            }
        }
    }
    // dense component ids in order of first appearance (zero-footprint
    // tasks stay frozen and componentless, as before)
    let mut n_comps = 0usize;
    for i in 0..n {
        if tasks[i].n == 0 {
            s.comp_of[i] = usize::MAX;
            continue;
        }
        let r = find(&mut s.parent, i);
        if s.roots[r] == usize::MAX {
            s.roots[r] = n_comps;
            n_comps += 1;
        }
        s.comp_of[i] = s.roots[r];
    }
    if n_comps == 0 {
        return;
    }
    // counting-sort members per component (ascending task order)
    s.comp_start.clear();
    s.comp_start.resize(n_comps + 1, 0);
    for i in 0..n {
        if s.comp_of[i] != usize::MAX {
            s.comp_start[s.comp_of[i] + 1] += 1;
        }
    }
    for c in 0..n_comps {
        s.comp_start[c + 1] += s.comp_start[c];
    }
    s.comp_tasks.clear();
    s.comp_tasks.resize(s.comp_start[n_comps], 0);
    s.comp_cursor.clear();
    s.comp_cursor.extend_from_slice(&s.comp_start[..n_comps]);
    for i in 0..n {
        let c = s.comp_of[i];
        if c == usize::MAX {
            continue;
        }
        s.comp_tasks[s.comp_cursor[c]] = i;
        s.comp_cursor[c] += 1;
    }
    for c in 0..n_comps {
        let (a, b) = (s.comp_start[c], s.comp_start[c + 1]);
        fill_subset(
            tasks,
            &s.comp_tasks[a..b],
            caps,
            rates,
            users,
            &mut s.frozen,
            &mut s.touched,
        );
    }
}

/// Max-min progressive filling (compatibility form of
/// [`maxmin_fill_res_in`]; constructs throwaway scratch — hot callers
/// thread an [`AllocScratch`] instead).
pub fn maxmin_fill_res(
    tasks: &[TaskRes],
    caps: &mut [f64],
    rates: &mut [f64],
    users: &mut [f64],
) {
    maxmin_fill_res_in(tasks, caps, rates, users, &mut AllocScratch::default());
}

/// Strict priority: levels high→low, max-min within a level on
/// residuals. Scratch-threading form.
pub fn priority_fill_res_in(
    tasks: &[TaskRes],
    prios: &[i64],
    caps: &mut [f64],
    rates: &mut [f64],
    users: &mut [f64],
    s: &mut AllocScratch,
) {
    let n = tasks.len();
    debug_assert_eq!(prios.len(), n);
    // the level vectors are taken out of the scratch so the recursive
    // maxmin call can borrow the rest of it
    let mut order = std::mem::take(&mut s.order);
    let mut level_tasks = std::mem::take(&mut s.level_tasks);
    let mut level_idx = std::mem::take(&mut s.level_idx);
    let mut level_rates = std::mem::take(&mut s.level_rates);
    order.clear();
    order.extend(0..n);
    order.sort_by_key(|&i| std::cmp::Reverse(prios[i]));
    let mut k = 0;
    while k < n {
        let p = prios[order[k]];
        level_tasks.clear();
        level_idx.clear();
        while k < n && prios[order[k]] == p {
            level_idx.push(order[k]);
            level_tasks.push(tasks[order[k]]);
            k += 1;
        }
        level_rates.clear();
        level_rates.resize(level_tasks.len(), 0.0);
        maxmin_fill_res_in(&level_tasks, caps, &mut level_rates, users, s);
        for (j, &i) in level_idx.iter().enumerate() {
            rates[i] = level_rates[j];
        }
    }
    s.order = order;
    s.level_tasks = level_tasks;
    s.level_idx = level_idx;
    s.level_rates = level_rates;
}

/// Strict priority (compatibility form of [`priority_fill_res_in`]).
pub fn priority_fill_res(
    tasks: &[TaskRes],
    prios: &[i64],
    caps: &mut [f64],
    rates: &mut [f64],
    users: &mut [f64],
) {
    priority_fill_res_in(tasks, prios, caps, rates, users, &mut AllocScratch::default());
}

/// Varys-style coflow allocation over the active *flows*: SEBF group
/// ordering + MADD rates on residual capacity. Ungrouped flows are
/// singleton groups. `remaining[i]` per active index. `caps0` holds the
/// *full* capacities: the SEBF bottleneck of a group is its completion
/// lower bound `max_r load_r / caps0[r]`, so narrow fabric links (e.g.
/// an oversubscribed aggregation uplink) correctly dominate wide NICs.
///
/// This whole-active-set form is the *reference implementation*: the
/// engine's incremental path keeps the same bounds as ready-queue keys
/// (`engine::sebf_bound_single` / `engine::sebf_bound_group`) and runs
/// the identical MADD per queue level — a semantic change here must be
/// mirrored there (the `prop_queue_equivalence` suite and the engine's
/// coflow tests guard the pairing). Group arithmetic is local to the
/// group's resources, so disjoint contention components never perturb
/// each other's rates even though SEBF orders all groups globally.
pub fn coflow_fill_res_in(
    tasks: &[TaskRes],
    coflow: &[Option<usize>],
    remaining: &[f64],
    caps0: &[f64],
    caps: &mut [f64],
    rates: &mut [f64],
    s: &mut AllocScratch,
) {
    let n = tasks.len();
    if n == 0 {
        return;
    }
    s.ensure(n, caps.len());
    // group members contiguously: grouped flows first (by group id,
    // members ascending), then singletons in index order — the same
    // order the old BTreeMap keyed by (0, g) / (1, i) produced
    let mut keys = std::mem::take(&mut s.keys);
    keys.clear();
    for i in 0..n {
        match coflow[i] {
            Some(g) => keys.push((0, g, i)),
            None => keys.push((1, i, i)),
        }
    }
    keys.sort_unstable();
    let mut spans = std::mem::take(&mut s.group_span);
    spans.clear();
    let mut a = 0;
    while a < keys.len() {
        let (tag, id, _) = keys[a];
        let mut b = a + 1;
        while b < keys.len() && keys[b].0 == tag && keys[b].1 == id {
            b += 1;
        }
        spans.push((a, b));
        a = b;
    }

    // SEBF: smallest bottleneck-completion-bound first (on full capacity)
    let mut bounds = std::mem::take(&mut s.group_bounds);
    bounds.clear();
    for (gi, &(a, b)) in spans.iter().enumerate() {
        s.load_epoch += 1;
        s.load_touched.clear();
        let mut max_rem: f64 = 0.0;
        for &(_, _, i) in &keys[a..b] {
            max_rem = max_rem.max(remaining[i]);
            for r in tasks[i].iter() {
                if s.load_seen[r] != s.load_epoch {
                    s.load_seen[r] = s.load_epoch;
                    s.load[r] = 0.0;
                    s.load_touched.push(r);
                }
                s.load[r] += remaining[i];
            }
        }
        let mut bnd = max_rem;
        for &r in s.load_touched.iter() {
            if caps0[r] <= EPS {
                bnd = f64::INFINITY;
            } else {
                bnd = bnd.max(s.load[r] / caps0[r]);
            }
        }
        bounds.push((bnd, gi as u32));
    }
    // NaN-safe total order; ties keep the group-key order above, exactly
    // like the old stable sort over the BTreeMap's values
    bounds.sort_unstable_by(|x, y| x.0.total_cmp(&y.0).then(x.1.cmp(&y.1)));

    for &(_, gi) in bounds.iter() {
        let (a, b) = spans[gi as usize];
        // MADD: all members finish at the same τ, feasible on residuals
        s.load_epoch += 1;
        s.load_touched.clear();
        let mut tau: f64 = 0.0;
        for &(_, _, i) in &keys[a..b] {
            tau = tau.max(remaining[i]); // rate ≤ 1 per flow
            for r in tasks[i].iter() {
                if s.load_seen[r] != s.load_epoch {
                    s.load_seen[r] = s.load_epoch;
                    s.load[r] = 0.0;
                    s.load_touched.push(r);
                }
                s.load[r] += remaining[i];
            }
        }
        for &r in s.load_touched.iter() {
            if caps[r] <= EPS {
                tau = f64::INFINITY;
            } else {
                tau = tau.max(s.load[r] / caps[r]);
            }
        }
        if !tau.is_finite() || tau <= EPS {
            continue;
        }
        for &(_, _, i) in &keys[a..b] {
            let rate = remaining[i] / tau;
            rates[i] = rate;
            for r in tasks[i].iter() {
                caps[r] = (caps[r] - rate).max(0.0);
            }
        }
    }

    s.keys = keys;
    s.group_span = spans;
    s.group_bounds = bounds;
}

/// Coflow allocation (compatibility form of [`coflow_fill_res_in`]).
pub fn coflow_fill_res(
    tasks: &[TaskRes],
    coflow: &[Option<usize>],
    remaining: &[f64],
    caps0: &[f64],
    caps: &mut [f64],
    rates: &mut [f64],
) {
    coflow_fill_res_in(
        tasks,
        coflow,
        remaining,
        caps0,
        caps,
        rates,
        &mut AllocScratch::default(),
    );
}

// ------------------------------------------------------------------
// Compatibility wrappers over &SimDag + task-id subsets (tests, tools).
// ------------------------------------------------------------------

fn subset_res(dag: &SimDag, active: &[usize]) -> Vec<TaskRes> {
    active.iter().map(|&t| TaskRes::of(&dag.tasks[t].kind)).collect()
}

/// Max-min fair over a task-id subset (wrapper; see `maxmin_fill_res`).
pub fn maxmin_fill(dag: &SimDag, active: &[usize], caps: &mut [f64], rates: &mut [f64]) {
    let tasks = subset_res(dag, active);
    let mut users = vec![0.0; caps.len()];
    maxmin_fill_res(&tasks, caps, rates, &mut users);
}

/// Strict priority over a task-id subset (wrapper).
pub fn priority_fill(dag: &SimDag, active: &[usize], caps: &mut [f64], rates: &mut [f64]) {
    let tasks = subset_res(dag, active);
    let prios: Vec<i64> = active.iter().map(|&t| dag.tasks[t].priority).collect();
    let mut users = vec![0.0; caps.len()];
    priority_fill_res(&tasks, &prios, caps, rates, &mut users);
}

/// Coflow allocation over a task-id subset (wrapper). `remaining` is
/// indexed by *task id* here (engine-internal layout); `caps` must hold
/// the full capacities on entry (they double as the SEBF reference).
pub fn coflow_fill(
    dag: &SimDag,
    active: &[usize],
    remaining: &[f64],
    caps: &mut [f64],
    rates: &mut [f64],
) {
    let tasks = subset_res(dag, active);
    let coflow: Vec<Option<usize>> = active.iter().map(|&t| dag.tasks[t].coflow).collect();
    let rem: Vec<f64> = active.iter().map(|&t| remaining[t]).collect();
    let caps0 = caps.to_vec();
    coflow_fill_res(&tasks, &coflow, &rem, &caps0, caps, rates);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::spec::{SimDag, SimKind, SimTask};

    fn flow(dag: &mut SimDag, src: usize, dst: usize, prio: i64, coflow: Option<usize>) -> usize {
        dag.push(SimTask {
            orig: 0,
            chunk: (0, 1),
            kind: SimKind::Flow { src, dst },
            size: 1.0,
            priority: prio,
            gate: 0.0,
            coflow,
        })
    }

    #[test]
    fn fair_shares_common_nic() {
        let mut d = SimDag::default();
        let a = flow(&mut d, 0, 1, 0, None);
        let b = flow(&mut d, 0, 2, 0, None);
        let mut caps = vec![1.0; 9];
        let mut rates = vec![0.0; 2];
        maxmin_fill(&d, &[a, b], &mut caps, &mut rates);
        assert!((rates[0] - 0.5).abs() < 1e-9);
        assert!((rates[1] - 0.5).abs() < 1e-9);
    }

    #[test]
    fn fair_no_contention_full_rate() {
        let mut d = SimDag::default();
        let a = flow(&mut d, 0, 1, 0, None);
        let b = flow(&mut d, 2, 1, 0, None); // shares only dst downlink
        let mut caps = vec![1.0; 9];
        caps[5] = 2.0; // beefy downlink on host 1
        let mut rates = vec![0.0; 2];
        maxmin_fill(&d, &[a, b], &mut caps, &mut rates);
        assert!((rates[0] - 1.0).abs() < 1e-9);
        assert!((rates[1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn fair_three_way_bottleneck() {
        let mut d = SimDag::default();
        let ids: Vec<usize> = (1..4).map(|dst| flow(&mut d, 0, dst, 0, None)).collect();
        let mut caps = vec![1.0; 12];
        let mut rates = vec![0.0; 3];
        maxmin_fill(&d, &ids, &mut caps, &mut rates);
        for r in rates {
            assert!((r - 1.0 / 3.0).abs() < 1e-9);
        }
    }

    /// Disjoint contention components fill independently: the solo task
    /// reaches its bottleneck in one exact step instead of accumulating
    /// the other component's increments (0.5 + 0.2 ≠ 0.7 in floats).
    #[test]
    fn maxmin_disjoint_components_fill_exactly() {
        let mut d = SimDag::default();
        let a = flow(&mut d, 0, 1, 0, None); // share up0
        let b = flow(&mut d, 0, 2, 0, None);
        let c = flow(&mut d, 3, 4, 0, None); // disjoint
        let mut caps = vec![1.0; 15];
        caps[10] = 0.7; // up3 bottlenecks the solo flow
        let mut rates = vec![0.0; 3];
        maxmin_fill(&d, &[a, b, c], &mut caps, &mut rates);
        assert!((rates[0] - 0.5).abs() < 1e-9);
        assert!((rates[1] - 0.5).abs() < 1e-9);
        assert_eq!(rates[2].to_bits(), 0.7f64.to_bits(), "exact one-step fill");
    }

    /// One scratch reused across different fills must give the same
    /// rates as fresh scratch per call.
    #[test]
    fn scratch_reuse_is_clean() {
        let mut d = SimDag::default();
        let a = flow(&mut d, 0, 1, 0, None);
        let b = flow(&mut d, 0, 2, 0, None);
        let c = flow(&mut d, 2, 1, 0, None);
        let tasks = subset_res(&d, &[a, b, c]);
        let mut s = AllocScratch::default();
        for subset in [vec![0usize, 1], vec![0, 1, 2], vec![2], vec![1, 0, 2]] {
            let sub: Vec<TaskRes> = subset.iter().map(|&i| tasks[i]).collect();
            let mut caps1 = vec![1.0; 9];
            let mut caps2 = vec![1.0; 9];
            let mut r1 = vec![0.0; sub.len()];
            let mut r2 = vec![0.0; sub.len()];
            let mut users = vec![0.0; 9];
            maxmin_fill_res_in(&sub, &mut caps1, &mut r1, &mut users, &mut s);
            maxmin_fill_res(&sub, &mut caps2, &mut r2, &mut users);
            for (x, y) in r1.iter().zip(r2.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            for (x, y) in caps1.iter().zip(caps2.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn priority_starves_lower_level() {
        let mut d = SimDag::default();
        let hi = flow(&mut d, 0, 1, 10, None);
        let lo = flow(&mut d, 0, 2, 1, None);
        let mut caps = vec![1.0; 9];
        let mut rates = vec![0.0; 2];
        priority_fill(&d, &[hi, lo], &mut caps, &mut rates);
        assert!((rates[0] - 1.0).abs() < 1e-9);
        assert!(rates[1] < 1e-9);
    }

    #[test]
    fn priority_equal_level_is_fair() {
        let mut d = SimDag::default();
        let a = flow(&mut d, 0, 1, 5, None);
        let b = flow(&mut d, 0, 2, 5, None);
        let mut caps = vec![1.0; 9];
        let mut rates = vec![0.0; 2];
        priority_fill(&d, &[a, b], &mut caps, &mut rates);
        assert!((rates[0] - 0.5).abs() < 1e-9);
        assert!((rates[1] - 0.5).abs() < 1e-9);
    }

    #[test]
    fn priority_lower_uses_leftover() {
        let mut d = SimDag::default();
        let hi = flow(&mut d, 0, 1, 10, None); // up0 + down1
        let lo = flow(&mut d, 2, 1, 1, None); // up2 + down1 (shared down)
        let mut caps = vec![1.0; 9];
        caps[5] = 1.5; // down1
        let mut rates = vec![0.0; 2];
        priority_fill(&d, &[hi, lo], &mut caps, &mut rates);
        assert!((rates[0] - 1.0).abs() < 1e-9);
        assert!((rates[1] - 0.5).abs() < 1e-9);
    }

    #[test]
    fn coflow_madd_finishes_together() {
        let mut d = SimDag::default();
        let a = flow(&mut d, 0, 1, 0, Some(0));
        let b = flow(&mut d, 0, 2, 0, Some(0));
        let mut caps = vec![1.0; 9];
        let mut rates = vec![0.0; 2];
        let mut remaining = vec![0.0; d.len()];
        remaining[a] = 2.0;
        remaining[b] = 1.0;
        coflow_fill(&d, &[a, b], &remaining, &mut caps, &mut rates);
        assert!((rates[0] - 2.0 / 3.0).abs() < 1e-9);
        assert!((rates[1] - 1.0 / 3.0).abs() < 1e-9);
        assert!((remaining[a] / rates[0] - remaining[b] / rates[1]).abs() < 1e-9);
    }

    #[test]
    fn coflow_sebf_orders_small_group_first() {
        let mut d = SimDag::default();
        let small = flow(&mut d, 0, 1, 0, Some(0));
        let big = flow(&mut d, 0, 2, 0, Some(1));
        let mut remaining = vec![0.0; d.len()];
        remaining[small] = 1.0;
        remaining[big] = 10.0;
        let mut caps = vec![1.0; 9];
        let mut rates = vec![0.0; 2];
        coflow_fill(&d, &[small, big], &remaining, &mut caps, &mut rates);
        assert!((rates[0] - 1.0).abs() < 1e-9);
        assert!(rates[1] < 1e-9);
    }

    #[test]
    fn compute_tasks_share_cores() {
        let mut d = SimDag::default();
        let mk = |d: &mut SimDag| {
            d.push(SimTask {
                orig: 0,
                chunk: (0, 1),
                kind: SimKind::Compute { host: 0 },
                size: 1.0,
                priority: 0,
                gate: 0.0,
                coflow: None,
            })
        };
        let a = mk(&mut d);
        let b = mk(&mut d);
        let mut caps = vec![1.0, 1.0, 1.0];
        let mut rates = vec![0.0; 2];
        maxmin_fill(&d, &[a, b], &mut caps, &mut rates);
        assert!((rates[0] - 0.5).abs() < 1e-9);
        assert!((rates[1] - 0.5).abs() < 1e-9);

        let mut caps = vec![2.0, 1.0, 1.0];
        let mut rates = vec![0.0; 2];
        maxmin_fill(&d, &[a, b], &mut caps, &mut rates);
        assert!((rates[0] - 1.0).abs() < 1e-9);
        assert!((rates[1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn task_res_footprints() {
        assert_eq!(TaskRes::of(&SimKind::Dummy).n, 0);
        let c = TaskRes::of(&SimKind::Compute { host: 2 });
        assert_eq!((c.n, c.res[0]), (1, 6));
        let f = TaskRes::of(&SimKind::Flow { src: 0, dst: 1 });
        assert_eq!((f.n, f.res[0], f.res[1]), (2, 1, 5));
    }

    #[test]
    fn task_res_push_variable_arity() {
        let mut tr = TaskRes::default();
        for r in [3, 9, 12, 15] {
            tr.push(r);
        }
        assert_eq!(tr.n as usize, MAX_TASK_RES);
        assert_eq!(tr.iter().collect::<Vec<_>>(), vec![3, 9, 12, 15]);
    }

    #[test]
    fn maxmin_k_resource_task() {
        // one 4-resource task: rate bounded by its narrowest resource
        let tasks = [{
            let mut tr = TaskRes::default();
            for r in 0..4 {
                tr.push(r);
            }
            tr
        }];
        let mut caps = vec![1.0, 0.25, 1.0, 0.5];
        let mut rates = vec![0.0];
        let mut users = vec![0.0; caps.len()];
        maxmin_fill_res(&tasks, &mut caps, &mut rates, &mut users);
        assert!((rates[0] - 0.25).abs() < 1e-9);
    }

    #[test]
    fn sebf_bottleneck_normalized_by_capacity() {
        // Two singleton groups with equal remaining bytes, but group B's
        // flow crosses a narrow shared link (capacity 0.25): its
        // completion bound is 4x worse, so SEBF must serve A first.
        // separate NIC pairs so only the narrow link distinguishes them
        let a = {
            let mut tr = TaskRes::default();
            tr.push(2);
            tr.push(3);
            tr
        };
        let b = {
            let mut tr = TaskRes::default();
            tr.push(0);
            tr.push(1);
            tr.push(4); // the narrow shared link
            tr
        };
        let tasks = [a, b];
        let coflow = [Some(0), Some(1)];
        let remaining = [1.0, 1.0];
        let caps0 = vec![1.0, 1.0, 1.0, 1.0, 0.25];
        let mut caps = caps0.clone();
        let mut rates = vec![0.0; 2];
        coflow_fill_res(&tasks, &coflow, &remaining, &caps0, &mut caps, &mut rates);
        // A (bound 1.0) ordered before B (bound 4.0); both can still run
        // (disjoint resources), but B is pinned to the narrow link rate.
        assert!((rates[0] - 1.0).abs() < 1e-9);
        assert!((rates[1] - 0.25).abs() < 1e-9);
    }
}
