//! The network-topology layer: maps a flow `(src, dst)` to the set of
//! capacity-bearing resources it draws on.
//!
//! The original simulator hard-coded a big switch — every flow touches
//! exactly `{nic_up(src), nic_down(dst)}`. Real clusters add *shared
//! fabric* constraints: oversubscribed leaf/spine aggregation links, or
//! parallel fabrics a path-selection rule spreads flows across. This
//! module makes that substrate pluggable while keeping the per-host
//! resource layout (`[core, up, down] × hosts`, see `spec::res_core`)
//! bit-for-bit identical, so `BigSwitch` reproduces the pre-refactor
//! engine exactly; fabric resources are appended after the `3 × hosts`
//! per-host slots.
//!
//! ## The `Topology` JSON schema
//!
//! A topology is a JSON object tagged by `"kind"`; it appears either
//! standalone (the value accepted by `Topology::from_json`) or as the
//! `"topology"` key of a cluster object in a `mxdag simulate --dag`
//! scenario file. The three kinds and their fields:
//!
//! ```json
//! {"kind": "bigswitch"}
//! {"kind": "oversubscribed", "racks": 2, "ratio": 4}
//! {"kind": "fabrics", "k": 2, "trunk": 0.5, "select": "bysrc"}
//! ```
//!
//! * `racks` — positive integer ≤ 1e6; hosts are block-partitioned into
//!   this many leaves.
//! * `ratio` — positive finite float; each leaf's aggregation link
//!   carries `Σ NIC / ratio` per direction (`1` = non-blocking).
//! * `k` — positive integer ≤ 1e6 parallel trunks.
//! * `trunk` — positive finite float capacity per trunk.
//! * `select` — `"hash"` (default when omitted) or `"bysrc"`.
//!
//! A worked cluster file fragment, equivalent to the CLI spec
//! `--topology oversub:2:4` on eight default hosts:
//!
//! ```json
//! {
//!   "tasks": [],
//!   "edges": [],
//!   "cluster": {
//!     "hosts": 8,
//!     "topology": {"kind": "oversubscribed", "racks": 2, "ratio": 4}
//!   }
//! }
//! ```
//!
//! With eight unit-NIC hosts in two racks at ratio 4, each rack's
//! aggregation link gets capacity `4 / 4 = 1` per direction — resources
//! `24..=27` in the flat arena (after the `3 × 8` per-host slots), which
//! a cross-rack flow occupies in addition to its endpoint NICs.

use crate::util::json::{Json, JsonError};

use super::alloc::TaskRes;

/// Which of `k` parallel fabrics a flow `(src, dst)` is routed through.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PathSelect {
    /// Deterministic ECMP-style hash: fabric = `(src + dst) % k`.
    Hash,
    /// Per-source striping: fabric = `src % k`.
    BySrc,
}

impl PathSelect {
    /// The trunk (of `k`) carrying a `(src, dst)` flow under this rule.
    pub fn pick(&self, src: usize, dst: usize, k: usize) -> usize {
        debug_assert!(k > 0);
        match self {
            PathSelect::Hash => (src + dst) % k,
            PathSelect::BySrc => src % k,
        }
    }

    /// Stable CLI/JSON spelling of this rule (`hash` / `bysrc`).
    pub fn label(&self) -> &'static str {
        match self {
            PathSelect::Hash => "hash",
            PathSelect::BySrc => "bysrc",
        }
    }
}

/// The fabric connecting the hosts' NICs.
#[derive(Debug, Clone, PartialEq)]
pub enum Topology {
    /// Non-blocking big switch: flows touch only their endpoint NICs
    /// (the pre-refactor semantics; the default).
    BigSwitch,
    /// Two-tier leaf/spine: hosts are block-partitioned into `racks`
    /// leaves; each leaf's aggregation link has capacity
    /// `Σ nic / ratio` in each direction. A cross-rack flow additionally
    /// occupies `agg_up(rack(src))` and `agg_down(rack(dst))`;
    /// intra-rack flows see only their NICs. `ratio == 1` is a
    /// non-blocking fabric, `ratio > 1` is oversubscribed.
    Oversubscribed { racks: usize, ratio: f64 },
    /// `k` parallel fabrics, each a shared trunk of capacity `trunk`.
    /// Every flow crosses exactly one trunk, chosen by `select`.
    ParallelFabrics { k: usize, select: PathSelect, trunk: f64 },
}

impl Default for Topology {
    fn default() -> Self {
        Topology::BigSwitch
    }
}

/// Hosts per rack under block partitioning (`ceil(n / racks)`).
fn rack_size(n_hosts: usize, racks: usize) -> usize {
    debug_assert!(racks > 0);
    (n_hosts + racks - 1) / racks
}

impl Topology {
    /// Fabric resources appended after the `3 × n_hosts` per-host slots.
    pub fn n_extra(&self, _n_hosts: usize) -> usize {
        match self {
            Topology::BigSwitch => 0,
            Topology::Oversubscribed { racks, .. } => 2 * racks,
            Topology::ParallelFabrics { k, .. } => *k,
        }
    }

    /// Rack of host `h` (leaf/spine only).
    pub fn rack_of(&self, h: usize, n_hosts: usize) -> Option<usize> {
        match self {
            Topology::Oversubscribed { racks, .. } => {
                Some((h / rack_size(n_hosts, *racks)).min(racks - 1))
            }
            _ => None,
        }
    }

    /// Resource index of rack `r`'s aggregation uplink.
    pub fn agg_up(r: usize, n_hosts: usize) -> usize {
        3 * n_hosts + 2 * r
    }
    /// Resource index of rack `r`'s aggregation downlink.
    pub fn agg_down(r: usize, n_hosts: usize) -> usize {
        3 * n_hosts + 2 * r + 1
    }
    /// Resource index of parallel fabric `j`'s trunk.
    pub fn trunk(j: usize, n_hosts: usize) -> usize {
        3 * n_hosts + j
    }

    /// Append the *fabric* resources a flow `(src, dst)` occupies (its
    /// endpoint NICs are pushed by the caller).
    pub fn push_flow_extras(&self, src: usize, dst: usize, n_hosts: usize, out: &mut TaskRes) {
        match self {
            Topology::BigSwitch => {}
            Topology::Oversubscribed { .. } => {
                let rs = self.rack_of(src, n_hosts).unwrap();
                let rd = self.rack_of(dst, n_hosts).unwrap();
                if rs != rd {
                    out.push(Topology::agg_up(rs, n_hosts));
                    out.push(Topology::agg_down(rd, n_hosts));
                }
            }
            Topology::ParallelFabrics { k, select, .. } => {
                out.push(Topology::trunk(select.pick(src, dst, *k), n_hosts));
            }
        }
    }

    /// Re-run `ParallelFabrics` path selection for a `(src, dst)` flow
    /// against the surviving trunk set. `alive` is the ascending list
    /// of fabric indices whose trunks are up (see `sim/dynamics.rs`).
    ///
    /// - All `k` trunks alive → the original static pick, so restoring
    ///   every failed link is a bit-exact round trip (and a restored
    ///   trunk is re-eligible the moment its restore event applies).
    /// - Some alive → the same selection rule applied over the alive
    ///   list (deterministic, shared by every engine corner).
    /// - None alive (or not `ParallelFabrics`) → `None`; the caller
    ///   keeps the dead footprint so the stuck flow is reported as
    ///   starved on the failed trunk slot.
    pub fn reroute_trunk(&self, src: usize, dst: usize, alive: &[usize]) -> Option<usize> {
        match self {
            Topology::ParallelFabrics { k, select, .. } => {
                if alive.is_empty() {
                    None
                } else if alive.len() == *k {
                    Some(select.pick(src, dst, *k))
                } else {
                    Some(alive[select.pick(src, dst, alive.len())])
                }
            }
            _ => None,
        }
    }

    /// Parse a CLI spec: `bigswitch`, `oversub:RACKS:RATIO`, or
    /// `fabrics:K:TRUNK[:hash|bysrc]`.
    pub fn parse(s: &str) -> Result<Topology, String> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts[0] {
            "bigswitch" if parts.len() == 1 => Ok(Topology::BigSwitch),
            "oversub" if parts.len() == 3 => {
                let racks: usize =
                    parts[1].parse().map_err(|_| format!("bad racks `{}`", parts[1]))?;
                let ratio: f64 =
                    parts[2].parse().map_err(|_| format!("bad ratio `{}`", parts[2]))?;
                if racks == 0 || !(ratio.is_finite() && ratio > 0.0) {
                    return Err("oversub wants racks >= 1 and finite ratio > 0".into());
                }
                Ok(Topology::Oversubscribed { racks, ratio })
            }
            "fabrics" if parts.len() == 3 || parts.len() == 4 => {
                let k: usize = parts[1].parse().map_err(|_| format!("bad k `{}`", parts[1]))?;
                let trunk: f64 =
                    parts[2].parse().map_err(|_| format!("bad trunk `{}`", parts[2]))?;
                let select = match parts.get(3).copied() {
                    None | Some("hash") => PathSelect::Hash,
                    Some("bysrc") => PathSelect::BySrc,
                    Some(other) => return Err(format!("bad path select `{other}`")),
                };
                if k == 0 || !(trunk.is_finite() && trunk > 0.0) {
                    return Err("fabrics wants k >= 1 and finite trunk > 0".into());
                }
                Ok(Topology::ParallelFabrics { k, select, trunk })
            }
            _ => Err(format!(
                "unknown topology `{s}` (want bigswitch | oversub:RACKS:RATIO | \
                 fabrics:K:TRUNK[:hash|bysrc])"
            )),
        }
    }

    /// JSON form (inverse of [`Topology::from_json`]).
    pub fn to_json(&self) -> Json {
        match self {
            Topology::BigSwitch => Json::obj(vec![("kind", Json::Str("bigswitch".into()))]),
            Topology::Oversubscribed { racks, ratio } => Json::obj(vec![
                ("kind", Json::Str("oversubscribed".into())),
                ("racks", Json::Num(*racks as f64)),
                ("ratio", Json::Num(*ratio)),
            ]),
            Topology::ParallelFabrics { k, select, trunk } => Json::obj(vec![
                ("kind", Json::Str("fabrics".into())),
                ("k", Json::Num(*k as f64)),
                ("trunk", Json::Num(*trunk)),
                ("select", Json::Str(select.label().into())),
            ]),
        }
    }

    /// Parse the JSON form produced by [`Topology::to_json`], with the
    /// same validation as [`Topology::parse`]: counts must be positive
    /// integers, capacities positive, and `select` a known rule.
    pub fn from_json(j: &Json) -> Result<Topology, JsonError> {
        let count = |key: &'static str| -> Result<usize, JsonError> {
            let v = j.get(key)?.as_f64()?;
            if !(v.is_finite() && v >= 1.0 && v <= 1e6 && v.fract() == 0.0) {
                return Err(JsonError::Type { want: "positive integer count", got: "number" });
            }
            Ok(v as usize)
        };
        let positive = |key: &'static str| -> Result<f64, JsonError> {
            let v = j.get(key)?.as_f64()?;
            if !(v.is_finite() && v > 0.0) {
                return Err(JsonError::Type { want: "positive capacity/ratio", got: "number" });
            }
            Ok(v)
        };
        match j.get("kind")?.as_str()? {
            "bigswitch" => Ok(Topology::BigSwitch),
            "oversubscribed" => Ok(Topology::Oversubscribed {
                racks: count("racks")?,
                ratio: positive("ratio")?,
            }),
            "fabrics" => {
                let select = match j.as_obj()?.get("select") {
                    None => PathSelect::Hash,
                    Some(s) => match s.as_str()? {
                        "hash" => PathSelect::Hash,
                        "bysrc" => PathSelect::BySrc,
                        _ => return Err(JsonError::Type { want: "path select (hash|bysrc)", got: "string" }),
                    },
                };
                Ok(Topology::ParallelFabrics {
                    k: count("k")?,
                    select,
                    trunk: positive("trunk")?,
                })
            }
            _ => Err(JsonError::Type { want: "topology kind", got: "string" }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bigswitch_has_no_extras() {
        let t = Topology::BigSwitch;
        assert_eq!(t.n_extra(8), 0);
        let mut tr = TaskRes::default();
        t.push_flow_extras(0, 5, 8, &mut tr);
        assert_eq!(tr.n, 0);
    }

    #[test]
    fn oversub_rack_partition_and_extras() {
        let t = Topology::Oversubscribed { racks: 2, ratio: 4.0 };
        // 4 hosts -> racks {0,1} and {2,3}
        assert_eq!(t.rack_of(0, 4), Some(0));
        assert_eq!(t.rack_of(1, 4), Some(0));
        assert_eq!(t.rack_of(2, 4), Some(1));
        assert_eq!(t.rack_of(3, 4), Some(1));
        assert_eq!(t.n_extra(4), 4);

        // intra-rack flow: no fabric resources
        let mut tr = TaskRes::default();
        t.push_flow_extras(0, 1, 4, &mut tr);
        assert_eq!(tr.n, 0);
        // cross-rack flow: agg_up(0) + agg_down(1) = indices 12, 15
        let mut tr = TaskRes::default();
        t.push_flow_extras(0, 3, 4, &mut tr);
        let rs: Vec<usize> = tr.iter().collect();
        assert_eq!(rs, vec![12, 15]);
    }

    #[test]
    fn oversub_odd_host_count() {
        let t = Topology::Oversubscribed { racks: 2, ratio: 1.0 };
        // 5 hosts -> rack size 3: {0,1,2} and {3,4}
        assert_eq!(t.rack_of(2, 5), Some(0));
        assert_eq!(t.rack_of(3, 5), Some(1));
        assert_eq!(t.rack_of(4, 5), Some(1));
    }

    #[test]
    fn fabrics_path_selection() {
        let hash = Topology::ParallelFabrics { k: 2, select: PathSelect::Hash, trunk: 0.5 };
        let mut tr = TaskRes::default();
        hash.push_flow_extras(0, 2, 4, &mut tr); // (0+2)%2 = 0 -> index 12
        assert_eq!(tr.iter().collect::<Vec<_>>(), vec![12]);
        let mut tr = TaskRes::default();
        hash.push_flow_extras(1, 3, 4, &mut tr); // (1+3)%2 = 0 -> collides
        assert_eq!(tr.iter().collect::<Vec<_>>(), vec![12]);

        let bysrc = Topology::ParallelFabrics { k: 2, select: PathSelect::BySrc, trunk: 0.5 };
        let mut tr = TaskRes::default();
        bysrc.push_flow_extras(1, 3, 4, &mut tr); // 1%2 = 1 -> index 13
        assert_eq!(tr.iter().collect::<Vec<_>>(), vec![13]);
    }

    #[test]
    fn reroute_trunk_over_surviving_fabrics() {
        let hash = Topology::ParallelFabrics { k: 3, select: PathSelect::Hash, trunk: 0.5 };
        // all alive -> the original static pick
        assert_eq!(hash.reroute_trunk(1, 3, &[0, 1, 2]), Some((1 + 3) % 3));
        // fabric 1 down -> selection rule over the alive list
        assert_eq!(hash.reroute_trunk(1, 3, &[0, 2]), Some([0, 2][(1 + 3) % 2]));
        // single survivor carries everything
        assert_eq!(hash.reroute_trunk(0, 1, &[2]), Some(2));
        assert_eq!(hash.reroute_trunk(4, 5, &[2]), Some(2));
        // no survivors -> no path
        assert_eq!(hash.reroute_trunk(0, 1, &[]), None);
        // non-fabric topologies never reroute
        assert_eq!(Topology::BigSwitch.reroute_trunk(0, 1, &[0]), None);
    }

    #[test]
    fn parse_specs() {
        assert_eq!(Topology::parse("bigswitch").unwrap(), Topology::BigSwitch);
        assert_eq!(
            Topology::parse("oversub:2:4").unwrap(),
            Topology::Oversubscribed { racks: 2, ratio: 4.0 }
        );
        assert_eq!(
            Topology::parse("fabrics:3:0.5").unwrap(),
            Topology::ParallelFabrics { k: 3, select: PathSelect::Hash, trunk: 0.5 }
        );
        assert_eq!(
            Topology::parse("fabrics:2:1:bysrc").unwrap(),
            Topology::ParallelFabrics { k: 2, select: PathSelect::BySrc, trunk: 1.0 }
        );
        assert!(Topology::parse("oversub:0:4").is_err());
        assert!(Topology::parse("oversub:2:nan").is_err());
        assert!(Topology::parse("oversub:2:inf").is_err());
        assert!(Topology::parse("fabrics:2:nan").is_err());
        assert!(Topology::parse("mesh").is_err());
        assert!(Topology::parse("oversub:2").is_err());
    }

    #[test]
    fn json_rejects_invalid_values() {
        for bad in [
            r#"{"kind": "oversubscribed", "racks": 0, "ratio": 4}"#,
            r#"{"kind": "oversubscribed", "racks": 2.5, "ratio": 4}"#,
            r#"{"kind": "oversubscribed", "racks": 2, "ratio": -1}"#,
            r#"{"kind": "oversubscribed", "racks": 1e18, "ratio": 4}"#,
            r#"{"kind": "fabrics", "k": 0, "trunk": 1}"#,
            r#"{"kind": "fabrics", "k": 2, "trunk": 0}"#,
            r#"{"kind": "fabrics", "k": 2, "trunk": 1, "select": "bysrcc"}"#,
            r#"{"kind": "mesh"}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(Topology::from_json(&j).is_err(), "must reject {bad}");
        }
    }

    #[test]
    fn json_roundtrip() {
        for t in [
            Topology::BigSwitch,
            Topology::Oversubscribed { racks: 4, ratio: 8.0 },
            Topology::ParallelFabrics { k: 2, select: PathSelect::BySrc, trunk: 0.25 },
        ] {
            let j = t.to_json();
            let back = Topology::from_json(&j).unwrap();
            assert_eq!(t, back, "roundtrip of {j}");
        }
    }
}
