//! The incremental ready-queue subsystem: the data structure side of the
//! engine ↔ scheduler contract (see `docs/ARCHITECTURE.md`).
//!
//! The engine keeps every *ready* task (all predecessors finished, gate
//! passed, coflow barrier open) in a priority-keyed [`ReadyQueue`] and,
//! at each event, walks the queue's levels from highest key downwards,
//! handing each level to the rate allocator. Two implementations back
//! the same trait:
//!
//! * [`BucketQueue`] — the production structure: an indexed bucket heap
//!   (one bucket per distinct key, ordered in a B-tree, with a per-task
//!   slot index for O(1) membership updates). Push / remove /
//!   [`update_key`](ReadyQueue::update_key) cost `O(log L)` in the
//!   number of *distinct levels* `L`, and an event that only needs the
//!   top levels never touches the rest — this is what makes strict
//!   priority scheduling `O(touched)` per event instead of a full
//!   re-sort of the ready set.
//! * [`ResortQueue`] — the pre-refactor baseline, kept as the oracle:
//!   an unordered vector that is fully re-sorted on every
//!   [`for_each_level`](ReadyQueue::for_each_level) walk, i.e. the old
//!   `O(R log R)`-per-event behaviour. Property tests assert the two
//!   produce identical level sequences (`tests` below) and identical
//!   simulations (`tests/prop_queue_equivalence.rs`).
//!
//! ## Keys
//!
//! A [`PrioKey`] is a 128-bit totally ordered key; **larger keys pop
//! first**. Each sharing policy maps its notion of urgency into one:
//!
//! | policy            | key                                              | invalidation |
//! |-------------------|--------------------------------------------------|--------------|
//! | fair              | [`PrioKey::LEVEL`] (one shared level)            | never        |
//! | static priority   | [`PrioKey::from_prio`] of the task priority      | never        |
//! | FIFO              | [`PrioKey::from_prio`] of `-queue_slot`          | never        |
//! | coflow (SEBF)     | [`PrioKey::from_bound_asc`] of the group bound   | every time a member's remaining bytes change |
//!
//! Policies whose keys drift as the simulation progresses (SEBF
//! remaining-bytes; altruistic leftover-bandwidth follow-ons) must call
//! [`ReadyQueue::update_key`] — the explicit *key invalidation hook* —
//! whenever the state a key was derived from changes. *When* the hook
//! fires depends on the engine's time-advance mode
//! ([`HorizonKind`](super::horizon::HorizonKind)): under **eager**
//! integration the engine re-keys after every progress step (every
//! event sweeps remaining bytes, so every event can invalidate);
//! under **anchored** time advance with component-wise allocation,
//! drift is detected at component *refill* time from the re-anchored
//! bytes — a clean component's keys may be stale in the queue, which
//! is sound because the component path never walks the global level
//! structure, and any event that could act on those keys dirties the
//! component (and thus re-keys) first.
//!
//! The same keys drive the engine's component-wise allocation
//! ([`AllocKind::Components`](super::components::AllocKind)): a dirty
//! contention component re-sorts its own members by key and walks the
//! resulting levels locally, reproducing exactly the level partition
//! these queues would expose globally. A key update therefore also
//! dirties the task's component — a re-keyed task can change its
//! component's level structure even when nothing else moved.
//!
//! Under the parallel event loop (`SimConfig.threads > 1`) refill
//! workers never mutate these queues: anchored SEBF re-keys are
//! computed against per-worker key shadows and replayed through
//! [`ReadyQueue::update_key`] by the engine's serial epilogue, in the
//! same order the serial loop would have issued them — the queue
//! remains a single-threaded structure by design.

use std::cmp::Reverse;
use std::collections::BTreeMap;

const SIGN: u64 = 1 << 63;

/// Order-preserving map from the `f64` total order onto `u64`
/// (`a.total_cmp(&b) == f64_ord(a).cmp(&f64_ord(b))`).
pub(crate) fn f64_ord(x: f64) -> u64 {
    let b = x.to_bits();
    if b >> 63 == 1 {
        !b
    } else {
        b | SIGN
    }
}

/// A totally ordered ready-queue key. Larger keys pop first; tasks with
/// equal keys form one *level* and are rate-shared by the allocator as a
/// unit. `tie` refines `primary` where a policy needs a deterministic
/// strict order (e.g. one level per coflow group).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PrioKey {
    /// Primary ordering component (policy urgency).
    pub primary: u64,
    /// Deterministic tie-break (0 where levels may merge).
    pub tie: u64,
}

impl PrioKey {
    /// The single shared level used by fair (no-priority) policies.
    pub const LEVEL: PrioKey = PrioKey { primary: 0, tie: 0 };

    /// Key for a static integer priority: higher priority pops first.
    pub fn from_prio(p: i64) -> PrioKey {
        PrioKey { primary: (p as u64) ^ SIGN, tie: 0 }
    }

    /// Key for an ascending `f64` bound (SEBF): *smaller* bounds pop
    /// first; equal bounds order by ascending `ord` (each distinct
    /// `(bound, ord)` pair is its own level).
    pub fn from_bound_asc(bound: f64, ord: u64) -> PrioKey {
        PrioKey { primary: !f64_ord(bound), tie: !ord }
    }
}

/// How a policy keys the ready queue — the declarative half of the
/// scheduler ↔ engine contract (`Scheduler::disciplines` declares which
/// of these a scheduler's plans may request; `Policy::discipline` maps a
/// concrete plan to one).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Keying {
    /// No ordering: every ready task shares one level (max-min fair).
    SingleLevel,
    /// Static per-task integer priorities fixed at planning time
    /// (critical-path rank, packing score). Keys never go stale.
    StaticPriority,
    /// Arrival-order slots assigned at first readiness (blocking send
    /// queue semantics). Keys are assigned once, then never go stale.
    FifoArrival,
    /// Coflow SEBF: one level per group, keyed by the group's
    /// bottleneck-completion bound over *remaining* bytes. Keys go stale
    /// as bytes drain and must be re-derived via the
    /// [`ReadyQueue::update_key`] invalidation hook — after every
    /// progress step under eager integration, or from re-anchored bytes
    /// at component refill under anchored time advance (see the module
    /// docs).
    SebfGroups,
}

impl Keying {
    /// Whether keys under this discipline can go stale while a task sits
    /// in the queue (and thus require `update_key` calls).
    pub fn dynamic(&self) -> bool {
        matches!(self, Keying::SebfGroups)
    }
}

/// The (cpu, net) keying pair a concrete [`Policy`](super::spec::Policy)
/// requests from the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueDiscipline {
    /// Keying of the compute-slot queue.
    pub cpu: Keying,
    /// Keying of the network-flow queue.
    pub net: Keying,
}

impl QueueDiscipline {
    /// Discipline of [`Policy::fair`](super::spec::Policy::fair).
    pub const FAIR: QueueDiscipline =
        QueueDiscipline { cpu: Keying::SingleLevel, net: Keying::SingleLevel };
    /// Discipline of [`Policy::priority`](super::spec::Policy::priority).
    pub const PRIORITY: QueueDiscipline =
        QueueDiscipline { cpu: Keying::StaticPriority, net: Keying::StaticPriority };
    /// Discipline of [`Policy::fifo`](super::spec::Policy::fifo).
    pub const FIFO: QueueDiscipline =
        QueueDiscipline { cpu: Keying::FifoArrival, net: Keying::FifoArrival };
    /// Discipline of [`Policy::coflow`](super::spec::Policy::coflow)
    /// (fair compute slots, SEBF network).
    pub const COFLOW: QueueDiscipline =
        QueueDiscipline { cpu: Keying::SingleLevel, net: Keying::SebfGroups };

    /// Whether any component requires key invalidation support.
    pub fn dynamic(&self) -> bool {
        self.cpu.dynamic() || self.net.dynamic()
    }
}

/// A priority-keyed multiset of ready tasks, iterated level by level in
/// descending key order.
///
/// Contract (shared by every implementation):
/// * a task is in the queue at most once; `push` requires absence,
///   `remove`/`update_key` require presence (checked with debug
///   assertions, tolerated in release);
/// * `for_each_level` visits each distinct key once, highest first,
///   passing all member tasks of that level; the visitor returns
///   `false` to signal that every remaining (lower-keyed) task would
///   receive a zero allocation — implementations *may* stop early then,
///   but are free to keep visiting (the baseline [`ResortQueue`] does,
///   faithfully reproducing the old full-walk cost);
/// * the *membership* of each level is identical across implementations;
///   the order of tasks *within* a level is unspecified (rate allocation
///   within a level is order-independent).
pub trait ReadyQueue {
    /// Insert `task` with `key`. The task must not already be queued.
    fn push(&mut self, task: usize, key: PrioKey);
    /// Remove `task` (no-op if absent).
    fn remove(&mut self, task: usize);
    /// Key invalidation hook: re-key an already-queued task after the
    /// state its key derives from changed (no-op if absent or unchanged).
    fn update_key(&mut self, task: usize, key: PrioKey);
    /// Number of queued tasks.
    fn len(&self) -> usize;
    /// Whether the queue is empty.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Visit levels in descending key order (see trait docs).
    fn for_each_level(&mut self, visit: &mut dyn FnMut(PrioKey, &[usize]) -> bool);
}

/// Indexed bucket heap: the incremental [`ReadyQueue`].
///
/// One `Vec` bucket per distinct key, ordered descending in a B-tree;
/// `pos[task]` holds the task's slot inside its bucket so removal is a
/// swap-remove plus an index fix-up. All operations are `O(log L)` with
/// `L` = number of distinct keys currently present.
#[derive(Debug, Default)]
pub struct BucketQueue {
    buckets: BTreeMap<Reverse<PrioKey>, Vec<usize>>,
    key_of: Vec<PrioKey>,
    pos: Vec<usize>,
    present: Vec<bool>,
    len: usize,
    /// Retired bucket buffers, recycled when a key (re)appears — a warm
    /// [`reset`](BucketQueue::reset) hands buffers back here instead of
    /// dropping them, so steady-state reuse allocates only B-tree nodes.
    spare: Vec<Vec<usize>>,
}

const ABSENT: usize = usize::MAX;

impl BucketQueue {
    /// Queue over task ids `0..n`.
    pub fn with_capacity(n: usize) -> BucketQueue {
        BucketQueue {
            buckets: BTreeMap::new(),
            key_of: vec![PrioKey::LEVEL; n],
            pos: vec![ABSENT; n],
            present: vec![false; n],
            len: 0,
            spare: Vec::new(),
        }
    }

    /// Empty the queue and re-index over task ids `0..n` — the
    /// between-runs reset used by the engine's reusable scratch
    /// ([`SimScratch`](crate::sim::SimScratch)). Per-task index
    /// capacity is kept and bucket buffers are recycled to the spare
    /// pool, so a warm reset reallocates nothing but B-tree nodes.
    pub fn reset(&mut self, n: usize) {
        for (_, mut v) in std::mem::take(&mut self.buckets) {
            v.clear();
            self.spare.push(v);
        }
        self.key_of.clear();
        self.key_of.resize(n, PrioKey::LEVEL);
        self.pos.clear();
        self.pos.resize(n, ABSENT);
        self.present.clear();
        self.present.resize(n, false);
        self.len = 0;
    }
}

impl ReadyQueue for BucketQueue {
    fn push(&mut self, task: usize, key: PrioKey) {
        debug_assert!(!self.present[task], "task {task} already queued");
        let bucket = match self.buckets.entry(Reverse(key)) {
            std::collections::btree_map::Entry::Occupied(e) => e.into_mut(),
            std::collections::btree_map::Entry::Vacant(e) => {
                e.insert(self.spare.pop().unwrap_or_default())
            }
        };
        self.pos[task] = bucket.len();
        bucket.push(task);
        self.key_of[task] = key;
        self.present[task] = true;
        self.len += 1;
    }

    fn remove(&mut self, task: usize) {
        if !self.present[task] {
            return;
        }
        let key = self.key_of[task];
        let i = self.pos[task];
        let bucket = self.buckets.get_mut(&Reverse(key)).expect("bucket of queued task");
        bucket.swap_remove(i);
        if i < bucket.len() {
            let moved = bucket[i];
            self.pos[moved] = i;
        }
        if bucket.is_empty() {
            if let Some(v) = self.buckets.remove(&Reverse(key)) {
                self.spare.push(v);
            }
        }
        self.pos[task] = ABSENT;
        self.present[task] = false;
        self.len -= 1;
    }

    fn update_key(&mut self, task: usize, key: PrioKey) {
        if !self.present[task] || self.key_of[task] == key {
            return;
        }
        self.remove(task);
        self.push(task, key);
    }

    fn len(&self) -> usize {
        self.len
    }

    fn for_each_level(&mut self, visit: &mut dyn FnMut(PrioKey, &[usize]) -> bool) {
        for (&Reverse(key), bucket) in self.buckets.iter() {
            if !visit(key, bucket) {
                break;
            }
        }
    }
}

/// Full re-sort baseline: an unordered vector, sorted from scratch on
/// every [`for_each_level`](ReadyQueue::for_each_level) walk — the
/// pre-refactor `O(R log R)`-per-event behaviour, kept as the
/// equivalence oracle and the benchmark baseline. It deliberately
/// ignores the visitor's early-exit hint (the old path always allocated
/// every level).
#[derive(Debug, Default)]
pub struct ResortQueue {
    items: Vec<usize>,
    key_of: Vec<PrioKey>,
    pos: Vec<usize>,
    scratch: Vec<usize>,
}

impl ResortQueue {
    /// Queue over task ids `0..n`.
    pub fn with_capacity(n: usize) -> ResortQueue {
        ResortQueue {
            items: Vec::new(),
            key_of: vec![PrioKey::LEVEL; n],
            pos: vec![ABSENT; n],
            scratch: Vec::new(),
        }
    }

    /// Empty the queue and re-index over task ids `0..n` (see
    /// [`BucketQueue::reset`]).
    pub fn reset(&mut self, n: usize) {
        self.items.clear();
        self.key_of.clear();
        self.key_of.resize(n, PrioKey::LEVEL);
        self.pos.clear();
        self.pos.resize(n, ABSENT);
    }
}

impl ReadyQueue for ResortQueue {
    fn push(&mut self, task: usize, key: PrioKey) {
        debug_assert!(self.pos[task] == ABSENT, "task {task} already queued");
        self.pos[task] = self.items.len();
        self.items.push(task);
        self.key_of[task] = key;
    }

    fn remove(&mut self, task: usize) {
        let i = self.pos[task];
        if i == ABSENT {
            return;
        }
        self.items.swap_remove(i);
        if i < self.items.len() {
            let moved = self.items[i];
            self.pos[moved] = i;
        }
        self.pos[task] = ABSENT;
    }

    fn update_key(&mut self, task: usize, key: PrioKey) {
        if self.pos[task] != ABSENT {
            self.key_of[task] = key;
        }
    }

    fn len(&self) -> usize {
        self.items.len()
    }

    fn for_each_level(&mut self, visit: &mut dyn FnMut(PrioKey, &[usize]) -> bool) {
        // the old path: re-sort the whole ready set, then walk every level
        let mut scratch = std::mem::take(&mut self.scratch);
        scratch.clear();
        scratch.extend_from_slice(&self.items);
        let key_of = &self.key_of;
        scratch.sort_unstable_by(|&a, &b| {
            key_of[b].cmp(&key_of[a]).then_with(|| a.cmp(&b))
        });
        let mut i = 0;
        while i < scratch.len() {
            let key = key_of[scratch[i]];
            let mut j = i + 1;
            while j < scratch.len() && key_of[scratch[j]] == key {
                j += 1;
            }
            // early-exit hint deliberately ignored (see type docs)
            let _ = visit(key, &scratch[i..j]);
            i = j;
        }
        self.scratch = scratch;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn levels_of(q: &mut dyn ReadyQueue) -> Vec<(PrioKey, Vec<usize>)> {
        let mut out = Vec::new();
        q.for_each_level(&mut |key, level| {
            let mut tasks = level.to_vec();
            tasks.sort_unstable();
            out.push((key, tasks));
            true
        });
        out
    }

    #[test]
    fn prio_key_orderings() {
        // higher integer priority pops first
        assert!(PrioKey::from_prio(10) > PrioKey::from_prio(1));
        assert!(PrioKey::from_prio(0) > PrioKey::from_prio(-5));
        assert!(PrioKey::from_prio(i64::MAX) > PrioKey::from_prio(i64::MIN));
        // smaller SEBF bound pops first
        assert!(PrioKey::from_bound_asc(1.0, 0) > PrioKey::from_bound_asc(2.0, 0));
        assert!(PrioKey::from_bound_asc(0.0, 0) > PrioKey::from_bound_asc(1e-12, 0));
        // infinity pops last
        assert!(PrioKey::from_bound_asc(1e300, 0) > PrioKey::from_bound_asc(f64::INFINITY, 0));
        // equal bounds: smaller ordinal pops first
        assert!(PrioKey::from_bound_asc(1.0, 0) > PrioKey::from_bound_asc(1.0, 1));
    }

    #[test]
    fn bucket_levels_descend_and_group() {
        let mut q = BucketQueue::with_capacity(8);
        q.push(0, PrioKey::from_prio(1));
        q.push(1, PrioKey::from_prio(5));
        q.push(2, PrioKey::from_prio(5));
        q.push(3, PrioKey::from_prio(-2));
        assert_eq!(q.len(), 4);
        let lv = levels_of(&mut q);
        assert_eq!(lv.len(), 3);
        assert_eq!(lv[0].1, vec![1, 2]);
        assert_eq!(lv[1].1, vec![0]);
        assert_eq!(lv[2].1, vec![3]);
    }

    #[test]
    fn bucket_remove_and_update() {
        let mut q = BucketQueue::with_capacity(8);
        for t in 0..5 {
            q.push(t, PrioKey::from_prio(t as i64));
        }
        q.remove(2);
        q.remove(2); // idempotent
        q.update_key(0, PrioKey::from_prio(100));
        assert_eq!(q.len(), 4);
        let lv = levels_of(&mut q);
        assert_eq!(lv[0].1, vec![0]); // re-keyed to the top
        assert!(lv.iter().all(|(_, ts)| !ts.contains(&2)));
    }

    #[test]
    fn bucket_early_exit_stops() {
        let mut q = BucketQueue::with_capacity(8);
        for t in 0..5 {
            q.push(t, PrioKey::from_prio(t as i64));
        }
        let mut seen = 0;
        q.for_each_level(&mut |_, _| {
            seen += 1;
            seen < 2
        });
        assert_eq!(seen, 2);
    }

    /// The equivalence oracle at the data-structure level: under a long
    /// random operation sequence both queues expose exactly the same
    /// level sequence (same keys, same membership, same order).
    #[test]
    fn bucket_matches_resort_under_random_ops() {
        let mut rng = Rng::new(0xDA6);
        let n = 64;
        let mut a = BucketQueue::with_capacity(n);
        let mut b = ResortQueue::with_capacity(n);
        let mut queued = vec![false; n];
        for _ in 0..2000 {
            let t = rng.below(n);
            let key = PrioKey {
                primary: rng.below(8) as u64, // few levels: heavy collisions
                tie: rng.below(3) as u64,
            };
            match rng.below(4) {
                0 | 1 => {
                    if !queued[t] {
                        a.push(t, key);
                        b.push(t, key);
                        queued[t] = true;
                    }
                }
                2 => {
                    a.remove(t);
                    b.remove(t);
                    queued[t] = false;
                }
                _ => {
                    if queued[t] {
                        a.update_key(t, key);
                        b.update_key(t, key);
                    }
                }
            }
            assert_eq!(a.len(), b.len());
        }
        assert_eq!(levels_of(&mut a), levels_of(&mut b));
    }

    #[test]
    fn discipline_constants_flag_dynamics() {
        assert!(!QueueDiscipline::FAIR.dynamic());
        assert!(!QueueDiscipline::PRIORITY.dynamic());
        assert!(!QueueDiscipline::FIFO.dynamic());
        assert!(QueueDiscipline::COFLOW.dynamic());
        assert!(Keying::SebfGroups.dynamic());
        assert!(!Keying::FifoArrival.dynamic());
    }
}
