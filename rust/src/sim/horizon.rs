//! Anchored time advance: the finish-time heap behind
//! [`HorizonKind::Anchored`].
//!
//! Under eager integration the engine pays `O(running)` per event in
//! steps 4–5 of the event loop: the next-event horizon is a min over
//! every rated task's projected completion and remaining bytes are
//! decremented for every running task — even in components whose rates
//! have not changed for thousands of events. Anchored progress turns
//! both into heap operations:
//!
//! * every rated task stores `(anchor_time, remaining_at_anchor, rate)`
//!   in engine-side arrays, and its absolute predicted finish time
//!   `anchor + remaining / rate` lives in a [`FinHeap`] — a global
//!   indexed min-heap;
//! * the event horizon is a heap peek (min of the finish-heap top and
//!   the gate-heap top) instead of a full scan;
//! * remaining bytes are materialized **lazily**: only when a component
//!   goes dirty (arrival / completion / gate expiry / SEBF
//!   invalidation touches it) does the engine re-anchor its members at
//!   `now` via `rem = rem_anchor − rate · (now − anchor)`.
//!
//! Clean components are never iterated per event. Their heap entries
//! stay valid because their memoized rates are immutable between the
//! events that touch them — the invariant `docs/ARCHITECTURE.md` ("The
//! allocation layer") established for component-wise allocation.
//!
//! This is a deliberate, documented semantics change: anchored
//! subtraction reorders the floating-point arithmetic (one fused
//! `rate · (now − anchor)` span instead of per-event decrements, and
//! completion fires when the *predicted finish time* arrives rather
//! than when remaining bytes cross the byte epsilon), so results are no
//! longer bit-identical to the eager path. The pairing contract is
//! therefore a **tolerance oracle** — per-task trace times and makespan
//! within `1e-6` relative — crossed over the full
//! `{Incremental, FullResort} × {Components, WholeSet} × {Eager,
//! Anchored}` matrix by `tests/prop_queue_equivalence.rs` and
//! `benches/sched_scaling.rs`, while the eager corners keep their
//! bit-exact oracle among themselves. The parallel event loop
//! (`SimConfig.threads`) answers to the same split: its eager runs are
//! bit-identical to serial, its anchored runs are promised at this
//! tolerance (the fan-out computes finish times in worker arenas and a
//! serial epilogue pushes them in serial order, so in practice the
//! heap content matches serial bit-for-bit too). See
//! `docs/ARCHITECTURE.md` ("Time advance") for the anchor lifecycle.

const ABSENT: usize = usize::MAX;

/// The cross-horizon tolerance contract, in relative terms: anchored
/// and eager results must agree on the makespan and every per-task
/// trace time within this bound. Every oracle site — the engine unit
/// tests, `tests/prop_queue_equivalence.rs` (including the long-run
/// drift regression) and `benches/sched_scaling.rs` — goes through
/// [`within_tolerance`], so the contract has exactly one definition.
pub const TOLERANCE_REL: f64 = 1e-6;

/// Whether two trace times satisfy the cross-horizon tolerance oracle:
/// `|a − b| ≤ TOLERANCE_REL · max(|a|, |b|, 1)`. Two NaNs (a chunk that
/// never started in either run) also agree.
pub fn within_tolerance(a: f64, b: f64) -> bool {
    (a - b).abs() <= TOLERANCE_REL * a.abs().max(b.abs()).max(1.0) || (a.is_nan() && b.is_nan())
}

/// How the engine advances time between events (`SimConfig::horizon`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HorizonKind {
    /// Integrate remaining bytes for every rated task each event and
    /// scan them all for the next completion — the pre-refactor
    /// semantics, kept as the bit-exact baseline the `{queue, alloc}`
    /// oracles compare within.
    Eager,
    /// Anchored progress (default): predicted finish times in a
    /// [`FinHeap`], remaining bytes materialized only when a component
    /// goes dirty. Quiescent components cost zero per event; results
    /// agree with [`HorizonKind::Eager`] within the tolerance oracle,
    /// not bit-for-bit. Note the win requires component-wise
    /// allocation: combined with `AllocKind::WholeSet` everything is
    /// dirty every event, so the heap is drained and rebuilt per event
    /// — strictly more work than the eager sweep. That corner exists
    /// for the equivalence matrix, not as a configuration to run at
    /// scale.
    Anchored,
}

impl HorizonKind {
    /// Parse the CLI / scenario-JSON spelling (`eager` | `anchored`).
    pub fn parse(s: &str) -> Result<HorizonKind, String> {
        match s {
            "eager" => Ok(HorizonKind::Eager),
            "anchored" => Ok(HorizonKind::Anchored),
            other => Err(format!("unknown horizon kind `{other}` (eager|anchored)")),
        }
    }
}

/// Indexed min-heap of predicted absolute finish times.
///
/// One entry per rated task, keyed by `(finish_time, task)` under the
/// `f64` total order — the task id tie-break makes every operation
/// deterministic, so anchored simulations are reproducible run to run.
/// `pos[task]` holds the task's slot in the heap array, making
/// [`remove`](FinHeap::remove) and [`set`](FinHeap::set) `O(log n)`
/// (the decrease/remove operations the engine's re-anchor step needs)
/// instead of a rebuild.
#[derive(Debug, Default)]
pub struct FinHeap {
    heap: Vec<(f64, usize)>,
    pos: Vec<usize>,
}

impl FinHeap {
    /// Heap over task ids `0..n`.
    pub fn with_capacity(n: usize) -> FinHeap {
        FinHeap { heap: Vec::new(), pos: vec![ABSENT; n] }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Whether `task` currently has an entry.
    pub fn contains(&self, task: usize) -> bool {
        self.pos[task] != ABSENT
    }

    /// Empty the heap and re-index over task ids `0..n` — the
    /// between-runs reset used by the engine's reusable scratch
    /// ([`SimScratch`](crate::sim::SimScratch)). Buffer capacity is
    /// kept, so a warm scratch never reallocates here.
    pub fn reset(&mut self, n: usize) {
        self.heap.clear();
        self.pos.clear();
        self.pos.resize(n, ABSENT);
    }

    /// Total reserved slots (heap array + position index) — the memory
    /// high-water mark across every run this heap has served. Read by
    /// the open-loop bounded-memory oracle: with epoch GC the heap
    /// sizes to the largest live task set, never to the stream total.
    pub fn capacity(&self) -> usize {
        self.heap.capacity() + self.pos.capacity()
    }

    /// The earliest `(finish, task)` entry, if any — the event horizon.
    pub fn peek(&self) -> Option<(f64, usize)> {
        self.heap.first().copied()
    }

    /// Insert `task` with predicted finish `fin`. The task must be
    /// absent (checked with a debug assertion; use [`set`](FinHeap::set)
    /// for push-or-rekey semantics).
    pub fn push(&mut self, task: usize, fin: f64) {
        debug_assert!(!self.contains(task), "task {task} already in the finish heap");
        self.pos[task] = self.heap.len();
        self.heap.push((fin, task));
        self.sift_up(self.heap.len() - 1);
    }

    /// Re-key `task` to `fin`, inserting it if absent. Handles both
    /// decrease and increase (sifts in whichever direction the new key
    /// demands).
    pub fn set(&mut self, task: usize, fin: f64) {
        let i = self.pos[task];
        if i == ABSENT {
            self.push(task, fin);
        } else {
            self.heap[i].0 = fin;
            self.resift(i);
        }
    }

    /// Remove `task`'s entry (no-op if absent).
    pub fn remove(&mut self, task: usize) {
        let i = self.pos[task];
        if i == ABSENT {
            return;
        }
        self.pos[task] = ABSENT;
        let last = self.heap.len() - 1;
        if i != last {
            self.heap.swap(i, last);
            self.heap.pop();
            self.pos[self.heap[i].1] = i;
            self.resift(i);
        } else {
            self.heap.pop();
        }
    }

    /// Pop the earliest `(finish, task)` entry.
    pub fn pop(&mut self) -> Option<(f64, usize)> {
        let top = *self.heap.first()?;
        self.remove(top.1);
        Some(top)
    }

    /// Apply a batch of removals and (re)insertions in one pass:
    /// stale entries are compacted out, the new entries appended, and
    /// the array re-heapified bottom-up — `O(n + k)` against the
    /// `k · O(log n)` of individual [`remove`](FinHeap::remove) /
    /// [`push`](FinHeap::push) calls. The engine switches to this when
    /// a dirty component covers more than half of the heap's rated
    /// tasks. Heap *layout* may differ from the incremental path, but
    /// the observable order — [`peek`](FinHeap::peek) / [`pop`](FinHeap::pop)
    /// by the total `(finish, task)` order — is identical, so
    /// simulations are bit-for-bit the same whichever path ran.
    ///
    /// Tasks listed in `remove` that are absent are ignored; a task may
    /// appear in both lists (removed, then re-inserted at a new finish)
    /// but must not appear twice in `insert`.
    pub fn apply_batch(&mut self, remove: &[usize], insert: &[(usize, f64)]) {
        for &t in remove {
            self.pos[t] = ABSENT;
        }
        self.heap.retain(|&(_, t)| self.pos[t] != ABSENT);
        for &(t, fin) in insert {
            debug_assert!(self.pos[t] == ABSENT, "task {t} already in the finish heap");
            self.pos[t] = self.heap.len(); // provisional: marks presence, fixed below
            self.heap.push((fin, t));
        }
        let len = self.heap.len();
        for i in (0..len / 2).rev() {
            self.sift_down(i);
        }
        for i in 0..len {
            let (_, t) = self.heap[i];
            self.pos[t] = i;
        }
    }

    #[inline]
    fn less(&self, a: usize, b: usize) -> bool {
        let (fa, ta) = self.heap[a];
        let (fb, tb) = self.heap[b];
        match fa.total_cmp(&fb) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => ta < tb,
        }
    }

    fn resift(&mut self, i: usize) {
        if i > 0 && self.less(i, (i - 1) / 2) {
            self.sift_up(i);
        } else {
            self.sift_down(i);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let p = (i - 1) / 2;
            if !self.less(i, p) {
                break;
            }
            self.swap_nodes(i, p);
            i = p;
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let l = 2 * i + 1;
            if l >= self.heap.len() {
                break;
            }
            let r = l + 1;
            let mut best = l;
            if r < self.heap.len() && self.less(r, l) {
                best = r;
            }
            if !self.less(best, i) {
                break;
            }
            self.swap_nodes(i, best);
            i = best;
        }
    }

    #[inline]
    fn swap_nodes(&mut self, a: usize, b: usize) {
        self.heap.swap(a, b);
        self.pos[self.heap[a].1] = a;
        self.pos[self.heap[b].1] = b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn horizon_kind_parses() {
        assert_eq!(HorizonKind::parse("eager"), Ok(HorizonKind::Eager));
        assert_eq!(HorizonKind::parse("anchored"), Ok(HorizonKind::Anchored));
        assert!(HorizonKind::parse("lazy").is_err());
    }

    #[test]
    fn push_peek_pop_orders_by_finish_then_task() {
        let mut h = FinHeap::with_capacity(8);
        h.push(3, 2.0);
        h.push(1, 1.0);
        h.push(5, 2.0);
        h.push(0, 3.0);
        assert_eq!(h.peek(), Some((1.0, 1)));
        assert_eq!(h.pop(), Some((1.0, 1)));
        // equal finishes break ties by ascending task id
        assert_eq!(h.pop(), Some((2.0, 3)));
        assert_eq!(h.pop(), Some((2.0, 5)));
        assert_eq!(h.pop(), Some((3.0, 0)));
        assert_eq!(h.pop(), None);
        assert!(h.is_empty());
    }

    #[test]
    fn set_rekeys_both_directions_and_remove_is_idempotent() {
        let mut h = FinHeap::with_capacity(8);
        for t in 0..5 {
            h.push(t, t as f64);
        }
        h.set(4, -1.0); // decrease to the top
        assert_eq!(h.peek(), Some((-1.0, 4)));
        h.set(4, 10.0); // increase to the bottom
        assert_eq!(h.peek(), Some((0.0, 0)));
        h.remove(2);
        h.remove(2); // idempotent
        assert_eq!(h.len(), 4);
        let order: Vec<usize> = std::iter::from_fn(|| h.pop()).map(|(_, t)| t).collect();
        assert_eq!(order, vec![0, 1, 3, 4]);
    }

    /// `apply_batch` must be observably identical to the equivalent
    /// sequence of individual `remove`/`push` calls: same membership,
    /// same drain order — whatever the internal layout.
    #[test]
    fn apply_batch_matches_incremental_ops() {
        let n = 12;
        let mut inc = FinHeap::with_capacity(n);
        let mut bat = FinHeap::with_capacity(n);
        for t in 0..8 {
            let fin = (t as f64) * 0.5 + 1.0;
            inc.push(t, fin);
            bat.push(t, fin);
        }
        // remove 0..5 (plus an absent task, ignored), re-insert 1 and 3
        // at new finishes, add two fresh tasks
        let remove = [0usize, 1, 2, 3, 4, 10];
        let insert = [(1usize, 9.0), (3, 0.25), (8, 2.0), (9, 2.0)];
        for &t in &remove {
            inc.remove(t);
        }
        for &(t, fin) in &insert {
            inc.push(t, fin);
        }
        bat.apply_batch(&remove, &insert);
        assert_eq!(inc.len(), bat.len());
        for t in 0..n {
            assert_eq!(inc.contains(t), bat.contains(t), "task {t}");
        }
        let a: Vec<(f64, usize)> = std::iter::from_fn(|| inc.pop()).collect();
        let b: Vec<(f64, usize)> = std::iter::from_fn(|| bat.pop()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn reset_empties_and_reindexes() {
        let mut h = FinHeap::with_capacity(4);
        h.push(1, 2.0);
        h.push(3, 1.0);
        h.reset(6);
        assert!(h.is_empty());
        for t in 0..6 {
            assert!(!h.contains(t));
        }
        h.push(5, 0.5); // beyond the old index range
        assert_eq!(h.pop(), Some((0.5, 5)));
    }

    /// The standalone property oracle: under a long random
    /// push/re-key/remove/pop/batch sequence the heap agrees with a
    /// naive scan over a plain vector — same membership, same minimum
    /// at every step, same final drain order.
    #[test]
    fn prop_heap_matches_naive_scan_under_random_ops() {
        let mut rng = Rng::new(0xF1A7);
        let n = 48;
        let mut h = FinHeap::with_capacity(n);
        // naive oracle: fin-by-task, NAN = absent
        let mut naive = vec![f64::NAN; n];
        let naive_min = |naive: &[f64]| -> Option<(f64, usize)> {
            let mut best: Option<(f64, usize)> = None;
            for (t, &f) in naive.iter().enumerate() {
                if f.is_nan() {
                    continue;
                }
                best = match best {
                    Some((bf, bt)) if (bf, bt) <= (f, t) => Some((bf, bt)),
                    _ => Some((f, t)),
                };
            }
            best
        };
        for step in 0..4000 {
            let t = rng.below(n);
            // coarse keys force heavy finish-time collisions
            let fin = (rng.below(16) as f64) * 0.25;
            match rng.below(6) {
                0 | 1 => {
                    if naive[t].is_nan() {
                        h.push(t, fin);
                        naive[t] = fin;
                    }
                }
                2 => {
                    h.set(t, fin);
                    naive[t] = fin;
                }
                3 => {
                    h.remove(t);
                    naive[t] = f64::NAN;
                }
                5 => {
                    // batch: remove a random prefix of ids, re-insert a
                    // disjoint batch at fresh finishes
                    let k = rng.below(n / 2) + 1;
                    let remove: Vec<usize> = (0..k).collect();
                    let mut insert = Vec::new();
                    for t in 0..k {
                        naive[t] = f64::NAN;
                    }
                    for t in k..n {
                        if naive[t].is_nan() && rng.bool(0.25) {
                            let f = (rng.below(16) as f64) * 0.25;
                            insert.push((t, f));
                            naive[t] = f;
                        }
                    }
                    h.apply_batch(&remove, &insert);
                }
                _ => {
                    let got = h.pop();
                    let want = naive_min(&naive);
                    assert_eq!(got, want, "pop mismatch at step {step}");
                    if let Some((_, t)) = want {
                        naive[t] = f64::NAN;
                    }
                }
            }
            let live = naive.iter().filter(|f| !f.is_nan()).count();
            assert_eq!(h.len(), live, "len mismatch at step {step}");
            assert_eq!(h.peek(), naive_min(&naive), "peek mismatch at step {step}");
            for t in 0..n {
                assert_eq!(h.contains(t), !naive[t].is_nan());
            }
        }
        // final drain reproduces the oracle's sorted order exactly
        let mut want: Vec<(f64, usize)> = naive
            .iter()
            .enumerate()
            .filter(|(_, f)| !f.is_nan())
            .map(|(t, &f)| (f, t))
            .collect();
        want.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let got: Vec<(f64, usize)> = std::iter::from_fn(|| h.pop()).collect();
        assert_eq!(got, want);
    }
}
