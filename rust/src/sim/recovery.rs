//! Fault recovery: task retry with backoff, and per-job quarantine.
//!
//! PR 7 made the *cluster* fail; this layer makes the *application*
//! survive it. [`RecoveryPolicy`] is the fifth orthogonal engine axis
//! (after queue / alloc / horizon / threads) and follows the same
//! oracle-pairing convention: the default [`RecoveryPolicy::FailFast`]
//! is bit-identical to the recovery-free engine — a stuck simulation
//! still aborts with `SimError::Deadlock` — while
//! [`RecoveryPolicy::Retry`] turns two kinds of misfortune into
//! simulated-time mechanics instead of aborts:
//!
//! - **Host crashes** ([`DynAction::FailHost`](super::dynamics::DynAction)):
//!   every in-flight task whose footprint touches the crashed host
//!   *loses its progress* — remaining bytes reset to full, held
//!   capacity is released through the component dirty protocol, and
//!   the task re-enters the engine behind a deterministic
//!   exponential-backoff timer ([`retry_backoff`]) implemented as a
//!   plain gate event, so eager event boundaries stay bit-comparable
//!   across every engine corner.
//! - **Terminal starvation**: where FailFast would deadlock (a flow
//!   stranded on a dead trunk with no survivor, a task parked behind a
//!   barrier that can never open, or attempts exhausted), Retry
//!   **quarantines the owning job** — removes its unfinished tasks in
//!   task-id order, releases every held cap, dirties exactly the
//!   touched contention components — and keeps simulating everyone
//!   else. The per-job verdicts come back as [`JobOutcome`]s on
//!   `SimResult`.
//!
//! Jobs are identified by `SimDag::job_of` (annotated through
//! `Annotations::jobs` by the multi-job planners; a DAG with no job map
//! is a single job `0`). See `docs/ARCHITECTURE.md` ("Failure
//! recovery") for the cap-release protocol and the recovery oracle.

use super::engine::StuckReason;
use crate::util::json::Json;

/// Default failed-attempt budget for `retry` with no arguments.
pub const DEFAULT_MAX_ATTEMPTS: usize = 3;
/// Default base backoff (simulated seconds) for `retry` with no
/// arguments.
pub const DEFAULT_BACKOFF: f64 = 1.0;

/// How the engine responds to lost work and terminally-stuck tasks.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RecoveryPolicy {
    /// Abort the whole simulation on the first terminally-stuck task
    /// (`SimError::Deadlock`), exactly as before this layer existed.
    /// The default, and the bitwise oracle corner: FailFast with *any*
    /// timeline is bit-identical to the recovery-free engine.
    FailFast,
    /// Survive failures: crashed-host victims retry behind
    /// [`retry_backoff`] gates, and terminally-stuck or
    /// attempts-exhausted tasks quarantine their job instead of
    /// aborting the run.
    Retry {
        /// A task's `max_attempts`-th *failed* attempt quarantines its
        /// job with [`JobOutcome::Exhausted`]; up to `max_attempts - 1`
        /// failures are retried. Must be at least 1.
        max_attempts: usize,
        /// Base backoff delay: the `k`-th failure re-gates the task at
        /// `now + backoff * 2^(k-1)` simulated seconds.
        backoff: f64,
    },
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy::FailFast
    }
}

impl RecoveryPolicy {
    /// `retry` with the default attempt budget and backoff.
    pub fn retry_default() -> Self {
        RecoveryPolicy::Retry { max_attempts: DEFAULT_MAX_ATTEMPTS, backoff: DEFAULT_BACKOFF }
    }

    pub fn is_retry(&self) -> bool {
        matches!(self, RecoveryPolicy::Retry { .. })
    }

    /// Parse the CLI spelling: `failfast`, `retry`, or
    /// `retry:MAX_ATTEMPTS:BACKOFF`.
    pub fn parse(s: &str) -> Result<RecoveryPolicy, String> {
        match s {
            "failfast" => return Ok(RecoveryPolicy::FailFast),
            "retry" => return Ok(RecoveryPolicy::retry_default()),
            _ => {}
        }
        if let Some(rest) = s.strip_prefix("retry:") {
            let (a, b) = rest
                .split_once(':')
                .ok_or_else(|| format!("recovery `{s}`: expected retry:MAX_ATTEMPTS:BACKOFF"))?;
            let max_attempts: usize = a
                .parse()
                .map_err(|_| format!("recovery `{s}`: bad max_attempts `{a}`"))?;
            let backoff: f64 = b
                .parse()
                .map_err(|_| format!("recovery `{s}`: bad backoff `{b}`"))?;
            let p = RecoveryPolicy::Retry { max_attempts, backoff };
            p.validate()?;
            return Ok(p);
        }
        Err(format!(
            "recovery `{s}`: expected failfast | retry | retry:MAX_ATTEMPTS:BACKOFF"
        ))
    }

    /// Stable string spelling, inverse of [`RecoveryPolicy::parse`].
    pub fn label(&self) -> String {
        match *self {
            RecoveryPolicy::FailFast => "failfast".into(),
            RecoveryPolicy::Retry { max_attempts, backoff } => {
                format!("retry:{max_attempts}:{backoff}")
            }
        }
    }

    /// Parse the scenario-JSON spelling: the string `"failfast"` /
    /// `"retry"`, or `{"kind": "retry", "max_attempts": N, "backoff": X}`
    /// (both object fields optional, defaulting as in
    /// [`RecoveryPolicy::retry_default`]).
    pub fn from_json(j: &Json) -> Result<RecoveryPolicy, String> {
        if let Ok(s) = j.as_str() {
            return RecoveryPolicy::parse(s);
        }
        let kind = j
            .get("kind")
            .and_then(|v| v.as_str())
            .map_err(|e| format!("recovery: {e}"))?;
        match kind {
            "failfast" => Ok(RecoveryPolicy::FailFast),
            "retry" => {
                let max_attempts = match j.get("max_attempts") {
                    Ok(v) => v.as_usize().map_err(|e| format!("recovery: {e}"))?,
                    Err(_) => DEFAULT_MAX_ATTEMPTS,
                };
                let backoff = match j.get("backoff") {
                    Ok(v) => v.as_f64().map_err(|e| format!("recovery: {e}"))?,
                    Err(_) => DEFAULT_BACKOFF,
                };
                let p = RecoveryPolicy::Retry { max_attempts, backoff };
                p.validate()?;
                Ok(p)
            }
            _ => Err(format!("recovery: unknown kind `{kind}` (failfast|retry)")),
        }
    }

    /// Serialize to the [`RecoveryPolicy::from_json`] format.
    pub fn to_json(&self) -> Json {
        match *self {
            RecoveryPolicy::FailFast => Json::Str("failfast".into()),
            RecoveryPolicy::Retry { max_attempts, backoff } => Json::obj(vec![
                ("kind", Json::Str("retry".into())),
                ("max_attempts", Json::Num(max_attempts as f64)),
                ("backoff", Json::Num(backoff)),
            ]),
        }
    }

    /// Reject degenerate parameters (`max_attempts == 0`, or a backoff
    /// that is negative / non-finite — zero is legal and means an
    /// immediate re-gate at `now`).
    pub fn validate(&self) -> Result<(), String> {
        if let RecoveryPolicy::Retry { max_attempts, backoff } = *self {
            if max_attempts == 0 {
                return Err("recovery: max_attempts must be at least 1".into());
            }
            if !backoff.is_finite() || backoff < 0.0 {
                return Err(format!("recovery: bad backoff {backoff}"));
            }
        }
        Ok(())
    }
}

/// Deterministic exponential backoff: the delay charged after a task's
/// `attempt`-th failure (`attempt >= 1`) is `backoff * 2^(attempt-1)`.
/// Pure simulated-time arithmetic — the retry lands as an ordinary gate
/// event, so event boundaries stay identical across engine corners.
pub fn retry_backoff(backoff: f64, attempt: usize) -> f64 {
    backoff * f64::powi(2.0, attempt.saturating_sub(1) as i32)
}

/// Per-job verdict reported by `SimResult::jobs`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum JobOutcome {
    /// Every task of the job finished; `finish` is the latest task
    /// finish time (the job's completion time).
    Completed { finish: f64 },
    /// The job was quarantined at simulated time `at` because a member
    /// task was terminally stuck for `reason` (dead-trunk starvation, a
    /// barrier that can never open, …).
    Quarantined { reason: StuckReason, at: f64 },
    /// A member task burned through its whole failed-attempt budget.
    Exhausted { attempts: usize },
    /// The open-loop admission controller refused the job at simulated
    /// time `at` (watermark exceeded, or the deferral window expired
    /// before load dropped). Distinct from [`JobOutcome::Quarantined`]:
    /// a rejected job never entered the engine, held no capacity and
    /// lost no work — `SimResult::lost_work` and the retry accounting
    /// never see it.
    Rejected { at: f64 },
}

impl JobOutcome {
    pub fn is_completed(&self) -> bool {
        matches!(self, JobOutcome::Completed { .. })
    }

    /// Completion time, when the job completed.
    pub fn finish(&self) -> Option<f64> {
        match *self {
            JobOutcome::Completed { finish } => Some(finish),
            _ => None,
        }
    }

    /// One row of the CLI's per-job outcome table.
    pub fn to_json(&self, job: usize) -> Json {
        match *self {
            JobOutcome::Completed { finish } => Json::obj(vec![
                ("job", Json::Num(job as f64)),
                ("outcome", Json::Str("completed".into())),
                ("finish", Json::Num(finish)),
            ]),
            JobOutcome::Quarantined { reason, at } => Json::obj(vec![
                ("job", Json::Num(job as f64)),
                ("outcome", Json::Str("quarantined".into())),
                ("reason", Json::Str(reason.label())),
                ("at", Json::Num(at)),
            ]),
            JobOutcome::Exhausted { attempts } => Json::obj(vec![
                ("job", Json::Num(job as f64)),
                ("outcome", Json::Str("exhausted".into())),
                ("attempts", Json::Num(attempts as f64)),
            ]),
            JobOutcome::Rejected { at } => Json::obj(vec![
                ("job", Json::Num(job as f64)),
                ("outcome", Json::Str("rejected".into())),
                ("at", Json::Num(at)),
            ]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_label_round_trip() {
        for s in ["failfast", "retry:5:0.25"] {
            let p = RecoveryPolicy::parse(s).unwrap();
            assert_eq!(p.label(), s);
        }
        assert_eq!(RecoveryPolicy::parse("retry").unwrap(), RecoveryPolicy::retry_default());
        assert!(RecoveryPolicy::parse("retry:0:1").is_err()); // zero attempts
        assert!(RecoveryPolicy::parse("retry:3:-1").is_err()); // negative backoff
        assert!(RecoveryPolicy::parse("retry:3").is_err()); // missing backoff
        assert!(RecoveryPolicy::parse("never").is_err());
    }

    #[test]
    fn json_round_trip_and_defaults() {
        for p in [RecoveryPolicy::FailFast, RecoveryPolicy::Retry { max_attempts: 7, backoff: 0.5 }]
        {
            assert_eq!(RecoveryPolicy::from_json(&p.to_json()).unwrap(), p);
        }
        // bare string and defaulted object fields
        let j = Json::parse(r#""retry""#).unwrap();
        assert_eq!(RecoveryPolicy::from_json(&j).unwrap(), RecoveryPolicy::retry_default());
        let j = Json::parse(r#"{"kind": "retry", "backoff": 2.0}"#).unwrap();
        assert_eq!(
            RecoveryPolicy::from_json(&j).unwrap(),
            RecoveryPolicy::Retry { max_attempts: DEFAULT_MAX_ATTEMPTS, backoff: 2.0 }
        );
        assert!(RecoveryPolicy::from_json(&Json::parse(r#"{"kind": "pray"}"#).unwrap()).is_err());
    }

    #[test]
    fn backoff_doubles_per_attempt() {
        assert_eq!(retry_backoff(0.5, 1), 0.5);
        assert_eq!(retry_backoff(0.5, 2), 1.0);
        assert_eq!(retry_backoff(0.5, 4), 4.0);
        assert_eq!(retry_backoff(0.0, 3), 0.0);
    }

    #[test]
    fn outcome_accessors() {
        let c = JobOutcome::Completed { finish: 2.5 };
        assert!(c.is_completed());
        assert_eq!(c.finish(), Some(2.5));
        let q = JobOutcome::Quarantined { reason: StuckReason::Blocked, at: 1.0 };
        assert!(!q.is_completed());
        assert_eq!(q.finish(), None);
        let row = q.to_json(3).to_string();
        assert!(row.contains("\"quarantined\""), "{row}");
    }

    #[test]
    fn rejected_is_distinct_from_quarantined() {
        let r = JobOutcome::Rejected { at: 4.5 };
        assert!(!r.is_completed());
        assert_eq!(r.finish(), None);
        let row = r.to_json(7).to_string();
        assert!(row.contains("\"rejected\""), "{row}");
        assert!(row.contains("4.5"), "{row}");
        // the admission verdict must never be confused with an
        // in-engine quarantine: different JSON outcome tags
        let q = JobOutcome::Quarantined { reason: StuckReason::Blocked, at: 4.5 };
        assert_ne!(r, q);
        assert!(!q.to_json(7).to_string().contains("\"rejected\""));
    }
}
