//! Open-system streaming driver: an unbounded stream of job arrivals
//! over the closed fluid engine, with admission control, overload
//! shedding and bounded-memory epoch GC.
//!
//! # Era chaining
//!
//! The closed engine (`sim/engine.rs`) simulates one fixed DAG to
//! completion. The open loop turns it into a streaming system by
//! *chaining* closed runs, one **era** per inter-boundary interval
//! (boundaries are job arrivals and deferral expiries):
//!
//! 1. Build a compacted DAG holding only the **live** jobs' unfinished
//!    tasks (sizes = carried remaining bytes, gates/retry backoffs
//!    rebased to the era clock, finished predecessors dropped).
//! 2. Run the engine with [`SimConfig::stop`] at the next boundary.
//!    The stop is an ordinary event-class boundary: no task integrates
//!    across it, and the run exports its in-flight state as
//!    [`StopState`].
//! 3. Harvest: record completions (absolute traces), carry remaining /
//!    attempts / backoff gates, retire finished or quarantined jobs —
//!    their state leaves the compacted DAG, which is what keeps the
//!    scratch arena, [`CompSet`](crate::sim::CompSet) and
//!    [`FinHeap`](crate::sim::FinHeap) sized to the largest *live* set
//!    rather than the stream total (the epoch GC).
//! 4. At the boundary: retest deferred jobs, expire overdue ones,
//!    admit or shed the arrivals due now. Repeat.
//!
//! The final era runs with `stop: None`, so deadlock detection and
//! quarantine semantics in the drained system are exactly the closed
//! engine's.
//!
//! # Admission control
//!
//! A job is admitted when the estimated drain time of the settled
//! cluster — queued live work plus the incoming job, divided by
//! settled capacity (see [`settled_cluster`]) — stays under
//! [`OpenConfig::watermark`]:
//!
//! ```text
//! drain = max(Σ compute remaining / Σ settled core caps,
//!             Σ flow remaining    / Σ settled (NIC up + down)/2)
//! ```
//!
//! Fabric extras are ignored by the estimate (it is an optimistic
//! bound, mirroring `settled_cluster`'s host-level view). A refused
//! job waits up to [`OpenConfig::defer_max`] in a deferral queue,
//! retested at every stream boundary (deferred jobs are retested
//! *before* same-instant fresh arrivals, oldest first) and gets one
//! last test at its expiry; a job whose *solo* drain already exceeds
//! the watermark can never pass and is rejected immediately, which
//! guarantees termination. Shed jobs get the distinct
//! [`JobOutcome::Rejected`] — they never entered the engine, so
//! `lost_work` and retry accounting never see them.
//!
//! # Determinism and the closed-mode oracle
//!
//! Everything is a pure function of (arrival trace, watermark, seed):
//! the admitted/rejected set and every per-job outcome are identical
//! across thread counts (bitwise under the eager horizon; anchored
//! runs inherit the engine's 1e-6 tolerance pairing). With every
//! arrival at `t = 0` and an infinite watermark the loop runs exactly
//! one era with `stop: None` over the [`concat_jobs`] concatenation —
//! bit-identical to a closed run of the same DAG, which is the oracle
//! `tests/prop_open_equivalence.rs` asserts across the whole
//! {queue}×{alloc}×{horizon}×{threads}×{recovery} matrix.
//!
//! # Dynamics across eras
//!
//! Each era re-folds the absolute [`DynTimeline`]: events strictly
//! before the era start replay at the era's `t = 0` in original order
//! (factors are absolute last-writer-wins, so the replay reconstructs
//! the exact factor state — independent of which jobs have departed,
//! so a restore arriving after the last touching job completed still
//! applies to later arrivals), with past [`DynAction::FailHost`]
//! crashes demoted to capacity-identical `SlowHost { factor: 0.0 }`
//! so a crash kills in-flight work exactly once. Future events shift
//! to era-relative time unchanged.
//!
//! One accounting caveat: a task killed in a later era than it started
//! loses *all* its progress (the carry restores the full original
//! size), and the extra prior-era loss is added to `lost_work` when
//! the era stops at a boundary; an era that runs to completion has no
//! per-task attempt export, so cross-era loss of victims that also
//! finish inside that era is undercounted by their prior-era progress.

use crate::sched::settled_cluster;
use crate::sim::dynamics::{DynAction, DynTimeline};
use crate::sim::engine::{simulate_in, SimConfig, SimError, SimScratch, TaskTrace};
use crate::sim::recovery::{JobOutcome, RecoveryPolicy};
use crate::sim::spec::{Cluster, SimDag, SimKind, SimTask};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Matches the engine's time-comparison epsilon.
const EPS: f64 = 1e-9;

/// One streaming arrival: a physical job DAG entering at `at`.
#[derive(Debug, Clone)]
pub struct OpenJob {
    /// Arrival instant on the absolute stream clock.
    pub at: f64,
    /// The job's physical DAG. Task gates are relative to the job's
    /// *admission* instant (the plan was computed as if starting at 0).
    pub dag: SimDag,
    /// Completion deadline measured from arrival, if any.
    pub deadline: Option<f64>,
}

/// Open-loop driver configuration.
#[derive(Debug, Clone)]
pub struct OpenConfig {
    /// Admission watermark: estimated drain time (module docs) above
    /// which arrivals are refused. `INFINITY` (default) admits all.
    pub watermark: f64,
    /// How long a refused job may wait in the deferral queue before it
    /// is shed for good. `0.0` (default) sheds immediately.
    pub defer_max: f64,
    /// The closed-engine configuration every era runs under.
    /// `engine.stop` / `engine.attempts0` are owned by the driver and
    /// overwritten per era.
    pub engine: SimConfig,
}

impl Default for OpenConfig {
    fn default() -> Self {
        OpenConfig {
            watermark: f64::INFINITY,
            defer_max: 0.0,
            engine: SimConfig::default(),
        }
    }
}

/// Per-job verdict, all times on the absolute stream clock.
#[derive(Debug, Clone)]
pub struct OpenJobResult {
    pub arrival: f64,
    /// When the job entered the engine (`None` = shed before entry).
    pub admitted_at: Option<f64>,
    /// [`JobOutcome::Rejected`] for shed jobs; `Completed` /
    /// `Quarantined` / `Exhausted` otherwise, times rebased absolute.
    pub outcome: JobOutcome,
    /// Completion latency (finish − arrival) for completed jobs.
    pub jct: Option<f64>,
    /// Whether `jct ≤ deadline`; `None` when the job has no deadline.
    /// Non-completed jobs with a deadline report `Some(false)`.
    pub deadline_met: Option<bool>,
    /// Absolute per-task trace, parallel to the job's DAG (`start` is
    /// the first instant work began; `NaN` where unknown). Empty for
    /// rejected jobs.
    pub trace: Vec<TaskTrace>,
}

/// Aggregate outcome of a streamed run.
#[derive(Debug, Clone)]
pub struct OpenResult {
    /// Per-job results, indexed like the input job list.
    pub jobs: Vec<OpenJobResult>,
    /// Latest completion / quarantine instant observed (0 if none).
    pub makespan: f64,
    /// Number of engine runs chained (idle boundary hops excluded).
    pub eras: usize,
    /// Engine iterations summed across eras.
    pub events: usize,
    /// Task re-enqueues summed across eras.
    pub retries: usize,
    /// Work destroyed by crashes, cross-era losses included (see the
    /// module-docs caveat).
    pub lost_work: f64,
    pub admitted: usize,
    pub rejected: usize,
    pub quarantined: usize,
    pub completed: usize,
}

impl OpenResult {
    /// Sorted JCTs of completed jobs.
    fn jcts(&self) -> Vec<f64> {
        let mut v: Vec<f64> = self.jobs.iter().filter_map(|j| j.jct).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }

    /// Nearest-rank percentile of completed-job JCTs (`q` in [0, 1]);
    /// `None` when nothing completed.
    pub fn jct_percentile(&self, q: f64) -> Option<f64> {
        let v = self.jcts();
        if v.is_empty() {
            return None;
        }
        let i = ((v.len() - 1) as f64 * q).round() as usize;
        Some(v[i])
    }

    /// Fraction of deadline-carrying jobs that completed within their
    /// deadline (`None` when no job had one). Shed and quarantined
    /// jobs count as misses.
    pub fn deadline_hit_rate(&self) -> Option<f64> {
        let with: Vec<bool> = self.jobs.iter().filter_map(|j| j.deadline_met).collect();
        if with.is_empty() {
            return None;
        }
        Some(with.iter().filter(|&&m| m).count() as f64 / with.len() as f64)
    }

    /// Summary object for the CLI outcome line: counters, JCT p50/p99
    /// and the deadline hit rate (keys omitted when undefined).
    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            // `n_jobs`, not `jobs`: the CLI outcome line reserves `jobs`
            // for the per-job verdict array ([`jobs_json`]), matching the
            // closed-path schema
            ("n_jobs", Json::Num(self.jobs.len() as f64)),
            ("admitted", Json::Num(self.admitted as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("quarantined", Json::Num(self.quarantined as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("eras", Json::Num(self.eras as f64)),
            ("events", Json::Num(self.events as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("lost_work", Json::Num(self.lost_work)),
            ("makespan", Json::Num(self.makespan)),
        ];
        if let Some(p50) = self.jct_percentile(0.5) {
            kv.push(("jct_p50", Json::Num(p50)));
        }
        if let Some(p99) = self.jct_percentile(0.99) {
            kv.push(("jct_p99", Json::Num(p99)));
        }
        if let Some(rate) = self.deadline_hit_rate() {
            kv.push(("deadline_hit_rate", Json::Num(rate)));
        }
        Json::obj(kv)
    }

    /// Per-job verdict array (one object per input job, input order).
    pub fn jobs_json(&self) -> Json {
        Json::Arr(
            self.jobs
                .iter()
                .enumerate()
                .map(|(i, j)| {
                    let mut kv = vec![
                        ("job", Json::Num(i as f64)),
                        ("arrival", Json::Num(j.arrival)),
                        ("outcome", j.outcome.to_json(i)),
                    ];
                    if let Some(a) = j.admitted_at {
                        kv.push(("admitted_at", Json::Num(a)));
                    }
                    if let Some(jct) = j.jct {
                        kv.push(("jct", Json::Num(jct)));
                    }
                    if let Some(m) = j.deadline_met {
                        kv.push(("deadline_met", Json::Bool(m)));
                    }
                    Json::obj(kv)
                })
                .collect(),
        )
    }
}

/// Deterministic Poisson arrival trace: `n` cumulative exponential
/// inter-arrival gaps at `rate` jobs per time unit, seeded.
pub fn poisson_arrivals(seed: u64, rate: f64, n: usize) -> Vec<f64> {
    assert!(rate.is_finite() && rate > 0.0, "rate must be finite and positive");
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        // u ∈ [0, 1) so 1 − u ∈ (0, 1] and the gap is finite, ≥ 0
        t += -(1.0 - rng.f64()).ln() / rate;
        out.push(t);
    }
    out
}

/// Logical-id namespace width of a job DAG (`max orig + 1`).
fn n_origs(d: &SimDag) -> usize {
    d.tasks.iter().map(|t| t.orig + 1).max().unwrap_or(0)
}

/// Coflow-id namespace width of a job DAG.
fn n_coflows(d: &SimDag) -> usize {
    d.tasks
        .iter()
        .map(|t| t.coflow.map_or(0, |c| c + 1))
        .max()
        .unwrap_or(0)
}

/// Concatenate whole jobs into one closed-mode DAG with the same
/// per-job `orig` / coflow offsets the era rebuild uses — the
/// closed-mode comparison DAG of the open-at-`t = 0` oracle.
pub fn concat_jobs(jobs: &[OpenJob]) -> SimDag {
    let mut all = SimDag::default();
    let (mut orig_off, mut cof_off) = (0usize, 0usize);
    for (j, job) in jobs.iter().enumerate() {
        all.append_job(&job.dag, j, orig_off, cof_off);
        orig_off += n_origs(&job.dag);
        cof_off += n_coflows(&job.dag);
    }
    all
}

/// Settled aggregate capacities backing the admission estimate.
struct SettledCaps {
    compute: f64,
    net: f64,
}

fn settled_caps(cluster: &Cluster, tl: &DynTimeline) -> SettledCaps {
    let settled = settled_cluster(cluster, tl);
    let mut compute = 0.0;
    let mut net = 0.0;
    for h in &settled.hosts {
        compute += h.cores;
        net += (h.nic_up + h.nic_down) / 2.0;
    }
    SettledCaps { compute, net }
}

/// (compute bytes, flow bytes) of a whole job DAG.
fn job_load(d: &SimDag) -> (f64, f64) {
    let mut c = 0.0;
    let mut f = 0.0;
    for t in &d.tasks {
        match t.kind {
            SimKind::Compute { .. } => c += t.size,
            SimKind::Flow { .. } => f += t.size,
            SimKind::Dummy => {}
        }
    }
    (c, f)
}

/// Estimated drain time of `(compute, flow)` load (module docs).
fn drain_time(load: (f64, f64), caps: &SettledCaps) -> f64 {
    let d = |l: f64, c: f64| {
        if l <= 0.0 {
            0.0
        } else if c <= 0.0 {
            f64::INFINITY
        } else {
            l / c
        }
    };
    d(load.0, caps.compute).max(d(load.1, caps.net))
}

/// A job currently inside the engine, carried between eras.
struct Live {
    /// Index into the input job list.
    idx: usize,
    /// Absolute admission instant (gates rebase from it).
    admit: f64,
    /// `orig` / coflow namespace widths, fixed at admission.
    origs: usize,
    coflows: usize,
    /// Unfinished bytes per local task (original size until started).
    remaining: Vec<f64>,
    /// Task finished (engine reported a finite finish).
    done: Vec<bool>,
    /// Effective earliest-start per local task, absolute: admission +
    /// plan gate, raised by carried retry-backoff gates.
    gate_abs: Vec<f64>,
    /// Carried failed-attempt counts (retry recovery only).
    attempts: Vec<usize>,
    /// Absolute first-start / finish per local task (`NaN` = unknown).
    start_abs: Vec<f64>,
    finish_abs: Vec<f64>,
}

impl Live {
    fn new(idx: usize, job: &OpenJob, admit: f64) -> Live {
        let n = job.dag.len();
        Live {
            idx,
            admit,
            origs: n_origs(&job.dag),
            coflows: n_coflows(&job.dag),
            remaining: job.dag.tasks.iter().map(|t| t.size).collect(),
            done: vec![false; n],
            gate_abs: job.dag.tasks.iter().map(|t| admit + t.gate).collect(),
            attempts: vec![0; n],
            start_abs: vec![f64::NAN; n],
            finish_abs: vec![f64::NAN; n],
        }
    }

    /// Remaining (compute, flow) bytes.
    fn load(&self, dag: &SimDag) -> (f64, f64) {
        let mut c = 0.0;
        let mut f = 0.0;
        for (t, task) in dag.tasks.iter().enumerate() {
            if self.done[t] || self.remaining[t] <= 0.0 {
                continue;
            }
            match task.kind {
                SimKind::Compute { .. } => c += self.remaining[t],
                SimKind::Flow { .. } => f += self.remaining[t],
                SimKind::Dummy => {}
            }
        }
        (c, f)
    }
}

/// As [`run_open`], allocating a fresh scratch.
pub fn run_open(
    jobs: &[OpenJob],
    cluster: &Cluster,
    cfg: &OpenConfig,
) -> Result<OpenResult, SimError> {
    run_open_in(jobs, cluster, cfg, &mut SimScratch::default())
}

/// Run the open-loop stream (module docs), reusing `scratch` across
/// eras — the bounded-memory entry point: the scratch grows to the
/// largest live set's high-water mark and plateaus there no matter how
/// many jobs stream through.
pub fn run_open_in(
    jobs: &[OpenJob],
    cluster: &Cluster,
    cfg: &OpenConfig,
    scratch: &mut SimScratch,
) -> Result<OpenResult, SimError> {
    assert!(
        cfg.watermark >= 0.0 && !cfg.watermark.is_nan(),
        "watermark must be ≥ 0 (INFINITY = admit all)"
    );
    assert!(
        cfg.defer_max >= 0.0 && cfg.defer_max.is_finite(),
        "defer_max must be finite and ≥ 0"
    );
    for j in jobs {
        assert!(j.at.is_finite() && j.at >= 0.0, "arrival times must be finite and ≥ 0");
    }
    let caps = settled_caps(cluster, &cfg.engine.dynamics);
    let retry_on = matches!(cfg.engine.recovery, RecoveryPolicy::Retry { .. });

    // Arrival order: by time, ties by input index (stable).
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    order.sort_by(|&a, &b| jobs[a].at.partial_cmp(&jobs[b].at).unwrap().then(a.cmp(&b)));

    let mut out: Vec<Option<OpenJobResult>> = jobs.iter().map(|_| None).collect();
    let mut live: Vec<Live> = Vec::new();
    let mut deferred: Vec<(usize, f64)> = Vec::new(); // (job idx, expiry), arrival order
    let mut next = 0usize;
    let mut now = 0.0f64;
    let (mut eras, mut events, mut retries) = (0usize, 0usize, 0usize);
    let mut lost_work = 0.0f64;
    let (mut admitted, mut rejected) = (0usize, 0usize);

    // Era-rebuild buffers, reused so per-era allocation is bounded by
    // the live set (the driver-side half of the epoch GC).
    let mut era_dag = SimDag::default();
    let mut era_map: Vec<(usize, usize)> = Vec::new(); // era task -> (slot, local)
    let mut local: Vec<usize> = Vec::new();
    let mut attempts0: Vec<usize> = Vec::new();

    let reject = |idx: usize, at: f64, out: &mut Vec<Option<OpenJobResult>>, n: &mut usize| {
        out[idx] = Some(OpenJobResult {
            arrival: jobs[idx].at,
            admitted_at: None,
            outcome: JobOutcome::Rejected { at },
            jct: None,
            deadline_met: jobs[idx].deadline.map(|_| false),
            trace: Vec::new(),
        });
        *n += 1;
    };

    loop {
        // ---- stream boundary: admit / defer / shed --------------------
        let (mut load_c, mut load_f) = live
            .iter()
            .fold((0.0, 0.0), |(c, f), lj| {
                let (jc, jf) = lj.load(&jobs[lj.idx].dag);
                (c + jc, f + jf)
            });
        // Deferred first (oldest first), each getting a final test at
        // its expiry before it is shed.
        for (idx, expiry) in std::mem::take(&mut deferred) {
            let jl = job_load(&jobs[idx].dag);
            if drain_time((load_c + jl.0, load_f + jl.1), &caps) <= cfg.watermark {
                live.push(Live::new(idx, &jobs[idx], now));
                admitted += 1;
                load_c += jl.0;
                load_f += jl.1;
            } else if expiry <= now + EPS {
                reject(idx, expiry, &mut out, &mut rejected);
            } else {
                deferred.push((idx, expiry));
            }
        }
        // Fresh arrivals due now, input order.
        while next < order.len() && jobs[order[next]].at <= now + EPS {
            let idx = order[next];
            next += 1;
            let jl = job_load(&jobs[idx].dag);
            let solo = drain_time(jl, &caps);
            if drain_time((load_c + jl.0, load_f + jl.1), &caps) <= cfg.watermark {
                live.push(Live::new(idx, &jobs[idx], now));
                admitted += 1;
                load_c += jl.0;
                load_f += jl.1;
            } else if solo > cfg.watermark || cfg.defer_max <= 0.0 {
                // Can never pass (or no deferral window): shed now.
                reject(idx, now, &mut out, &mut rejected);
            } else {
                deferred.push((idx, jobs[idx].at + cfg.defer_max));
            }
        }

        // ---- next boundary strictly after `now` -----------------------
        let next_arrival = order.get(next).map(|&i| jobs[i].at);
        let next_expiry = deferred.iter().fold(f64::INFINITY, |m, &(_, e)| m.min(e));
        let boundary = match next_arrival {
            Some(a) => Some(a.min(next_expiry)),
            None if next_expiry.is_finite() => Some(next_expiry),
            None => None,
        };

        // ---- era ------------------------------------------------------
        if live.is_empty() {
            match boundary {
                Some(b) => {
                    now = b;
                    continue;
                }
                None => break,
            }
        }

        // Rebuild the compacted live-jobs DAG on the era clock.
        era_dag.tasks.clear();
        era_dag.preds.clear();
        era_dag.succs.clear();
        era_dag.job_of.clear();
        era_map.clear();
        attempts0.clear();
        let mut any_attempts = false;
        let (mut orig_off, mut cof_off) = (0usize, 0usize);
        for (slot, lj) in live.iter().enumerate() {
            let jd = &jobs[lj.idx].dag;
            local.clear();
            local.resize(jd.len(), usize::MAX);
            for lt in 0..jd.len() {
                if lj.done[lt] {
                    continue;
                }
                let t0 = &jd.tasks[lt];
                let id = era_dag.push(SimTask {
                    orig: t0.orig + orig_off,
                    chunk: t0.chunk,
                    kind: t0.kind,
                    size: lj.remaining[lt],
                    priority: t0.priority,
                    gate: (lj.gate_abs[lt] - now).max(0.0),
                    coflow: t0.coflow.map(|c| c + cof_off),
                });
                era_dag.job_of.push(slot);
                local[lt] = id;
                era_map.push((slot, lt));
                if retry_on {
                    attempts0.push(lj.attempts[lt]);
                    any_attempts |= lj.attempts[lt] > 0;
                }
            }
            for lt in 0..jd.len() {
                if local[lt] == usize::MAX {
                    continue;
                }
                for &p in &jd.preds[lt] {
                    if local[p] != usize::MAX {
                        era_dag.dep(local[p], local[lt]);
                    }
                }
            }
            orig_off += lj.origs;
            cof_off += lj.coflows;
        }

        let mut ecfg = cfg.engine.clone();
        ecfg.stop = boundary.map(|b| b - now);
        if !cfg.engine.dynamics.is_empty() {
            ecfg.dynamics = fold_dynamics(&cfg.engine.dynamics, now);
        }
        ecfg.attempts0 = if any_attempts { attempts0.clone() } else { Vec::new() };

        let r = simulate_in(&era_dag, cluster, &ecfg, scratch)?;
        eras += 1;
        events += r.events;
        retries += r.retries;
        lost_work += r.lost_work;

        // ---- harvest --------------------------------------------------
        for (e, &(slot, lt)) in era_map.iter().enumerate() {
            let lj = &mut live[slot];
            let tr = r.trace[e];
            if tr.start.is_finite() && lj.start_abs[lt].is_nan() {
                lj.start_abs[lt] = now + tr.start;
            }
            if tr.finish.is_finite() {
                lj.done[lt] = true;
                lj.remaining[lt] = 0.0;
                lj.finish_abs[lt] = now + tr.finish;
            } else if let Some(st) = r.stopped.as_ref() {
                if !st.attempts.is_empty() && st.attempts[e] > lj.attempts[lt] {
                    // Killed this era: prior-era progress is lost too —
                    // restore the loss the engine could not see, then
                    // rebase remaining onto the original size.
                    let orig = jobs[lj.idx].dag.tasks[lt].size;
                    let era_size = lj.remaining[lt];
                    let kills = (st.attempts[e] - lj.attempts[lt]) as f64;
                    lost_work += kills * (orig - era_size);
                    lj.remaining[lt] = st.remaining[e] + (orig - era_size);
                } else {
                    lj.remaining[lt] = st.remaining[e];
                }
                if !st.attempts.is_empty() {
                    lj.attempts[lt] = st.attempts[e];
                    lj.gate_abs[lt] = lj.gate_abs[lt].max(now + st.retry_gate[e]);
                }
            }
        }

        // ---- retire (epoch GC) ----------------------------------------
        let mut slot = 0usize;
        live.retain(|lj| {
            let verdict = match r.jobs[slot] {
                JobOutcome::Quarantined { reason, at } => {
                    Some(JobOutcome::Quarantined { reason, at: now + at })
                }
                JobOutcome::Exhausted { attempts } => Some(JobOutcome::Exhausted { attempts }),
                _ if lj.done.iter().all(|&d| d) => {
                    let finish = lj
                        .finish_abs
                        .iter()
                        .fold(lj.admit, |m, &f| if f.is_finite() { m.max(f) } else { m });
                    Some(JobOutcome::Completed { finish })
                }
                _ => None,
            };
            slot += 1;
            if let Some(outcome) = verdict {
                let job = &jobs[lj.idx];
                let jct = outcome.finish().map(|f| f - job.at);
                out[lj.idx] = Some(OpenJobResult {
                    arrival: job.at,
                    admitted_at: Some(lj.admit),
                    outcome,
                    jct,
                    deadline_met: job.deadline.map(|d| jct.map_or(false, |t| t <= d)),
                    trace: lj
                        .start_abs
                        .iter()
                        .zip(&lj.finish_abs)
                        .map(|(&s, &f)| TaskTrace { start: s, finish: f })
                        .collect(),
                });
                false
            } else {
                true
            }
        });

        match boundary {
            Some(b) => now = b,
            None => {
                debug_assert!(live.is_empty(), "final era must retire every live job");
                break;
            }
        }
    }

    // ---- assemble -----------------------------------------------------
    let mut makespan = 0.0f64;
    let mut quarantined = 0usize;
    let mut completed = 0usize;
    let results: Vec<OpenJobResult> = out
        .into_iter()
        .map(|o| o.expect("every job must have a verdict"))
        .collect();
    for j in &results {
        match j.outcome {
            JobOutcome::Completed { finish } => {
                completed += 1;
                makespan = makespan.max(finish);
            }
            JobOutcome::Quarantined { at, .. } => {
                quarantined += 1;
                makespan = makespan.max(at);
            }
            JobOutcome::Exhausted { .. } => quarantined += 1,
            JobOutcome::Rejected { .. } => {}
        }
    }
    Ok(OpenResult {
        jobs: results,
        makespan,
        eras,
        events,
        retries,
        lost_work,
        admitted,
        rejected,
        quarantined,
        completed,
    })
}

/// Rebase the absolute timeline onto an era starting at `s`: past
/// events replay at the era's `t = 0` in original order (absolute
/// last-writer-wins factors make the replay exact) with `FailHost`
/// demoted to a capacity-identical slow-down so crashes kill in-flight
/// work exactly once; future events shift to era-relative time.
fn fold_dynamics(tl: &DynTimeline, s: f64) -> DynTimeline {
    let mut out = DynTimeline::new();
    for e in tl.events() {
        if e.at < s - EPS {
            let action = match e.action {
                DynAction::FailHost { host } => DynAction::SlowHost { host, factor: 0.0 },
                a => a,
            };
            out.push(0.0, action);
        } else {
            out.push((e.at - s).max(0.0), e.action);
        }
    }
    out
}

/// JSON arrival spec for `simulate --open FILE`:
///
/// ```json
/// {"arrivals": [0.0, 1.5, 3.0],
///  "watermark": 10.0, "defer_max": 2.0, "deadline": 5.0}
/// ```
///
/// or, trace generated from a seeded Poisson process:
///
/// ```json
/// {"poisson": {"seed": 7, "rate": 0.5, "n": 100}, "watermark": 10.0}
/// ```
///
/// `watermark` (default: admit all), `defer_max` (default 0) and
/// `deadline` (per-job, relative to arrival; default none) are
/// optional.
#[derive(Debug, Clone)]
pub struct OpenSpec {
    pub arrivals: Vec<f64>,
    pub watermark: f64,
    pub defer_max: f64,
    pub deadline: Option<f64>,
}

impl OpenSpec {
    pub fn from_json(j: &Json) -> Result<OpenSpec, String> {
        let obj = j.as_obj().map_err(|e| format!("open spec: {e}"))?;
        let arrivals = match (obj.get("arrivals"), obj.get("poisson")) {
            (Some(_), Some(_)) => {
                return Err("open spec: give `arrivals` or `poisson`, not both".into())
            }
            (Some(a), None) => {
                let arr = a.as_arr().map_err(|e| format!("open spec arrivals: {e}"))?;
                let mut v = Vec::with_capacity(arr.len());
                for (i, x) in arr.iter().enumerate() {
                    let t = x.as_f64().map_err(|e| format!("open spec arrivals[{i}]: {e}"))?;
                    if !t.is_finite() || t < 0.0 {
                        return Err(format!("open spec arrivals[{i}]: bad time {t}"));
                    }
                    v.push(t);
                }
                v
            }
            (None, Some(p)) => {
                let seed_f = p
                    .get("seed")
                    .and_then(|v| v.as_f64())
                    .map_err(|e| format!("open spec poisson.seed: {e}"))?;
                if !(seed_f.is_finite() && seed_f >= 0.0 && seed_f.fract() == 0.0) {
                    return Err(format!("open spec poisson.seed: bad seed {seed_f}"));
                }
                let seed = seed_f as u64;
                let rate = p
                    .get("rate")
                    .and_then(|v| v.as_f64())
                    .map_err(|e| format!("open spec poisson.rate: {e}"))?;
                let n = p
                    .get("n")
                    .and_then(|v| v.as_usize())
                    .map_err(|e| format!("open spec poisson.n: {e}"))?;
                if !rate.is_finite() || rate <= 0.0 {
                    return Err(format!("open spec poisson.rate: bad rate {rate}"));
                }
                poisson_arrivals(seed, rate, n)
            }
            (None, None) => return Err("open spec: need `arrivals` or `poisson`".into()),
        };
        let opt_f64 = |key: &str| -> Result<Option<f64>, String> {
            match obj.get(key) {
                None => Ok(None),
                Some(v) => {
                    let x = v.as_f64().map_err(|e| format!("open spec {key}: {e}"))?;
                    if x.is_nan() || x < 0.0 {
                        return Err(format!("open spec {key}: bad value {x}"));
                    }
                    Ok(Some(x))
                }
            }
        };
        let watermark = opt_f64("watermark")?.unwrap_or(f64::INFINITY);
        let defer_max = match opt_f64("defer_max")? {
            Some(d) if !d.is_finite() => return Err("open spec defer_max: must be finite".into()),
            Some(d) => d,
            None => 0.0,
        };
        let deadline = match opt_f64("deadline")? {
            Some(d) if !d.is_finite() => return Err("open spec deadline: must be finite".into()),
            d => d,
        };
        Ok(OpenSpec { arrivals, watermark, defer_max, deadline })
    }

    /// Instantiate the stream: one clone of `dag` per arrival.
    pub fn jobs(&self, dag: &SimDag) -> Vec<OpenJob> {
        self.arrivals
            .iter()
            .map(|&at| OpenJob { at, dag: dag.clone(), deadline: self.deadline })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::dynamics::LinkRef;
    use crate::sim::engine::simulate;
    use crate::sim::spec::SimKind;

    /// One compute task of `size` on `host`.
    fn one_task_job(at: f64, host: usize, size: f64) -> OpenJob {
        let mut d = SimDag::default();
        d.push(SimTask {
            orig: 0,
            chunk: (0, 1),
            kind: SimKind::Compute { host },
            size,
            priority: 0,
            gate: 0.0,
            coflow: None,
        });
        OpenJob { at, dag: d, deadline: None }
    }

    /// compute → flow chain starting on `host`, flowing to `host + 1`.
    fn chain_job(at: f64, host: usize, size: f64) -> OpenJob {
        let mut d = SimDag::default();
        let c = d.push(SimTask {
            orig: 0,
            chunk: (0, 1),
            kind: SimKind::Compute { host },
            size,
            priority: 0,
            gate: 0.0,
            coflow: None,
        });
        let f = d.push(SimTask {
            orig: 1,
            chunk: (0, 1),
            kind: SimKind::Flow { src: host, dst: host + 1 },
            size,
            priority: 0,
            gate: 0.0,
            coflow: None,
        });
        d.dep(c, f);
        OpenJob { at, dag: d, deadline: None }
    }

    #[test]
    fn poisson_is_deterministic_and_monotone() {
        let a = poisson_arrivals(7, 0.5, 50);
        let b = poisson_arrivals(7, 0.5, 50);
        assert_eq!(a, b);
        assert_eq!(a.len(), 50);
        for w in a.windows(2) {
            assert!(w[0] <= w[1]);
        }
        assert!(a.iter().all(|t| t.is_finite() && *t >= 0.0));
        assert_ne!(a, poisson_arrivals(8, 0.5, 50));
    }

    #[test]
    fn single_job_at_zero_matches_closed_run() {
        let jobs = vec![chain_job(0.0, 0, 2.0)];
        let cluster = Cluster::uniform(2);
        let open = run_open(&jobs, &cluster, &OpenConfig::default()).unwrap();
        let closed = simulate(&jobs[0].dag, &cluster, &SimConfig::default()).unwrap();
        assert_eq!(open.eras, 1);
        assert_eq!(open.admitted, 1);
        assert_eq!(open.completed, 1);
        assert_eq!(open.makespan.to_bits(), closed.makespan.to_bits());
        for (o, c) in open.jobs[0].trace.iter().zip(&closed.trace) {
            assert_eq!(o.start.to_bits(), c.start.to_bits());
            assert_eq!(o.finish.to_bits(), c.finish.to_bits());
        }
        assert_eq!(open.jobs[0].jct, Some(closed.makespan));
    }

    #[test]
    fn spaced_stream_completes_all_with_absolute_times() {
        // Disjoint hosts, spaced arrivals: each job runs solo; its
        // trace is the solo trace shifted by its arrival.
        let jobs = vec![one_task_job(0.0, 0, 1.0), one_task_job(5.0, 1, 2.0)];
        let cluster = Cluster::uniform(2);
        let r = run_open(&jobs, &cluster, &OpenConfig::default()).unwrap();
        assert_eq!(r.completed, 2);
        assert_eq!(r.jobs[0].jct, Some(1.0));
        assert_eq!(r.jobs[1].jct, Some(2.0));
        assert_eq!(r.jobs[1].trace[0].start, 5.0);
        assert_eq!(r.jobs[1].trace[0].finish, 7.0);
        assert_eq!(r.makespan, 7.0);
    }

    #[test]
    fn watermark_sheds_with_distinct_rejected_outcome() {
        // Host 0, capacity 1: job 0 queues 10 time units of work. The
        // watermark of 5 admits job 0 (solo drain 10 > 5? no — reject).
        // Use sizes that make the intent exact: job 0 drains in 4,
        // job 1 would push the estimate to 8 > 5 → shed.
        let jobs = vec![one_task_job(0.0, 0, 4.0), one_task_job(1.0, 0, 4.0)];
        let cluster = Cluster::uniform(1);
        let cfg = OpenConfig { watermark: 5.0, ..OpenConfig::default() };
        let r = run_open(&jobs, &cluster, &cfg).unwrap();
        assert_eq!(r.admitted, 1);
        assert_eq!(r.rejected, 1);
        assert!(matches!(r.jobs[1].outcome, JobOutcome::Rejected { at } if at == 1.0));
        assert_eq!(r.jobs[1].admitted_at, None);
        assert!(r.jobs[1].trace.is_empty());
        // The shed job never entered the engine: no lost work.
        assert_eq!(r.lost_work, 0.0);
        // Job 0 unaffected.
        assert_eq!(r.jobs[0].jct, Some(4.0));
    }

    #[test]
    fn solo_overweight_job_is_rejected_immediately_despite_deferral() {
        let jobs = vec![one_task_job(0.0, 0, 100.0)];
        let cluster = Cluster::uniform(1);
        let cfg = OpenConfig { watermark: 5.0, defer_max: 50.0, ..OpenConfig::default() };
        let r = run_open(&jobs, &cluster, &cfg).unwrap();
        assert!(matches!(r.jobs[0].outcome, JobOutcome::Rejected { at } if at == 0.0));
    }

    #[test]
    fn deferred_job_admits_once_load_drains() {
        // Job 0 drains at t = 4; job 1 arrives at t = 1 over the
        // watermark, defers, and is retested at its expiry t = 6 when
        // the cluster is empty → admitted there.
        let jobs = vec![one_task_job(0.0, 0, 4.0), one_task_job(1.0, 0, 4.0)];
        let cluster = Cluster::uniform(1);
        let cfg = OpenConfig { watermark: 5.0, defer_max: 5.0, ..OpenConfig::default() };
        let r = run_open(&jobs, &cluster, &cfg).unwrap();
        assert_eq!(r.admitted, 2);
        assert_eq!(r.rejected, 0);
        assert_eq!(r.jobs[1].admitted_at, Some(6.0));
        assert_eq!(r.jobs[1].trace[0].start, 6.0);
        assert_eq!(r.jobs[1].jct, Some(9.0)); // finished 10, arrived 1
    }

    #[test]
    fn deferral_expires_into_rejection_under_sustained_load() {
        // Job 0 holds the cluster past job 1's deferral window.
        let jobs = vec![one_task_job(0.0, 0, 20.0), one_task_job(1.0, 0, 4.0)];
        let cluster = Cluster::uniform(1);
        let cfg = OpenConfig { watermark: 5.0, defer_max: 2.0, ..OpenConfig::default() };
        let r = run_open(&jobs, &cluster, &cfg).unwrap();
        // Job 0's solo drain is 20 > 5: rejected at arrival, so the
        // cluster is actually empty — rebuild the scenario with an
        // admissible hog.
        assert!(matches!(r.jobs[0].outcome, JobOutcome::Rejected { .. }));

        let jobs = vec![one_task_job(0.0, 0, 4.9), one_task_job(1.0, 0, 4.9)];
        let cfg = OpenConfig { watermark: 5.0, defer_max: 2.0, ..OpenConfig::default() };
        let r = run_open(&jobs, &Cluster::uniform(1), &cfg).unwrap();
        assert_eq!(r.admitted, 1);
        assert_eq!(r.rejected, 1);
        // Shed at the deferral expiry, not at arrival.
        assert!(matches!(r.jobs[1].outcome, JobOutcome::Rejected { at } if at == 3.0));
    }

    #[test]
    fn deadline_metrics() {
        let mut early = one_task_job(0.0, 0, 1.0);
        early.deadline = Some(2.0);
        let mut late = one_task_job(0.0, 1, 5.0);
        late.deadline = Some(2.0);
        let r = run_open(&[early, late], &Cluster::uniform(2), &OpenConfig::default()).unwrap();
        assert_eq!(r.jobs[0].deadline_met, Some(true));
        assert_eq!(r.jobs[1].deadline_met, Some(false));
        assert_eq!(r.deadline_hit_rate(), Some(0.5));
        let p50 = r.jct_percentile(0.5).unwrap();
        assert!(p50 == 1.0 || p50 == 5.0);
        assert_eq!(r.jct_percentile(0.99), Some(5.0));
    }

    #[test]
    fn past_dynamics_still_apply_after_their_jobs_departed() {
        // Satellite regression: host 1 is slowed while only job 0 is
        // live; job 0 completes; the restore fires in an era where no
        // live job references host 1 — the *next* arrival must still
        // see the restored (full) capacity, and an arrival between
        // slow-down and restore must see the degraded capacity.
        let mut cfg = OpenConfig::default();
        cfg.engine.dynamics = DynTimeline::new()
            .with(0.5, DynAction::SlowHost { host: 1, factor: 0.5 })
            .with(6.0, DynAction::RestoreHost { host: 1 });
        let jobs = vec![
            one_task_job(0.0, 0, 1.0),  // departs at t = 1
            one_task_job(2.0, 1, 1.0),  // runs at 0.5 → finishes t = 4
            one_task_job(10.0, 1, 1.0), // after restore → finishes t = 11
        ];
        let r = run_open(&jobs, &Cluster::uniform(2), &cfg).unwrap();
        assert_eq!(r.completed, 3);
        assert_eq!(r.jobs[1].jct, Some(2.0));
        assert_eq!(r.jobs[2].jct, Some(1.0));
    }

    #[test]
    fn degraded_link_persists_across_idle_eras() {
        // Link-level flavour of the same regression: up:0 degraded
        // early, never restored; a job arriving long after every other
        // job departed must still see the degraded uplink.
        let mut cfg = OpenConfig::default();
        cfg.engine.dynamics = DynTimeline::new()
            .with(0.1, DynAction::Degrade { link: LinkRef::NicUp(0), factor: 0.25 });
        let jobs = vec![one_task_job(0.0, 1, 1.0), chain_job(5.0, 0, 1.0)];
        let r = run_open(&jobs, &Cluster::uniform(2), &cfg).unwrap();
        assert_eq!(r.completed, 2);
        // compute 1.0 at full rate, then 1.0 bytes at 0.25 → 4.0
        assert_eq!(r.jobs[1].jct, Some(5.0));
    }

    #[test]
    fn concat_jobs_offsets_namespaces() {
        let jobs = vec![chain_job(0.0, 0, 1.0), chain_job(0.0, 0, 2.0)];
        let all = concat_jobs(&jobs);
        assert_eq!(all.len(), 4);
        assert_eq!(all.job(0), 0);
        assert_eq!(all.job(2), 1);
        assert_eq!(all.tasks[2].orig, 2); // shifted by n_origs = 2
        assert_eq!(all.n_jobs(), 2);
    }

    #[test]
    fn open_spec_json_both_modes() {
        let j = Json::parse(
            r#"{"arrivals": [0.0, 1.5], "watermark": 10.0, "defer_max": 2.0, "deadline": 5.0}"#,
        )
        .unwrap();
        let s = OpenSpec::from_json(&j).unwrap();
        assert_eq!(s.arrivals, vec![0.0, 1.5]);
        assert_eq!(s.watermark, 10.0);
        assert_eq!(s.defer_max, 2.0);
        assert_eq!(s.deadline, Some(5.0));
        let jobs = s.jobs(&chain_job(0.0, 0, 1.0).dag);
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[1].at, 1.5);
        assert_eq!(jobs[1].deadline, Some(5.0));

        let j = Json::parse(r#"{"poisson": {"seed": 7, "rate": 0.5, "n": 10}}"#).unwrap();
        let s = OpenSpec::from_json(&j).unwrap();
        assert_eq!(s.arrivals, poisson_arrivals(7, 0.5, 10));
        assert!(s.watermark.is_infinite());
        assert_eq!(s.defer_max, 0.0);
        assert_eq!(s.deadline, None);
    }

    #[test]
    fn open_spec_json_rejects_bad_input() {
        for bad in [
            r#"{}"#,
            r#"{"arrivals": [0.0], "poisson": {"seed": 1, "rate": 1.0, "n": 2}}"#,
            r#"{"arrivals": [-1.0]}"#,
            r#"{"poisson": {"seed": 1, "rate": 0.0, "n": 2}}"#,
            r#"{"arrivals": [0.0], "watermark": -2.0}"#,
            r#"{"arrivals": [0.0], "defer_max": 1e999}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(OpenSpec::from_json(&j).is_err(), "must reject {bad}");
        }
    }

    #[test]
    fn result_json_has_counters_and_percentiles() {
        let jobs = vec![one_task_job(0.0, 0, 1.0), one_task_job(0.0, 1, 3.0)];
        let r = run_open(&jobs, &Cluster::uniform(2), &OpenConfig::default()).unwrap();
        let j = r.to_json();
        let s = format!("{j}");
        assert!(s.contains("\"admitted\""));
        assert!(s.contains("\"jct_p99\""));
        assert!(!s.contains("deadline_hit_rate")); // no deadlines given
        let pj = format!("{}", r.jobs_json());
        assert!(pj.contains("\"arrival\""));
    }
}
