//! Open-system streaming driver: an unbounded stream of job arrivals
//! over the closed fluid engine, with admission control, overload
//! shedding and bounded-memory epoch GC.
//!
//! # Era chaining
//!
//! The closed engine (`sim/engine.rs`) simulates one fixed DAG to
//! completion. The open loop turns it into a streaming system by
//! *chaining* closed runs, one **era** per inter-boundary interval
//! (boundaries are job arrivals and deferral expiries):
//!
//! 1. Build a compacted DAG holding only the **live** jobs' unfinished
//!    tasks (sizes = carried remaining bytes, gates/retry backoffs
//!    rebased to the era clock, finished predecessors dropped).
//! 2. Run the engine with [`SimConfig::stop`] at the next boundary.
//!    The stop is an ordinary event-class boundary: no task integrates
//!    across it, and the run exports its in-flight state as
//!    [`StopState`].
//! 3. Harvest: record completions (absolute traces), carry remaining /
//!    attempts / backoff gates, retire finished or quarantined jobs —
//!    their state leaves the compacted DAG, which is what keeps the
//!    scratch arena, [`CompSet`](crate::sim::CompSet) and
//!    [`FinHeap`](crate::sim::FinHeap) sized to the largest *live* set
//!    rather than the stream total (the epoch GC).
//! 4. At the boundary: retest deferred jobs, expire overdue ones,
//!    admit or shed the arrivals due now. Repeat.
//!
//! The final era runs with `stop: None`, so deadlock detection and
//! quarantine semantics in the drained system are exactly the closed
//! engine's.
//!
//! # Admission control
//!
//! A job is admitted when the estimated drain time of the settled
//! cluster — queued live work plus the incoming job, divided by
//! settled capacity (see [`settled_cluster`]) — stays under
//! [`OpenConfig::watermark`]:
//!
//! ```text
//! drain = max(Σ compute remaining / Σ settled core caps,
//!             Σ flow remaining    / Σ settled (NIC up + down)/2)
//! ```
//!
//! Fabric extras are ignored by the estimate (it is an optimistic
//! bound, mirroring `settled_cluster`'s host-level view). A refused
//! job waits up to [`OpenConfig::defer_max`] in a deferral queue,
//! retested at every stream boundary (deferred jobs are retested
//! *before* same-instant fresh arrivals, oldest first) and gets one
//! last test at its expiry; a job whose *solo* drain already exceeds
//! the watermark can never pass and is rejected immediately, which
//! guarantees termination. Shed jobs get the distinct
//! [`JobOutcome::Rejected`] — they never entered the engine, so
//! `lost_work` and retry accounting never see them.
//!
//! # Determinism and the closed-mode oracle
//!
//! Everything is a pure function of (arrival trace, watermark, seed):
//! the admitted/rejected set and every per-job outcome are identical
//! across thread counts (bitwise under the eager horizon; anchored
//! runs inherit the engine's 1e-6 tolerance pairing). With every
//! arrival at `t = 0` and an infinite watermark the loop runs exactly
//! one era with `stop: None` over the [`concat_jobs`] concatenation —
//! bit-identical to a closed run of the same DAG, which is the oracle
//! `tests/prop_open_equivalence.rs` asserts across the whole
//! {queue}×{alloc}×{horizon}×{threads}×{recovery} matrix.
//!
//! # Dynamics across eras
//!
//! Each era re-folds the absolute [`DynTimeline`]: events strictly
//! before the era start replay at the era's `t = 0` in original order
//! (factors are absolute last-writer-wins, so the replay reconstructs
//! the exact factor state — independent of which jobs have departed,
//! so a restore arriving after the last touching job completed still
//! applies to later arrivals), with past [`DynAction::FailHost`]
//! crashes demoted to capacity-identical `SlowHost { factor: 0.0 }`
//! so a crash kills in-flight work exactly once. Future events shift
//! to era-relative time unchanged.
//!
//! One accounting caveat: a task killed in a later era than it started
//! loses *all* its progress (the carry restores the full original
//! size), and the extra prior-era loss is added to `lost_work` when
//! the era stops at a boundary; an era that runs to completion has no
//! per-task attempt export, so cross-era loss of victims that also
//! finish inside that era is undercounted by their prior-era progress.

use crate::sched::settled_cluster;
use crate::sim::dynamics::{DynAction, DynTimeline};
use crate::sim::engine::{simulate_in, SimConfig, SimError, SimScratch, StuckReason, TaskTrace};
use crate::sim::recovery::{JobOutcome, RecoveryPolicy};
use crate::sim::spec::{Cluster, SimDag, SimKind, SimTask};
use crate::util::json::{f64_bits_hex, f64_from_bits_hex, Json};
use crate::util::rng::Rng;

/// Matches the engine's time-comparison epsilon.
const EPS: f64 = 1e-9;

/// One streaming arrival: a physical job DAG entering at `at`.
#[derive(Debug, Clone)]
pub struct OpenJob {
    /// Arrival instant on the absolute stream clock.
    pub at: f64,
    /// The job's physical DAG. Task gates are relative to the job's
    /// *admission* instant (the plan was computed as if starting at 0).
    pub dag: SimDag,
    /// Completion deadline measured from arrival, if any.
    pub deadline: Option<f64>,
    /// Tenant weight (default 1). Deferral retests at each boundary run
    /// in descending-weight order (stable: equal weights keep arrival
    /// order, so an all-equal stream is bitwise identical to the
    /// unweighted driver) — under contention a heavier tenant's deferred
    /// job grabs freed capacity before lighter ones.
    pub weight: i64,
}

/// Open-loop driver configuration.
#[derive(Debug, Clone)]
pub struct OpenConfig {
    /// Admission watermark: estimated drain time (module docs) above
    /// which arrivals are refused. `INFINITY` (default) admits all.
    pub watermark: f64,
    /// How long a refused job may wait in the deferral queue before it
    /// is shed for good. `0.0` (default) sheds immediately.
    pub defer_max: f64,
    /// The closed-engine configuration every era runs under.
    /// `engine.stop` / `engine.attempts0` are owned by the driver and
    /// overwritten per era.
    pub engine: SimConfig,
}

impl Default for OpenConfig {
    fn default() -> Self {
        OpenConfig {
            watermark: f64::INFINITY,
            defer_max: 0.0,
            engine: SimConfig::default(),
        }
    }
}

/// Per-job verdict, all times on the absolute stream clock.
#[derive(Debug, Clone)]
pub struct OpenJobResult {
    pub arrival: f64,
    /// When the job entered the engine (`None` = shed before entry).
    pub admitted_at: Option<f64>,
    /// [`JobOutcome::Rejected`] for shed jobs; `Completed` /
    /// `Quarantined` / `Exhausted` otherwise, times rebased absolute.
    pub outcome: JobOutcome,
    /// Completion latency (finish − arrival) for completed jobs.
    pub jct: Option<f64>,
    /// Whether `jct ≤ deadline`; `None` when the job has no deadline.
    /// Non-completed jobs with a deadline report `Some(false)`.
    pub deadline_met: Option<bool>,
    /// Absolute per-task trace, parallel to the job's DAG (`start` is
    /// the first instant work began; `NaN` where unknown). Empty for
    /// rejected jobs.
    pub trace: Vec<TaskTrace>,
}

/// Aggregate outcome of a streamed run.
#[derive(Debug, Clone)]
pub struct OpenResult {
    /// Per-job results, indexed like the input job list.
    pub jobs: Vec<OpenJobResult>,
    /// Latest completion / quarantine instant observed (0 if none).
    pub makespan: f64,
    /// Number of engine runs chained (idle boundary hops excluded).
    pub eras: usize,
    /// Engine iterations summed across eras.
    pub events: usize,
    /// Task re-enqueues summed across eras.
    pub retries: usize,
    /// Work destroyed by crashes, cross-era losses included (see the
    /// module-docs caveat).
    pub lost_work: f64,
    pub admitted: usize,
    pub rejected: usize,
    pub quarantined: usize,
    pub completed: usize,
}

impl OpenResult {
    /// Sorted JCTs of completed jobs.
    fn jcts(&self) -> Vec<f64> {
        let mut v: Vec<f64> = self.jobs.iter().filter_map(|j| j.jct).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }

    /// Nearest-rank percentile of completed-job JCTs (`q` in [0, 1]);
    /// `None` when nothing completed.
    pub fn jct_percentile(&self, q: f64) -> Option<f64> {
        let v = self.jcts();
        if v.is_empty() {
            return None;
        }
        let i = ((v.len() - 1) as f64 * q).round() as usize;
        Some(v[i])
    }

    /// Fraction of deadline-carrying jobs that completed within their
    /// deadline (`None` when no job had one). Shed and quarantined
    /// jobs count as misses.
    pub fn deadline_hit_rate(&self) -> Option<f64> {
        let with: Vec<bool> = self.jobs.iter().filter_map(|j| j.deadline_met).collect();
        if with.is_empty() {
            return None;
        }
        Some(with.iter().filter(|&&m| m).count() as f64 / with.len() as f64)
    }

    /// Summary object for the CLI outcome line: counters, JCT p50/p99
    /// and the deadline hit rate (keys omitted when undefined).
    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            // `n_jobs`, not `jobs`: the CLI outcome line reserves `jobs`
            // for the per-job verdict array ([`jobs_json`]), matching the
            // closed-path schema
            ("n_jobs", Json::Num(self.jobs.len() as f64)),
            ("admitted", Json::Num(self.admitted as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("quarantined", Json::Num(self.quarantined as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("eras", Json::Num(self.eras as f64)),
            ("events", Json::Num(self.events as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("lost_work", Json::Num(self.lost_work)),
            ("makespan", Json::Num(self.makespan)),
        ];
        if let Some(p50) = self.jct_percentile(0.5) {
            kv.push(("jct_p50", Json::Num(p50)));
        }
        if let Some(p99) = self.jct_percentile(0.99) {
            kv.push(("jct_p99", Json::Num(p99)));
        }
        if let Some(rate) = self.deadline_hit_rate() {
            kv.push(("deadline_hit_rate", Json::Num(rate)));
        }
        Json::obj(kv)
    }

    /// Per-job verdict array (one object per input job, input order).
    pub fn jobs_json(&self) -> Json {
        Json::Arr(
            self.jobs
                .iter()
                .enumerate()
                .map(|(i, j)| {
                    let mut kv = vec![
                        ("job", Json::Num(i as f64)),
                        ("arrival", Json::Num(j.arrival)),
                        ("outcome", j.outcome.to_json(i)),
                    ];
                    if let Some(a) = j.admitted_at {
                        kv.push(("admitted_at", Json::Num(a)));
                    }
                    if let Some(jct) = j.jct {
                        kv.push(("jct", Json::Num(jct)));
                    }
                    if let Some(m) = j.deadline_met {
                        kv.push(("deadline_met", Json::Bool(m)));
                    }
                    Json::obj(kv)
                })
                .collect(),
        )
    }
}

/// Deterministic Poisson arrival trace: `n` cumulative exponential
/// inter-arrival gaps at `rate` jobs per time unit, seeded.
pub fn poisson_arrivals(seed: u64, rate: f64, n: usize) -> Vec<f64> {
    assert!(rate.is_finite() && rate > 0.0, "rate must be finite and positive");
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        // u ∈ [0, 1) so 1 − u ∈ (0, 1] and the gap is finite, ≥ 0
        t += -(1.0 - rng.f64()).ln() / rate;
        out.push(t);
    }
    out
}

/// Logical-id namespace width of a job DAG (`max orig + 1`).
fn n_origs(d: &SimDag) -> usize {
    d.tasks.iter().map(|t| t.orig + 1).max().unwrap_or(0)
}

/// Coflow-id namespace width of a job DAG.
fn n_coflows(d: &SimDag) -> usize {
    d.tasks
        .iter()
        .map(|t| t.coflow.map_or(0, |c| c + 1))
        .max()
        .unwrap_or(0)
}

/// Concatenate whole jobs into one closed-mode DAG with the same
/// per-job `orig` / coflow offsets the era rebuild uses — the
/// closed-mode comparison DAG of the open-at-`t = 0` oracle.
pub fn concat_jobs(jobs: &[OpenJob]) -> SimDag {
    let mut all = SimDag::default();
    let (mut orig_off, mut cof_off) = (0usize, 0usize);
    for (j, job) in jobs.iter().enumerate() {
        all.append_job(&job.dag, j, orig_off, cof_off);
        orig_off += n_origs(&job.dag);
        cof_off += n_coflows(&job.dag);
    }
    all
}

/// Settled aggregate capacities backing the admission estimate.
struct SettledCaps {
    compute: f64,
    net: f64,
}

fn settled_caps(cluster: &Cluster, tl: &DynTimeline) -> SettledCaps {
    let settled = settled_cluster(cluster, tl);
    let mut compute = 0.0;
    let mut net = 0.0;
    for h in &settled.hosts {
        compute += h.cores;
        net += (h.nic_up + h.nic_down) / 2.0;
    }
    SettledCaps { compute, net }
}

/// (compute bytes, flow bytes) of a whole job DAG.
fn job_load(d: &SimDag) -> (f64, f64) {
    let mut c = 0.0;
    let mut f = 0.0;
    for t in &d.tasks {
        match t.kind {
            SimKind::Compute { .. } => c += t.size,
            SimKind::Flow { .. } => f += t.size,
            SimKind::Dummy => {}
        }
    }
    (c, f)
}

/// Estimated drain time of `(compute, flow)` load (module docs).
fn drain_time(load: (f64, f64), caps: &SettledCaps) -> f64 {
    let d = |l: f64, c: f64| {
        if l <= 0.0 {
            0.0
        } else if c <= 0.0 {
            f64::INFINITY
        } else {
            l / c
        }
    };
    d(load.0, caps.compute).max(d(load.1, caps.net))
}

/// A job currently inside the engine, carried between eras.
struct Live {
    /// Index into the input job list.
    idx: usize,
    /// Absolute admission instant (gates rebase from it).
    admit: f64,
    /// `orig` / coflow namespace widths, fixed at admission.
    origs: usize,
    coflows: usize,
    /// Unfinished bytes per local task (original size until started).
    remaining: Vec<f64>,
    /// Task finished (engine reported a finite finish).
    done: Vec<bool>,
    /// Effective earliest-start per local task, absolute: admission +
    /// plan gate, raised by carried retry-backoff gates.
    gate_abs: Vec<f64>,
    /// Carried failed-attempt counts (retry recovery only).
    attempts: Vec<usize>,
    /// Absolute first-start / finish per local task (`NaN` = unknown).
    start_abs: Vec<f64>,
    finish_abs: Vec<f64>,
}

impl Live {
    fn new(idx: usize, job: &OpenJob, admit: f64) -> Live {
        let n = job.dag.len();
        Live {
            idx,
            admit,
            origs: n_origs(&job.dag),
            coflows: n_coflows(&job.dag),
            remaining: job.dag.tasks.iter().map(|t| t.size).collect(),
            done: vec![false; n],
            gate_abs: job.dag.tasks.iter().map(|t| admit + t.gate).collect(),
            attempts: vec![0; n],
            start_abs: vec![f64::NAN; n],
            finish_abs: vec![f64::NAN; n],
        }
    }

    /// Remaining (compute, flow) bytes.
    fn load(&self, dag: &SimDag) -> (f64, f64) {
        let mut c = 0.0;
        let mut f = 0.0;
        for (t, task) in dag.tasks.iter().enumerate() {
            if self.done[t] || self.remaining[t] <= 0.0 {
                continue;
            }
            match task.kind {
                SimKind::Compute { .. } => c += self.remaining[t],
                SimKind::Flow { .. } => f += self.remaining[t],
                SimKind::Dummy => {}
            }
        }
        (c, f)
    }
}

/// Aggregate counters of a (possibly still-running) [`OpenLoop`].
#[derive(Debug, Clone, Copy, Default)]
pub struct OpenCounters {
    pub eras: usize,
    pub events: usize,
    pub retries: usize,
    pub lost_work: f64,
    pub admitted: usize,
    pub rejected: usize,
}

/// Incremental open-loop driver: the era-chaining engine behind
/// [`run_open_in`], exposed as a resumable state machine so a
/// long-lived coordinator (`mxdag serve`) can feed arrivals one at a
/// time, advance virtual time in increments, and serialize its exact
/// state for crash recovery.
///
/// # Contract
///
/// * [`push`](OpenLoop::push) registers an arrival (its stamp must not
///   predate the loop clock); [`advance_to`](OpenLoop::advance_to)
///   processes every boundary up to the target instant, running eras in
///   between. `advance_to(f64::INFINITY)` drains the system — exactly
///   what [`run_open_in`] does after pushing the whole trace, so the
///   batch path and the incremental path share every line of era logic.
/// * Outcomes are a pure function of the *call sequence* (pushes and
///   advance targets), not wall-clock time. Extra era stops introduced
///   by intermediate `advance_to` targets rebase remaining bytes and
///   gates through extra float round-trips, so two different call
///   sequences over the same arrivals agree only to the engine's
///   tolerance — which is why the serve WAL records every advance: a
///   resume replays the *same* sequence and lands on bitwise-identical
///   state (see [`state_json`](OpenLoop::state_json)).
/// * [`state_json`](OpenLoop::state_json) at a quiescent point (between
///   calls) captures the full driver state with bit-exact floats
///   (`f64::to_bits` hex); [`restore`](OpenLoop::restore) rebuilds an
///   identical loop given the original job DAGs (re-derived from logged
///   submission specs — DAG bytes are never serialized).
pub struct OpenLoop {
    cluster: Cluster,
    cfg: OpenConfig,
    caps: SettledCaps,
    retry_on: bool,
    jobs: Vec<OpenJob>,
    out: Vec<Option<OpenJobResult>>,
    live: Vec<Live>,
    /// (job idx, absolute expiry), in retest order.
    deferred: Vec<(usize, f64)>,
    /// Not-yet-arrived job indices sorted by (at, idx); `head` marks the
    /// consumed prefix (compacted lazily).
    pending: Vec<usize>,
    head: usize,
    now: f64,
    eras: usize,
    events: usize,
    retries: usize,
    lost_work: f64,
    admitted: usize,
    rejected: usize,
    // Era-rebuild buffers, reused so per-era allocation is bounded by
    // the live set (the driver-side half of the epoch GC).
    era_dag: SimDag,
    era_map: Vec<(usize, usize)>,
    local: Vec<usize>,
    attempts0: Vec<usize>,
}

impl OpenLoop {
    pub fn new(cluster: &Cluster, cfg: &OpenConfig) -> OpenLoop {
        assert!(
            cfg.watermark >= 0.0 && !cfg.watermark.is_nan(),
            "watermark must be ≥ 0 (INFINITY = admit all)"
        );
        assert!(
            cfg.defer_max >= 0.0 && cfg.defer_max.is_finite(),
            "defer_max must be finite and ≥ 0"
        );
        let caps = settled_caps(cluster, &cfg.engine.dynamics);
        let retry_on = matches!(cfg.engine.recovery, RecoveryPolicy::Retry { .. });
        OpenLoop {
            cluster: cluster.clone(),
            cfg: cfg.clone(),
            caps,
            retry_on,
            jobs: Vec::new(),
            out: Vec::new(),
            live: Vec::new(),
            deferred: Vec::new(),
            pending: Vec::new(),
            head: 0,
            now: 0.0,
            eras: 0,
            events: 0,
            retries: 0,
            lost_work: 0.0,
            admitted: 0,
            rejected: 0,
            era_dag: SimDag::default(),
            era_map: Vec::new(),
            local: Vec::new(),
            attempts0: Vec::new(),
        }
    }

    /// Current loop clock (last processed boundary / era stop).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn n_jobs(&self) -> usize {
        self.jobs.len()
    }

    /// Nothing live, deferred or pending: advancing is a no-op.
    pub fn is_idle(&self) -> bool {
        self.live.is_empty() && self.deferred.is_empty() && self.head == self.pending.len()
    }

    pub fn counters(&self) -> OpenCounters {
        OpenCounters {
            eras: self.eras,
            events: self.events,
            retries: self.retries,
            lost_work: self.lost_work,
            admitted: self.admitted,
            rejected: self.rejected,
        }
    }

    /// `"pending" | "deferred" | "live" | "done"`, or `None` for an
    /// unknown index.
    pub fn job_state(&self, idx: usize) -> Option<&'static str> {
        if idx >= self.jobs.len() {
            return None;
        }
        if self.out[idx].is_some() {
            return Some("done");
        }
        if self.live.iter().any(|lj| lj.idx == idx) {
            return Some("live");
        }
        if self.deferred.iter().any(|&(i, _)| i == idx) {
            return Some("deferred");
        }
        Some("pending")
    }

    /// Final verdict of job `idx`, once it has one.
    pub fn result(&self, idx: usize) -> Option<&OpenJobResult> {
        self.out.get(idx).and_then(|o| o.as_ref())
    }

    /// Latest completion / quarantine instant among settled jobs.
    pub fn max_finish(&self) -> f64 {
        self.out
            .iter()
            .flatten()
            .fold(0.0f64, |m, r| match r.outcome {
                JobOutcome::Completed { finish } => m.max(finish),
                JobOutcome::Quarantined { at, .. } => m.max(at),
                _ => m,
            })
    }

    /// Register an arrival. The stamp must be finite, ≥ 0 and must not
    /// predate the loop clock (the stream is causal). Returns the job's
    /// index (dense, in push order).
    pub fn push(&mut self, job: OpenJob) -> usize {
        assert!(
            job.at.is_finite() && job.at >= 0.0,
            "arrival times must be finite and ≥ 0"
        );
        assert!(
            job.at >= self.now - EPS,
            "arrival at t={} predates the loop clock t={}",
            job.at,
            self.now
        );
        let idx = self.jobs.len();
        let at = job.at;
        self.jobs.push(job);
        self.out.push(None);
        // Insert into the unconsumed pending tail, key (at, idx); `idx`
        // is the largest yet, so `<=` places ties after existing entries
        // (stable arrival order).
        let jobs = &self.jobs;
        let pos = self.pending[self.head..].partition_point(|&j| jobs[j].at <= at);
        self.pending.insert(self.head + pos, idx);
        idx
    }

    /// Process one stream boundary at the current clock: retest deferred
    /// jobs (descending weight, stable), expire overdue ones, then
    /// admit / defer / shed the fresh arrivals due now (input order).
    fn boundary(&mut self) {
        let now = self.now;
        let watermark = self.cfg.watermark;
        let defer_max = self.cfg.defer_max;
        let jobs = &self.jobs;
        let caps = &self.caps;
        let out = &mut self.out;
        let live = &mut self.live;

        let (mut load_c, mut load_f) = live.iter().fold((0.0, 0.0), |(c, f), lj| {
            let (jc, jf) = lj.load(&jobs[lj.idx].dag);
            (c + jc, f + jf)
        });
        let reject = |idx: usize, at: f64, out: &mut Vec<Option<OpenJobResult>>, n: &mut usize| {
            out[idx] = Some(OpenJobResult {
                arrival: jobs[idx].at,
                admitted_at: None,
                outcome: JobOutcome::Rejected { at },
                jct: None,
                deadline_met: jobs[idx].deadline.map(|_| false),
                trace: Vec::new(),
            });
            *n += 1;
        };

        // Deferred first, each getting a final test at its expiry before
        // it is shed. Heavier tenants retest first (stable sort: equal
        // weights keep the oldest-first order, bitwise identical to the
        // unweighted driver); retained jobs keep the processing order.
        if !self.deferred.is_empty() {
            let mut dq = std::mem::take(&mut self.deferred);
            dq.sort_by_key(|&(idx, _)| std::cmp::Reverse(jobs[idx].weight));
            for (idx, expiry) in dq {
                let jl = job_load(&jobs[idx].dag);
                if drain_time((load_c + jl.0, load_f + jl.1), caps) <= watermark {
                    live.push(Live::new(idx, &jobs[idx], now));
                    self.admitted += 1;
                    load_c += jl.0;
                    load_f += jl.1;
                } else if expiry <= now + EPS {
                    reject(idx, expiry, out, &mut self.rejected);
                } else {
                    self.deferred.push((idx, expiry));
                }
            }
        }
        // Fresh arrivals due now, input order.
        while self.head < self.pending.len() && jobs[self.pending[self.head]].at <= now + EPS {
            let idx = self.pending[self.head];
            self.head += 1;
            let jl = job_load(&jobs[idx].dag);
            let solo = drain_time(jl, caps);
            if drain_time((load_c + jl.0, load_f + jl.1), caps) <= watermark {
                live.push(Live::new(idx, &jobs[idx], now));
                self.admitted += 1;
                load_c += jl.0;
                load_f += jl.1;
            } else if solo > watermark || defer_max <= 0.0 {
                // Can never pass (or no deferral window): shed now.
                reject(idx, now, out, &mut self.rejected);
            } else {
                self.deferred.push((idx, jobs[idx].at + defer_max));
            }
        }
        // Compact the consumed pending prefix once it dominates.
        if self.head > 32 && self.head * 2 >= self.pending.len() {
            self.pending.drain(..self.head);
            self.head = 0;
        }
    }

    /// Next boundary strictly after the clock: the earlier of the next
    /// pending arrival and the nearest deferral expiry.
    fn next_boundary(&self) -> Option<f64> {
        let next_arrival = self.pending.get(self.head).map(|&i| self.jobs[i].at);
        let next_expiry = self.deferred.iter().fold(f64::INFINITY, |m, &(_, e)| m.min(e));
        match next_arrival {
            Some(a) => Some(a.min(next_expiry)),
            None if next_expiry.is_finite() => Some(next_expiry),
            None => None,
        }
    }

    /// Advance the stream clock to `h`, processing every boundary on the
    /// way (module docs). `h = INFINITY` drains the system: the final
    /// era runs with `stop: None`, so deadlock / quarantine semantics in
    /// the drained system are exactly the closed engine's. A finite `h`
    /// stops mid-stream with in-flight state carried for the next call.
    /// Targets at or before the clock still process arrivals due *at*
    /// the clock (so `push(at); advance_to(at)` admits immediately).
    pub fn advance_to(&mut self, h: f64, scratch: &mut SimScratch) -> Result<(), SimError> {
        assert!(!h.is_nan(), "advance target must not be NaN");
        loop {
            self.boundary();
            if h <= self.now + EPS {
                return Ok(());
            }
            let nb = self.next_boundary();
            if self.live.is_empty() {
                match nb {
                    Some(b) if b <= h => {
                        self.now = b;
                        continue;
                    }
                    // Idle until past `h` (or forever): nothing to run.
                    // The clock stays put — it only tracks processed
                    // boundaries, and an idle hop is not one.
                    _ => return Ok(()),
                }
            }
            let stop_abs = match nb {
                Some(b) => Some(b.min(h)),
                None if h.is_finite() => Some(h),
                None => None,
            };
            self.run_era(stop_abs, scratch)?;
            match stop_abs {
                Some(s) => self.now = s,
                None => {
                    debug_assert!(self.live.is_empty(), "final era must retire every live job");
                    return Ok(());
                }
            }
        }
    }

    /// One closed-engine era over the compacted live set, stopping at
    /// `stop_abs` (absolute; `None` = run to completion), then harvest
    /// carries and retire finished / quarantined jobs (epoch GC).
    fn run_era(&mut self, stop_abs: Option<f64>, scratch: &mut SimScratch) -> Result<(), SimError> {
        let now = self.now;
        let retry_on = self.retry_on;

        // Rebuild the compacted live-jobs DAG on the era clock. Buffers
        // are taken out and restored so the borrows stay field-disjoint.
        let mut era_dag = std::mem::take(&mut self.era_dag);
        let mut era_map = std::mem::take(&mut self.era_map);
        let mut local = std::mem::take(&mut self.local);
        let mut attempts0 = std::mem::take(&mut self.attempts0);
        era_dag.tasks.clear();
        era_dag.preds.clear();
        era_dag.succs.clear();
        era_dag.job_of.clear();
        era_map.clear();
        attempts0.clear();
        let mut any_attempts = false;
        let (mut orig_off, mut cof_off) = (0usize, 0usize);
        for (slot, lj) in self.live.iter().enumerate() {
            let jd = &self.jobs[lj.idx].dag;
            local.clear();
            local.resize(jd.len(), usize::MAX);
            for lt in 0..jd.len() {
                if lj.done[lt] {
                    continue;
                }
                let t0 = &jd.tasks[lt];
                let id = era_dag.push(SimTask {
                    orig: t0.orig + orig_off,
                    chunk: t0.chunk,
                    kind: t0.kind,
                    size: lj.remaining[lt],
                    priority: t0.priority,
                    gate: (lj.gate_abs[lt] - now).max(0.0),
                    coflow: t0.coflow.map(|c| c + cof_off),
                });
                era_dag.job_of.push(slot);
                local[lt] = id;
                era_map.push((slot, lt));
                if retry_on {
                    attempts0.push(lj.attempts[lt]);
                    any_attempts |= lj.attempts[lt] > 0;
                }
            }
            for lt in 0..jd.len() {
                if local[lt] == usize::MAX {
                    continue;
                }
                for &p in &jd.preds[lt] {
                    if local[p] != usize::MAX {
                        era_dag.dep(local[p], local[lt]);
                    }
                }
            }
            orig_off += lj.origs;
            cof_off += lj.coflows;
        }

        let mut ecfg = self.cfg.engine.clone();
        ecfg.stop = stop_abs.map(|b| b - now);
        if !self.cfg.engine.dynamics.is_empty() {
            ecfg.dynamics = fold_dynamics(&self.cfg.engine.dynamics, now);
        }
        ecfg.attempts0 = if any_attempts { attempts0.clone() } else { Vec::new() };

        let res = simulate_in(&era_dag, &self.cluster, &ecfg, scratch);
        self.era_dag = era_dag;
        self.local = local;
        self.attempts0 = attempts0;
        let r = match res {
            Ok(r) => r,
            Err(e) => {
                self.era_map = era_map;
                return Err(e);
            }
        };
        self.eras += 1;
        self.events += r.events;
        self.retries += r.retries;
        self.lost_work += r.lost_work;

        // ---- harvest --------------------------------------------------
        {
            let jobs = &self.jobs;
            let live = &mut self.live;
            let mut extra_lost = 0.0f64;
            for (e, &(slot, lt)) in era_map.iter().enumerate() {
                let lj = &mut live[slot];
                let tr = r.trace[e];
                if tr.start.is_finite() && lj.start_abs[lt].is_nan() {
                    lj.start_abs[lt] = now + tr.start;
                }
                if tr.finish.is_finite() {
                    lj.done[lt] = true;
                    lj.remaining[lt] = 0.0;
                    lj.finish_abs[lt] = now + tr.finish;
                } else if let Some(st) = r.stopped.as_ref() {
                    if !st.attempts.is_empty() && st.attempts[e] > lj.attempts[lt] {
                        // Killed this era: prior-era progress is lost too —
                        // restore the loss the engine could not see, then
                        // rebase remaining onto the original size.
                        let orig = jobs[lj.idx].dag.tasks[lt].size;
                        let era_size = lj.remaining[lt];
                        let kills = (st.attempts[e] - lj.attempts[lt]) as f64;
                        extra_lost += kills * (orig - era_size);
                        lj.remaining[lt] = st.remaining[e] + (orig - era_size);
                    } else {
                        lj.remaining[lt] = st.remaining[e];
                    }
                    if !st.attempts.is_empty() {
                        lj.attempts[lt] = st.attempts[e];
                        lj.gate_abs[lt] = lj.gate_abs[lt].max(now + st.retry_gate[e]);
                    }
                }
            }
            self.lost_work += extra_lost;
        }
        self.era_map = era_map;

        // ---- retire (epoch GC) ----------------------------------------
        {
            let jobs = &mut self.jobs;
            let out = &mut self.out;
            let mut slot = 0usize;
            self.live.retain(|lj| {
                let verdict = match r.jobs[slot] {
                    JobOutcome::Quarantined { reason, at } => {
                        Some(JobOutcome::Quarantined { reason, at: now + at })
                    }
                    JobOutcome::Exhausted { attempts } => {
                        Some(JobOutcome::Exhausted { attempts })
                    }
                    _ if lj.done.iter().all(|&d| d) => {
                        let finish = lj
                            .finish_abs
                            .iter()
                            .fold(lj.admit, |m, &f| if f.is_finite() { m.max(f) } else { m });
                        Some(JobOutcome::Completed { finish })
                    }
                    _ => None,
                };
                slot += 1;
                if let Some(outcome) = verdict {
                    let job = &jobs[lj.idx];
                    let jct = outcome.finish().map(|f| f - job.at);
                    out[lj.idx] = Some(OpenJobResult {
                        arrival: job.at,
                        admitted_at: Some(lj.admit),
                        outcome,
                        jct,
                        deadline_met: job.deadline.map(|d| jct.map_or(false, |t| t <= d)),
                        trace: lj
                            .start_abs
                            .iter()
                            .zip(&lj.finish_abs)
                            .map(|(&s, &f)| TaskTrace { start: s, finish: f })
                            .collect(),
                    });
                    // The retired job's DAG is never consulted again:
                    // free it so driver memory tracks the live set.
                    jobs[lj.idx].dag = SimDag::default();
                    false
                } else {
                    true
                }
            });
        }
        Ok(())
    }

    /// Finish the stream: every job must already have a verdict (call
    /// `advance_to(INFINITY)` first).
    pub fn into_result(self) -> OpenResult {
        let results: Vec<OpenJobResult> = self
            .out
            .into_iter()
            .map(|o| o.expect("every job must have a verdict"))
            .collect();
        let mut makespan = 0.0f64;
        let mut quarantined = 0usize;
        let mut completed = 0usize;
        for j in &results {
            match j.outcome {
                JobOutcome::Completed { finish } => {
                    completed += 1;
                    makespan = makespan.max(finish);
                }
                JobOutcome::Quarantined { at, .. } => {
                    quarantined += 1;
                    makespan = makespan.max(at);
                }
                JobOutcome::Exhausted { .. } => quarantined += 1,
                JobOutcome::Rejected { .. } => {}
            }
        }
        OpenResult {
            jobs: results,
            makespan,
            eras: self.eras,
            events: self.events,
            retries: self.retries,
            lost_work: self.lost_work,
            admitted: self.admitted,
            rejected: self.rejected,
            quarantined,
            completed,
        }
    }

    /// Serialize the full driver state at a quiescent point, floats as
    /// `f64::to_bits` hex so [`restore`](OpenLoop::restore) is bitwise.
    /// Job DAGs are *not* serialized — the restorer re-derives them from
    /// the logged submission specs (same spec text → same plan → same
    /// DAG, by determinism of the scheduler pipeline).
    pub fn state_json(&self) -> Json {
        let jobs: Vec<Json> = (0..self.jobs.len())
            .map(|idx| {
                if let Some(r) = &self.out[idx] {
                    Json::obj(vec![
                        ("state", Json::Str("done".into())),
                        ("result", result_bits_json(r)),
                    ])
                } else if let Some(lj) = self.live.iter().find(|lj| lj.idx == idx) {
                    let hexv = |v: &[f64]| Json::Arr(v.iter().map(|&x| jhex(x)).collect());
                    Json::obj(vec![
                        ("state", Json::Str("live".into())),
                        ("admit", jhex(lj.admit)),
                        ("remaining", hexv(&lj.remaining)),
                        ("done", Json::Arr(lj.done.iter().map(|&d| Json::Bool(d)).collect())),
                        ("gate", hexv(&lj.gate_abs)),
                        (
                            "attempts",
                            Json::Arr(
                                lj.attempts.iter().map(|&a| Json::Num(a as f64)).collect(),
                            ),
                        ),
                        ("start", hexv(&lj.start_abs)),
                        ("finish", hexv(&lj.finish_abs)),
                    ])
                } else {
                    Json::obj(vec![("state", Json::Str("queued".into()))])
                }
            })
            .collect();
        Json::obj(vec![
            ("v", Json::Num(1.0)),
            ("now", jhex(self.now)),
            ("eras", Json::Num(self.eras as f64)),
            ("events", Json::Num(self.events as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("lost_work", jhex(self.lost_work)),
            ("admitted", Json::Num(self.admitted as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("jobs", Json::Arr(jobs)),
            (
                "deferred",
                Json::Arr(
                    self.deferred
                        .iter()
                        .map(|&(i, e)| Json::Arr(vec![Json::Num(i as f64), jhex(e)]))
                        .collect(),
                ),
            ),
            (
                "pending",
                Json::Arr(
                    self.pending[self.head..]
                        .iter()
                        .map(|&i| Json::Num(i as f64))
                        .collect(),
                ),
            ),
        ])
    }

    /// Rebuild a loop from [`state_json`](OpenLoop::state_json) output.
    /// `fetch(idx)` must return the original [`OpenJob`] for every
    /// not-yet-done job (the caller re-derives it from its logged
    /// submission spec); it is not called for done jobs.
    pub fn restore(
        cluster: &Cluster,
        cfg: &OpenConfig,
        state: &Json,
        fetch: &mut dyn FnMut(usize) -> Result<OpenJob, String>,
    ) -> Result<OpenLoop, String> {
        let ctx = |e: crate::util::json::JsonError| format!("open state: {e}");
        if state.get("v").map_err(ctx)?.as_f64().map_err(ctx)? != 1.0 {
            return Err("open state: unsupported version".into());
        }
        let mut lp = OpenLoop::new(cluster, cfg);
        lp.now = unhex(state.get("now").map_err(ctx)?, "open state now")?;
        lp.eras = state.get("eras").map_err(ctx)?.as_usize().map_err(ctx)?;
        lp.events = state.get("events").map_err(ctx)?.as_usize().map_err(ctx)?;
        lp.retries = state.get("retries").map_err(ctx)?.as_usize().map_err(ctx)?;
        lp.lost_work = unhex(state.get("lost_work").map_err(ctx)?, "open state lost_work")?;
        lp.admitted = state.get("admitted").map_err(ctx)?.as_usize().map_err(ctx)?;
        lp.rejected = state.get("rejected").map_err(ctx)?.as_usize().map_err(ctx)?;

        let jobs = state.get("jobs").map_err(ctx)?.as_arr().map_err(ctx)?;
        for (idx, entry) in jobs.iter().enumerate() {
            let what = || format!("open state jobs[{idx}]");
            let st = entry
                .get("state")
                .and_then(|s| s.as_str())
                .map_err(|e| format!("{}: {e}", what()))?;
            match st {
                "done" => {
                    let r = result_bits_parse(entry.get("result").map_err(|e| {
                        format!("{}: {e}", what())
                    })?)
                    .map_err(|e| format!("{}: {e}", what()))?;
                    // The DAG of a settled job is never consulted again.
                    lp.jobs.push(OpenJob {
                        at: r.arrival,
                        dag: SimDag::default(),
                        deadline: None,
                        weight: 1,
                    });
                    lp.out.push(Some(r));
                }
                "live" => {
                    let job = fetch(idx)?;
                    let n = job.dag.len();
                    let f64s = |key: &str| -> Result<Vec<f64>, String> {
                        let arr = entry
                            .get(key)
                            .and_then(|v| v.as_arr().map(|a| a.to_vec()))
                            .map_err(|e| format!("{} {key}: {e}", what()))?;
                        arr.iter()
                            .map(|v| unhex(v, key))
                            .collect::<Result<Vec<f64>, String>>()
                            .map_err(|e| format!("{}: {e}", what()))
                    };
                    let admit = unhex(
                        entry.get("admit").map_err(|e| format!("{}: {e}", what()))?,
                        "admit",
                    )?;
                    let mut lj = Live::new(idx, &job, admit);
                    lj.remaining = f64s("remaining")?;
                    lj.gate_abs = f64s("gate")?;
                    lj.start_abs = f64s("start")?;
                    lj.finish_abs = f64s("finish")?;
                    lj.done = entry
                        .get("done")
                        .and_then(|v| v.as_arr().map(|a| a.to_vec()))
                        .map_err(|e| format!("{} done: {e}", what()))?
                        .iter()
                        .map(|v| v.as_bool())
                        .collect::<Result<Vec<bool>, _>>()
                        .map_err(|e| format!("{} done: {e}", what()))?;
                    lj.attempts = entry
                        .get("attempts")
                        .and_then(|v| v.as_arr().map(|a| a.to_vec()))
                        .map_err(|e| format!("{} attempts: {e}", what()))?
                        .iter()
                        .map(|v| v.as_usize())
                        .collect::<Result<Vec<usize>, _>>()
                        .map_err(|e| format!("{} attempts: {e}", what()))?;
                    for (k, len) in [
                        ("remaining", lj.remaining.len()),
                        ("done", lj.done.len()),
                        ("gate", lj.gate_abs.len()),
                        ("attempts", lj.attempts.len()),
                        ("start", lj.start_abs.len()),
                        ("finish", lj.finish_abs.len()),
                    ] {
                        if len != n {
                            return Err(format!(
                                "{} {k}: length {len} != dag tasks {n}",
                                what()
                            ));
                        }
                    }
                    lp.jobs.push(job);
                    lp.out.push(None);
                    lp.live.push(lj);
                }
                "queued" => {
                    let job = fetch(idx)?;
                    lp.jobs.push(job);
                    lp.out.push(None);
                }
                other => return Err(format!("{}: unknown state `{other}`", what())),
            }
        }

        let mut queued_seen = vec![false; lp.jobs.len()];
        for d in state.get("deferred").map_err(ctx)?.as_arr().map_err(ctx)? {
            let pair = d.as_arr().map_err(ctx)?;
            if pair.len() != 2 {
                return Err("open state deferred: expected [idx, expiry]".into());
            }
            let idx = pair[0].as_usize().map_err(ctx)?;
            let expiry = unhex(&pair[1], "open state deferred expiry")?;
            if idx >= lp.jobs.len() || lp.out[idx].is_some() {
                return Err(format!("open state deferred: bad job index {idx}"));
            }
            if std::mem::replace(&mut queued_seen[idx], true) {
                return Err(format!("open state: job {idx} queued twice"));
            }
            lp.deferred.push((idx, expiry));
        }
        for p in state.get("pending").map_err(ctx)?.as_arr().map_err(ctx)? {
            let idx = p.as_usize().map_err(ctx)?;
            if idx >= lp.jobs.len() || lp.out[idx].is_some() {
                return Err(format!("open state pending: bad job index {idx}"));
            }
            if std::mem::replace(&mut queued_seen[idx], true) {
                return Err(format!("open state: job {idx} queued twice"));
            }
            lp.pending.push(idx);
        }
        for w in lp.pending.windows(2) {
            let (a, b) = (w[0], w[1]);
            let (ta, tb) = (lp.jobs[a].at, lp.jobs[b].at);
            if ta > tb || (ta == tb && a > b) {
                return Err("open state pending: not sorted by (at, idx)".into());
            }
        }
        for idx in 0..lp.jobs.len() {
            let settled = lp.out[idx].is_some()
                || lp.live.iter().any(|lj| lj.idx == idx)
                || queued_seen[idx];
            if !settled {
                return Err(format!("open state: job {idx} is in no queue and has no verdict"));
            }
        }
        Ok(lp)
    }
}

/// Bit-exact float for crash-safe state.
fn jhex(x: f64) -> Json {
    Json::Str(f64_bits_hex(x))
}

fn unhex(j: &Json, what: &str) -> Result<f64, String> {
    let s = j.as_str().map_err(|e| format!("{what}: {e}"))?;
    f64_from_bits_hex(s).map_err(|e| format!("{what}: {e}"))
}

fn opt_jhex(x: Option<f64>) -> Json {
    x.map_or(Json::Null, jhex)
}

fn opt_unhex(j: &Json, what: &str) -> Result<Option<f64>, String> {
    match j {
        Json::Null => Ok(None),
        v => unhex(v, what).map(Some),
    }
}

/// Bit-exact JSON form of a [`JobOutcome`] (distinct from the human
/// [`JobOutcome::to_json`]: times are bit-hex strings).
fn outcome_bits_json(o: &JobOutcome) -> Json {
    match *o {
        JobOutcome::Completed { finish } => Json::obj(vec![
            ("kind", Json::Str("completed".into())),
            ("finish", jhex(finish)),
        ]),
        JobOutcome::Quarantined { reason, at } => Json::obj(vec![
            ("kind", Json::Str("quarantined".into())),
            ("reason", Json::Str(reason.label())),
            ("at", jhex(at)),
        ]),
        JobOutcome::Exhausted { attempts } => Json::obj(vec![
            ("kind", Json::Str("exhausted".into())),
            ("attempts", Json::Num(attempts as f64)),
        ]),
        JobOutcome::Rejected { at } => {
            Json::obj(vec![("kind", Json::Str("rejected".into())), ("at", jhex(at))])
        }
    }
}

fn outcome_bits_parse(j: &Json) -> Result<JobOutcome, String> {
    let ctx = |e: crate::util::json::JsonError| format!("outcome: {e}");
    match j.get("kind").map_err(ctx)?.as_str().map_err(ctx)? {
        "completed" => Ok(JobOutcome::Completed {
            finish: unhex(j.get("finish").map_err(ctx)?, "outcome finish")?,
        }),
        "quarantined" => {
            let label = j.get("reason").map_err(ctx)?.as_str().map_err(ctx)?;
            let reason = StuckReason::parse_label(label)
                .ok_or_else(|| format!("outcome: bad stuck reason `{label}`"))?;
            Ok(JobOutcome::Quarantined {
                reason,
                at: unhex(j.get("at").map_err(ctx)?, "outcome at")?,
            })
        }
        "exhausted" => Ok(JobOutcome::Exhausted {
            attempts: j.get("attempts").map_err(ctx)?.as_usize().map_err(ctx)?,
        }),
        "rejected" => Ok(JobOutcome::Rejected {
            at: unhex(j.get("at").map_err(ctx)?, "outcome at")?,
        }),
        other => Err(format!("outcome: unknown kind `{other}`")),
    }
}

/// Bit-exact JSON form of a settled [`OpenJobResult`].
fn result_bits_json(r: &OpenJobResult) -> Json {
    Json::obj(vec![
        ("arrival", jhex(r.arrival)),
        ("admitted_at", opt_jhex(r.admitted_at)),
        ("outcome", outcome_bits_json(&r.outcome)),
        ("jct", opt_jhex(r.jct)),
        (
            "deadline_met",
            r.deadline_met.map_or(Json::Null, Json::Bool),
        ),
        (
            "trace",
            Json::Arr(
                r.trace
                    .iter()
                    .map(|t| Json::Arr(vec![jhex(t.start), jhex(t.finish)]))
                    .collect(),
            ),
        ),
    ])
}

fn result_bits_parse(j: &Json) -> Result<OpenJobResult, String> {
    let ctx = |e: crate::util::json::JsonError| format!("result: {e}");
    let deadline_met = match j.get("deadline_met").map_err(ctx)? {
        Json::Null => None,
        v => Some(v.as_bool().map_err(ctx)?),
    };
    let mut trace = Vec::new();
    for t in j.get("trace").map_err(ctx)?.as_arr().map_err(ctx)? {
        let pair = t.as_arr().map_err(ctx)?;
        if pair.len() != 2 {
            return Err("result trace: expected [start, finish]".into());
        }
        trace.push(TaskTrace {
            start: unhex(&pair[0], "trace start")?,
            finish: unhex(&pair[1], "trace finish")?,
        });
    }
    Ok(OpenJobResult {
        arrival: unhex(j.get("arrival").map_err(ctx)?, "result arrival")?,
        admitted_at: opt_unhex(j.get("admitted_at").map_err(ctx)?, "result admitted_at")?,
        outcome: outcome_bits_parse(j.get("outcome").map_err(ctx)?)?,
        jct: opt_unhex(j.get("jct").map_err(ctx)?, "result jct")?,
        deadline_met,
        trace,
    })
}

/// As [`run_open`], allocating a fresh scratch.
pub fn run_open(
    jobs: &[OpenJob],
    cluster: &Cluster,
    cfg: &OpenConfig,
) -> Result<OpenResult, SimError> {
    run_open_in(jobs, cluster, cfg, &mut SimScratch::default())
}

/// Run the open-loop stream (module docs), reusing `scratch` across
/// eras — the bounded-memory entry point: the scratch grows to the
/// largest live set's high-water mark and plateaus there no matter how
/// many jobs stream through. Implemented as push-everything +
/// `advance_to(INFINITY)` over [`OpenLoop`]; with an infinite target
/// every era stops exactly at the next stream boundary, so this is
/// bit-identical to the pre-incremental batch driver.
pub fn run_open_in(
    jobs: &[OpenJob],
    cluster: &Cluster,
    cfg: &OpenConfig,
    scratch: &mut SimScratch,
) -> Result<OpenResult, SimError> {
    for j in jobs {
        assert!(j.at.is_finite() && j.at >= 0.0, "arrival times must be finite and ≥ 0");
    }
    let mut lp = OpenLoop::new(cluster, cfg);
    for j in jobs {
        lp.push(j.clone());
    }
    lp.advance_to(f64::INFINITY, scratch)?;
    Ok(lp.into_result())
}

/// Rebase the absolute timeline onto an era starting at `s`: past
/// events replay at the era's `t = 0` in original order (absolute
/// last-writer-wins factors make the replay exact) with `FailHost`
/// demoted to a capacity-identical slow-down so crashes kill in-flight
/// work exactly once; future events shift to era-relative time.
fn fold_dynamics(tl: &DynTimeline, s: f64) -> DynTimeline {
    let mut out = DynTimeline::new();
    for e in tl.events() {
        if e.at < s - EPS {
            let action = match e.action {
                DynAction::FailHost { host } => DynAction::SlowHost { host, factor: 0.0 },
                a => a,
            };
            out.push(0.0, action);
        } else {
            out.push((e.at - s).max(0.0), e.action);
        }
    }
    out
}

/// JSON arrival spec for `simulate --open FILE`:
///
/// ```json
/// {"arrivals": [0.0, 1.5, 3.0],
///  "watermark": 10.0, "defer_max": 2.0, "deadline": 5.0}
/// ```
///
/// or, trace generated from a seeded Poisson process:
///
/// ```json
/// {"poisson": {"seed": 7, "rate": 0.5, "n": 100}, "watermark": 10.0}
/// ```
///
/// `watermark` (default: admit all), `defer_max` (default 0) and
/// `deadline` (per-job, relative to arrival; default none) are
/// optional.
#[derive(Debug, Clone)]
pub struct OpenSpec {
    pub arrivals: Vec<f64>,
    pub watermark: f64,
    pub defer_max: f64,
    pub deadline: Option<f64>,
}

impl OpenSpec {
    pub fn from_json(j: &Json) -> Result<OpenSpec, String> {
        let obj = j.as_obj().map_err(|e| format!("open spec: {e}"))?;
        // Reject unknown keys so a misspelled field is a pinpointed 400
        // from `serve`, not a silently-ignored default.
        for k in obj.keys() {
            if !matches!(
                k.as_str(),
                "arrivals" | "poisson" | "watermark" | "defer_max" | "deadline"
            ) {
                return Err(format!(
                    "open spec: unknown key `{k}` (known: arrivals, poisson, watermark, \
                     defer_max, deadline)"
                ));
            }
        }
        if let Some(p) = obj.get("poisson") {
            let pobj = p.as_obj().map_err(|e| format!("open spec poisson: {e}"))?;
            for k in pobj.keys() {
                if !matches!(k.as_str(), "seed" | "rate" | "n") {
                    return Err(format!(
                        "open spec poisson: unknown key `{k}` (known: seed, rate, n)"
                    ));
                }
            }
        }
        let arrivals = match (obj.get("arrivals"), obj.get("poisson")) {
            (Some(_), Some(_)) => {
                return Err("open spec: give `arrivals` or `poisson`, not both".into())
            }
            (Some(a), None) => {
                let arr = a.as_arr().map_err(|e| format!("open spec arrivals: {e}"))?;
                let mut v = Vec::with_capacity(arr.len());
                for (i, x) in arr.iter().enumerate() {
                    let t = x.as_f64().map_err(|e| format!("open spec arrivals[{i}]: {e}"))?;
                    if !t.is_finite() || t < 0.0 {
                        return Err(format!("open spec arrivals[{i}]: bad time {t}"));
                    }
                    v.push(t);
                }
                v
            }
            (None, Some(p)) => {
                let seed_f = p
                    .get("seed")
                    .and_then(|v| v.as_f64())
                    .map_err(|e| format!("open spec poisson.seed: {e}"))?;
                if !(seed_f.is_finite() && seed_f >= 0.0 && seed_f.fract() == 0.0) {
                    return Err(format!("open spec poisson.seed: bad seed {seed_f}"));
                }
                let seed = seed_f as u64;
                let rate = p
                    .get("rate")
                    .and_then(|v| v.as_f64())
                    .map_err(|e| format!("open spec poisson.rate: {e}"))?;
                let n = p
                    .get("n")
                    .and_then(|v| v.as_usize())
                    .map_err(|e| format!("open spec poisson.n: {e}"))?;
                if !rate.is_finite() || rate <= 0.0 {
                    return Err(format!("open spec poisson.rate: bad rate {rate}"));
                }
                poisson_arrivals(seed, rate, n)
            }
            (None, None) => return Err("open spec: need `arrivals` or `poisson`".into()),
        };
        let opt_f64 = |key: &str| -> Result<Option<f64>, String> {
            match obj.get(key) {
                None => Ok(None),
                Some(v) => {
                    let x = v.as_f64().map_err(|e| format!("open spec {key}: {e}"))?;
                    if x.is_nan() || x < 0.0 {
                        return Err(format!("open spec {key}: bad value {x}"));
                    }
                    Ok(Some(x))
                }
            }
        };
        let watermark = opt_f64("watermark")?.unwrap_or(f64::INFINITY);
        let defer_max = match opt_f64("defer_max")? {
            Some(d) if !d.is_finite() => return Err("open spec defer_max: must be finite".into()),
            Some(d) => d,
            None => 0.0,
        };
        let deadline = match opt_f64("deadline")? {
            Some(d) if !d.is_finite() => return Err("open spec deadline: must be finite".into()),
            d => d,
        };
        Ok(OpenSpec { arrivals, watermark, defer_max, deadline })
    }

    /// Instantiate the stream: one clone of `dag` per arrival.
    pub fn jobs(&self, dag: &SimDag) -> Vec<OpenJob> {
        self.arrivals
            .iter()
            .map(|&at| OpenJob { at, dag: dag.clone(), deadline: self.deadline, weight: 1 })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::dynamics::LinkRef;
    use crate::sim::engine::simulate;
    use crate::sim::spec::SimKind;

    /// One compute task of `size` on `host`.
    fn one_task_job(at: f64, host: usize, size: f64) -> OpenJob {
        let mut d = SimDag::default();
        d.push(SimTask {
            orig: 0,
            chunk: (0, 1),
            kind: SimKind::Compute { host },
            size,
            priority: 0,
            gate: 0.0,
            coflow: None,
        });
        OpenJob { at, dag: d, deadline: None, weight: 1 }
    }

    /// compute → flow chain starting on `host`, flowing to `host + 1`.
    fn chain_job(at: f64, host: usize, size: f64) -> OpenJob {
        let mut d = SimDag::default();
        let c = d.push(SimTask {
            orig: 0,
            chunk: (0, 1),
            kind: SimKind::Compute { host },
            size,
            priority: 0,
            gate: 0.0,
            coflow: None,
        });
        let f = d.push(SimTask {
            orig: 1,
            chunk: (0, 1),
            kind: SimKind::Flow { src: host, dst: host + 1 },
            size,
            priority: 0,
            gate: 0.0,
            coflow: None,
        });
        d.dep(c, f);
        OpenJob { at, dag: d, deadline: None, weight: 1 }
    }

    #[test]
    fn poisson_is_deterministic_and_monotone() {
        let a = poisson_arrivals(7, 0.5, 50);
        let b = poisson_arrivals(7, 0.5, 50);
        assert_eq!(a, b);
        assert_eq!(a.len(), 50);
        for w in a.windows(2) {
            assert!(w[0] <= w[1]);
        }
        assert!(a.iter().all(|t| t.is_finite() && *t >= 0.0));
        assert_ne!(a, poisson_arrivals(8, 0.5, 50));
    }

    #[test]
    fn single_job_at_zero_matches_closed_run() {
        let jobs = vec![chain_job(0.0, 0, 2.0)];
        let cluster = Cluster::uniform(2);
        let open = run_open(&jobs, &cluster, &OpenConfig::default()).unwrap();
        let closed = simulate(&jobs[0].dag, &cluster, &SimConfig::default()).unwrap();
        assert_eq!(open.eras, 1);
        assert_eq!(open.admitted, 1);
        assert_eq!(open.completed, 1);
        assert_eq!(open.makespan.to_bits(), closed.makespan.to_bits());
        for (o, c) in open.jobs[0].trace.iter().zip(&closed.trace) {
            assert_eq!(o.start.to_bits(), c.start.to_bits());
            assert_eq!(o.finish.to_bits(), c.finish.to_bits());
        }
        assert_eq!(open.jobs[0].jct, Some(closed.makespan));
    }

    #[test]
    fn spaced_stream_completes_all_with_absolute_times() {
        // Disjoint hosts, spaced arrivals: each job runs solo; its
        // trace is the solo trace shifted by its arrival.
        let jobs = vec![one_task_job(0.0, 0, 1.0), one_task_job(5.0, 1, 2.0)];
        let cluster = Cluster::uniform(2);
        let r = run_open(&jobs, &cluster, &OpenConfig::default()).unwrap();
        assert_eq!(r.completed, 2);
        assert_eq!(r.jobs[0].jct, Some(1.0));
        assert_eq!(r.jobs[1].jct, Some(2.0));
        assert_eq!(r.jobs[1].trace[0].start, 5.0);
        assert_eq!(r.jobs[1].trace[0].finish, 7.0);
        assert_eq!(r.makespan, 7.0);
    }

    #[test]
    fn watermark_sheds_with_distinct_rejected_outcome() {
        // Host 0, capacity 1: job 0 queues 10 time units of work. The
        // watermark of 5 admits job 0 (solo drain 10 > 5? no — reject).
        // Use sizes that make the intent exact: job 0 drains in 4,
        // job 1 would push the estimate to 8 > 5 → shed.
        let jobs = vec![one_task_job(0.0, 0, 4.0), one_task_job(1.0, 0, 4.0)];
        let cluster = Cluster::uniform(1);
        let cfg = OpenConfig { watermark: 5.0, ..OpenConfig::default() };
        let r = run_open(&jobs, &cluster, &cfg).unwrap();
        assert_eq!(r.admitted, 1);
        assert_eq!(r.rejected, 1);
        assert!(matches!(r.jobs[1].outcome, JobOutcome::Rejected { at } if at == 1.0));
        assert_eq!(r.jobs[1].admitted_at, None);
        assert!(r.jobs[1].trace.is_empty());
        // The shed job never entered the engine: no lost work.
        assert_eq!(r.lost_work, 0.0);
        // Job 0 unaffected.
        assert_eq!(r.jobs[0].jct, Some(4.0));
    }

    #[test]
    fn solo_overweight_job_is_rejected_immediately_despite_deferral() {
        let jobs = vec![one_task_job(0.0, 0, 100.0)];
        let cluster = Cluster::uniform(1);
        let cfg = OpenConfig { watermark: 5.0, defer_max: 50.0, ..OpenConfig::default() };
        let r = run_open(&jobs, &cluster, &cfg).unwrap();
        assert!(matches!(r.jobs[0].outcome, JobOutcome::Rejected { at } if at == 0.0));
    }

    #[test]
    fn deferred_job_admits_once_load_drains() {
        // Job 0 drains at t = 4; job 1 arrives at t = 1 over the
        // watermark, defers, and is retested at its expiry t = 6 when
        // the cluster is empty → admitted there.
        let jobs = vec![one_task_job(0.0, 0, 4.0), one_task_job(1.0, 0, 4.0)];
        let cluster = Cluster::uniform(1);
        let cfg = OpenConfig { watermark: 5.0, defer_max: 5.0, ..OpenConfig::default() };
        let r = run_open(&jobs, &cluster, &cfg).unwrap();
        assert_eq!(r.admitted, 2);
        assert_eq!(r.rejected, 0);
        assert_eq!(r.jobs[1].admitted_at, Some(6.0));
        assert_eq!(r.jobs[1].trace[0].start, 6.0);
        assert_eq!(r.jobs[1].jct, Some(9.0)); // finished 10, arrived 1
    }

    #[test]
    fn deferral_expires_into_rejection_under_sustained_load() {
        // Job 0 holds the cluster past job 1's deferral window.
        let jobs = vec![one_task_job(0.0, 0, 20.0), one_task_job(1.0, 0, 4.0)];
        let cluster = Cluster::uniform(1);
        let cfg = OpenConfig { watermark: 5.0, defer_max: 2.0, ..OpenConfig::default() };
        let r = run_open(&jobs, &cluster, &cfg).unwrap();
        // Job 0's solo drain is 20 > 5: rejected at arrival, so the
        // cluster is actually empty — rebuild the scenario with an
        // admissible hog.
        assert!(matches!(r.jobs[0].outcome, JobOutcome::Rejected { .. }));

        let jobs = vec![one_task_job(0.0, 0, 4.9), one_task_job(1.0, 0, 4.9)];
        let cfg = OpenConfig { watermark: 5.0, defer_max: 2.0, ..OpenConfig::default() };
        let r = run_open(&jobs, &Cluster::uniform(1), &cfg).unwrap();
        assert_eq!(r.admitted, 1);
        assert_eq!(r.rejected, 1);
        // Shed at the deferral expiry, not at arrival.
        assert!(matches!(r.jobs[1].outcome, JobOutcome::Rejected { at } if at == 3.0));
    }

    #[test]
    fn deadline_metrics() {
        let mut early = one_task_job(0.0, 0, 1.0);
        early.deadline = Some(2.0);
        let mut late = one_task_job(0.0, 1, 5.0);
        late.deadline = Some(2.0);
        let r = run_open(&[early, late], &Cluster::uniform(2), &OpenConfig::default()).unwrap();
        assert_eq!(r.jobs[0].deadline_met, Some(true));
        assert_eq!(r.jobs[1].deadline_met, Some(false));
        assert_eq!(r.deadline_hit_rate(), Some(0.5));
        let p50 = r.jct_percentile(0.5).unwrap();
        assert!(p50 == 1.0 || p50 == 5.0);
        assert_eq!(r.jct_percentile(0.99), Some(5.0));
    }

    #[test]
    fn past_dynamics_still_apply_after_their_jobs_departed() {
        // Satellite regression: host 1 is slowed while only job 0 is
        // live; job 0 completes; the restore fires in an era where no
        // live job references host 1 — the *next* arrival must still
        // see the restored (full) capacity, and an arrival between
        // slow-down and restore must see the degraded capacity.
        let mut cfg = OpenConfig::default();
        cfg.engine.dynamics = DynTimeline::new()
            .with(0.5, DynAction::SlowHost { host: 1, factor: 0.5 })
            .with(6.0, DynAction::RestoreHost { host: 1 });
        let jobs = vec![
            one_task_job(0.0, 0, 1.0),  // departs at t = 1
            one_task_job(2.0, 1, 1.0),  // runs at 0.5 → finishes t = 4
            one_task_job(10.0, 1, 1.0), // after restore → finishes t = 11
        ];
        let r = run_open(&jobs, &Cluster::uniform(2), &cfg).unwrap();
        assert_eq!(r.completed, 3);
        assert_eq!(r.jobs[1].jct, Some(2.0));
        assert_eq!(r.jobs[2].jct, Some(1.0));
    }

    #[test]
    fn degraded_link_persists_across_idle_eras() {
        // Link-level flavour of the same regression: up:0 degraded
        // early, never restored; a job arriving long after every other
        // job departed must still see the degraded uplink.
        let mut cfg = OpenConfig::default();
        cfg.engine.dynamics = DynTimeline::new()
            .with(0.1, DynAction::Degrade { link: LinkRef::NicUp(0), factor: 0.25 });
        let jobs = vec![one_task_job(0.0, 1, 1.0), chain_job(5.0, 0, 1.0)];
        let r = run_open(&jobs, &Cluster::uniform(2), &cfg).unwrap();
        assert_eq!(r.completed, 2);
        // compute 1.0 at full rate, then 1.0 bytes at 0.25 → 4.0
        assert_eq!(r.jobs[1].jct, Some(5.0));
    }

    #[test]
    fn concat_jobs_offsets_namespaces() {
        let jobs = vec![chain_job(0.0, 0, 1.0), chain_job(0.0, 0, 2.0)];
        let all = concat_jobs(&jobs);
        assert_eq!(all.len(), 4);
        assert_eq!(all.job(0), 0);
        assert_eq!(all.job(2), 1);
        assert_eq!(all.tasks[2].orig, 2); // shifted by n_origs = 2
        assert_eq!(all.n_jobs(), 2);
    }

    #[test]
    fn open_spec_json_both_modes() {
        let j = Json::parse(
            r#"{"arrivals": [0.0, 1.5], "watermark": 10.0, "defer_max": 2.0, "deadline": 5.0}"#,
        )
        .unwrap();
        let s = OpenSpec::from_json(&j).unwrap();
        assert_eq!(s.arrivals, vec![0.0, 1.5]);
        assert_eq!(s.watermark, 10.0);
        assert_eq!(s.defer_max, 2.0);
        assert_eq!(s.deadline, Some(5.0));
        let jobs = s.jobs(&chain_job(0.0, 0, 1.0).dag);
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[1].at, 1.5);
        assert_eq!(jobs[1].deadline, Some(5.0));

        let j = Json::parse(r#"{"poisson": {"seed": 7, "rate": 0.5, "n": 10}}"#).unwrap();
        let s = OpenSpec::from_json(&j).unwrap();
        assert_eq!(s.arrivals, poisson_arrivals(7, 0.5, 10));
        assert!(s.watermark.is_infinite());
        assert_eq!(s.defer_max, 0.0);
        assert_eq!(s.deadline, None);
    }

    #[test]
    fn open_spec_json_rejects_bad_input() {
        for bad in [
            r#"{}"#,
            r#"{"arrivals": [0.0], "poisson": {"seed": 1, "rate": 1.0, "n": 2}}"#,
            r#"{"arrivals": [-1.0]}"#,
            r#"{"poisson": {"seed": 1, "rate": 0.0, "n": 2}}"#,
            r#"{"arrivals": [0.0], "watermark": -2.0}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(OpenSpec::from_json(&j).is_err(), "must reject {bad}");
        }
        // Non-finite defer_max can no longer be written in JSON text (the
        // hardened parser rejects 1e999), but the spec check still guards
        // hand-built values.
        let j = Json::obj(vec![
            ("arrivals", Json::Arr(vec![Json::Num(0.0)])),
            ("defer_max", Json::Num(f64::INFINITY)),
        ]);
        assert!(OpenSpec::from_json(&j).is_err());
    }

    /// Satellite: structured spec errors pinpoint the offending key with
    /// expected/got, and misspelled keys are called out by name.
    #[test]
    fn open_spec_errors_are_actionable() {
        let e = OpenSpec::from_json(
            &Json::parse(r#"{"arrivals": [0.0], "watermrk": 5}"#).unwrap(),
        )
        .unwrap_err();
        assert!(e.contains("unknown key `watermrk`"), "got: {e}");
        assert!(e.contains("watermark"), "should list known keys: {e}");

        let e = OpenSpec::from_json(
            &Json::parse(r#"{"arrivals": [0.0], "watermark": "high"}"#).unwrap(),
        )
        .unwrap_err();
        assert!(e.contains("watermark"), "got: {e}");
        assert!(e.contains("wanted number") && e.contains("got string"), "got: {e}");

        let e = OpenSpec::from_json(
            &Json::parse(r#"{"poisson": {"seed": 1, "rate": 1.0, "count": 5}}"#).unwrap(),
        )
        .unwrap_err();
        assert!(e.contains("unknown key `count`"), "got: {e}");

        let e = OpenSpec::from_json(
            &Json::parse(r#"{"arrivals": [0.0, "soon"]}"#).unwrap(),
        )
        .unwrap_err();
        assert!(e.contains("arrivals[1]"), "got: {e}");
    }

    #[test]
    fn incremental_ticks_match_batch_within_tolerance() {
        // Same arrivals, different advance sequences: intermediate
        // targets split eras, which perturbs carried floats only at
        // rounding scale. Pushes arrive out of stamp order to exercise
        // the pending insertion sort.
        let jobs = vec![
            one_task_job(1.0, 1, 3.0),
            chain_job(0.0, 0, 2.0),
            chain_job(2.5, 0, 1.0),
        ];
        let cluster = Cluster::uniform(3);
        let cfg = OpenConfig::default();
        let batch = run_open(&jobs, &cluster, &cfg).unwrap();

        let mut scratch = SimScratch::default();
        let mut lp = OpenLoop::new(&cluster, &cfg);
        for j in &jobs {
            lp.push(j.clone());
        }
        for h in [0.5, 1.0, 1.7, 2.5, 3.25, 4.0] {
            lp.advance_to(h, &mut scratch).unwrap();
        }
        lp.advance_to(f64::INFINITY, &mut scratch).unwrap();
        let inc = lp.into_result();
        assert_eq!(inc.completed, batch.completed);
        assert_eq!(inc.admitted, batch.admitted);
        for (a, b) in inc.jobs.iter().zip(&batch.jobs) {
            match (a.jct, b.jct) {
                (Some(x), Some(y)) => {
                    assert!((x - y).abs() <= 1e-6 * x.abs().max(1.0), "jct {x} vs {y}")
                }
                (x, y) => assert_eq!(x.is_some(), y.is_some()),
            }
        }
        assert!((inc.makespan - batch.makespan).abs() <= 1e-6 * batch.makespan.max(1.0));
    }

    #[test]
    fn snapshot_restore_is_bitwise() {
        // Deferral + retry + a mid-stream host crash: the snapshot
        // carries remaining bytes, retry gates, attempts, the deferred
        // queue and settled results; a loop restored at any tick must
        // finish bit-identically to the uninterrupted one under the
        // same advance sequence.
        let mut cfg = OpenConfig { watermark: 5.0, defer_max: 6.0, ..OpenConfig::default() };
        cfg.engine.recovery = RecoveryPolicy::Retry { max_attempts: 3, backoff: 0.5 };
        cfg.engine.dynamics = DynTimeline::new()
            .with(1.5, DynAction::FailHost { host: 1 })
            .with(3.0, DynAction::RestoreHost { host: 1 });
        let jobs = vec![
            one_task_job(0.0, 0, 4.0),
            one_task_job(0.5, 1, 4.0),
            one_task_job(1.0, 0, 9.0), // over the watermark → defers
            one_task_job(2.0, 1, 2.0),
        ];
        let cluster = Cluster::uniform(2);
        let ticks = [0.7, 1.2, 2.0, 2.6, 3.5, 5.0];

        let run = |resume_at: Option<usize>| -> String {
            let mut scratch = SimScratch::default();
            let mut lp = OpenLoop::new(&cluster, &cfg);
            for j in &jobs {
                lp.push(j.clone());
            }
            for (i, &h) in ticks.iter().enumerate() {
                if Some(i) == resume_at {
                    // "Crash": serialize through text, drop, rebuild
                    // from state + original specs with a cold scratch.
                    let state = Json::parse(&lp.state_json().to_string()).unwrap();
                    lp = OpenLoop::restore(&cluster, &cfg, &state, &mut |idx| {
                        Ok(jobs[idx].clone())
                    })
                    .unwrap();
                    scratch = SimScratch::default();
                }
                lp.advance_to(h, &mut scratch).unwrap();
            }
            lp.advance_to(f64::INFINITY, &mut scratch).unwrap();
            lp.state_json().to_string()
        };

        let uninterrupted = run(None);
        for k in 0..ticks.len() {
            assert_eq!(run(Some(k)), uninterrupted, "kill before tick {k}");
        }
    }

    #[test]
    fn heavier_tenant_wins_deferral_retest() {
        // Hog admitted at t = 0 drains at t = 4; two deferred jobs
        // expire at t = 11 when only one fits under the watermark: the
        // heavier one is retested first and admitted, the lighter one
        // sheds at its expiry. With equal weights, arrival order wins.
        let cluster = Cluster::uniform(1);
        let cfg = OpenConfig { watermark: 5.0, defer_max: 10.0, ..OpenConfig::default() };
        let mk = |w: i64| {
            let mut j = one_task_job(1.0, 0, 4.0);
            j.weight = w;
            j
        };
        let hog = one_task_job(0.0, 0, 4.0);

        let r = run_open(&[hog.clone(), mk(1), mk(1)], &cluster, &cfg).unwrap();
        assert!(matches!(r.jobs[1].outcome, JobOutcome::Completed { .. }));
        assert!(matches!(r.jobs[2].outcome, JobOutcome::Rejected { .. }));

        let r = run_open(&[hog, mk(1), mk(5)], &cluster, &cfg).unwrap();
        assert!(matches!(r.jobs[1].outcome, JobOutcome::Rejected { .. }));
        assert!(matches!(r.jobs[2].outcome, JobOutcome::Completed { .. }));
    }

    #[test]
    fn idle_advance_is_a_noop_and_states_progress() {
        let cluster = Cluster::uniform(1);
        let mut scratch = SimScratch::default();
        let mut lp = OpenLoop::new(&cluster, &OpenConfig::default());
        assert!(lp.is_idle());
        lp.advance_to(100.0, &mut scratch).unwrap();
        // Idle: the clock only tracks processed boundaries.
        assert_eq!(lp.now(), 0.0);
        assert_eq!(lp.counters().eras, 0);
        let i = lp.push(one_task_job(3.0, 0, 1.0));
        assert_eq!(lp.job_state(i), Some("pending"));
        lp.advance_to(3.0, &mut scratch).unwrap();
        assert_eq!(lp.job_state(i), Some("live"));
        assert_eq!(lp.now(), 3.0);
        lp.advance_to(f64::INFINITY, &mut scratch).unwrap();
        assert_eq!(lp.job_state(i), Some("done"));
        assert_eq!(lp.result(i).unwrap().jct, Some(1.0));
        assert_eq!(lp.max_finish(), 4.0);
    }

    #[test]
    fn result_json_has_counters_and_percentiles() {
        let jobs = vec![one_task_job(0.0, 0, 1.0), one_task_job(0.0, 1, 3.0)];
        let r = run_open(&jobs, &Cluster::uniform(2), &OpenConfig::default()).unwrap();
        let j = r.to_json();
        let s = format!("{j}");
        assert!(s.contains("\"admitted\""));
        assert!(s.contains("\"jct_p99\""));
        assert!(!s.contains("deadline_hit_rate")); // no deadlines given
        let pj = format!("{}", r.jobs_json());
        assert!(pj.contains("\"arrival\""));
    }
}
