//! The fluid discrete-event engine.
//!
//! Tasks become ready when all predecessors finish (chunk-level deps
//! encode pipelining), their gate time has passed and — under coflow
//! semantics — their whole group is ready (all-or-nothing). At every
//! event boundary the policy recomputes rates; the engine advances to
//! the next completion or gate expiry.
//!
//! ## Incremental ready queues (§Perf)
//!
//! The engine keeps the ready set in two persistent priority-keyed
//! [`ReadyQueue`]s (compute slots and network flows draw on disjoint
//! resource classes). Tasks are pushed once when they become ready and
//! popped once when they finish; per event the engine only
//!
//! 1. admits newly ready tasks (dependency completions and gate
//!    expiries, in *live order* — the order tasks entered the ready
//!    set, which FIFO slot assignment depends on);
//! 2. refreshes stale SEBF keys via the
//!    [`update_key`](ReadyQueue::update_key) invalidation hook (coflow
//!    bounds shift with remaining bytes; static-priority and FIFO keys
//!    never go stale);
//! 3. walks queue levels high → low, allocating rates per level on
//!    residual capacity, and **stops as soon as every positive-capacity
//!    resource of the class is saturated** — all lower levels would
//!    allocate zero, exactly as the old full walk did (a task makes
//!    progress only if *all* of its resources have headroom, so a level
//!    whose every task touches a saturated resource is skipped by a
//!    cheap pre-check without running the filler).
//!
//! [`SimConfig::queue`] selects [`QueueKind::Incremental`] (default) or
//! [`QueueKind::FullResort`], the pre-refactor re-sort-every-event
//! baseline kept as an equivalence oracle
//! (`tests/prop_queue_equivalence.rs`) and benchmark baseline
//! (`benches/sched_scaling.rs`). Both produce identical simulations;
//! level allocation is order-independent, so the walks are even
//! bit-for-bit comparable.
//!
//! ## Component-wise allocation (§Perf)
//!
//! Orthogonally to the queue kind, [`SimConfig::alloc`] selects how much
//! of the active set each event reprices. Under
//! [`AllocKind::Components`] (default) the engine maintains an
//! incremental partition of the queued tasks into contention components
//! ([`CompSet`], `sim/components.rs`) and re-runs the fluid fill only
//! for components the event touched — arrival, completion, gate expiry,
//! coflow progress — while clean components keep their **memoized
//! rates**. [`AllocKind::WholeSet`] is the reprice-everything path
//! (the pre-refactor cost profile), kept as the second equivalence
//! oracle.
//! Because the fills themselves decompose by exact resource
//! connectivity (`alloc::maxmin_fill_res_in`) and coflow groups are
//! held atomic through virtual component resources, the two produce
//! bit-for-bit identical rates, event counts, makespans and traces —
//! asserted across all five policies by `benches/sched_scaling.rs` and
//! `tests/prop_queue_equivalence.rs`. See `docs/ARCHITECTURE.md` ("The
//! allocation layer") for the dirty-marking rules per event type.
//!
//! ## Anchored time advance (§Perf)
//!
//! The third orthogonal axis, [`SimConfig::horizon`], selects how time
//! advances between events. Under [`HorizonKind::Anchored`] (default)
//! every rated task stores `(anchor, remaining-at-anchor, rate)` and
//! its predicted absolute finish time lives in a global indexed
//! min-heap ([`FinHeap`], `sim/horizon.rs`): the event horizon is a
//! heap peek instead of a scan over every rated task, and remaining
//! bytes are materialized lazily — only when a component goes dirty
//! does the engine re-anchor its members at `now` via
//! `rem = rem_anchor − rate · (now − anchor)`. Quiescent components
//! are never iterated per event; their heap entries stay valid because
//! their memoized rates are immutable between the events that touch
//! them. [`HorizonKind::Eager`] keeps the pre-refactor per-event
//! integration sweep as the bit-exact baseline. Anchored arithmetic
//! reorders floating-point operations, so the cross-horizon oracle is
//! tolerance-based (per-task trace times and makespan within `1e-6`
//! relative) rather than bitwise — see `sim/horizon.rs` and
//! `docs/ARCHITECTURE.md` ("Time advance").
//!
//! ## Parallel event loop (§Perf)
//!
//! The fourth orthogonal axis, [`SimConfig::threads`], exploits the
//! component partition for wall-clock parallelism. Every event is an
//! *epoch*: a serial prologue on the coordinating thread drains the
//! dirty-component list (re-anchoring, capacity release, partition
//! rebuild — every merge/split of the contention graph happens here,
//! behind the epoch barrier), then the refills of the freshly rebuilt
//! components — mutually independent by construction, since fresh
//! components have disjoint members *and* disjoint resources — fan out
//! across worker threads via [`crate::util::par::par_map_with`], and a
//! serial epilogue replays each worker's recorded effects (key
//! updates, capacity residuals, rates, starts, finish predictions) in
//! component order, which is exactly the serial path's order. Workers
//! write only to per-worker arenas (`EngineWorker`, kept warm in the
//! [`SimScratch`] across events and runs), so each refill is a pure
//! function of `(component, pre-epoch state)` and the result is
//! bit-identical for every thread count — `threads == 1` (default) is
//! the serial oracle path, exactly like `FullResort` / `WholeSet` /
//! `Eager` before it. Only [`AllocKind::Components`] has shardable
//! work; other configs run serially regardless of `threads`. Events
//! that touch few tasks skip the fan-out entirely
//! (`PAR_FILL_MIN_TASKS`) so thread-spawn overhead never lands on
//! the small-event fast path. See `docs/ARCHITECTURE.md` ("Parallel
//! event loop") for the shard-ownership and barrier rules.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};

use super::alloc::{self, AllocScratch, TaskRes};
use super::components::{AllocKind, CompSet};
use super::dynamics::{DynState, DynTimeline};
use super::horizon::{FinHeap, HorizonKind};
use super::ready::{f64_ord, BucketQueue, PrioKey, ReadyQueue, ResortQueue};
use super::recovery::{retry_backoff, JobOutcome, RecoveryPolicy};
use super::spec::{res_down, res_up, CpuPolicy, Cluster, NetPolicy, Policy, SimDag, SimKind};
use super::topology::Topology;
use crate::mxdag::TaskId;
use crate::util::json::Json;
use crate::util::par::par_map_with;

const EPS: f64 = 1e-9;
/// Resource-saturation threshold. Must match the allocator's internal
/// epsilon (`alloc`'s starvation test) so the early-exit pre-check and
/// the filler agree bit-for-bit on which tasks are starved.
const ALLOC_EPS: f64 = 1e-12;

/// Why a sampled task could make no progress at deadlock time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StuckReason {
    /// Queued but rated zero; carries a zero-capacity resource from the
    /// task's footprint when one exists (the usual cause).
    Starved { resource: Option<usize> },
    /// Parked behind a coflow all-or-nothing barrier that never opened
    /// (the blocking group's *raw* coflow id, as the plan spelled it).
    Parked { group: usize },
    /// Dependencies unmet — stuck upstream of the reported deadlock.
    Blocked,
}

impl StuckReason {
    /// Stable string spelling for structured reports (CLI JSON, per-job
    /// outcome tables).
    pub fn label(&self) -> String {
        match *self {
            StuckReason::Starved { resource: Some(r) } => format!("starved:res{r}"),
            StuckReason::Starved { resource: None } => "starved".into(),
            StuckReason::Parked { group } => format!("parked:coflow{group}"),
            StuckReason::Blocked => "blocked".into(),
        }
    }

    /// Inverse of [`StuckReason::label`], used when crash-safe state
    /// (WAL snapshots) round-trips job outcomes through JSON.
    pub fn parse_label(s: &str) -> Option<StuckReason> {
        if s == "starved" {
            return Some(StuckReason::Starved { resource: None });
        }
        if s == "blocked" {
            return Some(StuckReason::Blocked);
        }
        if let Some(r) = s.strip_prefix("starved:res") {
            return r.parse().ok().map(|r| StuckReason::Starved { resource: Some(r) });
        }
        if let Some(g) = s.strip_prefix("parked:coflow") {
            return g.parse().ok().map(|group| StuckReason::Parked { group });
        }
        None
    }
}

/// Simulation failure modes.
#[derive(Debug)]
pub enum SimError {
    /// No task can make progress and no gate is pending. Carries enough
    /// context to debug the plan from the error alone.
    Deadlock {
        /// Simulation time progress stopped at.
        now: f64,
        /// Unfinished tasks.
        n_remaining: usize,
        /// A sample stuck task (physical chunk id) and why it is stuck;
        /// starved / parked tasks are preferred over merely-blocked
        /// ones, which only restate the deadlock.
        stuck: Option<(usize, StuckReason)>,
        /// The nearest future gate among unfinished tasks — it never
        /// fired because readiness is blocked upstream of it.
        nearest_gate: Option<(usize, f64)>,
    },
    /// [`SimConfig::max_events`] exceeded.
    EventLimit(usize),
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::Deadlock { now, n_remaining, stuck, nearest_gate } => {
                write!(f, "deadlock at t={now}: {n_remaining} tasks can make no progress")?;
                match stuck {
                    Some((t, StuckReason::Starved { resource: Some(r) })) => {
                        write!(f, " (task {t} starved: resource {r} has zero capacity")?
                    }
                    Some((t, StuckReason::Starved { resource: None })) => {
                        write!(f, " (task {t} starved on saturated resources")?
                    }
                    Some((t, StuckReason::Parked { group })) => {
                        write!(f, " (task {t} parked on coflow group {group}")?
                    }
                    Some((t, StuckReason::Blocked)) => {
                        write!(f, " (task {t} blocked on unmet dependencies")?
                    }
                    None => return Ok(()),
                }
                if let Some((t, g)) = nearest_gate {
                    write!(f, "; nearest blocked gate t={g} on task {t}")?;
                }
                write!(f, ")")
            }
            SimError::EventLimit(n) => write!(f, "event limit exceeded ({n} events)"),
        }
    }
}

impl SimError {
    /// Stable machine-readable kind for structured error reports (the
    /// CLI `outcome` line, `serve` logs).
    pub fn kind_str(&self) -> &'static str {
        match self {
            SimError::Deadlock { .. } => "deadlock",
            SimError::EventLimit(_) => "event_limit",
        }
    }

    /// Documented process exit code for this failure: 2 = deadlock,
    /// 3 = event-limit (1 is reserved for config errors, see README).
    /// Shared by `simulate` (closed and `--open`) and `serve`.
    pub fn exit_code(&self) -> i32 {
        match self {
            SimError::Deadlock { .. } => 2,
            SimError::EventLimit(_) => 3,
        }
    }
}

impl std::error::Error for SimError {}

/// Build the enriched [`SimError::Deadlock`] report: scan once for a
/// representative stuck task (preferring a starved or parked one over a
/// merely-blocked successor) and the nearest never-fired gate. Deadlock
/// is terminal, so the `O(n)` scan is free.
#[allow(clippy::too_many_arguments)]
fn deadlock_report(
    dag: &SimDag,
    caps0: &[f64],
    task_res: &[TaskRes],
    done: &[bool],
    queued: &[bool],
    indeg: &[usize],
    group_of: &[Option<usize>],
    group_open: &[bool],
    now: f64,
    n_remaining: usize,
) -> SimError {
    let mut stuck: Option<(usize, StuckReason)> = None;
    let mut nearest_gate: Option<(usize, f64)> = None;
    for t in 0..dag.len() {
        if done[t] {
            continue;
        }
        let reason = if queued[t] {
            StuckReason::Starved {
                resource: task_res[t].iter().find(|&r| caps0[r] <= ALLOC_EPS),
            }
        } else if indeg[t] == 0 {
            match group_of[t] {
                Some(gi) if !group_open[gi] => StuckReason::Parked {
                    group: dag.tasks[t].coflow.unwrap_or(gi),
                },
                _ => StuckReason::Blocked,
            }
        } else {
            StuckReason::Blocked
        };
        let better = match (&stuck, &reason) {
            (None, _) => true,
            (Some((_, StuckReason::Blocked)), r) => *r != StuckReason::Blocked,
            _ => false,
        };
        if better {
            stuck = Some((t, reason));
        }
        let gate = dag.tasks[t].gate;
        if gate > now + EPS && !nearest_gate.map_or(false, |(_, g)| g <= gate) {
            nearest_gate = Some((t, gate));
        }
    }
    SimError::Deadlock { now, n_remaining, stuck, nearest_gate }
}

/// Per-task execution record.
#[derive(Debug, Clone, Copy)]
pub struct TaskTrace {
    pub start: f64,
    pub finish: f64,
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Completion time of the whole DAG.
    pub makespan: f64,
    /// Per physical task trace.
    pub trace: Vec<TaskTrace>,
    /// Aggregated per *logical* MXTask: earliest chunk start.
    pub orig_start: BTreeMap<TaskId, f64>,
    /// Aggregated per logical MXTask: latest chunk finish.
    pub orig_finish: BTreeMap<TaskId, f64>,
    /// Number of engine iterations (profiling).
    pub events: usize,
    /// Per-job verdicts, indexed by job id (`SimDag::job_of`; a DAG
    /// with no job map is the single job 0). Every job is
    /// [`JobOutcome::Completed`] unless the recovery layer quarantined
    /// it; quarantined jobs keep `NaN` start/finish entries in `trace`
    /// for their unfinished chunks and are absent from the per-logical
    /// aggregates.
    pub jobs: Vec<JobOutcome>,
    /// Task re-enqueues performed by [`RecoveryPolicy::Retry`].
    pub retries: usize,
    /// Work destroyed by host crashes: the sum over killed attempts of
    /// the bytes/work completed at kill time.
    pub lost_work: f64,
    /// In-flight state at an open-loop stop bound ([`SimConfig::stop`]):
    /// `Some` iff the run halted at the bound with unfinished tasks
    /// still live. Closed-mode runs (the default `stop: None`) always
    /// carry `None`. For a stopped run, `jobs` / `orig_*` cover only
    /// the work that finished inside the window — the open-loop driver
    /// owns job verdicts across epochs.
    pub stopped: Option<StopState>,
}

/// Per-task carry-over exported when a run halts at [`SimConfig::stop`]:
/// everything the open-loop driver needs to rebuild the next epoch's
/// compacted DAG. `remaining` is fully materialized as of the stop
/// instant (anchored runs integrate lazily; the export settles them).
/// `attempts` / `retry_gate` are empty unless the run used
/// [`RecoveryPolicy::Retry`]; gates are absolute simulated time within
/// the stopped run's own clock.
#[derive(Debug, Clone)]
pub struct StopState {
    /// The instant the run actually halted (≥ the requested bound only
    /// by a completed event landing within `EPS` of it).
    pub at: f64,
    /// Materialized unfinished bytes per task (0 for completed tasks).
    pub remaining: Vec<f64>,
    /// Failed-attempt counts per task (empty under FailFast).
    pub attempts: Vec<usize>,
    /// Backoff-gate expiries per task (empty under FailFast).
    pub retry_gate: Vec<f64>,
}

impl SimResult {
    /// Finish time of a logical task.
    pub fn finish_of(&self, orig: TaskId) -> f64 {
        *self.orig_finish.get(&orig).expect("unknown task")
    }
    pub fn start_of(&self, orig: TaskId) -> f64 {
        *self.orig_start.get(&orig).expect("unknown task")
    }
}

/// Which [`ReadyQueue`] implementation the engine runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueKind {
    /// Indexed bucket heap + early exit on class saturation (default).
    Incremental,
    /// Re-sort the whole ready set every event (pre-refactor baseline;
    /// identical results, `O(R log R)` per event).
    FullResort,
}

impl QueueKind {
    /// Parse the CLI / scenario-JSON spelling
    /// (`incremental` | `fullresort`).
    pub fn parse(s: &str) -> Result<QueueKind, String> {
        match s {
            "incremental" => Ok(QueueKind::Incremental),
            "fullresort" => Ok(QueueKind::FullResort),
            other => Err(format!("unknown queue kind `{other}` (incremental|fullresort)")),
        }
    }
}

#[derive(Debug, Clone)]
pub struct SimConfig {
    pub policy: Policy,
    pub max_events: usize,
    /// Ready-queue implementation (see [`QueueKind`]).
    pub queue: QueueKind,
    /// Allocation strategy per event (see [`AllocKind`]): component-wise
    /// repricing with memoized rates, or the whole-active-set oracle.
    pub alloc: AllocKind,
    /// Time-advance strategy (see [`HorizonKind`]): anchored progress
    /// with a finish-time heap, or the eager per-event integration
    /// sweep. Anchored is the default; eager is the bit-exact baseline
    /// the tolerance oracle pairs it with.
    pub horizon: HorizonKind,
    /// Worker threads for the component-sharded parallel fill (see the
    /// module docs, "Parallel event loop"): `1` is the serial oracle
    /// path; `N > 1` fans dirty-component refills across `N` workers
    /// with effects replayed in deterministic serial order, so results
    /// are bit-identical across thread counts. Only
    /// [`AllocKind::Components`] has shardable work; other configs run
    /// serially regardless. The default is `1`, overridable by the
    /// `MXDAG_TEST_THREADS` environment variable (read once per
    /// process) so CI can sweep the whole test suite through the
    /// parallel path without touching every construction site.
    pub threads: usize,
    /// Mid-simulation cluster dynamics (see `sim/dynamics.rs`): a
    /// time-sorted churn timeline folded into the event loop as its own
    /// event class. Empty (the default) means a frozen cluster — the
    /// engine then never copies capacities or footprints and every
    /// code path is bit-identical to the pre-dynamics behaviour.
    pub dynamics: DynTimeline,
    /// Fault-recovery policy (see `sim/recovery.rs`):
    /// [`RecoveryPolicy::FailFast`] (the default) aborts on the first
    /// terminally-stuck task exactly as the pre-recovery engine did —
    /// the bitwise oracle corner — while [`RecoveryPolicy::Retry`]
    /// retries crashed-host victims behind exponential-backoff gates
    /// and quarantines terminally-stuck jobs instead of failing the
    /// run.
    pub recovery: RecoveryPolicy,
    /// Open-loop stop bound (see `sim/openloop.rs`): `Some(t)` halts
    /// the run at simulated time `t` — the next streaming-arrival
    /// boundary — exporting the in-flight state as
    /// [`SimResult::stopped`] so the open-loop driver can re-seed the
    /// next epoch. Checked in the serial prologue alongside the
    /// dynamics cursor, so a stop is an ordinary event-class boundary:
    /// no task integrates across it. `None` (the default) leaves every
    /// code path bit-identical to the closed-mode engine.
    pub stop: Option<f64>,
    /// Carried failed-attempt counts for open-loop epoch chaining,
    /// aligned with `dag` tasks. Empty (the default) means a fresh
    /// budget for every task — the closed-mode behaviour. Only read
    /// under [`RecoveryPolicy::Retry`].
    pub attempts0: Vec<usize>,
}

/// Default worker-thread count: `1` (serial oracle), or the
/// `MXDAG_TEST_THREADS` override when set to an integer ≥ 1. Read once
/// per process so `SimConfig::default()` stays cheap on the hot path.
fn default_threads() -> usize {
    static THREADS: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *THREADS.get_or_init(|| {
        std::env::var("MXDAG_TEST_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&t| t >= 1)
            .unwrap_or(1)
    })
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            policy: Policy::fair(),
            max_events: 20_000_000,
            queue: QueueKind::Incremental,
            alloc: AllocKind::Components,
            horizon: HorizonKind::Anchored,
            threads: default_threads(),
            dynamics: DynTimeline::default(),
            recovery: RecoveryPolicy::FailFast,
            stop: None,
            attempts0: Vec::new(),
        }
    }
}

impl SimConfig {
    /// Apply a scenario-JSON `"engine"` object, the file-side mirror of
    /// the CLI's `--queue` / `--alloc` / `--horizon` / `--threads` /
    /// `--recovery` flags (which override it): `{"queue":
    /// "incremental|fullresort", "alloc": "components|wholeset",
    /// "horizon": "eager|anchored", "threads": N, "recovery":
    /// "failfast" | {"kind": "retry", ...}}`, every key
    /// optional. `threads` must be an integer ≥ 1 (0 is rejected — the
    /// serial oracle is `threads: 1`, not "no threads").
    pub fn apply_json(&mut self, j: &Json) -> Result<(), String> {
        let obj = j.as_obj().map_err(|e| e.to_string())?;
        for key in obj.keys() {
            if !matches!(
                key.as_str(),
                "queue" | "alloc" | "horizon" | "threads" | "recovery"
            ) {
                return Err(format!(
                    "unknown engine key `{key}` (queue|alloc|horizon|threads|recovery)"
                ));
            }
        }
        if let Some(v) = obj.get("queue") {
            self.queue = QueueKind::parse(v.as_str().map_err(|e| e.to_string())?)?;
        }
        if let Some(v) = obj.get("alloc") {
            self.alloc = AllocKind::parse(v.as_str().map_err(|e| e.to_string())?)?;
        }
        if let Some(v) = obj.get("horizon") {
            self.horizon = HorizonKind::parse(v.as_str().map_err(|e| e.to_string())?)?;
        }
        if let Some(v) = obj.get("threads") {
            let x = v.as_f64().map_err(|e| e.to_string())?;
            if x.fract() != 0.0 || x < 1.0 {
                return Err(format!("engine threads must be an integer >= 1, got {x}"));
            }
            self.threads = x as usize;
        }
        if let Some(v) = obj.get("recovery") {
            self.recovery = RecoveryPolicy::from_json(v)?;
        }
        Ok(())
    }
}

/// Max-min fill one priority level on residual capacity, with the
/// starvation pre-check (a task with any exhausted resource would be
/// frozen with rate 0 in the filler's first round — excluding it up
/// front leaves every other rate bit-for-bit unchanged). Leaves
/// `sub_idx` populated with the filled tasks — the whole-set walk reads
/// it to update its class-saturation counter for the early-exit test
/// (the component path walks all of a component's levels and needs no
/// saturation bookkeeping).
///
/// Starts are *deferred*: a not-yet-started task receiving its first
/// positive rate is appended to `starts` (at most once per event — a
/// task is filled by exactly one level of one walk) and the caller
/// stamps `started` / `trace` after the allocation step. `started` is
/// read-only here so fills can run on worker threads against shared
/// state.
#[allow(clippy::too_many_arguments)]
fn alloc_level_maxmin(
    level: &[usize],
    task_res: &[TaskRes],
    caps: &mut [f64],
    users: &mut [f64],
    ascr: &mut AllocScratch,
    sub_res: &mut Vec<TaskRes>,
    sub_idx: &mut Vec<usize>,
    sub_rates: &mut Vec<f64>,
    started: &[bool],
    starts: &mut Vec<usize>,
    rated: &mut Vec<(usize, f64)>,
) {
    sub_res.clear();
    sub_idx.clear();
    for &t in level {
        let starved = task_res[t].iter().any(|r| caps[r] <= ALLOC_EPS);
        if !starved {
            sub_idx.push(t);
            sub_res.push(task_res[t]);
        }
    }
    if sub_idx.is_empty() {
        return;
    }
    sub_rates.clear();
    sub_rates.resize(sub_idx.len(), 0.0);
    alloc::maxmin_fill_res_in(sub_res, caps, sub_rates, users, ascr);
    for (i, &t) in sub_idx.iter().enumerate() {
        let r = sub_rates[i];
        if r > EPS {
            if !started[t] {
                starts.push(t);
            }
            rated.push((t, r));
        }
    }
}

/// MADD-rate one SEBF unit (a coflow group or a singleton flow) on
/// residual capacity: all members finish at the same τ. `level` must be
/// in ascending task-id order — the canonical member order that keeps
/// every (queue, alloc) configuration bit-for-bit comparable. Leaves
/// `touched` populated with the unit's resources (the whole-set walk
/// reads it for saturation marking); `load_touched` is reset on return.
/// Starts are deferred into `starts` exactly as in
/// [`alloc_level_maxmin`].
#[allow(clippy::too_many_arguments)]
fn madd_level(
    level: &[usize],
    remaining: &[f64],
    task_res: &[TaskRes],
    caps: &mut [f64],
    load: &mut [f64],
    load_touched: &mut [bool],
    touched: &mut Vec<usize>,
    started: &[bool],
    starts: &mut Vec<usize>,
    rated: &mut Vec<(usize, f64)>,
) {
    let mut tau = 0.0f64;
    touched.clear();
    for &t in level {
        tau = tau.max(remaining[t]); // rate ≤ 1 per flow
        for r in task_res[t].iter() {
            if !load_touched[r] {
                load_touched[r] = true;
                load[r] = 0.0;
                touched.push(r);
            }
            load[r] += remaining[t];
        }
    }
    for &r in touched.iter() {
        if caps[r] <= ALLOC_EPS {
            tau = f64::INFINITY;
        } else {
            tau = tau.max(load[r] / caps[r]);
        }
    }
    if tau.is_finite() && tau > ALLOC_EPS {
        for &t in level {
            let rate = remaining[t] / tau;
            if rate > EPS {
                if !started[t] {
                    starts.push(t);
                }
                rated.push((t, rate));
            }
            for r in task_res[t].iter() {
                caps[r] = (caps[r] - rate).max(0.0);
            }
        }
    }
    for &r in touched.iter() {
        load_touched[r] = false;
    }
}

/// Refill one (freshly rebuilt) contention component: sort its members
/// into the same key levels the ready queues would expose, then walk
/// them high → low allocating on residual capacity. The rates are
/// *appended* to `out_rated` (serial callers clear the memoized slot
/// first; parallel workers pack many components' rates into one arena
/// and slice it by spans) — the component's memoized allocation. The
/// caller must have reset the component's resources to full capacity
/// first — only this component's tasks draw on them, so the
/// per-resource arithmetic replays exactly what the whole-set walk
/// would do. Everything shared is `&` (read-only); all mutation lands
/// in caller-owned scratch/output buffers, which is what lets the
/// parallel path run this concurrently per component.
#[allow(clippy::too_many_arguments)]
fn fill_component(
    sorted: &mut Vec<usize>,
    members: &[usize],
    key_of: &[PrioKey],
    coflow_on: bool,
    is_flow: &[bool],
    task_res: &[TaskRes],
    remaining: &[f64],
    caps: &mut [f64],
    users: &mut [f64],
    ascr: &mut AllocScratch,
    sub_res: &mut Vec<TaskRes>,
    sub_idx: &mut Vec<usize>,
    sub_rates: &mut Vec<f64>,
    started: &[bool],
    starts: &mut Vec<usize>,
    out_rated: &mut Vec<(usize, f64)>,
    load: &mut [f64],
    load_touched: &mut [bool],
    touched: &mut Vec<usize>,
) {
    sorted.clear();
    sorted.extend_from_slice(members);
    // the queue's level partition: descending key, ascending id within a
    // level (the canonical member order MADD requires)
    sorted.sort_unstable_by(|&a, &b| key_of[b].cmp(&key_of[a]).then_with(|| a.cmp(&b)));
    let mut i = 0;
    while i < sorted.len() {
        let key = key_of[sorted[i]];
        let mut j = i + 1;
        while j < sorted.len() && key_of[sorted[j]] == key {
            j += 1;
        }
        if coflow_on && is_flow[sorted[i]] {
            madd_level(
                &sorted[i..j],
                remaining,
                task_res,
                caps,
                load,
                load_touched,
                touched,
                started,
                starts,
                out_rated,
            );
        } else {
            alloc_level_maxmin(
                &sorted[i..j],
                task_res,
                caps,
                users,
                ascr,
                sub_res,
                sub_idx,
                sub_rates,
                started,
                starts,
                out_rated,
            );
        }
        i = j;
    }
}

/// SEBF bound of a single ungrouped flow: its completion lower bound at
/// full capacity, `max(rem, max_r rem / caps0[r])`.
fn sebf_bound_single(t: usize, remaining: &[f64], task_res: &[TaskRes], caps0: &[f64]) -> f64 {
    let rem = remaining[t];
    let mut bnd = rem;
    for r in task_res[t].iter() {
        if caps0[r] <= ALLOC_EPS {
            bnd = f64::INFINITY;
        } else {
            bnd = bnd.max(rem / caps0[r]);
        }
    }
    bnd
}

/// SEBF bound of a coflow group over its currently *queued, flow*
/// members (a coflow tag on a compute task gates readiness but never
/// contributes network load): `max(max_rem, max_r load_r / caps0[r])` —
/// narrow fabric links correctly dominate wide NICs.
/// `load`/`load_touched` are caller scratch (left reset on return).
#[allow(clippy::too_many_arguments)]
fn sebf_bound_group(
    mem: &[usize],
    queued: &[bool],
    is_flow: &[bool],
    remaining: &[f64],
    task_res: &[TaskRes],
    caps0: &[f64],
    load: &mut [f64],
    load_touched: &mut [bool],
    touched: &mut Vec<usize>,
) -> f64 {
    let mut max_rem = 0.0f64;
    touched.clear();
    for &t in mem {
        if !queued[t] || !is_flow[t] {
            continue;
        }
        max_rem = max_rem.max(remaining[t]);
        for r in task_res[t].iter() {
            if !load_touched[r] {
                load_touched[r] = true;
                load[r] = 0.0;
                touched.push(r);
            }
            load[r] += remaining[t];
        }
    }
    let mut bnd = max_rem;
    for &r in touched.iter() {
        if caps0[r] <= ALLOC_EPS {
            bnd = f64::INFINITY;
        } else {
            bnd = bnd.max(load[r] / caps0[r]);
        }
    }
    for &r in touched.iter() {
        load_touched[r] = false;
    }
    bnd
}

/// Minimum total member count (summed over an epoch's freshly rebuilt
/// components) before the refill fan-out spawns worker threads. Below
/// this the epoch runs inline on the coordinating thread through the
/// *same* code path (one worker state), so the choice is pure wall
/// clock: a scoped spawn costs tens of microseconds while a typical
/// small-event refill costs ~1 µs. The threshold is deterministic —
/// it depends only on the epoch's dirty set, never on timing — so it
/// cannot perturb results.
const PAR_FILL_MIN_TASKS: usize = 256;

/// Per-worker state for the component-sharded parallel fill (module
/// docs, "Parallel event loop"). A worker owns private scratch
/// (capacities, keys, allocation buffers) plus append-only output
/// arenas; the coordinator slices the arenas by the spans each refill
/// returns and replays them in component order. Workers live in the
/// [`SimScratch`] so they stay warm across epochs and runs.
#[derive(Debug, Default)]
struct EngineWorker {
    /// This worker's index in the fan-out slice, stamped by the
    /// coordinator before each epoch so a refill can record which
    /// arenas its spans point into.
    id: usize,
    /// Private residual capacities, seeded per component from `caps0`
    /// over the component's (exact, disjoint) resource set.
    wcaps: Vec<f64>,
    /// Private key view (anchored+coflow only): global `key_of` seeded
    /// for the component's members, then locally re-keyed from
    /// re-anchored bytes. The refreshed keys are recorded in
    /// `keys_out` for the coordinator to apply to the real queues.
    wkeys: Vec<PrioKey>,
    users: Vec<f64>,
    ascr: AllocScratch,
    load: Vec<f64>,
    load_touched: Vec<bool>,
    touched: Vec<usize>,
    sorted: Vec<usize>,
    grp_seen: Vec<bool>,
    grp_list: Vec<usize>,
    sub_res: Vec<TaskRes>,
    sub_idx: Vec<usize>,
    sub_rates: Vec<f64>,
    // append-only output arenas, sliced by `FillSpans`
    keys_out: Vec<(usize, PrioKey)>,
    rated_out: Vec<(usize, f64)>,
    starts_out: Vec<usize>,
    caps_out: Vec<(usize, f64)>,
}

impl EngineWorker {
    /// Grow the private per-resource / per-group buffers to this run's
    /// arena shape (grow-only, so warm workers allocate nothing in
    /// steady state). `load_touched` / `grp_seen` keep their all-false
    /// invariant: new slots are false and the fill algorithms reset
    /// every slot they set.
    fn ensure(&mut self, n_res: usize, n_groups: usize) {
        if self.wcaps.len() < n_res {
            self.wcaps.resize(n_res, 0.0);
            self.users.resize(n_res, 0.0);
            self.load.resize(n_res, 0.0);
            self.load_touched.resize(n_res, false);
        }
        if self.grp_seen.len() < n_groups {
            self.grp_seen.resize(n_groups, false);
        }
    }
}

/// One parallel refill's result: which worker ran it plus half-open
/// ranges into that worker's output arenas. Replaying the ranges in
/// item (= component) order reproduces the serial path's effect order
/// exactly.
#[derive(Clone, Copy)]
struct FillSpans {
    worker: usize,
    keys: (usize, usize),
    rated: (usize, usize),
    starts: (usize, usize),
    caps: (usize, usize),
}

/// Reusable engine state for batched plan evaluation: the ready queues
/// (both kinds, kept warm), the contention partition ([`CompSet`]), the
/// finish-time heap ([`FinHeap`]), the allocation scratch
/// ([`AllocScratch`]) and every per-task / per-resource / per-group
/// buffer the event loop touches. [`simulate_in`] *resets* (never
/// reallocates) this state between runs, so scoring plan `k+1` of a
/// sweep costs only the simulation itself — a warm scratch allocates
/// nothing in steady state. One scratch serves DAGs and clusters of any
/// size (buffers grow to high-water marks). It is plain mutable state
/// with no cross-run semantics: a simulation's result is bit-for-bit
/// independent of what the scratch ran before (asserted by the
/// `scratch_reuse_is_bit_identical` test and, transitively, by the
/// parallel-what-if equivalence oracle).
#[derive(Debug, Default)]
pub struct SimScratch {
    rq_cpu_bucket: BucketQueue,
    rq_net_bucket: BucketQueue,
    rq_cpu_resort: ResortQueue,
    rq_net_resort: ResortQueue,
    comps: CompSet,
    fins: FinHeap,
    ascr: AllocScratch,
    // per-task
    remaining: Vec<f64>,
    indeg: Vec<usize>,
    done: Vec<bool>,
    started: Vec<bool>,
    seq: Vec<u64>,
    queued: Vec<bool>,
    key_of: Vec<PrioKey>,
    rate_of: Vec<f64>,
    anchor_t: Vec<f64>,
    group_of: Vec<Option<usize>>,
    virt: Vec<Option<usize>>,
    // per-resource
    caps: Vec<f64>,
    users: Vec<f64>,
    sat_mark: Vec<bool>,
    load: Vec<f64>,
    load_touched: Vec<bool>,
    // per-coflow-group
    members: Vec<Vec<usize>>,
    group_pending: Vec<usize>,
    group_open: Vec<bool>,
    parked: Vec<Vec<usize>>,
    group_dirty: Vec<bool>,
    grp_seen: Vec<bool>,
    // heaps / maps
    arrivals: BinaryHeap<Reverse<(u64, usize)>>,
    gates: BinaryHeap<Reverse<(u64, u64, usize)>>,
    fifo_prio_orig: BTreeMap<TaskId, i64>,
    comp_rated: Vec<Vec<(usize, f64)>>,
    // worklists
    comp_sorted: Vec<usize>,
    new_comps: Vec<usize>,
    live_scratch: Vec<usize>,
    near_done: Vec<usize>,
    grp_list: Vec<usize>,
    sub_res: Vec<TaskRes>,
    sub_idx: Vec<usize>,
    sub_rates: Vec<f64>,
    rated: Vec<(usize, f64)>,
    completed: Vec<usize>,
    touched: Vec<usize>,
    grp_scratch: Vec<usize>,
    dirty_groups: Vec<usize>,
    dirty_singles: Vec<usize>,
    heap_removed: Vec<usize>,
    heap_inserts: Vec<(usize, f64)>,
    // deferred starts: tasks receiving their first positive rate this
    // event, stamped into `started`/`trace` right after step 3
    starts: Vec<usize>,
    // parallel event loop: warm per-worker states and the epoch's
    // fresh-component worklist (see "Parallel event loop" module docs)
    workers: Vec<EngineWorker>,
    fill_list: Vec<usize>,
    // footprint buffers for the `simulate_in` convenience path
    fp_task_res: Vec<TaskRes>,
    fp_is_flow: Vec<bool>,
    // cluster dynamics (`sim/dynamics.rs`): timeline cursor + factor
    // state, the engine-owned effective capacities / footprints, the
    // touched-slot marks of the event being applied, and the surviving
    // trunk list for `ParallelFabrics` reroute. All empty (and never
    // touched) while the run's timeline is empty.
    dyn_state: DynState,
    dyn_caps: Vec<f64>,
    dyn_task_res: Vec<TaskRes>,
    dyn_touched: Vec<bool>,
    dyn_touched_list: Vec<usize>,
    dyn_alive: Vec<usize>,
    // fault recovery (`sim/recovery.rs`): per-task failed-attempt
    // counters and retry gates, per-task quarantine marks, per-job
    // recorded outcomes / stuck reasons, and the crashed-host list the
    // dynamics cursor reports into. All empty (and never touched)
    // under `RecoveryPolicy::FailFast`.
    attempts: Vec<usize>,
    retry_gate: Vec<f64>,
    quarantined: Vec<bool>,
    job_down: Vec<Option<JobOutcome>>,
    job_stuck: Vec<Option<StuckReason>>,
    failed_hosts: Vec<usize>,
}

impl SimScratch {
    /// Total reserved slots across the scratch's major per-task,
    /// per-resource and per-group buffers (capacities, not lengths) —
    /// the memory high-water mark of every run this scratch has served.
    /// The open-loop bounded-memory oracle asserts this plateaus over
    /// an unbounded job stream: epoch GC compacts departed jobs out of
    /// each epoch's DAG, so the scratch only ever sizes to the largest
    /// *live* set, never to the stream total.
    pub fn footprint(&self) -> usize {
        self.remaining.capacity()
            + self.indeg.capacity()
            + self.done.capacity()
            + self.started.capacity()
            + self.seq.capacity()
            + self.queued.capacity()
            + self.key_of.capacity()
            + self.rate_of.capacity()
            + self.anchor_t.capacity()
            + self.group_of.capacity()
            + self.virt.capacity()
            + self.caps.capacity()
            + self.users.capacity()
            + self.sat_mark.capacity()
            + self.load.capacity()
            + self.members.iter().map(|v| v.capacity()).sum::<usize>()
            + self.members.capacity()
            + self.parked.iter().map(|v| v.capacity()).sum::<usize>()
            + self.parked.capacity()
            + self.comp_rated.iter().map(|v| v.capacity()).sum::<usize>()
            + self.comp_rated.capacity()
            + self.arrivals.capacity()
            + self.gates.capacity()
            + self.rated.capacity()
            + self.completed.capacity()
            + self.fp_task_res.capacity()
            + self.fp_is_flow.capacity()
            + self.dyn_caps.capacity()
            + self.dyn_task_res.capacity()
            + self.attempts.capacity()
            + self.retry_gate.capacity()
            + self.quarantined.capacity()
            + self.job_down.capacity()
            + self.comps.capacity()
            + self.fins.capacity()
    }
}

/// Truncate/grow a nested scratch vector to `n` cleared inner buffers,
/// keeping inner capacity wherever the shape matches across runs.
fn reset_nested<T>(v: &mut Vec<Vec<T>>, n: usize) {
    v.truncate(n);
    for inner in v.iter_mut() {
        inner.clear();
    }
    while v.len() < n {
        v.push(Vec::new());
    }
}

/// Run the fluid simulation to completion (cold path: throwaway
/// scratch). Sweeps that score many plans reuse one [`SimScratch`] via
/// [`simulate_in`] instead.
pub fn simulate(dag: &SimDag, cluster: &Cluster, cfg: &SimConfig) -> Result<SimResult, SimError> {
    simulate_in(dag, cluster, cfg, &mut SimScratch::default())
}

/// As [`simulate`], but reusing `scratch` across calls (reset, not
/// reallocated). Resource footprints and arena capacities are
/// recomputed per run into scratch-owned buffers; callers that can
/// cache them per `(expansion, cluster)` — the evaluation context at
/// the sched/sim boundary — call [`simulate_with_footprints`] directly.
pub fn simulate_in(
    dag: &SimDag,
    cluster: &Cluster,
    cfg: &SimConfig,
    scratch: &mut SimScratch,
) -> Result<SimResult, SimError> {
    let mut tr_buf = std::mem::take(&mut scratch.fp_task_res);
    let mut if_buf = std::mem::take(&mut scratch.fp_is_flow);
    tr_buf.clear();
    if_buf.clear();
    for t in dag.tasks.iter() {
        tr_buf.push(cluster.task_res(&t.kind));
        if_buf.push(t.kind.is_flow());
    }
    let caps_v = cluster.capacities();
    let r = simulate_with_footprints(dag, cluster, cfg, &tr_buf, &if_buf, &caps_v, scratch);
    scratch.fp_task_res = tr_buf;
    scratch.fp_is_flow = if_buf;
    r
}

/// The engine core behind [`simulate`] / [`simulate_in`]: the caller
/// supplies the per-chunk resource footprints (`task_res`, computed by
/// [`Cluster::task_res`] for this cluster), the per-chunk flow flags
/// and the arena capacities ([`Cluster::capacities`]). All three are
/// pure functions of `(dag, cluster)`, which is what lets evaluation
/// contexts cache them across plan evaluations. Passing footprints
/// computed for a *different* cluster or expansion is a logic error
/// (debug-asserted on length only).
///
/// On success the scratch keeps its buffers warm for the next run; on
/// an error return some buffers are left drained — still valid (the
/// next reset rebuilds them), just cold.
#[allow(clippy::too_many_arguments)]
pub fn simulate_with_footprints(
    dag: &SimDag,
    cluster: &Cluster,
    cfg: &SimConfig,
    task_res_in: &[TaskRes],
    is_flow_v: &[bool],
    caps0_in: &[f64],
    scratch: &mut SimScratch,
) -> Result<SimResult, SimError> {
    let n = dag.len();
    debug_assert_eq!(task_res_in.len(), n, "footprints must cover every task");
    debug_assert_eq!(is_flow_v.len(), n, "flow flags must cover every task");
    let n_hosts = cluster.n_hosts();
    let n_res = caps0_in.len();

    // Resource classes are disjoint: computes draw only on cores
    // (`res_core`), flows only on NICs + fabric links. Count the
    // positive-capacity resources of each class once — when a level walk
    // has saturated all of them, every remaining level allocates zero.
    // (Recounted in dynamics step 0 whenever churn rescales a capacity.)
    let mut n_cores_pos = 0usize;
    let mut n_net_pos = 0usize;
    for (r, &c) in caps0_in.iter().enumerate() {
        if c > ALLOC_EPS {
            if super::spec::is_core_slot(r, n_hosts) {
                n_cores_pos += 1;
            } else {
                n_net_pos += 1;
            }
        }
    }

    // Cluster dynamics (`sim/dynamics.rs`). With an empty timeline the
    // engine copies nothing: the per-iteration `caps0` / `task_res`
    // bindings below alias the caller's slices directly and every code
    // path is bit-identical to a frozen cluster. With a non-empty
    // timeline the engine owns mutable copies (scratch-backed, warm
    // across runs) that step 0 rescales / reroutes in place. The
    // timeline must be valid for `cluster` (CLI and what-if layers
    // validate; direct callers are debug-asserted here).
    let dyn_on = !cfg.dynamics.is_empty();
    let mut dyn_state = std::mem::take(&mut scratch.dyn_state);
    let mut dyn_caps = std::mem::take(&mut scratch.dyn_caps);
    let mut dyn_task_res = std::mem::take(&mut scratch.dyn_task_res);
    let mut dyn_touched = std::mem::take(&mut scratch.dyn_touched);
    let mut dyn_touched_list = std::mem::take(&mut scratch.dyn_touched_list);
    let mut dyn_alive = std::mem::take(&mut scratch.dyn_alive);
    if dyn_on {
        debug_assert!(
            cfg.dynamics.validate(cluster).is_ok(),
            "invalid dynamics timeline (validate against the cluster before simulating)"
        );
        dyn_state.reset(n_res, n_hosts);
        dyn_caps.clear();
        dyn_caps.extend_from_slice(caps0_in);
        dyn_task_res.clear();
        dyn_task_res.extend_from_slice(task_res_in);
        dyn_touched.clear();
        dyn_touched.resize(n_res, false);
        dyn_touched_list.clear();
        dyn_alive.clear();
    }

    // Fault recovery (`sim/recovery.rs`). Like the dynamics buffers,
    // the retry bookkeeping is live only under `RecoveryPolicy::Retry`;
    // FailFast initializes none of it and every code path below is
    // bit-identical to the recovery-free engine.
    let (retry_on, max_attempts, backoff) = match cfg.recovery {
        RecoveryPolicy::FailFast => (false, 0usize, 0.0f64),
        RecoveryPolicy::Retry { max_attempts, backoff } => (true, max_attempts, backoff),
    };
    let n_jobs = dag.n_jobs();
    let mut attempts = std::mem::take(&mut scratch.attempts);
    let mut retry_gate = std::mem::take(&mut scratch.retry_gate);
    let mut quarantined = std::mem::take(&mut scratch.quarantined);
    let mut job_down = std::mem::take(&mut scratch.job_down);
    let mut job_stuck = std::mem::take(&mut scratch.job_stuck);
    let mut failed_hosts = std::mem::take(&mut scratch.failed_hosts);
    failed_hosts.clear();
    if retry_on {
        debug_assert!(cfg.recovery.validate().is_ok(), "invalid recovery policy");
        attempts.clear();
        if cfg.attempts0.is_empty() {
            attempts.resize(n, 0);
        } else {
            // open-loop epoch chaining: spent budgets survive the epoch
            // boundary so a crash-looping task still exhausts
            debug_assert_eq!(cfg.attempts0.len(), n, "attempts0 must cover every task");
            attempts.extend_from_slice(&cfg.attempts0);
        }
        retry_gate.clear();
        retry_gate.resize(n, 0.0);
        quarantined.clear();
        quarantined.resize(n, false);
        job_down.clear();
        job_down.resize(n_jobs, None);
        job_stuck.clear();
        job_stuck.resize(n_jobs, None);
    }
    let mut retries = 0usize;
    let mut lost_work = 0.0f64;

    let mut remaining = std::mem::take(&mut scratch.remaining);
    remaining.clear();
    remaining.extend(dag.tasks.iter().map(|t| t.size));
    let mut indeg = std::mem::take(&mut scratch.indeg);
    indeg.clear();
    indeg.extend(dag.preds.iter().map(|p| p.len()));
    let mut done = std::mem::take(&mut scratch.done);
    done.clear();
    done.resize(n, false);
    let mut started = std::mem::take(&mut scratch.started);
    started.clear();
    started.resize(n, false);
    // the trace is the run's *output* (moved into the result), so it is
    // the one per-task buffer allocated fresh each run
    let mut trace = vec![TaskTrace { start: f64::NAN, finish: f64::NAN }; n];
    let mut n_done = 0usize;
    let mut now = 0.0f64;
    let mut events = 0usize;
    // open-loop stop bound: set when the loop breaks at `cfg.stop`
    // instead of draining the DAG (never set in closed mode)
    let mut stopped = false;

    // FIFO queue positions, assigned per *logical* task at its first
    // chunk's readiness. Semantics of a blocking send queue + concurrent
    // pipelined streams: single-chunk tasks get strictly increasing
    // positions (serialized even when ready simultaneously — the order
    // the application issued them), while multi-chunk (pipelined) tasks
    // ready at the same instant share one position and therefore share
    // bandwidth fairly (concurrent streams). This is what makes Fig. 3's
    // baseline serialize f1 before f3 but lets case-3's pipelined f1/f3
    // contend.
    //
    // Encoding: a global slot counter. Assignments happen in live order
    // (see `seq` below), so time ordering falls out of the counter;
    // `fifo_base` jumps past every slot of earlier instants so tasks
    // from different instants can never share a priority level.
    let use_fifo = cfg.policy.cpu == CpuPolicy::Fifo || cfg.policy.net == NetPolicy::Fifo;
    let mut fifo_prio_orig = std::mem::take(&mut scratch.fifo_prio_orig);
    fifo_prio_orig.clear();
    let mut fifo_tie_time: i64 = i64::MIN;
    let mut fifo_tie_count: i64 = 0;
    let mut fifo_base: i64 = 0;
    let mut fifo_max: i64 = 0;

    // Coflow state (NetPolicy::Coflow only): group membership with dense
    // ids in ascending raw-id order — the SEBF tie order is (groups by
    // raw id, then singleton flows in live order), matching the old
    // stable-sort path. `group_pending[g]` counts members whose
    // dependencies are still unmet; the all-or-nothing barrier opens
    // when it reaches zero, releasing any parked members.
    let coflow_on = cfg.policy.net == NetPolicy::Coflow;
    let mut group_of = std::mem::take(&mut scratch.group_of);
    group_of.clear();
    group_of.resize(n, None);
    let mut members = std::mem::take(&mut scratch.members);
    if coflow_on {
        let mut dense: BTreeMap<usize, usize> = BTreeMap::new();
        for t in dag.tasks.iter() {
            if let Some(g) = t.coflow {
                dense.entry(g).or_insert(0);
            }
        }
        for (i, (_, v)) in dense.iter_mut().enumerate() {
            *v = i;
        }
        reset_nested(&mut members, dense.len());
        for (i, t) in dag.tasks.iter().enumerate() {
            if let Some(g) = t.coflow {
                let gi = dense[&g];
                members[gi].push(i);
                group_of[i] = Some(gi);
            }
        }
    } else {
        reset_nested(&mut members, 0);
    }
    let n_groups = members.len();
    let mut group_pending = std::mem::take(&mut scratch.group_pending);
    group_pending.clear();
    group_pending.extend(members.iter().map(|m| m.len()));
    let mut group_open = std::mem::take(&mut scratch.group_open);
    group_open.clear();
    group_open.resize(n_groups, false);
    let mut parked = std::mem::take(&mut scratch.parked);
    reset_nested(&mut parked, n_groups);

    // Live-entry sequence numbers: the order tasks entered the ready
    // ("live") set. Arrival processing, FIFO slot assignment and
    // same-instant completion handling all follow this order, which is
    // exactly the old engine's linear live-vector scan order.
    let mut seq = std::mem::take(&mut scratch.seq);
    seq.clear();
    seq.resize(n, 0);
    let mut next_seq: u64 = 0;
    // Worklist of tasks whose dependencies are met, awaiting
    // classification (gate check → gate heap; barrier check → parked;
    // otherwise enqueue or instant-complete), drained in seq order.
    let mut arrivals = std::mem::take(&mut scratch.arrivals);
    arrivals.clear();
    // Gate min-heap: (gate time bits, live seq, task).
    let mut gates = std::mem::take(&mut scratch.gates);
    gates.clear();

    // both queue kinds stay warm in the scratch; `cfg.queue` picks the
    // pair this run dispatches through
    let mut q_cpu_bucket = std::mem::take(&mut scratch.rq_cpu_bucket);
    let mut q_net_bucket = std::mem::take(&mut scratch.rq_net_bucket);
    let mut q_cpu_resort = std::mem::take(&mut scratch.rq_cpu_resort);
    let mut q_net_resort = std::mem::take(&mut scratch.rq_net_resort);
    q_cpu_bucket.reset(n);
    q_net_bucket.reset(n);
    q_cpu_resort.reset(n);
    q_net_resort.reset(n);
    let (rq_cpu, rq_net): (&mut dyn ReadyQueue, &mut dyn ReadyQueue) = match cfg.queue {
        QueueKind::Incremental => (&mut q_cpu_bucket, &mut q_net_bucket),
        QueueKind::FullResort => (&mut q_cpu_resort, &mut q_net_resort),
    };
    let mut queued = std::mem::take(&mut scratch.queued);
    queued.clear();
    queued.resize(n, false);

    // Contention components (AllocKind::Components): incremental
    // partition of the queued tasks over the flat arena. Coflow groups
    // are linked through one virtual resource per group (id n_res + gi)
    // so MADD-coupled flows are never split across components. The
    // engine tracks each task's current queue key so a dirty component
    // can replay the queues' level partition locally.
    let comps_on = cfg.alloc == AllocKind::Components;
    let mut comps = std::mem::take(&mut scratch.comps);
    comps.reset(n, n_res + n_groups);
    let mut virt = std::mem::take(&mut scratch.virt);
    virt.clear();
    virt.extend((0..n).map(|t| group_of[t].map(|gi| n_res + gi)));
    let mut key_of = std::mem::take(&mut scratch.key_of);
    key_of.clear();
    key_of.resize(n, PrioKey::LEVEL);
    // per-component memoized allocation, indexed by component slot
    // (stale inner content is overwritten by `fill_component` before a
    // slot can be read; clearing keeps dumps comprehensible)
    let mut comp_rated = std::mem::take(&mut scratch.comp_rated);
    for v in comp_rated.iter_mut() {
        v.clear();
    }
    let mut comp_sorted = std::mem::take(&mut scratch.comp_sorted);
    comp_sorted.clear();
    let mut new_comps = std::mem::take(&mut scratch.new_comps);
    new_comps.clear();
    let mut live_scratch = std::mem::take(&mut scratch.live_scratch);
    live_scratch.clear();
    let mut ascr = std::mem::take(&mut scratch.ascr);

    // Anchored time advance (HorizonKind::Anchored): a rated task's
    // `remaining` holds its bytes *as of* `anchor_t`, its current rate
    // lives in `rate_of`, and its predicted absolute finish time sits in
    // the `fins` min-heap. Materialization (`rem -= rate · (now −
    // anchor)`) happens lazily: for a dirty component's members at
    // refill, for every previously-rated task under whole-set
    // allocation, and at completion (remaining := 0). Unrated tasks
    // carry exact bytes (rate 0 ⇒ nothing to integrate), so
    // `remaining[t]` is always exact for tasks outside the heap.
    let anchored = cfg.horizon == HorizonKind::Anchored;
    let mut rate_of = std::mem::take(&mut scratch.rate_of);
    rate_of.clear();
    rate_of.resize(n, 0.0);
    let mut anchor_t = std::mem::take(&mut scratch.anchor_t);
    anchor_t.clear();
    anchor_t.resize(n, 0.0);
    let mut fins = std::mem::take(&mut scratch.fins);
    fins.reset(n);
    // tasks whose materialized bytes crossed the completion epsilon
    // while unrated — re-armed with an immediate finish after refill so
    // they cannot strand in a quiescent component (see step 3)
    let mut near_done = std::mem::take(&mut scratch.near_done);
    near_done.clear();
    // scratch for the per-component SEBF key refresh
    let mut grp_seen = std::mem::take(&mut scratch.grp_seen);
    grp_seen.clear();
    grp_seen.resize(n_groups, false);
    let mut grp_list = std::mem::take(&mut scratch.grp_list);
    grp_list.clear();
    // staging for the batch `FinHeap` rebuild (dominant dirty component)
    let mut heap_removed = std::mem::take(&mut scratch.heap_removed);
    heap_removed.clear();
    let mut heap_inserts = std::mem::take(&mut scratch.heap_inserts);
    heap_inserts.clear();
    // deferred starts (applied after step 3 each event)
    let mut starts = std::mem::take(&mut scratch.starts);
    starts.clear();

    // Parallel event loop (module docs): fan dirty-component refills
    // across `cfg.threads` warm workers. Shardable work only exists
    // under component-wise allocation; `threads <= 1` keeps the serial
    // oracle path.
    let par_on = comps_on && cfg.threads > 1;
    let mut workers = std::mem::take(&mut scratch.workers);
    if par_on && workers.len() < cfg.threads {
        workers.resize_with(cfg.threads, EngineWorker::default);
    }
    let mut fill_list = std::mem::take(&mut scratch.fill_list);
    fill_list.clear();

    // A task's dependencies are met: record its live order, hand it to
    // the arrival worklist, and update its coflow barrier.
    macro_rules! on_ready {
        ($t:expr) => {{
            let t_ = $t;
            seq[t_] = next_seq;
            next_seq += 1;
            arrivals.push(Reverse((seq[t_], t_)));
            if coflow_on {
                if let Some(gi) = group_of[t_] {
                    group_pending[gi] -= 1;
                    if group_pending[gi] == 0 {
                        group_open[gi] = true;
                        for &m in parked[gi].iter() {
                            arrivals.push(Reverse((seq[m], m)));
                        }
                        parked[gi].clear();
                    }
                }
            }
        }};
    }

    for t in 0..n {
        if indeg[t] == 0 {
            on_ready!(t);
        }
    }

    // allocation scratch; under component-wise allocation `caps` is
    // *persistent* residual state (a component's slice is reset to full
    // capacity exactly when that component refills)
    let mut users_scratch = std::mem::take(&mut scratch.users);
    users_scratch.clear();
    users_scratch.resize(n_res, 0.0);
    let mut caps = std::mem::take(&mut scratch.caps);
    caps.clear();
    caps.extend_from_slice(caps0_in);
    let mut sub_res = std::mem::take(&mut scratch.sub_res);
    sub_res.clear();
    let mut sub_idx = std::mem::take(&mut scratch.sub_idx);
    sub_idx.clear();
    let mut sub_rates = std::mem::take(&mut scratch.sub_rates);
    sub_rates.clear();
    let mut rated = std::mem::take(&mut scratch.rated);
    rated.clear();
    let mut completed = std::mem::take(&mut scratch.completed);
    completed.clear();
    let mut sat_mark = std::mem::take(&mut scratch.sat_mark);
    sat_mark.clear();
    sat_mark.resize(n_res, false);
    let mut load = std::mem::take(&mut scratch.load);
    load.clear();
    load.resize(n_res, 0.0);
    let mut load_touched = std::mem::take(&mut scratch.load_touched);
    load_touched.clear();
    load_touched.resize(n_res, false);
    let mut touched = std::mem::take(&mut scratch.touched);
    touched.clear();
    let mut grp_scratch = std::mem::take(&mut scratch.grp_scratch);
    grp_scratch.clear();
    // SEBF key invalidation worklists
    let mut dirty_groups = std::mem::take(&mut scratch.dirty_groups);
    dirty_groups.clear();
    let mut group_dirty = std::mem::take(&mut scratch.group_dirty);
    group_dirty.clear();
    group_dirty.resize(n_groups, false);
    let mut dirty_singles = std::mem::take(&mut scratch.dirty_singles);
    dirty_singles.clear();

    // Fault-recovery machinery (`sim/recovery.rs`); every call site is
    // guarded by `retry_on`, so FailFast runs stay bit-identical to the
    // recovery-free engine.
    //
    // Effective gate of a task: its plan gate, or the retry-backoff
    // gate when a crashed attempt re-gated it later. For a retried task
    // the backoff gate always dominates (the task was admitted once, so
    // `retry_gate >= now-at-kill >= plan gate`), which keeps the gate
    // heap's pushed keys consistent with this accessor.
    macro_rules! eff_gate {
        ($t:expr) => {{
            let t_: usize = $t;
            if retry_on {
                dag.tasks[t_].gate.max(retry_gate[t_])
            } else {
                dag.tasks[t_].gate
            }
        }};
    }

    // Quarantine job `$j` with outcome `$out` (first writer wins):
    // remove every unfinished task of the job in task-id order, marking
    // it done and releasing its queue / component / finish-heap /
    // coflow state through the same protocol completions use. Held
    // capacity is released by the component dirty protocol —
    // `comps.remove` dirties the victim's component, whose stale
    // resource list still covers the victim's slots at the next refill
    // (the reroute path established this invariant). Dummy tasks
    // (shared structure) are left to complete through the normal
    // cascade; surviving dependents outside the job are released as if
    // the quarantined task had finished.
    macro_rules! quarantine_job {
        ($j:expr, $out:expr) => {{
            let j_: usize = $j;
            if job_down[j_].is_none() {
                job_down[j_] = Some($out);
                for t_q in 0..n {
                    if dag.job(t_q) == j_ && !matches!(dag.tasks[t_q].kind, SimKind::Dummy) {
                        quarantined[t_q] = true;
                    }
                }
                for t_q in 0..n {
                    if !quarantined[t_q] || dag.job(t_q) != j_ || done[t_q] {
                        continue;
                    }
                    done[t_q] = true;
                    n_done += 1;
                    if queued[t_q] {
                        queued[t_q] = false;
                        if comps_on {
                            comps.remove(t_q);
                        }
                        if anchored {
                            fins.remove(t_q);
                        }
                        rate_of[t_q] = 0.0;
                        if is_flow_v[t_q] {
                            rq_net.remove(t_q);
                        } else {
                            rq_cpu.remove(t_q);
                        }
                    }
                    if coflow_on {
                        if let Some(gi) = group_of[t_q] {
                            parked[gi].retain(|&m| m != t_q);
                            if is_flow_v[t_q] && !group_dirty[gi] {
                                group_dirty[gi] = true;
                                dirty_groups.push(gi);
                            }
                            if indeg[t_q] > 0 {
                                // never became ready, so the barrier
                                // still counts it — release it so the
                                // group's survivors are not parked
                                // forever
                                group_pending[gi] -= 1;
                                if group_pending[gi] == 0 {
                                    group_open[gi] = true;
                                    for &m in parked[gi].iter() {
                                        arrivals.push(Reverse((seq[m], m)));
                                    }
                                    parked[gi].clear();
                                }
                            }
                        }
                    }
                    for &s in &dag.succs[t_q] {
                        indeg[s] -= 1;
                        if indeg[s] == 0 && !quarantined[s] {
                            on_ready!(s);
                        }
                    }
                }
                gates.retain(|&Reverse((_, _, t_q))| !quarantined[t_q]);
            }
        }};
    }

    // Terminal-stuck catch-all: where FailFast aborts with
    // `SimError::Deadlock`, Retry quarantines every job still owning an
    // unfinished non-dummy task — per-job reasons sampled exactly as
    // `deadlock_report` samples them (starved / parked preferred over
    // merely-blocked). Evaluates to whether anything was quarantined;
    // the caller falls through to the deadlock report when nothing was
    // (all-dummy remainders cannot happen, but the guard keeps the
    // loop provably progressing).
    macro_rules! quarantine_stuck {
        ($caps0:expr, $task_res:expr) => {{
            for r in job_stuck.iter_mut() {
                *r = None;
            }
            for t_q in 0..n {
                if done[t_q] || matches!(dag.tasks[t_q].kind, SimKind::Dummy) {
                    continue;
                }
                let reason = if queued[t_q] {
                    StuckReason::Starved {
                        resource: $task_res[t_q].iter().find(|&r| $caps0[r] <= ALLOC_EPS),
                    }
                } else if indeg[t_q] == 0 {
                    match group_of[t_q] {
                        Some(gi) if !group_open[gi] => StuckReason::Parked {
                            group: dag.tasks[t_q].coflow.unwrap_or(gi),
                        },
                        _ => StuckReason::Blocked,
                    }
                } else {
                    StuckReason::Blocked
                };
                let slot = &mut job_stuck[dag.job(t_q)];
                let better = match slot {
                    None => true,
                    Some(StuckReason::Blocked) => reason != StuckReason::Blocked,
                    _ => false,
                };
                if better {
                    *slot = Some(reason);
                }
            }
            let mut any_q = false;
            for j_q in 0..n_jobs {
                if let Some(reason) = job_stuck[j_q] {
                    any_q = true;
                    quarantine_job!(j_q, JobOutcome::Quarantined { reason, at: now });
                }
            }
            any_q
        }};
    }

    while n_done < n {
        events += 1;
        if events > cfg.max_events {
            return Err(SimError::EventLimit(events));
        }

        // 0. cluster dynamics: fold every timeline entry due at `now`
        //    into the effective cluster state. Rescale touched
        //    capacities, re-run `ParallelFabrics` path selection over
        //    the surviving trunks when a fabric extra changed, and
        //    dirty exactly the queued tasks whose footprints meet a
        //    touched slot — their components reprice (and their SEBF
        //    keys refresh) this event, clean components stay memoized.
        //    Time advance (steps 4/4') never integrates across a
        //    pending entry, so rates read here are never stale.
        if dyn_on && dyn_state.next_at(&cfg.dynamics).map_or(false, |at| at <= now + EPS) {
            let trunk_change = dyn_state.apply_due(
                &cfg.dynamics,
                now,
                EPS,
                n_hosts,
                caps0_in,
                &mut dyn_caps,
                &mut dyn_touched,
                &mut dyn_touched_list,
                &mut failed_hosts,
            );
            // the class-saturation counters follow the effective caps
            n_cores_pos = 0;
            n_net_pos = 0;
            for (r, &c) in dyn_caps.iter().enumerate() {
                if c > ALLOC_EPS {
                    if super::spec::is_core_slot(r, n_hosts) {
                        n_cores_pos += 1;
                    } else {
                        n_net_pos += 1;
                    }
                }
            }
            // reroute: re-pick each unfinished flow's trunk over the
            // surviving fabrics (deterministic task-id order). A flow
            // with no surviving path keeps its dead footprint so it is
            // reported as starved on the failed trunk slot.
            if trunk_change {
                if let Topology::ParallelFabrics { k, .. } = cluster.topology {
                    dyn_alive.clear();
                    for j in 0..k {
                        if dyn_state.link_alive(Topology::trunk(j, n_hosts)) {
                            dyn_alive.push(j);
                        }
                    }
                    for t in 0..n {
                        if done[t] || !is_flow_v[t] {
                            continue;
                        }
                        let (src, dst) = match dag.tasks[t].kind {
                            SimKind::Flow { src, dst } => (src, dst),
                            _ => continue,
                        };
                        let new_trunk = cluster
                            .topology
                            .reroute_trunk(src, dst, &dyn_alive)
                            .map(|j| Topology::trunk(j, n_hosts));
                        let cur_trunk = dyn_task_res[t].iter().find(|&r| r >= 3 * n_hosts);
                        let nt = match (new_trunk, cur_trunk) {
                            (Some(nt), Some(cur)) if nt != cur => nt,
                            _ => continue,
                        };
                        let mut tr = TaskRes::default();
                        tr.push(res_up(src));
                        tr.push(res_down(dst));
                        tr.push(nt);
                        dyn_task_res[t] = tr;
                        if queued[t] {
                            if comps_on {
                                // re-home the flow: removal dirties the
                                // old component (whose stale resource
                                // list still covers the old trunk's
                                // release), insertion claims the new
                                // trunk and dirties the new home
                                comps.remove(t);
                                comps.insert(t, &dyn_task_res[t], virt[t]);
                            }
                            if coflow_on {
                                match group_of[t] {
                                    Some(gi) => {
                                        if !group_dirty[gi] {
                                            group_dirty[gi] = true;
                                            dirty_groups.push(gi);
                                        }
                                    }
                                    None => dirty_singles.push(t),
                                }
                            }
                        }
                    }
                }
            }
            // Host crashes (`DynAction::FailHost`) under Retry: every
            // in-flight victim — queued, started, footprint touching a
            // crashed host's slots — loses its progress. Bytes reset to
            // full, held capacity is released through the component
            // dirty protocol (`comps.remove` dirties the old component,
            // whose stale resource list covers the release at the next
            // refill), and the task re-enters the gate heap behind its
            // exponential-backoff timer, keeping its original live
            // order. A victim whose failed-attempt budget is spent
            // quarantines its job instead. Under FailFast the crash is
            // purely a capacity event (identical to `SlowHost{0}`).
            if retry_on && !failed_hosts.is_empty() {
                for t in 0..n {
                    if !queued[t] || !started[t] || done[t] {
                        continue;
                    }
                    let hit = failed_hosts.iter().any(|&h| {
                        dyn_task_res[t].iter().any(|r| r >= 3 * h && r < 3 * h + 3)
                    });
                    if !hit {
                        continue;
                    }
                    // materialize the killed attempt's progress for the
                    // lost-work account (anchored runs integrate lazily)
                    let rem_now = if anchored && rate_of[t] > 0.0 {
                        (remaining[t] - rate_of[t] * (now - anchor_t[t])).max(0.0)
                    } else {
                        remaining[t]
                    };
                    lost_work += (dag.tasks[t].size - rem_now).max(0.0);
                    remaining[t] = dag.tasks[t].size;
                    rate_of[t] = 0.0;
                    anchor_t[t] = now;
                    if anchored {
                        fins.remove(t);
                    }
                    queued[t] = false;
                    if comps_on {
                        comps.remove(t);
                    }
                    if is_flow_v[t] {
                        rq_net.remove(t);
                    } else {
                        rq_cpu.remove(t);
                    }
                    if coflow_on && is_flow_v[t] {
                        if let Some(gi) = group_of[t] {
                            if !group_dirty[gi] {
                                group_dirty[gi] = true;
                                dirty_groups.push(gi);
                            }
                        }
                    }
                    attempts[t] += 1;
                    if attempts[t] >= max_attempts {
                        quarantine_job!(dag.job(t), JobOutcome::Exhausted { attempts: attempts[t] });
                    } else {
                        retries += 1;
                        retry_gate[t] = now + retry_backoff(backoff, attempts[t]);
                        gates.push(Reverse((f64_ord(retry_gate[t]), seq[t], t)));
                    }
                }
            }
            // the cursor reports crashes whatever the policy; FailFast
            // treats them as pure capacity events and drops the list
            failed_hosts.clear();
            // dirty every queued task whose footprint meets a touched
            // slot: the component repricing (step 3) and the SEBF key
            // refresh (step 2b) pick these up
            for t in 0..n {
                if !queued[t] || !dyn_task_res[t].iter().any(|r| dyn_touched[r]) {
                    continue;
                }
                if comps_on {
                    comps.mark_task_dirty(t);
                }
                if coflow_on && is_flow_v[t] {
                    match group_of[t] {
                        Some(gi) => {
                            if !group_dirty[gi] {
                                group_dirty[gi] = true;
                                dirty_groups.push(gi);
                            }
                        }
                        None => dirty_singles.push(t),
                    }
                }
            }
            for &r in dyn_touched_list.iter() {
                dyn_touched[r] = false;
            }
            dyn_touched_list.clear();
        }

        // Effective cluster state for this iteration: with dynamics the
        // engine-owned copies, otherwise the caller's slices verbatim
        // (no copies, bit-identical to the pre-dynamics engine).
        let caps0: &[f64] = if dyn_on { &dyn_caps } else { caps0_in };
        let task_res: &[TaskRes] = if dyn_on { &dyn_task_res } else { task_res_in };

        // 1. admit gate-expired tasks back into the arrival stream (their
        //    original live order is preserved through `seq`; retried
        //    tasks sit here behind their backoff gate)
        while let Some(&Reverse((_, s, t))) = gates.peek() {
            if now + EPS >= eff_gate!(t) {
                gates.pop();
                arrivals.push(Reverse((s, t)));
            } else {
                break;
            }
        }

        // 2. classify arrivals in live order; zero-size tasks complete
        //    instantly and cascade
        while let Some(Reverse((_, t))) = arrivals.pop() {
            if done[t] {
                continue;
            }
            debug_assert_eq!(indeg[t], 0);
            let gate_t = eff_gate!(t);
            if now + EPS < gate_t {
                gates.push(Reverse((f64_ord(gate_t), seq[t], t)));
                continue;
            }
            if remaining[t] <= EPS {
                // dummy / zero-size: completes at readiness, bypassing the
                // coflow barrier
                done[t] = true;
                n_done += 1;
                if !started[t] {
                    started[t] = true;
                    trace[t].start = now;
                }
                trace[t].finish = now;
                for &s in &dag.succs[t] {
                    indeg[s] -= 1;
                    if indeg[s] == 0 {
                        on_ready!(s);
                    }
                }
                continue;
            }
            if coflow_on {
                if let Some(gi) = group_of[t] {
                    if !group_open[gi] {
                        // all-or-nothing: wait for the whole group
                        parked[gi].push(t);
                        continue;
                    }
                }
            }
            let orig = dag.tasks[t].orig;
            if use_fifo && !fifo_prio_orig.contains_key(&orig) {
                let tq = (now * 1e6).round() as i64;
                if tq != fifo_tie_time {
                    fifo_tie_time = tq;
                    fifo_tie_count = 0;
                    fifo_base = fifo_max + 1;
                }
                let tie = if dag.tasks[t].chunk.1 > 1 {
                    // pipelined stream: concurrent connection — shares
                    // the slot after the singles issued so far, so
                    // same-instant streams fair-share each other
                    fifo_tie_count + 1
                } else {
                    // blocking send: takes the next exclusive slot
                    fifo_tie_count += 1;
                    fifo_tie_count
                };
                let slot = fifo_base + tie;
                fifo_max = fifo_max.max(slot);
                fifo_prio_orig.insert(orig, -slot);
            }
            // enqueue under the policy's priority key
            if dag.tasks[t].kind.is_flow() {
                let key = match cfg.policy.net {
                    NetPolicy::Fair => PrioKey::LEVEL,
                    NetPolicy::Priority => PrioKey::from_prio(dag.tasks[t].priority),
                    NetPolicy::Fifo => PrioKey::from_prio(
                        fifo_prio_orig.get(&orig).copied().unwrap_or(0),
                    ),
                    NetPolicy::Coflow => match group_of[t] {
                        Some(gi) => {
                            // placeholder: the group key is refreshed for
                            // all members right after this drain
                            if !group_dirty[gi] {
                                group_dirty[gi] = true;
                                dirty_groups.push(gi);
                            }
                            PrioKey::from_bound_asc(f64::INFINITY, gi as u64)
                        }
                        // tie-break singletons by live order (`seq`):
                        // exactly the per-event active-list order the old
                        // stable sort fell back to on equal bounds
                        None => PrioKey::from_bound_asc(
                            sebf_bound_single(t, &remaining, task_res, caps0),
                            n_groups as u64 + seq[t],
                        ),
                    },
                };
                queued[t] = true;
                key_of[t] = key;
                rq_net.push(t, key);
                if comps_on {
                    comps.insert(t, &task_res[t], virt[t]);
                }
            } else {
                let key = match cfg.policy.cpu {
                    CpuPolicy::Fair => PrioKey::LEVEL,
                    CpuPolicy::Priority => PrioKey::from_prio(dag.tasks[t].priority),
                    CpuPolicy::Fifo => PrioKey::from_prio(
                        fifo_prio_orig.get(&orig).copied().unwrap_or(0),
                    ),
                };
                queued[t] = true;
                key_of[t] = key;
                rq_cpu.push(t, key);
                if comps_on {
                    comps.insert(t, &task_res[t], virt[t]);
                }
            }
        }

        // 2a. anchored + whole-set: every event reprices the whole
        //     active set anyway, so the eager integration sweep is
        //     replayed here, deferred to the event that needs the bytes:
        //     drain the finish heap, materialize every running task at
        //     `now`, and mark coflow drift exactly as the eager advance
        //     would. (Component-wise allocation instead re-anchors per
        //     dirty component in step 3 — clean components stay
        //     untouched, which is the whole point.)
        if anchored && !comps_on {
            while let Some((_, t)) = fins.pop() {
                let r = rate_of[t];
                rate_of[t] = 0.0;
                remaining[t] = (remaining[t] - r * (now - anchor_t[t])).max(0.0);
                anchor_t[t] = now;
                if remaining[t] <= EPS {
                    near_done.push(t);
                }
                if coflow_on && is_flow_v[t] {
                    match group_of[t] {
                        Some(gi) => {
                            if !group_dirty[gi] {
                                group_dirty[gi] = true;
                                dirty_groups.push(gi);
                            }
                        }
                        None => dirty_singles.push(t),
                    }
                }
            }
        }

        // 2b. key invalidation: refresh SEBF bounds that went stale
        //     through progress (last event) or new arrivals (this event).
        //     Under anchored + component-wise allocation this sweep never
        //     runs: drift is detected at refill time from re-anchored
        //     bytes (step 3), and arrival-placeholder keys are replaced
        //     there too — the marks are dropped, the component dirtied by
        //     the arrival itself carries the work.
        if coflow_on && anchored && comps_on {
            for &gi in dirty_groups.iter() {
                group_dirty[gi] = false;
            }
            dirty_groups.clear();
            dirty_singles.clear();
        }
        if coflow_on && (!dirty_groups.is_empty() || !dirty_singles.is_empty()) {
            for &gi in dirty_groups.iter() {
                group_dirty[gi] = false;
                let bnd = sebf_bound_group(
                    &members[gi],
                    &queued,
                    is_flow_v,
                    &remaining,
                    task_res,
                    caps0,
                    &mut load,
                    &mut load_touched,
                    &mut touched,
                );
                let key = PrioKey::from_bound_asc(bnd, gi as u64);
                for &m in members[gi].iter() {
                    if queued[m] && is_flow_v[m] {
                        key_of[m] = key;
                        rq_net.update_key(m, key);
                        if comps_on {
                            comps.mark_task_dirty(m);
                        }
                    }
                }
            }
            dirty_groups.clear();
            for &t in dirty_singles.iter() {
                if queued[t] {
                    let bnd = sebf_bound_single(t, &remaining, task_res, caps0);
                    let key = PrioKey::from_bound_asc(bnd, n_groups as u64 + seq[t]);
                    key_of[t] = key;
                    rq_net.update_key(t, key);
                    if comps_on {
                        comps.mark_task_dirty(t);
                    }
                }
            }
            dirty_singles.clear();
        }

        if n_done == n {
            break;
        }

        if rq_cpu.is_empty() && rq_net.is_empty() {
            // nothing runnable: jump to the next gate expiry, quarantine
            // the stuck jobs (Retry), or give up (FailFast). An open-loop
            // stop bound before the next gate (or with no gate at all)
            // halts the epoch instead — stuck detection is deferred to
            // the final, unbounded epoch, where the closed-mode paths
            // below run unchanged.
            if let Some(&Reverse((_, _, tg))) = gates.peek() {
                let g = eff_gate!(tg);
                if let Some(stop) = cfg.stop {
                    if g > stop + EPS {
                        now = now.max(stop);
                        stopped = true;
                        break;
                    }
                }
                now = g;
                continue;
            }
            if let Some(stop) = cfg.stop {
                now = now.max(stop);
                stopped = true;
                break;
            }
            if retry_on && quarantine_stuck!(caps0, task_res) {
                continue;
            }
            return Err(deadlock_report(
                dag, caps0, task_res, &done, &queued, &indeg, &group_of, &group_open, now,
                n - n_done,
            ));
        }

        // 3. allocate rates
        let allow_exit = cfg.queue == QueueKind::Incremental;
        if par_on {
            // Parallel event loop (module docs): the same component-wise
            // allocation, restructured as one epoch per event.
            //
            // Phase A (serial prologue): drain every dirty component —
            // re-anchor members, release capacity, rebuild the
            // partition. All merges/splits of the contention graph
            // happen here, behind the epoch barrier, so the fresh
            // components collected in `fill_list` are mutually
            // independent: disjoint members *and* disjoint exact
            // resource sets.
            fill_list.clear();
            let mut total_members = 0usize;
            while let Some(c) = comps.pop_dirty() {
                if anchored {
                    for &t in comps.members(c) {
                        let r = rate_of[t];
                        if r > 0.0 {
                            rate_of[t] = 0.0;
                            remaining[t] = (remaining[t] - r * (now - anchor_t[t])).max(0.0);
                        }
                        fins.remove(t);
                        anchor_t[t] = now;
                        if remaining[t] <= EPS {
                            near_done.push(t);
                        }
                    }
                }
                for &r in comps.res_of(c) {
                    if r < n_res {
                        caps[r] = caps0[r];
                    }
                }
                new_comps.clear();
                comps.rebuild(c, task_res, &virt, &mut new_comps);
                for &nc in &new_comps {
                    total_members += comps.members(nc).len();
                    fill_list.push(nc);
                }
            }
            if comp_rated.len() < comps.slot_bound() {
                comp_rated.resize_with(comps.slot_bound(), Vec::new);
            }

            // Phase B (parallel): refill every fresh component. Below
            // the deterministic size threshold the same closure runs
            // inline on one worker state — identical results, no spawn
            // overhead on small events. Workers read only pre-epoch
            // shared state and write only their own arenas, so each
            // refill is a pure function of `(component, epoch state)`.
            let nw = if total_members >= PAR_FILL_MIN_TASKS {
                cfg.threads.min(workers.len())
            } else {
                1
            };
            for (i, w) in workers.iter_mut().enumerate().take(nw) {
                w.id = i;
                w.keys_out.clear();
                w.rated_out.clear();
                w.starts_out.clear();
                w.caps_out.clear();
            }
            let rekey = anchored && coflow_on;
            let spans = {
                let comps_view = &comps;
                let key_view: &[PrioKey] = &key_of;
                let started_view: &[bool] = &started;
                let remaining_view: &[f64] = &remaining;
                let queued_view: &[bool] = &queued;
                let seq_view: &[u64] = &seq;
                let group_of_view: &[Option<usize>] = &group_of;
                let members_view: &[Vec<usize>] = &members;
                par_map_with(&fill_list, &mut workers[..nw], |w, _i, &nc| {
                    w.ensure(n_res, n_groups);
                    let mem = comps_view.members(nc);
                    // seed private capacities: exactly the post-release
                    // state the serial fill reads
                    for &r in comps_view.res_of(nc) {
                        if r < n_res {
                            w.wcaps[r] = caps0[r];
                        }
                    }
                    let keys_s = w.keys_out.len();
                    if rekey {
                        // SEBF drift detection, parallel flavour: the
                        // serial path's per-component re-key loop run
                        // against the worker's private key view, every
                        // refreshed key recorded for the coordinator to
                        // replay onto the real queues in order.
                        if w.wkeys.len() < n {
                            w.wkeys.resize(n, PrioKey::LEVEL);
                        }
                        for &t in mem {
                            w.wkeys[t] = key_view[t];
                        }
                        w.grp_list.clear();
                        for &t in mem {
                            if !is_flow_v[t] {
                                continue;
                            }
                            match group_of_view[t] {
                                Some(gi) => {
                                    if !w.grp_seen[gi] {
                                        w.grp_seen[gi] = true;
                                        w.grp_list.push(gi);
                                    }
                                }
                                None => {
                                    let bnd = sebf_bound_single(
                                        t,
                                        remaining_view,
                                        task_res,
                                        caps0,
                                    );
                                    let key = PrioKey::from_bound_asc(
                                        bnd,
                                        n_groups as u64 + seq_view[t],
                                    );
                                    w.wkeys[t] = key;
                                    w.keys_out.push((t, key));
                                }
                            }
                        }
                        for gi_at in 0..w.grp_list.len() {
                            let gi = w.grp_list[gi_at];
                            w.grp_seen[gi] = false;
                            let bnd = sebf_bound_group(
                                &members_view[gi],
                                queued_view,
                                is_flow_v,
                                remaining_view,
                                task_res,
                                caps0,
                                &mut w.load,
                                &mut w.load_touched,
                                &mut w.touched,
                            );
                            let key = PrioKey::from_bound_asc(bnd, gi as u64);
                            for &m in members_view[gi].iter() {
                                if queued_view[m] && is_flow_v[m] {
                                    w.wkeys[m] = key;
                                    w.keys_out.push((m, key));
                                }
                            }
                        }
                    }
                    let rated_s = w.rated_out.len();
                    let starts_s = w.starts_out.len();
                    let keyref: &[PrioKey] = if rekey { &w.wkeys } else { key_view };
                    fill_component(
                        &mut w.sorted,
                        mem,
                        keyref,
                        coflow_on,
                        is_flow_v,
                        task_res,
                        remaining_view,
                        &mut w.wcaps,
                        &mut w.users,
                        &mut w.ascr,
                        &mut w.sub_res,
                        &mut w.sub_idx,
                        &mut w.sub_rates,
                        started_view,
                        &mut w.starts_out,
                        &mut w.rated_out,
                        &mut w.load,
                        &mut w.load_touched,
                        &mut w.touched,
                    );
                    let caps_s = w.caps_out.len();
                    for &r in comps_view.res_of(nc) {
                        if r < n_res {
                            w.caps_out.push((r, w.wcaps[r]));
                        }
                    }
                    FillSpans {
                        worker: w.id,
                        keys: (keys_s, w.keys_out.len()),
                        rated: (rated_s, w.rated_out.len()),
                        starts: (starts_s, w.starts_out.len()),
                        caps: (caps_s, w.caps_out.len()),
                    }
                })
            };

            // Epilogue (serial): replay each refill's recorded effects
            // in component order — exactly the serial path's order, so
            // key updates, capacity residuals, memoized rates, starts
            // and finish predictions land byte-for-byte where the
            // `threads == 1` oracle puts them.
            for (k, sp) in spans.iter().enumerate() {
                let nc = fill_list[k];
                let w = &workers[sp.worker];
                for &(t, key) in &w.keys_out[sp.keys.0..sp.keys.1] {
                    key_of[t] = key;
                    rq_net.update_key(t, key);
                }
                for &(r, v) in &w.caps_out[sp.caps.0..sp.caps.1] {
                    caps[r] = v;
                }
                comp_rated[nc].clear();
                comp_rated[nc].extend_from_slice(&w.rated_out[sp.rated.0..sp.rated.1]);
                starts.extend_from_slice(&w.starts_out[sp.starts.0..sp.starts.1]);
                if anchored {
                    for &(t, r) in comp_rated[nc].iter() {
                        rate_of[t] = r;
                        anchor_t[t] = now;
                        let fin =
                            if remaining[t] <= EPS { now } else { now + remaining[t] / r };
                        fins.push(t, fin);
                    }
                }
            }
        } else if comps_on {
            // Component-wise: release and refill only the components an
            // event has touched; every clean component keeps its
            // memoized rates (immutable between the events that touch
            // it — the invariant `docs/ARCHITECTURE.md` documents).
            while let Some(c) = comps.pop_dirty() {
                // Batch `FinHeap` rebuild: when this dirty component
                // covers more than half of the heap's rated tasks, the
                // per-task `remove`/`push` calls (n·O(log n)) lose to
                // compacting + re-heapifying wholesale (O(n)), so the
                // removals and re-inserts are staged and applied in one
                // `apply_batch` at the end of this iteration. Pop/peek
                // order is a total (fin, task) order either way — the
                // two paths are bit-identical.
                let batch = anchored && 2 * comps.members(c).len() > fins.len();
                // anchored: a dirty component's members re-anchor at
                // `now` — bytes are materialized exactly when the refill
                // is about to read them, and the stale finish predictions
                // leave the heap (fresh ones are pushed after the fill)
                if anchored {
                    for &t in comps.members(c) {
                        let r = rate_of[t];
                        if r > 0.0 {
                            rate_of[t] = 0.0;
                            remaining[t] = (remaining[t] - r * (now - anchor_t[t])).max(0.0);
                        }
                        // unconditional: a zero-rate member may still
                        // hold a near-done re-arm entry (below)
                        if batch {
                            if fins.contains(t) {
                                heap_removed.push(t);
                            }
                        } else {
                            fins.remove(t);
                        }
                        anchor_t[t] = now;
                        if remaining[t] <= EPS {
                            near_done.push(t);
                        }
                    }
                }
                // release the old allocation: only this component's
                // tasks ever drew on these resources
                for &r in comps.res_of(c) {
                    if r < n_res {
                        caps[r] = caps0[r];
                    }
                }
                new_comps.clear();
                comps.rebuild(c, task_res, &virt, &mut new_comps);
                if comp_rated.len() < comps.slot_bound() {
                    comp_rated.resize_with(comps.slot_bound(), Vec::new);
                }
                for &nc in &new_comps {
                    if anchored && coflow_on {
                        // SEBF drift detection, anchored flavour:
                        // recompute every unit key in this component from
                        // the just-re-anchored bytes (the sweep-mode
                        // invalidation in step 2b never runs here). A
                        // group's queued flows all share its virtual
                        // resource, so the whole unit is in this
                        // component by construction.
                        grp_list.clear();
                        for &t in comps.members(nc) {
                            if !is_flow_v[t] {
                                continue;
                            }
                            match group_of[t] {
                                Some(gi) => {
                                    if !grp_seen[gi] {
                                        grp_seen[gi] = true;
                                        grp_list.push(gi);
                                    }
                                }
                                None => {
                                    let bnd =
                                        sebf_bound_single(t, &remaining, task_res, caps0);
                                    let key = PrioKey::from_bound_asc(
                                        bnd,
                                        n_groups as u64 + seq[t],
                                    );
                                    key_of[t] = key;
                                    rq_net.update_key(t, key);
                                }
                            }
                        }
                        for gi_at in 0..grp_list.len() {
                            let gi = grp_list[gi_at];
                            grp_seen[gi] = false;
                            let bnd = sebf_bound_group(
                                &members[gi],
                                &queued,
                                is_flow_v,
                                &remaining,
                                task_res,
                                caps0,
                                &mut load,
                                &mut load_touched,
                                &mut touched,
                            );
                            let key = PrioKey::from_bound_asc(bnd, gi as u64);
                            for &m in members[gi].iter() {
                                if queued[m] && is_flow_v[m] {
                                    key_of[m] = key;
                                    rq_net.update_key(m, key);
                                }
                            }
                        }
                    }
                    comp_rated[nc].clear();
                    fill_component(
                        &mut comp_sorted,
                        comps.members(nc),
                        &key_of,
                        coflow_on,
                        is_flow_v,
                        task_res,
                        &remaining,
                        &mut caps,
                        &mut users_scratch,
                        &mut ascr,
                        &mut sub_res,
                        &mut sub_idx,
                        &mut sub_rates,
                        &started,
                        &mut starts,
                        &mut comp_rated[nc],
                        &mut load,
                        &mut load_touched,
                        &mut touched,
                    );
                    if anchored {
                        // fresh finish predictions anchor the refilled
                        // rates; they stay valid until the next event
                        // that dirties this component. A member whose
                        // bytes already sit at ≤ EPS finishes *now* —
                        // under MADD its rate is rem/τ, so rem/rate
                        // would predict the whole unit's τ instead of
                        // the immediate completion eager grants it.
                        for &(t, r) in comp_rated[nc].iter() {
                            rate_of[t] = r;
                            anchor_t[t] = now;
                            let fin =
                                if remaining[t] <= EPS { now } else { now + remaining[t] / r };
                            if batch {
                                heap_inserts.push((t, fin));
                            } else {
                                fins.push(t, fin);
                            }
                        }
                    }
                }
                if batch {
                    fins.apply_batch(&heap_removed, &heap_inserts);
                    heap_removed.clear();
                    heap_inserts.clear();
                }
            }
        } else {
            // Whole-set oracle: reprice everything, walking priority
            // levels high → low on residual capacity.
            caps.copy_from_slice(caps0);
            rated.clear();
            for m in sat_mark.iter_mut() {
                *m = false;
            }

            // compute slots first (independent resources from NICs)
            {
                let mut sat = 0usize;
                rq_cpu.for_each_level(&mut |_key, level| {
                    alloc_level_maxmin(
                        level,
                        task_res,
                        &mut caps,
                        &mut users_scratch,
                        &mut ascr,
                        &mut sub_res,
                        &mut sub_idx,
                        &mut sub_rates,
                        &started,
                        &mut starts,
                        &mut rated,
                    );
                    for &t in sub_idx.iter() {
                        for r in task_res[t].iter() {
                            if !sat_mark[r] && caps[r] <= ALLOC_EPS && caps0[r] > ALLOC_EPS {
                                sat_mark[r] = true;
                                sat += 1;
                            }
                        }
                    }
                    !(allow_exit && sat >= n_cores_pos)
                });
            }
            {
                let mut sat = 0usize;
                if coflow_on {
                    // each level is one SEBF unit (a coflow group or a
                    // singleton flow); MADD makes all members finish at
                    // the same τ, feasible on residual capacity
                    rq_net.for_each_level(&mut |_key, level| {
                        grp_scratch.clear();
                        grp_scratch.extend_from_slice(level);
                        // canonical member order: keeps every (queue,
                        // alloc) configuration bit-for-bit comparable
                        grp_scratch.sort_unstable();
                        madd_level(
                            &grp_scratch,
                            &remaining,
                            task_res,
                            &mut caps,
                            &mut load,
                            &mut load_touched,
                            &mut touched,
                            &started,
                            &mut starts,
                            &mut rated,
                        );
                        for &r in touched.iter() {
                            if !sat_mark[r] && caps[r] <= ALLOC_EPS && caps0[r] > ALLOC_EPS {
                                sat_mark[r] = true;
                                sat += 1;
                            }
                        }
                        !(allow_exit && sat >= n_net_pos)
                    });
                } else {
                    rq_net.for_each_level(&mut |_key, level| {
                        alloc_level_maxmin(
                            level,
                            task_res,
                            &mut caps,
                            &mut users_scratch,
                            &mut ascr,
                            &mut sub_res,
                            &mut sub_idx,
                            &mut sub_rates,
                            &started,
                            &mut starts,
                            &mut rated,
                        );
                        for &t in sub_idx.iter() {
                            for r in task_res[t].iter() {
                                if !sat_mark[r] && caps[r] <= ALLOC_EPS && caps0[r] > ALLOC_EPS {
                                    sat_mark[r] = true;
                                    sat += 1;
                                }
                            }
                        }
                        !(allow_exit && sat >= n_net_pos)
                    });
                }
            }
        }

        // Apply the deferred starts: every task that received its first
        // positive rate this event (each appears at most once — a task
        // is filled by exactly one level of one walk). Deferring the
        // `started`/`trace` stamps to this single serial site keeps the
        // fills read-only on shared per-task state, which is what the
        // parallel phase-B workers rely on; the observable effect
        // (`trace[t].start = now`) is identical, and nothing between
        // the fill and this point reads `started`.
        for &t in starts.iter() {
            if !started[t] {
                started[t] = true;
                trace[t].start = now;
            }
        }
        starts.clear();

        if anchored {
            if !comps_on {
                // re-arm the heap from the fresh whole-set allocation
                // (step 2a drained it, so every rated task is absent);
                // ≤ EPS bytes finish now, as in the component path
                for &(t, r) in rated.iter() {
                    rate_of[t] = r;
                    anchor_t[t] = now;
                    let fin = if remaining[t] <= EPS { now } else { now + remaining[t] / r };
                    fins.push(t, fin);
                }
            }
            // A task whose materialized bytes crossed the completion
            // epsilon while ending up unrated (MADD rates scale with
            // remaining, so a near-empty unit can rate below EPS) must
            // still finish: arm an immediate completion so it cannot
            // strand inside a component that then goes quiescent. Eager
            // never creates this state — its sweep completes any task
            // at ≤ EPS bytes on the spot.
            for &t in near_done.iter() {
                if queued[t] && !fins.contains(t) {
                    fins.push(t, now);
                }
            }
            near_done.clear();

            // 4'. anchored horizon: the earliest predicted finish (heap
            //     peek) vs the next gate expiry — no per-task scan
            let mut t_next = match fins.peek() {
                Some((fin, _)) => fin,
                None => f64::INFINITY,
            };
            if let Some(&Reverse((_, _, tg))) = gates.peek() {
                t_next = t_next.min(eff_gate!(tg));
            }
            // never advance across a pending dynamics entry: memoized
            // rates and predicted finishes are only valid up to the
            // capacity change (step 0 applies it next iteration)
            if dyn_on {
                if let Some(at) = dyn_state.next_at(&cfg.dynamics) {
                    t_next = t_next.min(at);
                }
            }
            // open-loop stop bound: nothing (finish, gate or dynamics
            // entry) is due before the bound — halt the epoch here,
            // before the deadlock check, so a cluster that is merely
            // quiescent until the next arrival stops cleanly
            if let Some(stop) = cfg.stop {
                if t_next > stop + EPS {
                    now = now.max(stop);
                    stopped = true;
                    break;
                }
            }
            if !t_next.is_finite() {
                if retry_on && quarantine_stuck!(caps0, task_res) {
                    continue;
                }
                return Err(deadlock_report(
                    dag, caps0, task_res, &done, &queued, &indeg, &group_of, &group_open,
                    now, n - n_done,
                ));
            }

            // 5'. advance to the horizon and pop every finish that has
            //     arrived. Nothing else is touched: clean components'
            //     bytes stay un-materialized, their heap entries stay
            //     valid — a quiescent component costs zero this event.
            now = now.max(t_next);
            completed.clear();
            while let Some((fin, t)) = fins.peek() {
                if fin > now + EPS {
                    break;
                }
                fins.pop();
                rate_of[t] = 0.0;
                remaining[t] = 0.0;
                completed.push(t);
                if coflow_on && is_flow_v[t] {
                    // a finishing member shifts its group's SEBF bound.
                    // Under components the completion dirties the
                    // component (step 5 tail) and the refill re-keys;
                    // under whole-set the 2b sweep needs the mark — the
                    // same mark the eager sweep makes.
                    if let Some(gi) = group_of[t] {
                        if !group_dirty[gi] {
                            group_dirty[gi] = true;
                            dirty_groups.push(gi);
                        }
                    }
                }
            }
        } else {
            // 4. eager horizon: the min over every running task's
            //    projected completion (memoized per component) and the
            //    next gate expiry — a min-reduction, so iteration order
            //    is free
            let mut dt = f64::INFINITY;
            if comps_on {
                for &c in comps.live_slots() {
                    for &(t, r) in comp_rated[c].iter() {
                        dt = dt.min(remaining[t] / r);
                    }
                }
            } else {
                for &(t, r) in rated.iter() {
                    dt = dt.min(remaining[t] / r);
                }
            }
            if let Some(&Reverse((_, _, tg))) = gates.peek() {
                dt = dt.min(eff_gate!(tg) - now);
            }
            // stop the integration sweep at the next dynamics entry
            // (strictly ahead of `now`: step 0 consumed everything due,
            // so this can never pin `dt` at zero)
            if dyn_on {
                if let Some(at) = dyn_state.next_at(&cfg.dynamics) {
                    dt = dt.min(at - now);
                }
            }
            // open-loop stop bound: the next completion / gate /
            // dynamics boundary lies beyond the bound, so no task can
            // finish inside the remaining span — integrate the partial
            // span at the standing rates and halt the epoch
            if let Some(stop) = cfg.stop {
                if !dt.is_finite() || dt <= 0.0 || now + dt > stop + EPS {
                    let span = (stop - now).max(0.0);
                    if span > 0.0 {
                        if comps_on {
                            for &c in comps.live_slots() {
                                for &(t, r) in comp_rated[c].iter() {
                                    remaining[t] = (remaining[t] - r * span).max(0.0);
                                }
                            }
                        } else {
                            for &(t, r) in rated.iter() {
                                remaining[t] = (remaining[t] - r * span).max(0.0);
                            }
                        }
                    }
                    now = now.max(stop);
                    stopped = true;
                    break;
                }
            }
            if !dt.is_finite() || dt <= 0.0 {
                if retry_on && quarantine_stuck!(caps0, task_res) {
                    continue;
                }
                return Err(deadlock_report(
                    dag, caps0, task_res, &done, &queued, &indeg, &group_of, &group_open,
                    now, n - n_done,
                ));
            }

            // 5. advance; completions are processed in live order so
            //    that downstream readiness (and FIFO slots) follow the
            //    same order under every (queue, alloc) configuration.
            //    Progress under coflow dirties the progressing
            //    component: SEBF bounds and MADD rates drift with
            //    remaining bytes (static-key policies leave clean
            //    components untouched — their rates depend only on
            //    membership).
            now += dt;
            completed.clear();
            if comps_on {
                live_scratch.clear();
                live_scratch.extend_from_slice(comps.live_slots());
                for &c in &live_scratch {
                    for k in 0..comp_rated[c].len() {
                        let (t, r) = comp_rated[c][k];
                        remaining[t] -= r * dt;
                        let finished = remaining[t] <= EPS;
                        if finished {
                            remaining[t] = 0.0;
                            completed.push(t);
                        }
                        if coflow_on && is_flow_v[t] {
                            comps.mark_task_dirty(t);
                            match group_of[t] {
                                Some(gi) => {
                                    if !group_dirty[gi] {
                                        group_dirty[gi] = true;
                                        dirty_groups.push(gi);
                                    }
                                }
                                None => {
                                    if !finished {
                                        dirty_singles.push(t);
                                    }
                                }
                            }
                        }
                    }
                }
            } else {
                for &(t, r) in rated.iter() {
                    remaining[t] -= r * dt;
                    let finished = remaining[t] <= EPS;
                    if finished {
                        remaining[t] = 0.0;
                        completed.push(t);
                    }
                    if coflow_on && dag.tasks[t].kind.is_flow() {
                        match group_of[t] {
                            Some(gi) => {
                                if !group_dirty[gi] {
                                    group_dirty[gi] = true;
                                    dirty_groups.push(gi);
                                }
                            }
                            None => {
                                if !finished {
                                    dirty_singles.push(t);
                                }
                            }
                        }
                    }
                }
            }
        }
        completed.sort_unstable_by_key(|&t| seq[t]);
        for &t in completed.iter() {
            done[t] = true;
            n_done += 1;
            if !started[t] {
                // only reachable through the near-done re-arm above: the
                // task finished without ever holding a positive rate
                started[t] = true;
                trace[t].start = now;
            }
            trace[t].finish = now;
            queued[t] = false;
            if comps_on {
                comps.remove(t);
            }
            if dag.tasks[t].kind.is_flow() {
                rq_net.remove(t);
            } else {
                rq_cpu.remove(t);
            }
            for &s in &dag.succs[t] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    on_ready!(s);
                }
            }
        }
    }

    // Open-loop stop: settle the lazily-integrated byte counts (the
    // anchored horizon only materializes on component repricing) and
    // export the carry-over state. Closed-mode runs never set
    // `stopped`, so this block is unreachable for them.
    let stop_state = if stopped {
        if anchored {
            for t in 0..n {
                if !done[t] && rate_of[t] > 0.0 {
                    remaining[t] = (remaining[t] - rate_of[t] * (now - anchor_t[t])).max(0.0);
                    rate_of[t] = 0.0;
                    anchor_t[t] = now;
                }
            }
        }
        Some(StopState {
            at: now,
            remaining: remaining.clone(),
            attempts: if retry_on { attempts.clone() } else { Vec::new() },
            retry_gate: if retry_on { retry_gate.clone() } else { Vec::new() },
        })
    } else {
        None
    };

    // aggregate per logical task; quarantined chunks keep NaN traces
    // and are skipped (a fully-quarantined logical task has no entry —
    // without recovery every finish is set, so nothing is ever skipped)
    let mut orig_start: BTreeMap<TaskId, f64> = BTreeMap::new();
    let mut orig_finish: BTreeMap<TaskId, f64> = BTreeMap::new();
    for (i, t) in dag.tasks.iter().enumerate() {
        if trace[i].finish.is_nan() {
            continue;
        }
        let e = orig_start.entry(t.orig).or_insert(f64::INFINITY);
        *e = e.min(trace[i].start);
        let e = orig_finish.entry(t.orig).or_insert(f64::NEG_INFINITY);
        *e = e.max(trace[i].finish);
    }

    // per-job verdicts: a quarantined / exhausted job carries the
    // outcome recorded when it went down; every other job completed at
    // its latest member finish
    let mut job_fin = vec![0.0f64; n_jobs];
    for i in 0..n {
        if !trace[i].finish.is_nan() {
            let j = dag.job(i);
            if trace[i].finish > job_fin[j] {
                job_fin[j] = trace[i].finish;
            }
        }
    }
    let jobs: Vec<JobOutcome> = (0..n_jobs)
        .map(|j| match job_down.get(j).copied().flatten() {
            Some(out) => out,
            None => JobOutcome::Completed { finish: job_fin[j] },
        })
        .collect();

    // hand every buffer back so the next run on this scratch is warm
    scratch.rq_cpu_bucket = q_cpu_bucket;
    scratch.rq_net_bucket = q_net_bucket;
    scratch.rq_cpu_resort = q_cpu_resort;
    scratch.rq_net_resort = q_net_resort;
    scratch.comps = comps;
    scratch.fins = fins;
    scratch.ascr = ascr;
    scratch.remaining = remaining;
    scratch.indeg = indeg;
    scratch.done = done;
    scratch.started = started;
    scratch.seq = seq;
    scratch.queued = queued;
    scratch.key_of = key_of;
    scratch.rate_of = rate_of;
    scratch.anchor_t = anchor_t;
    scratch.group_of = group_of;
    scratch.virt = virt;
    scratch.caps = caps;
    scratch.users = users_scratch;
    scratch.sat_mark = sat_mark;
    scratch.load = load;
    scratch.load_touched = load_touched;
    scratch.members = members;
    scratch.group_pending = group_pending;
    scratch.group_open = group_open;
    scratch.parked = parked;
    scratch.group_dirty = group_dirty;
    scratch.grp_seen = grp_seen;
    scratch.arrivals = arrivals;
    scratch.gates = gates;
    scratch.fifo_prio_orig = fifo_prio_orig;
    scratch.comp_rated = comp_rated;
    scratch.comp_sorted = comp_sorted;
    scratch.new_comps = new_comps;
    scratch.live_scratch = live_scratch;
    scratch.near_done = near_done;
    scratch.grp_list = grp_list;
    scratch.sub_res = sub_res;
    scratch.sub_idx = sub_idx;
    scratch.sub_rates = sub_rates;
    scratch.rated = rated;
    scratch.completed = completed;
    scratch.touched = touched;
    scratch.grp_scratch = grp_scratch;
    scratch.dirty_groups = dirty_groups;
    scratch.dirty_singles = dirty_singles;
    scratch.heap_removed = heap_removed;
    scratch.heap_inserts = heap_inserts;
    scratch.starts = starts;
    scratch.workers = workers;
    scratch.fill_list = fill_list;
    scratch.dyn_state = dyn_state;
    scratch.dyn_caps = dyn_caps;
    scratch.dyn_task_res = dyn_task_res;
    scratch.dyn_touched = dyn_touched;
    scratch.dyn_touched_list = dyn_touched_list;
    scratch.dyn_alive = dyn_alive;
    scratch.attempts = attempts;
    scratch.retry_gate = retry_gate;
    scratch.quarantined = quarantined;
    scratch.job_down = job_down;
    scratch.job_stuck = job_stuck;
    scratch.failed_hosts = failed_hosts;

    Ok(SimResult {
        makespan: now,
        trace,
        orig_start,
        orig_finish,
        events,
        jobs,
        retries,
        lost_work,
        stopped: stop_state,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::spec::{Cluster, SimKind, SimTask};

    fn task(kind: SimKind, size: f64) -> SimTask {
        SimTask { orig: 0, chunk: (0, 1), kind, size, priority: 0, gate: 0.0, coflow: None }
    }

    #[test]
    fn single_task_runs_at_full_rate() {
        let mut d = SimDag::default();
        let mut t = task(SimKind::Compute { host: 0 }, 5.0);
        t.orig = 1;
        d.push(t);
        let r = simulate(&d, &Cluster::uniform(1), &SimConfig::default()).unwrap();
        assert!((r.makespan - 5.0).abs() < 1e-9);
        assert!((r.finish_of(1) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn chain_respects_dependencies() {
        let mut d = SimDag::default();
        let a = d.push({ let mut t = task(SimKind::Compute { host: 0 }, 2.0); t.orig = 1; t });
        let f = d.push({ let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 3.0); t.orig = 2; t });
        let b = d.push({ let mut t = task(SimKind::Compute { host: 1 }, 1.0); t.orig = 3; t });
        d.dep(a, f);
        d.dep(f, b);
        let r = simulate(&d, &Cluster::uniform(2), &SimConfig::default()).unwrap();
        assert!((r.makespan - 6.0).abs() < 1e-9);
        assert!((r.start_of(2) - 2.0).abs() < 1e-9);
        assert!((r.start_of(3) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn fair_sharing_extends_completion() {
        // two unit flows from host 0: fair => both finish at 2
        let mut d = SimDag::default();
        let a = d.push({ let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 1.0); t.orig = 1; t });
        let b = d.push({ let mut t = task(SimKind::Flow { src: 0, dst: 2 }, 1.0); t.orig = 2; t });
        let _ = (a, b);
        let r = simulate(&d, &Cluster::uniform(3), &SimConfig::default()).unwrap();
        assert!((r.finish_of(1) - 2.0).abs() < 1e-9);
        assert!((r.finish_of(2) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn priority_serializes_flows() {
        let mut d = SimDag::default();
        let mut t1 = task(SimKind::Flow { src: 0, dst: 1 }, 1.0);
        t1.orig = 1;
        t1.priority = 10;
        let mut t2 = task(SimKind::Flow { src: 0, dst: 2 }, 1.0);
        t2.orig = 2;
        t2.priority = 1;
        d.push(t1);
        d.push(t2);
        let cfg = SimConfig { policy: Policy::priority(), ..Default::default() };
        let r = simulate(&d, &Cluster::uniform(3), &cfg).unwrap();
        assert!((r.finish_of(1) - 1.0).abs() < 1e-9);
        assert!((r.finish_of(2) - 2.0).abs() < 1e-9);
    }

    /// The early exit must be per resource class and per resource: a
    /// low-priority flow on disjoint NICs keeps running after the top
    /// level saturates its own links.
    #[test]
    fn priority_disjoint_low_level_still_served() {
        let mut d = SimDag::default();
        let mut hi = task(SimKind::Flow { src: 0, dst: 1 }, 1.0);
        hi.orig = 1;
        hi.priority = 10;
        let mut lo = task(SimKind::Flow { src: 2, dst: 3 }, 1.0);
        lo.orig = 2;
        lo.priority = 1;
        d.push(hi);
        d.push(lo);
        let cfg = SimConfig { policy: Policy::priority(), ..Default::default() };
        let r = simulate(&d, &Cluster::uniform(4), &cfg).unwrap();
        assert!((r.finish_of(1) - 1.0).abs() < 1e-9);
        assert!((r.finish_of(2) - 1.0).abs() < 1e-9, "disjoint flow must run concurrently");
    }

    #[test]
    fn gate_delays_start() {
        let mut d = SimDag::default();
        let mut t = task(SimKind::Compute { host: 0 }, 1.0);
        t.orig = 1;
        t.gate = 4.0;
        d.push(t);
        let r = simulate(&d, &Cluster::uniform(1), &SimConfig::default()).unwrap();
        assert!((r.start_of(1) - 4.0).abs() < 1e-9);
        assert!((r.makespan - 5.0).abs() < 1e-9);
    }

    #[test]
    fn fifo_orders_by_readiness() {
        // a(2) -> f1 ; b(1) -> f2 ; both flows share up0.
        // b finishes first so f2 ready first => f2 runs to completion first.
        let mut d = SimDag::default();
        let a = d.push({ let mut t = task(SimKind::Compute { host: 0 }, 2.0); t.orig = 1; t });
        let b = d.push({ let mut t = task(SimKind::Compute { host: 0 }, 1.0); t.orig = 2; t });
        let f1 = d.push({ let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 1.0); t.orig = 3; t });
        let f2 = d.push({ let mut t = task(SimKind::Flow { src: 0, dst: 2 }, 1.0); t.orig = 4; t });
        d.dep(a, f1);
        d.dep(b, f2);
        let cluster = Cluster::with_cores(3, 2.0);
        let cfg = SimConfig { policy: Policy::fifo(), ..Default::default() };
        let r = simulate(&d, &cluster, &cfg).unwrap();
        // b done t=1, f2 runs 1->2 ; a done t=2, f1 runs 2->3
        assert!((r.finish_of(4) - 2.0).abs() < 1e-9);
        assert!((r.finish_of(3) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn coflow_all_or_nothing_barrier() {
        // f1 ready at 0 (coflow 0 with f2); f2 gated behind compute(3).
        // Under coflow policy f1 must wait for f2's readiness.
        let mut d = SimDag::default();
        let c = d.push({ let mut t = task(SimKind::Compute { host: 3 }, 3.0); t.orig = 1; t });
        let f1 = d.push({
            let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 1.0);
            t.orig = 2;
            t.coflow = Some(0);
            t
        });
        let f2 = d.push({
            let mut t = task(SimKind::Flow { src: 2, dst: 1 }, 1.0);
            t.orig = 3;
            t.coflow = Some(0);
            t
        });
        d.dep(c, f2);
        let _ = f1;
        let cfg = SimConfig { policy: Policy::coflow(), ..Default::default() };
        let r = simulate(&d, &Cluster::uniform(4), &cfg).unwrap();
        assert!(r.start_of(2) >= 3.0 - 1e-9, "f1 must wait for the whole coflow");
    }

    #[test]
    fn deadlock_reported_not_hung() {
        // flow into a zero-capacity NIC can never progress; the report
        // names the starved task and the dead resource (up(0) = slot 1)
        let mut d = SimDag::default();
        d.push({ let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 1.0); t.orig = 1; t });
        let mut cluster = Cluster::uniform(2);
        cluster.hosts[0].nic_up = 0.0;
        for horizon in [HorizonKind::Eager, HorizonKind::Anchored] {
            let cfg = SimConfig { horizon, ..Default::default() };
            let err = simulate(&d, &cluster, &cfg).unwrap_err();
            match err {
                SimError::Deadlock { n_remaining, stuck, nearest_gate, .. } => {
                    assert_eq!(n_remaining, 1);
                    assert_eq!(stuck, Some((0, StuckReason::Starved { resource: Some(1) })));
                    assert_eq!(nearest_gate, None);
                }
                other => panic!("expected deadlock, got {other:?}"),
            }
        }
    }

    /// A coflow barrier that can never open is reported as such: the
    /// parked member, its raw group id, and the gate that will never
    /// fire all appear in the error.
    #[test]
    fn deadlock_reports_parked_coflow_and_blocked_gate() {
        let mut d = SimDag::default();
        // f1 is ready but parked: its group peer f2 depends on fz,
        // which feeds a zero-capacity NIC
        let f1 = d.push({
            let mut t = task(SimKind::Flow { src: 2, dst: 3 }, 1.0);
            t.orig = 1;
            t.coflow = Some(9);
            t
        });
        let fz = d.push({ let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 1.0); t.orig = 2; t });
        let f2 = d.push({
            let mut t = task(SimKind::Flow { src: 2, dst: 1 }, 1.0);
            t.orig = 3;
            t.coflow = Some(9);
            t.gate = 7.5;
            t
        });
        d.dep(fz, f2);
        let _ = f1;
        let mut cluster = Cluster::uniform(4);
        cluster.hosts[0].nic_up = 0.0;
        let cfg = SimConfig { policy: Policy::coflow(), ..Default::default() };
        let err = simulate(&d, &cluster, &cfg).unwrap_err();
        match err {
            SimError::Deadlock { now, n_remaining, stuck, nearest_gate } => {
                assert_eq!(now, 0.0);
                assert_eq!(n_remaining, 3);
                // task 0 (f1) is parked on raw coflow group 9
                assert_eq!(stuck, Some((0, StuckReason::Parked { group: 9 })));
                // f2's gate never fires: its dependency is starved
                assert_eq!(nearest_gate, Some((2, 7.5)));
                let msg = format!("{err}");
                assert!(msg.contains("parked on coflow group 9"), "{msg}");
                assert!(msg.contains("gate t=7.5"), "{msg}");
            }
            other => panic!("expected deadlock, got {other:?}"),
        }
    }

    #[test]
    fn dummy_tasks_cost_nothing() {
        let mut d = SimDag::default();
        let s = d.push({ let mut t = task(SimKind::Dummy, 0.0); t.orig = 0; t });
        let c = d.push({ let mut t = task(SimKind::Compute { host: 0 }, 1.0); t.orig = 1; t });
        let e = d.push({ let mut t = task(SimKind::Dummy, 0.0); t.orig = 2; t });
        d.dep(s, c);
        d.dep(c, e);
        let r = simulate(&d, &Cluster::uniform(1), &SimConfig::default()).unwrap();
        assert!((r.makespan - 1.0).abs() < 1e-9);
    }

    /// Regression for the FIFO tie-slot cap: the old packed encoding
    /// collapsed same-instant singles past the 1023rd into one shared
    /// priority level, which made them fair-share instead of serialize.
    #[test]
    fn fifo_many_simultaneous_singles_stay_serialized() {
        let n = 1100usize;
        let mut d = SimDag::default();
        for i in 0..n {
            d.push(SimTask {
                orig: i,
                chunk: (0, 1),
                kind: SimKind::Flow { src: 0, dst: 1 },
                size: 1.0,
                priority: 0,
                gate: 0.0,
                coflow: None,
            });
        }
        let cfg = SimConfig { policy: Policy::fifo(), ..Default::default() };
        let r = simulate(&d, &Cluster::uniform(2), &cfg).unwrap();
        assert!((r.makespan - n as f64).abs() < 1e-6);
        // strict serialization: the k-th flow to finish does so at k
        let mut finishes: Vec<f64> = (0..n).map(|i| r.finish_of(i)).collect();
        finishes.sort_by(f64::total_cmp);
        for (k, f) in finishes.iter().enumerate() {
            assert!(
                (f - (k + 1) as f64).abs() < 1e-6,
                "flow #{k} finished at {f}, want {}",
                k + 1
            );
        }
    }

    #[test]
    fn oversubscribed_agg_link_throttles_cross_rack_flow() {
        // 4 hosts, 2 racks, ratio 4: agg capacity 2/4 = 0.5. A unit
        // cross-rack flow takes 2; the same flow intra-rack takes 1.
        let mk = |src: usize, dst: usize| {
            let mut d = SimDag::default();
            d.push({
                let mut t = task(SimKind::Flow { src, dst }, 1.0);
                t.orig = 1;
                t
            });
            d
        };
        let cluster = Cluster::oversubscribed(4, 2, 4.0);
        let cross = simulate(&mk(0, 3), &cluster, &SimConfig::default()).unwrap();
        assert!((cross.makespan - 2.0).abs() < 1e-9, "cross {}", cross.makespan);
        let intra = simulate(&mk(0, 1), &cluster, &SimConfig::default()).unwrap();
        assert!((intra.makespan - 1.0).abs() < 1e-9, "intra {}", intra.makespan);
    }

    #[test]
    fn nonblocking_ratio_matches_bigswitch() {
        // ratio small enough that the agg links can never bind: results
        // must equal the plain big switch exactly.
        let mut d = SimDag::default();
        let a = d.push({ let mut t = task(SimKind::Flow { src: 0, dst: 2 }, 1.0); t.orig = 1; t });
        let b = d.push({ let mut t = task(SimKind::Flow { src: 0, dst: 3 }, 1.0); t.orig = 2; t });
        let _ = (a, b);
        let big = simulate(&d, &Cluster::uniform(4), &SimConfig::default()).unwrap();
        let slack = simulate(&d, &Cluster::oversubscribed(4, 2, 0.01), &SimConfig::default())
            .unwrap();
        assert!((big.makespan - slack.makespan).abs() < 1e-12);
        for i in 0..d.len() {
            assert!((big.trace[i].finish - slack.trace[i].finish).abs() < 1e-12);
        }
    }

    #[test]
    fn parallel_fabric_path_selection_decides_contention() {
        // flows (0->2) and (1->3): under Hash both map to trunk (s+d)%2=0
        // and halve its 0.5 capacity; under BySrc they split trunks and
        // each gets the full 0.5.
        use crate::sim::topology::{PathSelect, Topology};
        let mut d = SimDag::default();
        d.push({ let mut t = task(SimKind::Flow { src: 0, dst: 2 }, 1.0); t.orig = 1; t });
        d.push({ let mut t = task(SimKind::Flow { src: 1, dst: 3 }, 1.0); t.orig = 2; t });
        let hash = Cluster::parallel_fabrics(4, 2, 0.5);
        let r = simulate(&d, &hash, &SimConfig::default()).unwrap();
        assert!((r.makespan - 4.0).abs() < 1e-9, "hash-collision {}", r.makespan);
        let bysrc = Cluster::uniform(4).with_topology(Topology::ParallelFabrics {
            k: 2,
            select: PathSelect::BySrc,
            trunk: 0.5,
        });
        let r = simulate(&d, &bysrc, &SimConfig::default()).unwrap();
        assert!((r.makespan - 2.0).abs() < 1e-9, "split-fabrics {}", r.makespan);
    }

    #[test]
    fn makespan_monotone_in_sizes() {
        let build = |sz: f64| {
            let mut d = SimDag::default();
            let a = d.push({ let mut t = task(SimKind::Compute { host: 0 }, sz); t.orig = 1; t });
            let f = d.push({ let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 1.0); t.orig = 2; t });
            d.dep(a, f);
            d
        };
        let r1 = simulate(&build(1.0), &Cluster::uniform(2), &SimConfig::default()).unwrap();
        let r2 = simulate(&build(2.0), &Cluster::uniform(2), &SimConfig::default()).unwrap();
        assert!(r2.makespan > r1.makespan);
    }

    /// A mixed DAG (priorities, gates, a shared NIC) must produce the
    /// same events and traces under both queue kinds — the unit-level
    /// slice of the `prop_queue_equivalence` oracle.
    #[test]
    fn queue_kinds_agree_on_mixed_dag() {
        let mut d = SimDag::default();
        let a = d.push({ let mut t = task(SimKind::Compute { host: 0 }, 1.5); t.orig = 1; t });
        let f1 = d.push({
            let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 2.0);
            t.orig = 2;
            t.priority = 5;
            t
        });
        let f2 = d.push({
            let mut t = task(SimKind::Flow { src: 0, dst: 2 }, 1.0);
            t.orig = 3;
            t.priority = 1;
            t.gate = 0.5;
            t
        });
        let b = d.push({ let mut t = task(SimKind::Compute { host: 1 }, 1.0); t.orig = 4; t });
        d.dep(a, f1);
        d.dep(f1, b);
        let _ = f2;
        let cluster = Cluster::uniform(3);
        for policy in [Policy::fair(), Policy::priority(), Policy::fifo()] {
            // the bitwise queue oracle lives inside the eager horizon;
            // cross-horizon agreement is tolerance-based (see below)
            let full = simulate(
                &d,
                &cluster,
                &SimConfig {
                    policy,
                    queue: QueueKind::FullResort,
                    horizon: HorizonKind::Eager,
                    ..Default::default()
                },
            )
            .unwrap();
            let inc = simulate(
                &d,
                &cluster,
                &SimConfig {
                    policy,
                    queue: QueueKind::Incremental,
                    horizon: HorizonKind::Eager,
                    ..Default::default()
                },
            )
            .unwrap();
            assert_eq!(full.events, inc.events, "{policy:?}");
            assert!((full.makespan - inc.makespan).abs() < 1e-12, "{policy:?}");
            for i in 0..d.len() {
                assert!((full.trace[i].finish - inc.trace[i].finish).abs() < 1e-12);
            }
        }
    }

    /// Component-wise allocation must replay the whole-set oracle
    /// bit-for-bit: same events, same makespan, same traces — on a DAG
    /// that exercises merges (a flow bridging NICs), splits (completions
    /// severing a chain), gates and priorities.
    #[test]
    fn alloc_kinds_agree_on_mixed_dag() {
        let mut d = SimDag::default();
        let a = d.push({ let mut t = task(SimKind::Compute { host: 0 }, 1.5); t.orig = 1; t });
        let f1 = d.push({
            let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 2.0);
            t.orig = 2;
            t.priority = 5;
            t
        });
        let f2 = d.push({
            let mut t = task(SimKind::Flow { src: 0, dst: 2 }, 1.0);
            t.orig = 3;
            t.priority = 1;
            t.gate = 0.5;
            t
        });
        let f3 = d.push({
            let mut t = task(SimKind::Flow { src: 2, dst: 1 }, 0.7);
            t.orig = 5;
            t
        });
        let b = d.push({ let mut t = task(SimKind::Compute { host: 1 }, 1.0); t.orig = 4; t });
        d.dep(a, f1);
        d.dep(f1, b);
        let _ = (f2, f3);
        let cluster = Cluster::uniform(3);
        for policy in [Policy::fair(), Policy::priority(), Policy::fifo(), Policy::coflow()] {
            // bitwise only within the eager horizon: anchored re-anchors
            // whole-set and component paths at different cadences
            let whole = simulate(
                &d,
                &cluster,
                &SimConfig {
                    policy,
                    alloc: AllocKind::WholeSet,
                    horizon: HorizonKind::Eager,
                    ..Default::default()
                },
            )
            .unwrap();
            let comp = simulate(
                &d,
                &cluster,
                &SimConfig {
                    policy,
                    alloc: AllocKind::Components,
                    horizon: HorizonKind::Eager,
                    ..Default::default()
                },
            )
            .unwrap();
            assert_eq!(whole.events, comp.events, "{policy:?}");
            assert_eq!(
                whole.makespan.to_bits(),
                comp.makespan.to_bits(),
                "{policy:?}: {} vs {}",
                whole.makespan,
                comp.makespan
            );
            for i in 0..d.len() {
                assert_eq!(whole.trace[i].finish.to_bits(), comp.trace[i].finish.to_bits());
                assert_eq!(whole.trace[i].start.to_bits(), comp.trace[i].start.to_bits());
            }
        }
    }

    /// A quiescent disjoint component must not be repriced: two flows on
    /// separate NIC pairs finish at their solo times under both alloc
    /// kinds, and the coflow barrier + SEBF preemption path stays
    /// bit-identical when groups arrive mid-run.
    #[test]
    fn coflow_alloc_kinds_agree_with_preemption() {
        let mut d = SimDag::default();
        let c = d.push({ let mut t = task(SimKind::Compute { host: 3 }, 2.5); t.orig = 1; t });
        let fa = d.push({
            let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 3.0);
            t.orig = 2;
            t.coflow = Some(7);
            t
        });
        let fb = d.push({
            let mut t = task(SimKind::Flow { src: 0, dst: 2 }, 1.0);
            t.orig = 3;
            t.coflow = Some(9);
            t
        });
        // a disjoint singleton flow in its own component
        let fc = d.push({
            let mut t = task(SimKind::Flow { src: 2, dst: 3 }, 1.2);
            t.orig = 4;
            t
        });
        d.dep(c, fb);
        let _ = (fa, fc);
        let cfg = |alloc| SimConfig {
            policy: Policy::coflow(),
            alloc,
            horizon: HorizonKind::Eager,
            ..Default::default()
        };
        let whole = simulate(&d, &Cluster::uniform(4), &cfg(AllocKind::WholeSet)).unwrap();
        let comp = simulate(&d, &Cluster::uniform(4), &cfg(AllocKind::Components)).unwrap();
        assert_eq!(whole.events, comp.events);
        assert_eq!(whole.makespan.to_bits(), comp.makespan.to_bits());
        for i in 0..d.len() {
            assert_eq!(whole.trace[i].finish.to_bits(), comp.trace[i].finish.to_bits());
        }
        // semantics unchanged from the invalidation test: A keeps the NIC
        assert!((comp.finish_of(2) - 3.0).abs() < 1e-9);
        assert!((comp.finish_of(3) - 4.0).abs() < 1e-9);
        assert!((comp.finish_of(4) - 1.2).abs() < 1e-9, "disjoint flow runs solo");
    }

    /// SEBF keys must be refreshed as remaining bytes drain: a big
    /// coflow that becomes the smallest-bound group mid-run preempts.
    #[test]
    fn coflow_key_invalidation_reorders_groups() {
        // Group A (size 3) runs alone from t=0; at t=2.5 group B
        // (size 1) arrives behind a compute. A has 0.5 remaining — its
        // bound (0.5) now beats B's (1.0), so A keeps the NIC and
        // finishes at 3; B follows at 4. Without invalidation A's stale
        // bound (3.0) would let B preempt.
        let mut d = SimDag::default();
        let c = d.push({ let mut t = task(SimKind::Compute { host: 3 }, 2.5); t.orig = 1; t });
        let fa = d.push({
            let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 3.0);
            t.orig = 2;
            t.coflow = Some(7);
            t
        });
        let fb = d.push({
            let mut t = task(SimKind::Flow { src: 0, dst: 2 }, 1.0);
            t.orig = 3;
            t.coflow = Some(9);
            t
        });
        d.dep(c, fb);
        let _ = fa;
        let cfg = SimConfig { policy: Policy::coflow(), ..Default::default() };
        let r = simulate(&d, &Cluster::uniform(4), &cfg).unwrap();
        assert!((r.finish_of(2) - 3.0).abs() < 1e-9, "A finishes first: {}", r.finish_of(2));
        assert!((r.finish_of(3) - 4.0).abs() < 1e-9, "B follows: {}", r.finish_of(3));
    }

    /// The cross-horizon tolerance oracle at unit scale: anchored and
    /// eager time advance must agree on makespan and every per-chunk
    /// trace within 1e-6 relative, for every policy and both alloc
    /// kinds, on DAGs that exercise gates, priorities, coflow barriers
    /// and SEBF preemption. (Bit-identity is deliberately *not*
    /// claimed: anchored subtraction reorders the float arithmetic.)
    #[test]
    fn horizon_kinds_agree_within_tolerance() {
        // the shared contract every oracle site uses
        let close = crate::sim::horizon::within_tolerance;
        // DAG 1: the mixed priorities/gates DAG; DAG 2: the coflow
        // preemption DAG from coflow_key_invalidation_reorders_groups
        let mut d1 = SimDag::default();
        let a = d1.push({ let mut t = task(SimKind::Compute { host: 0 }, 1.5); t.orig = 1; t });
        let f1 = d1.push({
            let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 2.0);
            t.orig = 2;
            t.priority = 5;
            t
        });
        let f2 = d1.push({
            let mut t = task(SimKind::Flow { src: 0, dst: 2 }, 1.0);
            t.orig = 3;
            t.priority = 1;
            t.gate = 0.5;
            t
        });
        let b = d1.push({ let mut t = task(SimKind::Compute { host: 1 }, 1.0); t.orig = 4; t });
        d1.dep(a, f1);
        d1.dep(f1, b);
        let _ = f2;
        let mut d2 = SimDag::default();
        let c = d2.push({ let mut t = task(SimKind::Compute { host: 3 }, 2.5); t.orig = 1; t });
        let fa = d2.push({
            let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 3.0);
            t.orig = 2;
            t.coflow = Some(7);
            t
        });
        let fb = d2.push({
            let mut t = task(SimKind::Flow { src: 0, dst: 2 }, 1.0);
            t.orig = 3;
            t.coflow = Some(9);
            t
        });
        let fc = d2.push({
            let mut t = task(SimKind::Flow { src: 2, dst: 3 }, 1.2);
            t.orig = 4;
            t
        });
        d2.dep(c, fb);
        let _ = (fa, fc);
        let cluster = Cluster::uniform(4);
        for d in [&d1, &d2] {
            for policy in
                [Policy::fair(), Policy::priority(), Policy::fifo(), Policy::coflow()]
            {
                for alloc in [AllocKind::Components, AllocKind::WholeSet] {
                    let mk = |horizon| SimConfig { policy, alloc, horizon, ..Default::default() };
                    let eager = simulate(d, &cluster, &mk(HorizonKind::Eager)).unwrap();
                    let anch = simulate(d, &cluster, &mk(HorizonKind::Anchored)).unwrap();
                    assert!(
                        close(eager.makespan, anch.makespan),
                        "{policy:?}/{alloc:?}: makespan {} vs {}",
                        eager.makespan,
                        anch.makespan
                    );
                    for i in 0..d.len() {
                        assert!(
                            close(eager.trace[i].start, anch.trace[i].start)
                                && close(eager.trace[i].finish, anch.trace[i].finish),
                            "{policy:?}/{alloc:?}: chunk {i} {:?}..{:?} vs {:?}..{:?}",
                            eager.trace[i].start,
                            eager.trace[i].finish,
                            anch.trace[i].start,
                            anch.trace[i].finish
                        );
                    }
                }
            }
        }
    }

    /// The scenario-JSON `"engine"` object mirrors the CLI flags.
    #[test]
    fn engine_config_from_json() {
        use crate::util::json::Json;
        let j = Json::parse(r#"{"queue":"fullresort","alloc":"wholeset","horizon":"eager"}"#)
            .unwrap();
        let mut cfg = SimConfig::default();
        cfg.apply_json(&j).unwrap();
        assert_eq!(cfg.queue, QueueKind::FullResort);
        assert_eq!(cfg.alloc, AllocKind::WholeSet);
        assert_eq!(cfg.horizon, HorizonKind::Eager);
        // keys are optional; unknown keys and values are rejected
        let mut cfg = SimConfig::default();
        cfg.apply_json(&Json::parse(r#"{"horizon":"anchored"}"#).unwrap()).unwrap();
        assert_eq!(cfg.queue, QueueKind::Incremental);
        assert_eq!(cfg.horizon, HorizonKind::Anchored);
        assert!(cfg.apply_json(&Json::parse(r#"{"horizon":"lazy"}"#).unwrap()).is_err());
        assert!(cfg.apply_json(&Json::parse(r#"{"quue":"incremental"}"#).unwrap()).is_err());
        // threads: integer >= 1; 0, fractions and non-numbers rejected
        let mut cfg = SimConfig::default();
        cfg.apply_json(&Json::parse(r#"{"threads":4}"#).unwrap()).unwrap();
        assert_eq!(cfg.threads, 4);
        cfg.apply_json(&Json::parse(r#"{"threads":1}"#).unwrap()).unwrap();
        assert_eq!(cfg.threads, 1);
        assert!(cfg.apply_json(&Json::parse(r#"{"threads":0}"#).unwrap()).is_err());
        assert!(cfg.apply_json(&Json::parse(r#"{"threads":2.5}"#).unwrap()).is_err());
        assert!(cfg.apply_json(&Json::parse(r#"{"threads":"four"}"#).unwrap()).is_err());
        assert_eq!(cfg.threads, 4, "rejected values must not clobber the config");
    }

    /// One scratch, many runs: every run must be bit-identical to a
    /// cold run whatever ran on the scratch before — the invariant
    /// batched plan evaluation (`EvalContext`, `whatif::explore`)
    /// relies on. Crosses two structurally different DAGs (different
    /// sizes, coflow groups vs none), all four policies and both
    /// orders, over the default engine configuration.
    #[test]
    fn scratch_reuse_is_bit_identical() {
        let mut d1 = SimDag::default();
        let a = d1.push({ let mut t = task(SimKind::Compute { host: 0 }, 1.5); t.orig = 1; t });
        let f1 = d1.push({
            let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 2.0);
            t.orig = 2;
            t.priority = 5;
            t
        });
        let f2 = d1.push({
            let mut t = task(SimKind::Flow { src: 0, dst: 2 }, 1.0);
            t.orig = 3;
            t.priority = 1;
            t.gate = 0.5;
            t
        });
        let b = d1.push({ let mut t = task(SimKind::Compute { host: 1 }, 1.0); t.orig = 4; t });
        d1.dep(a, f1);
        d1.dep(f1, b);
        let _ = f2;
        let mut d2 = SimDag::default();
        let c = d2.push({ let mut t = task(SimKind::Compute { host: 3 }, 2.5); t.orig = 1; t });
        let fa = d2.push({
            let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 3.0);
            t.orig = 2;
            t.coflow = Some(7);
            t
        });
        let fb = d2.push({
            let mut t = task(SimKind::Flow { src: 0, dst: 2 }, 1.0);
            t.orig = 3;
            t.coflow = Some(9);
            t
        });
        d2.dep(c, fb);
        let _ = fa;
        let cluster = Cluster::uniform(4);
        let policies = [Policy::fair(), Policy::priority(), Policy::fifo(), Policy::coflow()];
        let mut scratch = SimScratch::default();
        for &(da, db) in &[(&d1, &d2), (&d2, &d1)] {
            for pa in policies {
                for pb in policies {
                    let cfg_a = SimConfig { policy: pa, ..Default::default() };
                    let cfg_b = SimConfig { policy: pb, ..Default::default() };
                    let cold_a = simulate(da, &cluster, &cfg_a).unwrap();
                    let cold_b = simulate(db, &cluster, &cfg_b).unwrap();
                    let warm_a = simulate_in(da, &cluster, &cfg_a, &mut scratch).unwrap();
                    let warm_b = simulate_in(db, &cluster, &cfg_b, &mut scratch).unwrap();
                    for (cold, warm) in [(&cold_a, &warm_a), (&cold_b, &warm_b)] {
                        assert_eq!(cold.events, warm.events);
                        assert_eq!(cold.makespan.to_bits(), warm.makespan.to_bits());
                        for i in 0..cold.trace.len() {
                            assert_eq!(
                                cold.trace[i].start.to_bits(),
                                warm.trace[i].start.to_bits()
                            );
                            assert_eq!(
                                cold.trace[i].finish.to_bits(),
                                warm.trace[i].finish.to_bits()
                            );
                        }
                    }
                }
            }
        }
    }

    /// Anchored + components: a disjoint quiescent flow is never
    /// re-anchored by events elsewhere, and still finishes exactly at
    /// its solo time while the coflow preemption plays out around it.
    #[test]
    fn anchored_quiescent_component_finishes_at_solo_time() {
        let mut d = SimDag::default();
        let c = d.push({ let mut t = task(SimKind::Compute { host: 3 }, 2.5); t.orig = 1; t });
        let fa = d.push({
            let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 3.0);
            t.orig = 2;
            t.coflow = Some(7);
            t
        });
        let fb = d.push({
            let mut t = task(SimKind::Flow { src: 0, dst: 2 }, 1.0);
            t.orig = 3;
            t.coflow = Some(9);
            t
        });
        // disjoint singleton on its own NIC pair: its component sees no
        // event until its own completion
        let fc = d.push({
            let mut t = task(SimKind::Flow { src: 4, dst: 5 }, 1.2);
            t.orig = 4;
            t
        });
        d.dep(c, fb);
        let _ = (fa, fc);
        let cfg = SimConfig { policy: Policy::coflow(), ..Default::default() };
        assert_eq!(cfg.horizon, HorizonKind::Anchored, "anchored is the default");
        let r = simulate(&d, &Cluster::uniform(6), &cfg).unwrap();
        assert!((r.finish_of(2) - 3.0).abs() < 1e-9, "A keeps the NIC: {}", r.finish_of(2));
        assert!((r.finish_of(3) - 4.0).abs() < 1e-9, "B follows: {}", r.finish_of(3));
        assert!((r.finish_of(4) - 1.2).abs() < 1e-9, "solo flow: {}", r.finish_of(4));
    }

    /// A wide wave of flows over disjoint host pairs (many live
    /// components, enough members to cross `PAR_FILL_MIN_TASKS`) plus a
    /// gated bridge wave that merges neighbouring pairs as the first
    /// wave drains: the parallel event loop must reproduce the
    /// `threads = 1` oracle for every thread count — bitwise under the
    /// eager horizon, within the documented `1e-6` tolerance under
    /// anchored (in practice the epilogue replay makes anchored bitwise
    /// too, but the promised contract is the tolerance one).
    fn wave_dag() -> (SimDag, Cluster) {
        let hosts = 64;
        let n_wave = 2 * PAR_FILL_MIN_TASKS;
        let mut d = SimDag::default();
        let mut prev = Vec::new();
        for i in 0..n_wave {
            let src = (2 * i) % hosts;
            let dst = (2 * i + 1) % hosts;
            let t = d.push({
                let mut t = task(SimKind::Flow { src, dst }, 1.0 + (i % 7) as f64 * 0.25);
                t.orig = i;
                t
            });
            prev.push(t);
        }
        // bridge wave: each flow straddles two neighbouring pairs and
        // is gated behind both, so completions repeatedly merge and
        // re-split components; every fourth shares a coflow group to
        // drive the grouped re-key path through the workers
        for i in 0..n_wave / 2 {
            let src = (2 * i + 1) % hosts;
            let dst = (2 * i + 2) % hosts;
            let t = d.push({
                let mut t = task(SimKind::Flow { src, dst }, 0.5 + (i % 5) as f64 * 0.3);
                t.orig = n_wave + i;
                t.coflow = Some(i / 4);
                t
            });
            d.dep(prev[i], t);
            d.dep(prev[i + 1], t);
        }
        (d, Cluster::uniform(hosts))
    }

    #[test]
    fn parallel_threads_match_serial_oracle() {
        let (d, cluster) = wave_dag();
        for policy in [Policy::fair(), Policy::priority(), Policy::fifo(), Policy::coflow()] {
            for horizon in [HorizonKind::Eager, HorizonKind::Anchored] {
                let mk = |threads| SimConfig { policy, horizon, threads, ..Default::default() };
                let base = simulate(&d, &cluster, &mk(1)).unwrap();
                for threads in [2usize, 4] {
                    let par = simulate(&d, &cluster, &mk(threads)).unwrap();
                    if horizon == HorizonKind::Eager {
                        assert_eq!(
                            base.events, par.events,
                            "{policy:?}/{horizon:?} t{threads}"
                        );
                        assert_eq!(
                            base.makespan.to_bits(),
                            par.makespan.to_bits(),
                            "{policy:?}/{horizon:?} t{threads}: {} vs {}",
                            base.makespan,
                            par.makespan
                        );
                        for i in 0..d.len() {
                            assert_eq!(
                                base.trace[i].start.to_bits(),
                                par.trace[i].start.to_bits(),
                                "{policy:?} t{threads} chunk {i} start"
                            );
                            assert_eq!(
                                base.trace[i].finish.to_bits(),
                                par.trace[i].finish.to_bits(),
                                "{policy:?} t{threads} chunk {i} finish"
                            );
                        }
                    } else {
                        let close = crate::sim::horizon::within_tolerance;
                        assert!(
                            close(base.makespan, par.makespan),
                            "{policy:?} t{threads}: makespan {} vs {}",
                            base.makespan,
                            par.makespan
                        );
                        for i in 0..d.len() {
                            assert!(
                                close(base.trace[i].start, par.trace[i].start)
                                    && close(base.trace[i].finish, par.trace[i].finish),
                                "{policy:?} t{threads} chunk {i}"
                            );
                        }
                    }
                }
            }
        }
    }

    /// Small events must not regress under `threads > 1`: a DAG far
    /// below the fan-out threshold runs the parallel path inline (one
    /// worker, zero spawns) and still matches the oracle bitwise.
    #[test]
    fn parallel_inline_below_threshold_is_bit_identical() {
        let mut d = SimDag::default();
        let a = d.push({ let mut t = task(SimKind::Compute { host: 0 }, 1.5); t.orig = 1; t });
        let f1 = d.push({
            let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 2.0);
            t.orig = 2;
            t.priority = 5;
            t
        });
        let b = d.push({ let mut t = task(SimKind::Compute { host: 1 }, 1.0); t.orig = 4; t });
        d.dep(a, f1);
        d.dep(f1, b);
        let cluster = Cluster::uniform(3);
        for horizon in [HorizonKind::Eager, HorizonKind::Anchored] {
            let mk = |threads| SimConfig {
                policy: Policy::priority(),
                horizon,
                threads,
                ..Default::default()
            };
            let base = simulate(&d, &cluster, &mk(1)).unwrap();
            let par = simulate(&d, &cluster, &mk(4)).unwrap();
            assert_eq!(base.events, par.events, "{horizon:?}");
            assert_eq!(base.makespan.to_bits(), par.makespan.to_bits(), "{horizon:?}");
            for i in 0..d.len() {
                assert_eq!(base.trace[i].start.to_bits(), par.trace[i].start.to_bits());
                assert_eq!(base.trace[i].finish.to_bits(), par.trace[i].finish.to_bits());
            }
        }
    }
}
