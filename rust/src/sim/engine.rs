//! The fluid discrete-event engine.
//!
//! Tasks become ready when all predecessors finish (chunk-level deps
//! encode pipelining), their gate time has passed and — under coflow
//! semantics — their whole group is ready (all-or-nothing). At every
//! event boundary the policy recomputes rates; the engine advances to
//! the next completion or gate expiry.

use std::collections::BTreeMap;

use super::alloc;
use super::spec::{CpuPolicy, Cluster, NetPolicy, Policy, SimDag};
use crate::mxdag::TaskId;

const EPS: f64 = 1e-9;

#[derive(Debug)]
pub enum SimError {
    Deadlock(f64, usize),
    EventLimit(usize),
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::Deadlock(t, n) => {
                write!(f, "deadlock at t={t}: {n} tasks can make no progress")
            }
            SimError::EventLimit(n) => write!(f, "event limit exceeded ({n} events)"),
        }
    }
}

impl std::error::Error for SimError {}

/// Per-task execution record.
#[derive(Debug, Clone, Copy)]
pub struct TaskTrace {
    pub start: f64,
    pub finish: f64,
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Completion time of the whole DAG.
    pub makespan: f64,
    /// Per physical task trace.
    pub trace: Vec<TaskTrace>,
    /// Aggregated per *logical* MXTask: earliest chunk start.
    pub orig_start: BTreeMap<TaskId, f64>,
    /// Aggregated per logical MXTask: latest chunk finish.
    pub orig_finish: BTreeMap<TaskId, f64>,
    /// Number of engine iterations (profiling).
    pub events: usize,
}

impl SimResult {
    /// Finish time of a logical task.
    pub fn finish_of(&self, orig: TaskId) -> f64 {
        *self.orig_finish.get(&orig).expect("unknown task")
    }
    pub fn start_of(&self, orig: TaskId) -> f64 {
        *self.orig_start.get(&orig).expect("unknown task")
    }
}

#[derive(Debug, Clone)]
pub struct SimConfig {
    pub policy: Policy,
    pub max_events: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { policy: Policy::fair(), max_events: 20_000_000 }
    }
}

/// Run the fluid simulation to completion.
pub fn simulate(dag: &SimDag, cluster: &Cluster, cfg: &SimConfig) -> Result<SimResult, SimError> {
    let n = dag.len();
    let caps0 = cluster.capacities();
    // §Perf: precompute per-task resource footprints once (topology-aware:
    // a flow's footprint includes the fabric links it crosses); reuse
    // scratch buffers across events (no allocation in the re-fill loop).
    let task_res: Vec<alloc::TaskRes> =
        dag.tasks.iter().map(|t| cluster.task_res(&t.kind)).collect();
    let mut users_scratch = vec![0.0; caps0.len()];
    let mut sub_res: Vec<alloc::TaskRes> = Vec::with_capacity(n);
    let mut sub_aux: Vec<f64> = Vec::with_capacity(n);
    let mut sub_prios: Vec<i64> = Vec::with_capacity(n);
    let mut sub_coflow: Vec<Option<usize>> = Vec::with_capacity(n);
    let mut sub_rates: Vec<f64> = Vec::with_capacity(n);
    let mut remaining: Vec<f64> = dag.tasks.iter().map(|t| t.size).collect();
    let mut indeg: Vec<usize> = dag.preds.iter().map(|p| p.len()).collect();
    let mut done = vec![false; n];
    let mut started = vec![false; n];
    let mut trace = vec![TaskTrace { start: f64::NAN, finish: f64::NAN }; n];
    let mut n_done = 0;
    let mut now = 0.0;
    let mut events = 0;
    // FIFO queue positions, assigned per *logical* task at its first
    // chunk's readiness. Semantics of a blocking send queue + concurrent
    // pipelined streams: single-chunk tasks get strictly increasing
    // positions (serialized even when ready simultaneously — the order
    // the application issued them), while multi-chunk (pipelined) tasks
    // ready at the same instant share one position and therefore share
    // bandwidth fairly (concurrent streams). This is what makes Fig. 3's
    // baseline serialize f1 before f3 but lets case-3's pipelined f1/f3
    // contend.
    //
    // Encoding: a global slot counter. Assignments happen in
    // chronological scan order, so time ordering falls out of the
    // counter; `fifo_base` jumps past every slot of earlier instants so
    // tasks from different instants can never share a priority level.
    // (The previous packed `time*1024 + tie.min(1023)` encoding silently
    // collapsed ≥1023 same-instant tasks into one level.)
    let mut fifo_prio_orig: BTreeMap<TaskId, i64> = BTreeMap::new();
    let mut fifo_tie_time: i64 = i64::MIN;
    let mut fifo_tie_count: i64 = 0;
    let mut fifo_base: i64 = 0;
    let mut fifo_max: i64 = 0;
    let mut was_ready = vec![false; n];

    // coflow membership: group -> all member task ids (static)
    let mut coflow_members: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for (i, t) in dag.tasks.iter().enumerate() {
        if let Some(g) = t.coflow {
            coflow_members.entry(g).or_default().push(i);
        }
    }

    // §Perf: incremental live set — tasks whose indeg reached 0 and are
    // not yet done. Avoids O(n) full scans per event.
    let mut live: Vec<usize> = (0..n).filter(|&t| indeg[t] == 0).collect();

    while n_done < n {
        events += 1;
        if events > cfg.max_events {
            return Err(SimError::EventLimit(events));
        }

        // 1. instantly complete zero-size ready tasks (dummies) — cascades.
        //    NB: removal must preserve `live` order — FIFO queue positions
        //    are assigned in readiness-scan order.
        let mut progressed = true;
        while progressed {
            progressed = false;
            let mut i = 0;
            while i < live.len() {
                let t = live[i];
                if !done[t] && remaining[t] <= EPS && now + EPS >= dag.tasks[t].gate {
                    done[t] = true;
                    n_done += 1;
                    if !started[t] {
                        started[t] = true;
                        trace[t].start = now;
                    }
                    trace[t].finish = now;
                    for &s in &dag.succs[t] {
                        indeg[s] -= 1;
                        if indeg[s] == 0 {
                            live.push(s);
                        }
                    }
                    progressed = true;
                }
                i += 1;
            }
        }
        live.retain(|&t| !done[t]);
        if n_done == n {
            break;
        }

        // 2. collect ready tasks (live = indeg 0, not done)
        let mut next_gate = f64::INFINITY;
        let mut ready: Vec<usize> = Vec::with_capacity(live.len());
        for idx in 0..live.len() {
            let t = live[idx];
            debug_assert!(!done[t] && indeg[t] == 0);
            if now + EPS < dag.tasks[t].gate {
                next_gate = next_gate.min(dag.tasks[t].gate);
                continue;
            }
            // coflow all-or-nothing: every member must have indeg 0
            if cfg.policy.net == NetPolicy::Coflow {
                if let Some(g) = dag.tasks[t].coflow {
                    let all_ready = coflow_members[&g]
                        .iter()
                        .all(|&m| done[m] || indeg[m] == 0);
                    if !all_ready {
                        continue;
                    }
                }
            }
            if !was_ready[t] {
                was_ready[t] = true;
                let orig = dag.tasks[t].orig;
                fifo_prio_orig.entry(orig).or_insert_with(|| {
                    let tq = (now * 1e6).round() as i64;
                    if tq != fifo_tie_time {
                        fifo_tie_time = tq;
                        fifo_tie_count = 0;
                        fifo_base = fifo_max + 1;
                    }
                    let tie = if dag.tasks[t].chunk.1 > 1 {
                        // pipelined stream: concurrent connection — shares
                        // the slot after the singles issued so far, so
                        // same-instant streams fair-share each other
                        fifo_tie_count + 1
                    } else {
                        // blocking send: takes the next exclusive slot
                        fifo_tie_count += 1;
                        fifo_tie_count
                    };
                    let slot = fifo_base + tie;
                    fifo_max = fifo_max.max(slot);
                    -slot
                });
            }
            ready.push(t);
        }

        if ready.is_empty() {
            if next_gate.is_finite() {
                now = next_gate;
                continue;
            }
            let stuck = n - n_done;
            return Err(SimError::Deadlock(now, stuck));
        }

        // 3. allocate rates
        let flows: Vec<usize> = ready.iter().copied().filter(|&t| dag.tasks[t].kind.is_flow()).collect();
        let computes: Vec<usize> =
            ready.iter().copied().filter(|&t| !dag.tasks[t].kind.is_flow()).collect();
        let mut caps = caps0.clone();
        let mut rate = vec![0.0; n];

        // FIFO priority override
        let effective_prio = |t: usize| -> i64 {
            let fifo = || fifo_prio_orig.get(&dag.tasks[t].orig).copied().unwrap_or(0);
            match dag.tasks[t].kind.is_flow() {
                true if cfg.policy.net == NetPolicy::Fifo => fifo(),
                false if cfg.policy.cpu == CpuPolicy::Fifo => fifo(),
                _ => dag.tasks[t].priority,
            }
        };

        // compute slots first (independent resources from NICs)
        {
            sub_res.clear();
            sub_res.extend(computes.iter().map(|&t| task_res[t]));
            sub_rates.clear();
            sub_rates.resize(computes.len(), 0.0);
            match cfg.policy.cpu {
                CpuPolicy::Fair => alloc::maxmin_fill_res(
                    &sub_res,
                    &mut caps,
                    &mut sub_rates,
                    &mut users_scratch,
                ),
                CpuPolicy::Priority | CpuPolicy::Fifo => {
                    sub_prios.clear();
                    sub_prios.extend(computes.iter().map(|&t| effective_prio(t)));
                    alloc::priority_fill_res(
                        &sub_res,
                        &sub_prios,
                        &mut caps,
                        &mut sub_rates,
                        &mut users_scratch,
                    )
                }
            }
            for (i, &t) in computes.iter().enumerate() {
                rate[t] = sub_rates[i];
            }
        }
        {
            sub_res.clear();
            sub_res.extend(flows.iter().map(|&t| task_res[t]));
            sub_rates.clear();
            sub_rates.resize(flows.len(), 0.0);
            match cfg.policy.net {
                NetPolicy::Fair => alloc::maxmin_fill_res(
                    &sub_res,
                    &mut caps,
                    &mut sub_rates,
                    &mut users_scratch,
                ),
                NetPolicy::Priority | NetPolicy::Fifo => {
                    sub_prios.clear();
                    sub_prios.extend(flows.iter().map(|&t| effective_prio(t)));
                    alloc::priority_fill_res(
                        &sub_res,
                        &sub_prios,
                        &mut caps,
                        &mut sub_rates,
                        &mut users_scratch,
                    )
                }
                NetPolicy::Coflow => {
                    sub_coflow.clear();
                    sub_coflow.extend(flows.iter().map(|&t| dag.tasks[t].coflow));
                    sub_aux.clear();
                    sub_aux.extend(flows.iter().map(|&t| remaining[t]));
                    alloc::coflow_fill_res(
                        &sub_res,
                        &sub_coflow,
                        &sub_aux,
                        &caps0,
                        &mut caps,
                        &mut sub_rates,
                    )
                }
            }
            for (i, &t) in flows.iter().enumerate() {
                rate[t] = sub_rates[i];
            }
        }

        // 4. find next event horizon
        let mut dt = f64::INFINITY;
        for &t in &ready {
            if rate[t] > EPS {
                if !started[t] {
                    started[t] = true;
                    trace[t].start = now;
                }
                dt = dt.min(remaining[t] / rate[t]);
            }
        }
        if next_gate.is_finite() {
            dt = dt.min(next_gate - now);
        }
        if !dt.is_finite() || dt <= 0.0 {
            let stuck = n - n_done;
            return Err(SimError::Deadlock(now, stuck));
        }

        // 5. advance
        now += dt;
        for &t in &ready {
            if rate[t] > EPS {
                remaining[t] -= rate[t] * dt;
                if remaining[t] <= EPS {
                    remaining[t] = 0.0;
                    done[t] = true;
                    n_done += 1;
                    trace[t].finish = now;
                    for &s in &dag.succs[t] {
                        indeg[s] -= 1;
                        if indeg[s] == 0 {
                            live.push(s);
                        }
                    }
                }
            }
        }
    }

    // aggregate per logical task
    let mut orig_start: BTreeMap<TaskId, f64> = BTreeMap::new();
    let mut orig_finish: BTreeMap<TaskId, f64> = BTreeMap::new();
    for (i, t) in dag.tasks.iter().enumerate() {
        let e = orig_start.entry(t.orig).or_insert(f64::INFINITY);
        *e = e.min(trace[i].start);
        let e = orig_finish.entry(t.orig).or_insert(f64::NEG_INFINITY);
        *e = e.max(trace[i].finish);
    }

    Ok(SimResult { makespan: now, trace, orig_start, orig_finish, events })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::spec::{Cluster, SimKind, SimTask};

    fn task(kind: SimKind, size: f64) -> SimTask {
        SimTask { orig: 0, chunk: (0, 1), kind, size, priority: 0, gate: 0.0, coflow: None }
    }

    #[test]
    fn single_task_runs_at_full_rate() {
        let mut d = SimDag::default();
        let mut t = task(SimKind::Compute { host: 0 }, 5.0);
        t.orig = 1;
        d.push(t);
        let r = simulate(&d, &Cluster::uniform(1), &SimConfig::default()).unwrap();
        assert!((r.makespan - 5.0).abs() < 1e-9);
        assert!((r.finish_of(1) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn chain_respects_dependencies() {
        let mut d = SimDag::default();
        let a = d.push({ let mut t = task(SimKind::Compute { host: 0 }, 2.0); t.orig = 1; t });
        let f = d.push({ let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 3.0); t.orig = 2; t });
        let b = d.push({ let mut t = task(SimKind::Compute { host: 1 }, 1.0); t.orig = 3; t });
        d.dep(a, f);
        d.dep(f, b);
        let r = simulate(&d, &Cluster::uniform(2), &SimConfig::default()).unwrap();
        assert!((r.makespan - 6.0).abs() < 1e-9);
        assert!((r.start_of(2) - 2.0).abs() < 1e-9);
        assert!((r.start_of(3) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn fair_sharing_extends_completion() {
        // two unit flows from host 0: fair => both finish at 2
        let mut d = SimDag::default();
        let a = d.push({ let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 1.0); t.orig = 1; t });
        let b = d.push({ let mut t = task(SimKind::Flow { src: 0, dst: 2 }, 1.0); t.orig = 2; t });
        let _ = (a, b);
        let r = simulate(&d, &Cluster::uniform(3), &SimConfig::default()).unwrap();
        assert!((r.finish_of(1) - 2.0).abs() < 1e-9);
        assert!((r.finish_of(2) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn priority_serializes_flows() {
        let mut d = SimDag::default();
        let mut t1 = task(SimKind::Flow { src: 0, dst: 1 }, 1.0);
        t1.orig = 1;
        t1.priority = 10;
        let mut t2 = task(SimKind::Flow { src: 0, dst: 2 }, 1.0);
        t2.orig = 2;
        t2.priority = 1;
        d.push(t1);
        d.push(t2);
        let cfg = SimConfig { policy: Policy::priority(), ..Default::default() };
        let r = simulate(&d, &Cluster::uniform(3), &cfg).unwrap();
        assert!((r.finish_of(1) - 1.0).abs() < 1e-9);
        assert!((r.finish_of(2) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn gate_delays_start() {
        let mut d = SimDag::default();
        let mut t = task(SimKind::Compute { host: 0 }, 1.0);
        t.orig = 1;
        t.gate = 4.0;
        d.push(t);
        let r = simulate(&d, &Cluster::uniform(1), &SimConfig::default()).unwrap();
        assert!((r.start_of(1) - 4.0).abs() < 1e-9);
        assert!((r.makespan - 5.0).abs() < 1e-9);
    }

    #[test]
    fn fifo_orders_by_readiness() {
        // a(2) -> f1 ; b(1) -> f2 ; both flows share up0.
        // b finishes first so f2 ready first => f2 runs to completion first.
        let mut d = SimDag::default();
        let a = d.push({ let mut t = task(SimKind::Compute { host: 0 }, 2.0); t.orig = 1; t });
        let b = d.push({ let mut t = task(SimKind::Compute { host: 0 }, 1.0); t.orig = 2; t });
        let f1 = d.push({ let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 1.0); t.orig = 3; t });
        let f2 = d.push({ let mut t = task(SimKind::Flow { src: 0, dst: 2 }, 1.0); t.orig = 4; t });
        d.dep(a, f1);
        d.dep(b, f2);
        let cluster = Cluster::with_cores(3, 2.0);
        let cfg = SimConfig { policy: Policy::fifo(), ..Default::default() };
        let r = simulate(&d, &cluster, &cfg).unwrap();
        // b done t=1, f2 runs 1->2 ; a done t=2, f1 runs 2->3
        assert!((r.finish_of(4) - 2.0).abs() < 1e-9);
        assert!((r.finish_of(3) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn coflow_all_or_nothing_barrier() {
        // f1 ready at 0 (coflow 0 with f2); f2 gated behind compute(3).
        // Under coflow policy f1 must wait for f2's readiness.
        let mut d = SimDag::default();
        let c = d.push({ let mut t = task(SimKind::Compute { host: 3 }, 3.0); t.orig = 1; t });
        let f1 = d.push({
            let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 1.0);
            t.orig = 2;
            t.coflow = Some(0);
            t
        });
        let f2 = d.push({
            let mut t = task(SimKind::Flow { src: 2, dst: 1 }, 1.0);
            t.orig = 3;
            t.coflow = Some(0);
            t
        });
        d.dep(c, f2);
        let _ = f1;
        let cfg = SimConfig { policy: Policy::coflow(), ..Default::default() };
        let r = simulate(&d, &Cluster::uniform(4), &cfg).unwrap();
        assert!(r.start_of(2) >= 3.0 - 1e-9, "f1 must wait for the whole coflow");
    }

    #[test]
    fn deadlock_reported_not_hung() {
        // flow into a zero-capacity NIC can never progress
        let mut d = SimDag::default();
        d.push({ let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 1.0); t.orig = 1; t });
        let mut cluster = Cluster::uniform(2);
        cluster.hosts[0].nic_up = 0.0;
        let err = simulate(&d, &cluster, &SimConfig::default()).unwrap_err();
        assert!(matches!(err, SimError::Deadlock(_, _)));
    }

    #[test]
    fn dummy_tasks_cost_nothing() {
        let mut d = SimDag::default();
        let s = d.push({ let mut t = task(SimKind::Dummy, 0.0); t.orig = 0; t });
        let c = d.push({ let mut t = task(SimKind::Compute { host: 0 }, 1.0); t.orig = 1; t });
        let e = d.push({ let mut t = task(SimKind::Dummy, 0.0); t.orig = 2; t });
        d.dep(s, c);
        d.dep(c, e);
        let r = simulate(&d, &Cluster::uniform(1), &SimConfig::default()).unwrap();
        assert!((r.makespan - 1.0).abs() < 1e-9);
    }

    /// Regression for the FIFO tie-slot cap: the old packed encoding
    /// collapsed same-instant singles past the 1023rd into one shared
    /// priority level, which made them fair-share instead of serialize.
    #[test]
    fn fifo_many_simultaneous_singles_stay_serialized() {
        let n = 1100usize;
        let mut d = SimDag::default();
        for i in 0..n {
            d.push(SimTask {
                orig: i,
                chunk: (0, 1),
                kind: SimKind::Flow { src: 0, dst: 1 },
                size: 1.0,
                priority: 0,
                gate: 0.0,
                coflow: None,
            });
        }
        let cfg = SimConfig { policy: Policy::fifo(), ..Default::default() };
        let r = simulate(&d, &Cluster::uniform(2), &cfg).unwrap();
        assert!((r.makespan - n as f64).abs() < 1e-6);
        // strict serialization: the k-th flow to finish does so at k
        let mut finishes: Vec<f64> = (0..n).map(|i| r.finish_of(i)).collect();
        finishes.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (k, f) in finishes.iter().enumerate() {
            assert!(
                (f - (k + 1) as f64).abs() < 1e-6,
                "flow #{k} finished at {f}, want {}",
                k + 1
            );
        }
    }

    #[test]
    fn oversubscribed_agg_link_throttles_cross_rack_flow() {
        // 4 hosts, 2 racks, ratio 4: agg capacity 2/4 = 0.5. A unit
        // cross-rack flow takes 2; the same flow intra-rack takes 1.
        let mk = |src: usize, dst: usize| {
            let mut d = SimDag::default();
            d.push({
                let mut t = task(SimKind::Flow { src, dst }, 1.0);
                t.orig = 1;
                t
            });
            d
        };
        let cluster = Cluster::oversubscribed(4, 2, 4.0);
        let cross = simulate(&mk(0, 3), &cluster, &SimConfig::default()).unwrap();
        assert!((cross.makespan - 2.0).abs() < 1e-9, "cross {}", cross.makespan);
        let intra = simulate(&mk(0, 1), &cluster, &SimConfig::default()).unwrap();
        assert!((intra.makespan - 1.0).abs() < 1e-9, "intra {}", intra.makespan);
    }

    #[test]
    fn nonblocking_ratio_matches_bigswitch() {
        // ratio small enough that the agg links can never bind: results
        // must equal the plain big switch exactly.
        let mut d = SimDag::default();
        let a = d.push({ let mut t = task(SimKind::Flow { src: 0, dst: 2 }, 1.0); t.orig = 1; t });
        let b = d.push({ let mut t = task(SimKind::Flow { src: 0, dst: 3 }, 1.0); t.orig = 2; t });
        let _ = (a, b);
        let big = simulate(&d, &Cluster::uniform(4), &SimConfig::default()).unwrap();
        let slack = simulate(&d, &Cluster::oversubscribed(4, 2, 0.01), &SimConfig::default())
            .unwrap();
        assert!((big.makespan - slack.makespan).abs() < 1e-12);
        for i in 0..d.len() {
            assert!((big.trace[i].finish - slack.trace[i].finish).abs() < 1e-12);
        }
    }

    #[test]
    fn parallel_fabric_path_selection_decides_contention() {
        // flows (0->2) and (1->3): under Hash both map to trunk (s+d)%2=0
        // and halve its 0.5 capacity; under BySrc they split trunks and
        // each gets the full 0.5.
        use crate::sim::topology::{PathSelect, Topology};
        let mut d = SimDag::default();
        d.push({ let mut t = task(SimKind::Flow { src: 0, dst: 2 }, 1.0); t.orig = 1; t });
        d.push({ let mut t = task(SimKind::Flow { src: 1, dst: 3 }, 1.0); t.orig = 2; t });
        let hash = Cluster::parallel_fabrics(4, 2, 0.5);
        let r = simulate(&d, &hash, &SimConfig::default()).unwrap();
        assert!((r.makespan - 4.0).abs() < 1e-9, "hash-collision {}", r.makespan);
        let bysrc = Cluster::uniform(4).with_topology(Topology::ParallelFabrics {
            k: 2,
            select: PathSelect::BySrc,
            trunk: 0.5,
        });
        let r = simulate(&d, &bysrc, &SimConfig::default()).unwrap();
        assert!((r.makespan - 2.0).abs() < 1e-9, "split-fabrics {}", r.makespan);
    }

    #[test]
    fn makespan_monotone_in_sizes() {
        let build = |sz: f64| {
            let mut d = SimDag::default();
            let a = d.push({ let mut t = task(SimKind::Compute { host: 0 }, sz); t.orig = 1; t });
            let f = d.push({ let mut t = task(SimKind::Flow { src: 0, dst: 1 }, 1.0); t.orig = 2; t });
            d.dep(a, f);
            d
        };
        let r1 = simulate(&build(1.0), &Cluster::uniform(2), &SimConfig::default()).unwrap();
        let r2 = simulate(&build(2.0), &Cluster::uniform(2), &SimConfig::default()).unwrap();
        assert!(r2.makespan > r1.makespan);
    }
}
