//! NIC pacer: the real-execution counterpart of the simulator's
//! bandwidth model. Each host has an uplink and a downlink token; a
//! transfer occupies `src`'s uplink and `dst`'s downlink for
//! `bytes / bandwidth` (scaled) seconds. Among waiting transfers the
//! highest (priority, then FIFO seq) wins — the same strict-priority
//! semantics the MXDAG co-scheduler plans for.

use std::sync::{Condvar, Mutex};
use std::time::Duration;

#[derive(Debug)]
struct Waiter {
    id: u64,
    priority: i64,
    seq: u64,
    src: usize,
    dst: usize,
}

#[derive(Debug, Default)]
struct PacerState {
    busy_up: Vec<bool>,
    busy_down: Vec<bool>,
    waiters: Vec<Waiter>,
    next_id: u64,
    next_seq: u64,
}

/// Paced, prioritised NIC substrate.
pub struct NicPacer {
    state: Mutex<PacerState>,
    cv: Condvar,
    /// bytes per second of simulated wall time.
    pub bandwidth: f64,
    /// wall-time scale: simulated_seconds * scale = slept seconds.
    pub time_scale: f64,
}

impl NicPacer {
    pub fn new(hosts: usize, bandwidth: f64, time_scale: f64) -> NicPacer {
        assert!(bandwidth > 0.0 && time_scale >= 0.0);
        NicPacer {
            state: Mutex::new(PacerState {
                busy_up: vec![false; hosts],
                busy_down: vec![false; hosts],
                ..Default::default()
            }),
            cv: Condvar::new(),
            bandwidth,
            time_scale,
        }
    }

    /// Duration a transfer of `bytes` occupies its NICs (wall time).
    pub fn wall_time(&self, bytes: usize) -> Duration {
        Duration::from_secs_f64(bytes as f64 / self.bandwidth * self.time_scale)
    }

    /// Blocking prioritized transfer src→dst. Returns simulated seconds.
    pub fn transfer(&self, src: usize, dst: usize, bytes: usize, priority: i64) -> f64 {
        let my_id;
        {
            let mut st = self.state.lock().unwrap();
            my_id = st.next_id;
            st.next_id += 1;
            let seq = st.next_seq;
            st.next_seq += 1;
            st.waiters.push(Waiter { id: my_id, priority, seq, src, dst });

            loop {
                let free = !st.busy_up[src] && !st.busy_down[dst];
                let me = st.waiters.iter().find(|w| w.id == my_id).unwrap();
                // blocked if any *other* waiter that shares one of my NICs
                // (and whose own NICs are free) outranks me
                let outranked = st.waiters.iter().any(|w| {
                    w.id != my_id
                        && (w.src == src || w.dst == dst)
                        && !st.busy_up[w.src]
                        && !st.busy_down[w.dst]
                        && (w.priority, std::cmp::Reverse(w.seq))
                            > (me.priority, std::cmp::Reverse(me.seq))
                });
                if free && !outranked {
                    st.busy_up[src] = true;
                    st.busy_down[dst] = true;
                    st.waiters.retain(|w| w.id != my_id);
                    break;
                }
                st = self.cv.wait(st).unwrap();
            }
        }

        let wall = self.wall_time(bytes);
        if !wall.is_zero() {
            std::thread::sleep(wall);
        }

        let mut st = self.state.lock().unwrap();
        st.busy_up[src] = false;
        st.busy_down[dst] = false;
        drop(st);
        self.cv.notify_all();
        bytes as f64 / self.bandwidth
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Instant;

    #[test]
    fn independent_transfers_run_concurrently() {
        let p = Arc::new(NicPacer::new(4, 1000.0, 0.05)); // 50ms per 1000B
        let t0 = Instant::now();
        let hs: Vec<_> = [(0usize, 1usize), (2, 3)]
            .into_iter()
            .map(|(s, d)| {
                let p = p.clone();
                std::thread::spawn(move || p.transfer(s, d, 1000, 0))
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        // concurrent: ~50ms, serialized would be ~100ms
        assert!(t0.elapsed() < Duration::from_millis(90), "{:?}", t0.elapsed());
    }

    #[test]
    fn shared_uplink_serializes() {
        let p = Arc::new(NicPacer::new(4, 1000.0, 0.05));
        let t0 = Instant::now();
        let hs: Vec<_> = [(0usize, 1usize), (0, 2)]
            .into_iter()
            .map(|(s, d)| {
                let p = p.clone();
                std::thread::spawn(move || p.transfer(s, d, 1000, 0))
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert!(t0.elapsed() >= Duration::from_millis(95), "{:?}", t0.elapsed());
    }

    #[test]
    fn priority_wins_contention() {
        let p = Arc::new(NicPacer::new(3, 1000.0, 0.03));
        // occupy the uplink, then enqueue low and high priority waiters
        let p0 = p.clone();
        let hold = std::thread::spawn(move || p0.transfer(0, 1, 1000, 100));
        std::thread::sleep(Duration::from_millis(5));
        let order = Arc::new(Mutex::new(Vec::new()));
        let mut hs = Vec::new();
        for (prio, tag) in [(1i64, "low"), (10, "high")] {
            let p = p.clone();
            let order = order.clone();
            hs.push(std::thread::spawn(move || {
                // stagger registration so "low" registers first
                if tag == "high" {
                    std::thread::sleep(Duration::from_millis(5));
                }
                p.transfer(0, 2, 500, prio);
                order.lock().unwrap().push(tag);
            }));
        }
        hold.join().unwrap();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(*order.lock().unwrap(), vec!["high", "low"]);
    }

    #[test]
    fn fifo_within_priority() {
        let p = Arc::new(NicPacer::new(3, 1000.0, 0.02));
        let p0 = p.clone();
        let hold = std::thread::spawn(move || p0.transfer(0, 1, 1000, 0));
        std::thread::sleep(Duration::from_millis(5));
        let order = Arc::new(Mutex::new(Vec::new()));
        let mut hs = Vec::new();
        for tag in ["first", "second"] {
            let p = p.clone();
            let order = order.clone();
            hs.push(std::thread::spawn(move || {
                if tag == "second" {
                    std::thread::sleep(Duration::from_millis(6));
                }
                p.transfer(0, 2, 200, 0);
                order.lock().unwrap().push(tag);
            }));
            std::thread::sleep(Duration::from_millis(2));
        }
        hold.join().unwrap();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(*order.lock().unwrap(), vec!["first", "second"]);
    }

    #[test]
    fn wall_time_scaling() {
        let p = NicPacer::new(1, 2000.0, 0.5);
        assert_eq!(p.wall_time(1000), Duration::from_secs_f64(0.25));
        let sim = NicPacer::new(1, 2000.0, 0.0); // no real sleeping
        assert!(sim.wall_time(1_000_000).is_zero());
    }
}
