//! L3 coordinator: the real execution path. A thread-pool executor
//! drains MXDAGs (compute = PJRT executions, flows = paced prioritised
//! transfers), and the DDL trainer (§4.1.1) runs data-parallel training
//! end-to-end under MXDAG vs FIFO transmission schedules.

pub mod ddl;
pub mod executor;
pub mod metrics;
pub mod pacer;

pub use ddl::{train, DdlConfig, StepStats, SyncSchedule, TrainReport};
pub use executor::{execute_mxdag, ExecEvent, ExecReport, Work};
pub use metrics::Metrics;
pub use pacer::NicPacer;
