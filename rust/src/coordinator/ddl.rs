//! Data-parallel distributed-training coordinator — the §4.1.1 use case
//! run for real on the three-layer stack.
//!
//! Topology: `workers` worker hosts (ids 0..W) plus a parameter server
//! (id W). Each worker thread owns its own PJRT [`Engine`] (the xla
//! client is not `Send`) and per step:
//!
//! 1. executes the AOT `grad_step` artifact (JAX bwd, Pallas matmuls);
//! 2. *pushes* per-layer gradients through the [`NicPacer`] in the
//!    schedule's layer order;
//! 3. the leader aggregates each layer once all workers pushed it,
//!    applies SGD to the master copy, and hands the layer to per-worker
//!    pull threads (paced *pull* flows);
//! 4. the worker runs the next forward pass **layer by layer** via the
//!    `layer_fwd_i` artifacts, each layer waiting only for its own pull —
//!    so the transmission order chosen by the scheduler (MXDAG:
//!    lowest-layer-first; FIFO: BP production order) directly moves the
//!    step time, exactly like Fig. 6.

use std::path::PathBuf;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::error::{anyhow, Context, Result};

use super::pacer::NicPacer;
use crate::runtime::{Engine, Tensor};
use crate::util::rng::Rng;

/// Which transmission order the coordinator uses (Fig. 6 comparison).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncSchedule {
    /// Critical-path order from the MXDAG analysis: lowest layer first,
    /// strict priority (ByteScheduler-equivalent).
    Mxdag,
    /// Plain FIFO: tensors go out in BP production order (top layer
    /// first), no priorities.
    Fifo,
}

impl SyncSchedule {
    pub fn label(&self) -> &'static str {
        match self {
            SyncSchedule::Mxdag => "mxdag",
            SyncSchedule::Fifo => "fifo",
        }
    }
}

#[derive(Debug, Clone)]
pub struct DdlConfig {
    pub artifacts_dir: PathBuf,
    pub workers: usize,
    pub steps: usize,
    /// Simulated NIC bandwidth, bytes/sec.
    pub bandwidth: f64,
    /// Wall-clock scale of simulated transfer time (0 = don't sleep).
    pub time_scale: f64,
    pub schedule: SyncSchedule,
    pub seed: u64,
    pub log_every: usize,
    /// Forward repetitions per layer (validation microbatches) — sets the
    /// compute available to overlap with pulls.
    pub fwd_reps: usize,
}

impl Default for DdlConfig {
    fn default() -> Self {
        DdlConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            workers: 2,
            steps: 20,
            bandwidth: 25e6,
            time_scale: 1.0,
            schedule: SyncSchedule::Mxdag,
            seed: 0,
            log_every: 5,
            fwd_reps: 6,
        }
    }
}

/// Per-step record.
#[derive(Debug, Clone)]
pub struct StepStats {
    pub step: usize,
    pub loss: f64,
    pub wall: Duration,
}

/// Training outcome.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub steps: Vec<StepStats>,
    pub total: Duration,
    pub schedule: SyncSchedule,
}

impl TrainReport {
    pub fn first_loss(&self) -> f64 {
        self.steps.first().map(|s| s.loss).unwrap_or(f64::NAN)
    }
    pub fn last_loss(&self) -> f64 {
        self.steps.last().map(|s| s.loss).unwrap_or(f64::NAN)
    }
    /// Mean steady-state step time (skips step 0, which pays PJRT
    /// compilation in every worker engine).
    pub fn mean_step_wall(&self) -> Duration {
        let steady: Vec<&StepStats> = self.steps.iter().skip(1).collect();
        if steady.is_empty() {
            return self.steps.first().map(|s| s.wall).unwrap_or(Duration::ZERO);
        }
        steady.iter().map(|s| s.wall).sum::<Duration>() / steady.len() as u32
    }
}

/// Deterministic synthetic classification data (class-center Gaussians,
/// mirroring python/compile/model.py::synthetic_batch).
pub struct DataGen {
    centers: Vec<Vec<f32>>, // [classes][input_dim]
    input_dim: usize,
    classes: usize,
    batch: usize,
}

impl DataGen {
    pub fn new(input_dim: usize, classes: usize, batch: usize, seed: u64) -> DataGen {
        let mut rng = Rng::new(seed ^ 0xDA7A);
        let centers = (0..classes)
            .map(|_| (0..input_dim).map(|_| rng.normal() as f32).collect())
            .collect();
        DataGen { centers, input_dim, classes, batch }
    }

    /// Batch for (step, worker): (x `[batch, input_dim]` f32, y `[batch]` s32).
    pub fn batch(&self, step: usize, worker: usize) -> (Tensor, Tensor) {
        let mut rng = Rng::new(((step as u64) << 20) | ((worker as u64) << 8) | 7);
        let mut xs = Vec::with_capacity(self.batch * self.input_dim);
        let mut ys = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let y = rng.below(self.classes);
            ys.push(y as i32);
            for d in 0..self.input_dim {
                xs.push(self.centers[y][d] + 0.3 * rng.normal() as f32);
            }
        }
        (
            Tensor::f32(&[self.batch, self.input_dim], xs),
            Tensor::s32(&[self.batch], ys),
        )
    }
}

/// He-style init matching python's scale (seeded; numerics validated
/// end-to-end by the decreasing loss, not bit-exactness).
pub fn init_params(shapes: &[Vec<usize>], seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed ^ 0x1217);
    shapes
        .iter()
        .map(|s| {
            if s.len() == 2 {
                let scale = (2.0 / s[0] as f64).sqrt();
                let data = (0..s[0] * s[1])
                    .map(|_| (rng.normal() * scale) as f32)
                    .collect();
                Tensor::f32(s, data)
            } else {
                Tensor::zeros(s)
            }
        })
        .collect()
}

enum ToLeader {
    Loss { step: usize, worker: usize, loss: f64 },
    LayerGrads { step: usize, layer: usize, w: Tensor, b: Tensor },
}

impl ToLeader {
    fn step(&self) -> usize {
        match self {
            ToLeader::Loss { step, .. } | ToLeader::LayerGrads { step, .. } => *step,
        }
    }
}

/// Run data-parallel training; see module docs for the step anatomy.
pub fn train(cfg: &DdlConfig) -> Result<TrainReport> {
    assert!(cfg.workers >= 1 && cfg.steps >= 1);
    // Leader engine provides the manifest (compute happens on workers).
    let leader = Engine::load(&cfg.artifacts_dir).context("loading artifacts (leader)")?;
    let m = leader.manifest.clone();
    let layers = m.model.n_layers;
    let ps_host = cfg.workers; // parameter-server host id
    let pacer = Arc::new(NicPacer::new(cfg.workers + 1, cfg.bandwidth, cfg.time_scale));
    let data = Arc::new(DataGen::new(
        m.model.input_dim,
        m.model.classes,
        m.model.batch,
        cfg.seed,
    ));

    let layer_prio: Arc<Vec<i64>> = Arc::new(
        (0..layers)
            .map(|l| match cfg.schedule {
                SyncSchedule::Mxdag => (layers - l) as i64, // lower layer wins
                SyncSchedule::Fifo => 0,                    // pure arrival order
            })
            .collect(),
    );
    let push_order: Arc<Vec<usize>> = Arc::new(match cfg.schedule {
        SyncSchedule::Mxdag => (0..layers).collect(),
        SyncSchedule::Fifo => (0..layers).rev().collect(), // BP production order
    });
    let layer_bytes: Arc<Vec<usize>> =
        Arc::new((0..layers).map(|l| m.layer_param_bytes(l)).collect());

    let mut master = init_params(&m.model.param_shapes, cfg.seed);
    let lr = m.model.lr as f32;

    // persistent workers: engines compile once
    let (to_leader_tx, to_leader_rx) = mpsc::channel::<ToLeader>();
    let mut pull_txs = Vec::new();
    let mut worker_handles = Vec::new();
    for w in 0..cfg.workers {
        let (pull_tx, pull_rx) = mpsc::channel::<(usize, Tensor, Tensor)>();
        pull_txs.push(pull_tx);
        let to_leader = to_leader_tx.clone();
        let pacer = Arc::clone(&pacer);
        let data = Arc::clone(&data);
        let dir = cfg.artifacts_dir.clone();
        let push_order = Arc::clone(&push_order);
        let layer_prio = Arc::clone(&layer_prio);
        let layer_bytes = Arc::clone(&layer_bytes);
        let mut params = master.clone();
        let steps = cfg.steps;
        let fwd_reps = cfg.fwd_reps.max(1);

        worker_handles.push(std::thread::spawn(move || -> Result<()> {
            // each worker owns its runtime (xla client is not Send)
            let engine = Engine::load(&dir).context("worker engine")?;
            let nl = layer_prio.len();
            for step in 0..steps {
                let (x, y) = data.batch(step, w);

                // 1. gradient step on the local replica
                let mut inputs = params.clone();
                inputs.push(x.clone());
                inputs.push(y);
                let out = engine.execute("grad_step", &inputs)?;
                let loss = out[0].scalar_f32() as f64;
                to_leader
                    .send(ToLeader::Loss { step, worker: w, loss })
                    .ok();
                let grads = &out[1..];

                // 2. push per-layer grads in schedule order (paced flows)
                for &l in push_order.iter() {
                    pacer.transfer(w, ps_host, layer_bytes[l], layer_prio[l]);
                    to_leader
                        .send(ToLeader::LayerGrads {
                            step,
                            layer: l,
                            w: grads[2 * l].clone(),
                            b: grads[2 * l + 1].clone(),
                        })
                        .ok();
                }

                // 3. consume pulls; run next forward layer by layer
                let mut have: Vec<Option<(Tensor, Tensor)>> = vec![None; nl];
                let mut h = x; // probe activations
                let mut next_fwd = 0usize;
                let mut received = 0usize;
                while received < nl {
                    let (l, wt, bt) = pull_rx.recv().map_err(|e| anyhow!("pull: {e}"))?;
                    received += 1;
                    have[l] = Some((wt, bt));
                    while next_fwd < nl {
                        let Some((wt, bt)) = have[next_fwd].take() else { break };
                        let name = format!("layer_fwd_{next_fwd}");
                        // validation microbatches: the per-layer compute that
                        // overlapping pulls can hide
                        for _ in 0..fwd_reps - 1 {
                            engine.execute(&name, &[h.clone(), wt.clone(), bt.clone()])?;
                        }
                        h = engine
                            .execute(&name, &[h, wt.clone(), bt.clone()])?
                            .pop()
                            .unwrap();
                        params[2 * next_fwd] = wt;
                        params[2 * next_fwd + 1] = bt;
                        next_fwd += 1;
                    }
                }
            }
            Ok(())
        }));
    }
    drop(to_leader_tx);

    // Leader loop: per step, aggregate W losses + W×L layer pushes,
    // update master per layer, fan out paced pulls.
    let mut stats = Vec::with_capacity(cfg.steps);
    let t_total = Instant::now();
    let pull_threads: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
        Arc::new(Mutex::new(Vec::new()));
    // fast workers may race one step ahead of the leader loop
    let mut stash: Vec<ToLeader> = Vec::new();
    for step in 0..cfg.steps {
        let t_step = Instant::now();
        let mut acc: Vec<Option<(Tensor, Tensor, usize)>> = vec![None; layers];
        let mut losses = vec![0.0; cfg.workers];
        let mut pending = cfg.workers * (layers + 1);
        let mut queue: Vec<ToLeader> = std::mem::take(&mut stash);
        while pending > 0 {
            let msg = match queue.pop() {
                Some(m) => m,
                None => to_leader_rx
                    .recv()
                    .map_err(|e| anyhow!("leader channel: {e}"))?,
            };
            if msg.step() != step {
                debug_assert!(msg.step() == step + 1, "messages skew by at most one step");
                stash.push(msg);
                continue;
            }
            pending -= 1;
            match msg {
                ToLeader::Loss { worker, loss, .. } => losses[worker] = loss,
                ToLeader::LayerGrads { layer, w: gw, b: gb, .. } => {
                    let slot = acc[layer].get_or_insert_with(|| {
                        (Tensor::zeros(gw.shape()), Tensor::zeros(gb.shape()), 0)
                    });
                    slot.0.add_assign(&gw);
                    slot.1.add_assign(&gb);
                    slot.2 += 1;
                    if slot.2 == cfg.workers {
                        let (mut aw, mut ab, _) = acc[layer].take().unwrap();
                        aw.scale(1.0 / cfg.workers as f32);
                        ab.scale(1.0 / cfg.workers as f32);
                        master[2 * layer].axpy_neg(lr, &aw);
                        master[2 * layer + 1].axpy_neg(lr, &ab);
                        let wt = master[2 * layer].clone();
                        let bt = master[2 * layer + 1].clone();
                        let bytes = layer_bytes[layer];
                        let prio = layer_prio[layer];
                        for (wkr, tx) in pull_txs.iter().enumerate() {
                            let tx = tx.clone();
                            let pacer = Arc::clone(&pacer);
                            let (wt, bt) = (wt.clone(), bt.clone());
                            let h = std::thread::spawn(move || {
                                pacer.transfer(ps_host, wkr, bytes, prio);
                                tx.send((layer, wt, bt)).ok();
                            });
                            pull_threads.lock().unwrap().push(h);
                        }
                    }
                }
            }
        }
        // pulls of this step must land before we time the step boundary
        for h in std::mem::take(&mut *pull_threads.lock().unwrap()) {
            h.join().ok();
        }
        let loss = losses.iter().sum::<f64>() / cfg.workers as f64;
        let wall = t_step.elapsed();
        if cfg.log_every > 0 && step % cfg.log_every == 0 {
            println!(
                "[{}] step {step:>4}  loss {loss:.4}  wall {wall:?}",
                cfg.schedule.label()
            );
        }
        stats.push(StepStats { step, loss, wall });
    }

    for h in worker_handles {
        h.join().map_err(|_| anyhow!("worker panicked"))??;
    }
    Ok(TrainReport { steps: stats, total: t_total.elapsed(), schedule: cfg.schedule })
}
