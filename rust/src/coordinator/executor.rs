//! Real MXDAG executor: a thread pool drains a priority ready-queue of
//! MXTasks. Compute tasks run caller-provided work (PJRT executions in
//! the DDL trainer); network tasks go through the [`NicPacer`] with the
//! plan's priorities — the execution twin of the fluid simulator.

use std::collections::BinaryHeap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::error::{anyhow, Result};

use super::pacer::NicPacer;
use crate::mxdag::{MXDag, TaskId, TaskKind};

/// Per-task execution record (wall clock, relative to run start).
#[derive(Debug, Clone)]
pub struct ExecEvent {
    pub task: TaskId,
    pub name: String,
    pub start: Duration,
    pub end: Duration,
}

/// Result of one executed MXDAG.
#[derive(Debug, Clone, Default)]
pub struct ExecReport {
    pub makespan: Duration,
    pub events: Vec<ExecEvent>,
}

impl ExecReport {
    pub fn event(&self, name: &str) -> Option<&ExecEvent> {
        self.events.iter().find(|e| e.name == name)
    }
}

/// Work payload for compute tasks.
pub trait Work: Send + Sync {
    /// Execute compute task `task` (flows are handled by the pacer).
    fn run(&self, dag: &MXDag, task: TaskId) -> Result<()>;
}

impl<F> Work for F
where
    F: Fn(&MXDag, TaskId) -> Result<()> + Send + Sync,
{
    fn run(&self, dag: &MXDag, task: TaskId) -> Result<()> {
        self(dag, task)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct QueueEntry {
    priority: i64,
    seq: std::cmp::Reverse<u64>, // FIFO among equal priorities
    task: TaskId,
}

impl Ord for QueueEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.priority, self.seq, self.task).cmp(&(other.priority, other.seq, other.task))
    }
}
impl PartialOrd for QueueEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

struct ExecState {
    indeg: Vec<usize>,
    ready: BinaryHeap<QueueEntry>,
    next_seq: u64,
    done: usize,
    failed: Option<String>,
    events: Vec<ExecEvent>,
}

/// Execute `dag` on `threads` workers.
///
/// * `priorities[t]` orders both the ready queue and the NIC pacer;
/// * flow task sizes are interpreted as *bytes* via `bytes_of`;
/// * `work` runs compute tasks (dummies are free).
pub fn execute_mxdag(
    dag: &MXDag,
    priorities: &[i64],
    pacer: &NicPacer,
    work: &dyn Work,
    bytes_of: &(dyn Fn(TaskId) -> usize + Sync),
    threads: usize,
) -> Result<ExecReport> {
    assert_eq!(priorities.len(), dag.len());
    let n = dag.len();
    let t0 = Instant::now();

    let state = Arc::new((
        Mutex::new(ExecState {
            indeg: (0..n).map(|t| dag.preds(t).len()).collect(),
            ready: BinaryHeap::new(),
            next_seq: 0,
            done: 0,
            failed: None,
            events: Vec::with_capacity(n),
        }),
        Condvar::new(),
    ));

    // seed the queue
    {
        let mut st = state.0.lock().unwrap();
        for t in 0..n {
            if st.indeg[t] == 0 {
                let seq = st.next_seq;
                st.next_seq += 1;
                st.ready.push(QueueEntry {
                    priority: priorities[t],
                    seq: std::cmp::Reverse(seq),
                    task: t,
                });
            }
        }
    }

    std::thread::scope(|scope| {
        for _ in 0..threads.max(1) {
            let state = Arc::clone(&state);
            scope.spawn(move || {
                let (lock, cv) = &*state;
                loop {
                    let task = {
                        let mut st = lock.lock().unwrap();
                        loop {
                            if st.failed.is_some() || st.done == n {
                                cv.notify_all();
                                return;
                            }
                            if let Some(e) = st.ready.pop() {
                                break e.task;
                            }
                            st = cv.wait(st).unwrap();
                        }
                    };

                    let started = t0.elapsed();
                    let outcome: Result<()> = match dag.task(task).kind {
                        TaskKind::Start | TaskKind::End => Ok(()),
                        TaskKind::Compute { .. } => work.run(dag, task),
                        TaskKind::Flow { src, dst } => {
                            pacer.transfer(src, dst, bytes_of(task), priorities[task]);
                            Ok(())
                        }
                    };
                    let ended = t0.elapsed();

                    let mut st = lock.lock().unwrap();
                    match outcome {
                        Err(e) => {
                            st.failed = Some(format!(
                                "task `{}` failed: {e:#}",
                                dag.task(task).name
                            ));
                        }
                        Ok(()) => {
                            st.events.push(ExecEvent {
                                task,
                                name: dag.task(task).name.clone(),
                                start: started,
                                end: ended,
                            });
                            st.done += 1;
                            for &s in dag.succs(task) {
                                st.indeg[s] -= 1;
                                if st.indeg[s] == 0 {
                                    let seq = st.next_seq;
                                    st.next_seq += 1;
                                    st.ready.push(QueueEntry {
                                        priority: priorities[s],
                                        seq: std::cmp::Reverse(seq),
                                        task: s,
                                    });
                                }
                            }
                        }
                    }
                    cv.notify_all();
                }
            });
        }
    });

    let st = state.0.lock().unwrap();
    if let Some(msg) = &st.failed {
        return Err(anyhow!(msg.clone()));
    }
    let mut events = st.events.clone();
    events.sort_by_key(|e| e.start);
    Ok(ExecReport { makespan: t0.elapsed(), events })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn diamond() -> MXDag {
        let mut b = MXDag::builder();
        let a = b.compute("a", 0, 1.0);
        let f1 = b.flow("f1", 0, 1, 100.0);
        let f2 = b.flow("f2", 0, 2, 100.0);
        let c = b.compute("c", 1, 1.0);
        b.dep(a, f1).dep(a, f2).dep(f1, c).dep(f2, c);
        b.finalize().unwrap()
    }

    #[test]
    fn executes_all_tasks_in_order() {
        let dag = diamond();
        let pacer = NicPacer::new(3, 1e6, 0.0);
        let count = AtomicUsize::new(0);
        let work = |_: &MXDag, _: TaskId| {
            count.fetch_add(1, Ordering::SeqCst);
            Ok(())
        };
        let prios = vec![0; dag.len()];
        let r = execute_mxdag(&dag, &prios, &pacer, &work, &|t| dag.task(t).size as usize, 4)
            .unwrap();
        assert_eq!(count.load(Ordering::SeqCst), 2); // a and c
        assert_eq!(r.events.len(), dag.len());
        // c must start after both flows end
        let c = r.event("c").unwrap().start;
        assert!(c >= r.event("f1").unwrap().end);
        assert!(c >= r.event("f2").unwrap().end);
    }

    #[test]
    fn failure_propagates() {
        let dag = diamond();
        let pacer = NicPacer::new(3, 1e6, 0.0);
        let work = |dag: &MXDag, t: TaskId| {
            if dag.task(t).name == "c" {
                Err(anyhow!("boom"))
            } else {
                Ok(())
            }
        };
        let prios = vec![0; dag.len()];
        let err = execute_mxdag(&dag, &prios, &pacer, &work, &|_| 0, 2).unwrap_err();
        assert!(err.to_string().contains("boom"));
    }

    #[test]
    fn priority_orders_contending_flows() {
        // two flows share the uplink; higher priority goes first
        let mut b = MXDag::builder();
        let hi = b.flow("hi", 0, 1, 0.0);
        let lo = b.flow("lo", 0, 2, 0.0);
        let dag = b.finalize().unwrap();
        let pacer = NicPacer::new(3, 1000.0, 0.02); // 20ms per 1000B
        let mut prios = vec![0i64; dag.len()];
        prios[hi] = 10;
        prios[lo] = 1;
        let work = |_: &MXDag, _: TaskId| Ok(());
        // single thread forces queue ordering to decide
        let r = execute_mxdag(&dag, &prios, &pacer, &work, &|_| 1000, 1).unwrap();
        assert!(r.event("hi").unwrap().end <= r.event("lo").unwrap().start);
    }

    #[test]
    fn parallel_flows_overlap_on_distinct_nics() {
        let mut b = MXDag::builder();
        let _f1 = b.flow("fa", 0, 1, 0.0);
        let _f2 = b.flow("fb", 2, 3, 0.0);
        let dag = b.finalize().unwrap();
        let pacer = NicPacer::new(4, 1000.0, 0.05);
        let prios = vec![0i64; dag.len()];
        let work = |_: &MXDag, _: TaskId| Ok(());
        let r = execute_mxdag(&dag, &prios, &pacer, &work, &|_| 1000, 4).unwrap();
        assert!(r.makespan < Duration::from_millis(95), "{:?}", r.makespan);
    }
}
