//! Lightweight runtime metrics: counters and duration histograms for the
//! coordinator hot path (no external metrics crate in this image).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    timings: BTreeMap<String, Vec<f64>>, // seconds
}

/// Thread-safe metrics registry.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        *self
            .inner
            .lock()
            .unwrap()
            .counters
            .entry(name.to_string())
            .or_insert(0) += by;
    }

    pub fn observe(&self, name: &str, d: Duration) {
        self.observe_secs(name, d.as_secs_f64());
    }

    /// Record a timing already expressed in seconds. The serve path's
    /// job-completion times run on the *simulation* clock, not wall
    /// time, so there is no `Duration` to hand over.
    pub fn observe_secs(&self, name: &str, secs: f64) {
        self.inner
            .lock()
            .unwrap()
            .timings
            .entry(name.to_string())
            .or_default()
            .push(secs);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .counters
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    /// (count, mean, p50, p99) seconds for a timing series.
    pub fn summary(&self, name: &str) -> Option<(usize, f64, f64, f64)> {
        let inner = self.inner.lock().unwrap();
        let xs = inner.timings.get(name)?;
        if xs.is_empty() {
            return None;
        }
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = sorted.iter().sum::<f64>() / sorted.len() as f64;
        let p = |q: f64| sorted[((sorted.len() - 1) as f64 * q) as usize];
        Some((sorted.len(), mean, p(0.5), p(0.99)))
    }

    pub fn report(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let mut out = String::new();
        for (k, v) in &inner.counters {
            out.push_str(&format!("{k}: {v}\n"));
        }
        for (k, xs) in &inner.timings {
            if xs.is_empty() {
                continue;
            }
            let mut sorted = xs.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mean = sorted.iter().sum::<f64>() / sorted.len() as f64;
            out.push_str(&format!(
                "{k}: n={} mean={:.3}ms p50={:.3}ms p99={:.3}ms\n",
                sorted.len(),
                mean * 1e3,
                sorted[sorted.len() / 2] * 1e3,
                sorted[(sorted.len() - 1) * 99 / 100] * 1e3,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("x", 2);
        m.incr("x", 3);
        assert_eq!(m.counter("x"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn timing_summary() {
        let m = Metrics::new();
        for ms in [1u64, 2, 3, 4, 100] {
            m.observe("t", Duration::from_millis(ms));
        }
        let (n, mean, p50, p99) = m.summary("t").unwrap();
        assert_eq!(n, 5);
        assert!(mean > 0.0 && p50 <= p99);
        assert!(m.summary("none").is_none());
    }

    #[test]
    fn observe_secs_feeds_the_same_series() {
        let m = Metrics::new();
        m.observe("t", Duration::from_millis(10));
        m.observe_secs("t", 0.5);
        let (n, _, _, p99) = m.summary("t").unwrap();
        assert_eq!(n, 2);
        assert!((p99 - 0.5).abs() < 1e-12);
    }

    #[test]
    fn report_renders() {
        let m = Metrics::new();
        m.incr("req", 1);
        m.observe("lat", Duration::from_millis(5));
        let r = m.report();
        assert!(r.contains("req: 1"));
        assert!(r.contains("lat:"));
    }

    #[test]
    fn thread_safety() {
        let m = std::sync::Arc::new(Metrics::new());
        let hs: Vec<_> = (0..8)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.incr("c", 1);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.counter("c"), 800);
    }
}
