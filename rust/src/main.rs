//! `mxdag` — CLI for the MXDAG reproduction.
//!
//! Subcommands:
//!   figures   — run every paper-figure experiment and print the tables
//!   train     — DDL training end-to-end (PJRT compute + paced network)
//!   whatif    — pipeline what-if analysis on a scenario DAG
//!   monitor   — straggler-detection demo (host vs network)
//!   simulate  — schedule+simulate a DAG from a JSON file
//!   serve     — crash-safe long-lived coordinator (HTTP + WAL resume)
//!   info      — artifact/platform info

use std::path::Path;

use mxdag::coordinator::{self, DdlConfig, SyncSchedule};
use mxdag::mxdag::MXDag;
use mxdag::sched::{
    self, evaluate, evaluate_with, AltruisticScheduler, CoflowScheduler, FairScheduler,
    FifoScheduler, Grouping, MxScheduler, PackingScheduler, Plan, Scheduler, SelfishScheduler,
};
use mxdag::sim::{
    expand, run_open, AllocKind, Annotations, Cluster, HorizonKind, OpenConfig, OpenSpec, Policy,
    QueueKind, RecoveryPolicy, SimConfig, SimError,
};
use mxdag::util::bench::Table;
use mxdag::util::json::Json;
use mxdag::util::cli::Args;
use mxdag::workloads::{self, WukongCoflows};

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand.as_deref() {
        Some("figures") => cmd_figures(),
        Some("train") => cmd_train(&args),
        Some("whatif") => cmd_whatif(&args),
        Some("monitor") => cmd_monitor(),
        Some("simulate") => cmd_simulate(&args),
        Some("serve") => mxdag::serve::run(&args),
        Some("info") => cmd_info(&args),
        _ => {
            print_usage();
            0
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "mxdag — compute/network co-scheduling (MXDAG reproduction)\n\n\
         USAGE: mxdag <subcommand> [options]\n\n\
         SUBCOMMANDS:\n\
           figures                       reproduce Figs. 1, 2, 3, 6, 7\n\
           train [--workers N] [--steps N] [--schedule mxdag|fifo]\n\
                 [--bandwidth BYTES_PER_S] [--time-scale X] [--artifacts DIR]\n\
           whatif [--threads N]          pipeline what-if on the Fig. 3 DAG\n\
                 (N worker threads score the hypotheticals in parallel;\n\
                  results are bit-identical for every N — default 1)\n\
           monitor                       straggler classification demo\n\
           simulate --dag FILE.json [--scheduler mxdag|fair|fifo|coflow|packing]\n\
                    [--topology bigswitch|oversub:RACKS:RATIO|fabrics:K:TRUNK[:hash|bysrc]]\n\
                    [--queue incremental|fullresort] [--alloc components|wholeset]\n\
                    [--horizon eager|anchored] [--threads N] [--dynamics FILE.json]\n\
                    [--recovery failfast|retry|retry:MAX_ATTEMPTS:BACKOFF]\n\
                    [--open ARRIVALS.json [--watermark X] [--defer-max X]]\n\
                    (the DAG file may also declare a \"cluster\" object and an\n\
                     \"engine\" object {{\"queue\", \"alloc\", \"horizon\", \"threads\",\n\
                     \"recovery\"}}; the --topology/--queue/--alloc/--horizon/\n\
                     --threads/--recovery flags override them and select the\n\
                     engine's ready-queue, rate-allocation, time-advance,\n\
                     parallel-refill and fault-recovery paths;\n\
                     N>1 fans component refills across worker threads with\n\
                     results identical to the N=1 serial oracle;\n\
                     --dynamics FILE.json injects a cluster-churn timeline —\n\
                     a JSON array of events like\n\
                     {{\"at\": 2.0, \"kind\": \"degrade\", \"link\": \"up:0\", \"factor\": 0.5}}\n\
                     {{\"at\": 3.0, \"kind\": \"fail\", \"link\": \"trunk:1\"}}\n\
                     {{\"at\": 4.0, \"kind\": \"restore\", \"link\": \"trunk:1\"}}\n\
                     {{\"at\": 5.0, \"kind\": \"slow_host\", \"host\": 2, \"factor\": 0.25}}\n\
                     {{\"at\": 6.0, \"kind\": \"fail_host\", \"host\": 2}}\n\
                     — the DAG file may declare the same array under a\n\
                     top-level \"dynamics\" key; the flag overrides it;\n\
                     under --recovery retry a fail_host kills the host's\n\
                     in-flight tasks, retries them behind exponential backoff\n\
                     and quarantines terminally-stuck jobs instead of failing;\n\
                     the run always ends with one JSON line of per-job\n\
                     outcomes; exit code 0 = ok, 1 = config error,\n\
                     2 = deadlock, 3 = event-limit;\n\
                     --open ARRIVALS.json streams one copy of the DAG per\n\
                     arrival through the open-system driver instead of one\n\
                     closed run — the file gives {{\"arrivals\": [t0, t1, ...]}}\n\
                     or {{\"poisson\": {{\"seed\": S, \"rate\": R, \"n\": N}}}} plus\n\
                     optional \"watermark\" (admission drain-time bound,\n\
                     default unbounded), \"defer_max\" (how long an arrival\n\
                     may wait for admission before it is shed, default 0)\n\
                     and \"deadline\" (per-job, relative to arrival);\n\
                     --watermark/--defer-max override the file; the JSON\n\
                     outcome line then carries admitted/rejected/completed\n\
                     counters, JCT p50/p99 and the deadline hit rate)\n\
           serve --dir DIR | --resume DIR [--check]\n\
                 [--host H] [--port P] [--addr-file FILE]\n\
                 [--hosts N | --cluster FILE.json] [--scheduler NAME]\n\
                 [--watermark X] [--defer-max X] [--weights a=3,b=1]\n\
                 [--queue ...] [--alloc ...] [--horizon ...] [--threads N]\n\
                 [--recovery ...] [--workers N] [--queue-cap N]\n\
                 [--max-body BYTES] [--read-timeout-ms MS] [--time-scale X]\n\
                 [--tick-ms MS] [--snap-every N]\n\
                 (long-lived coordinator: POST /jobs submits an OpenSpec-\n\
                  compatible {{\"dag\", \"scheduler\", \"deadline\", \"tenant\"}}\n\
                  JSON, GET /jobs/N polls it, GET /healthz and /metrics\n\
                  serve liveness + counters; every accepted submission and\n\
                  clock advance is write-ahead-logged under DIR and\n\
                  --resume DIR replays the log into bitwise-identical\n\
                  state (--check prints the recovered report and exits);\n\
                  SIGTERM drains gracefully: stop admitting, finish live\n\
                  eras, flush the WAL, exit 0; exit codes 0 = clean\n\
                  drain, 1 = config error, 2 = deadlock, 3 = event-limit\n\
                  — the same simulation codes as `simulate`)\n\
           info [--artifacts DIR]        platform + artifact inventory"
    );
}

fn cmd_figures() -> i32 {
    fig1();
    fig2();
    fig3();
    fig6();
    fig7();
    0
}

fn fig1() {
    let g = workloads::fig1_dag();
    let cluster = Cluster::uniform(3);
    let fair = sched::run(&FairScheduler, &g, &cluster).unwrap();
    let mx = sched::run(&MxScheduler::without_pipelining(), &g, &cluster).unwrap();
    let mut t = Table::new(
        "Fig 1 — network-aware fair share vs MXDAG co-scheduling",
        &["JCT", "C starts"],
    );
    let c = g.by_name("C").unwrap();
    t.row_f64("fair share (T1)", &[fair.makespan, fair.start_of(c)]);
    t.row_f64("mxdag (T2)", &[mx.makespan, mx.start_of(c)]);
    t.print();
}

fn fig2() {
    // 2(a/c): asymmetric compute times
    let (g, flows) = workloads::fig2a_dag(3.0, 1.0);
    let cluster = Cluster::uniform(4);
    let mx = sched::run(&MxScheduler::without_pipelining(), &g, &cluster).unwrap();
    let co = sched::run(
        &CoflowScheduler::new(Grouping::Explicit(vec![
            vec![flows[0], flows[1]],
            vec![flows[2], flows[3]],
        ])),
        &g,
        &cluster,
    )
    .unwrap();
    let mut t = Table::new(
        "Fig 2(c) — asymmetric compute times (t1=3, t2=1)",
        &["JCT"],
    );
    t.row_f64("mxdag per-flow", &[mx.makespan]);
    t.row_f64("coflow {f1,f2},{f3,f4}", &[co.makespan]);
    t.print();

    // 2(b/d): Wukong topology and the three coflow definitions
    let (g, flows) = workloads::wukong_dag();
    let cluster = Cluster::uniform(6);
    let mut t = Table::new("Fig 2(d) — Wukong DAG, coflow definition ambiguity", &["JCT"]);
    let mx = sched::run(&MxScheduler::without_pipelining(), &g, &cluster).unwrap();
    t.row_f64("mxdag per-flow", &[mx.makespan]);
    for v in WukongCoflows::all() {
        let r = sched::run(
            &CoflowScheduler::new(Grouping::Explicit(v.groups(&flows))),
            &g,
            &cluster,
        )
        .unwrap();
        t.row_f64(v.label(), &[r.makespan]);
    }
    t.print();
}

fn fig3() {
    let (g, _) = workloads::fig3_dag();
    let cluster = workloads::figs::fig3_cluster();
    let mut t = Table::new("Fig 3 — pipelineability choices (FIFO runtime)", &["JCT"]);
    for (name, pipes) in workloads::fig3_pipeline_sets() {
        let pipelined = pipes.iter().map(|n| g.by_name(n).unwrap()).collect();
        let plan = Plan {
            ann: Annotations { pipelined, ..Default::default() },
            policy: Policy::fifo(),
        };
        t.row_f64(name, &[evaluate(&g, &cluster, &plan).unwrap().makespan]);
    }
    let mx = sched::run(&MxScheduler::default(), &g, &cluster).unwrap();
    t.row_f64("mxdag (auto pipeline search)", &[mx.makespan]);
    t.print();
}

fn fig6() {
    let cluster = Cluster::with_cores(2, 2.0);
    let mut t = Table::new(
        "Fig 6 — DDL layer-wise sync (simulated)",
        &["iter time (fifo)", "iter time (mxdag)", "speedup"],
    );
    for layers in [2usize, 4, 8] {
        let p = workloads::DdlParams { layers, ..Default::default() };
        let (g, _) = workloads::ddl_dag(&p);
        let fifo = sched::run(&FifoScheduler, &g, &cluster).unwrap().makespan;
        let mx = sched::run(&MxScheduler::without_pipelining(), &g, &cluster)
            .unwrap()
            .makespan;
        t.row_f64(&format!("{layers} layers"), &[fifo, mx, fifo / mx]);
    }
    t.print();
}

fn fig7() {
    let (j1, j2) = workloads::fig7_jobs();
    let multi = mxdag::sched::altruistic::merge(&[j1, j2]);
    let cluster = Cluster::uniform(4);
    let selfish = evaluate(&multi.dag, &cluster, &SelfishScheduler.plan_multi(&multi)).unwrap();
    let altru = evaluate(&multi.dag, &cluster, &AltruisticScheduler.plan_multi_checked(&multi, &cluster)).unwrap();
    let mut t = Table::new("Fig 7 — altruistic multi-job scheduling", &["job1 JCT", "job2 JCT"]);
    t.row_f64("selfish (c)", &[multi.jct(0, &selfish), multi.jct(1, &selfish)]);
    t.row_f64("altruistic (d)", &[multi.jct(0, &altru), multi.jct(1, &altru)]);
    t.print();
}

fn cmd_train(args: &Args) -> i32 {
    let schedule = match args.get_or("schedule", "both").as_str() {
        "mxdag" => vec![SyncSchedule::Mxdag],
        "fifo" => vec![SyncSchedule::Fifo],
        _ => vec![SyncSchedule::Fifo, SyncSchedule::Mxdag],
    };
    let mut rows = Vec::new();
    for s in schedule {
        let cfg = DdlConfig {
            artifacts_dir: args.get_or("artifacts", "artifacts").into(),
            workers: args.usize_or("workers", 2),
            steps: args.usize_or("steps", 20),
            bandwidth: args.f64_or("bandwidth", 25e6),
            time_scale: args.f64_or("time-scale", 1.0),
            schedule: s,
            seed: args.usize_or("seed", 0) as u64,
            log_every: args.usize_or("log-every", 5),
            fwd_reps: args.usize_or("fwd-reps", 6),
        };
        match coordinator::train(&cfg) {
            Ok(r) => {
                println!(
                    "[{}] loss {:.4} -> {:.4}, mean step {:?}",
                    s.label(),
                    r.first_loss(),
                    r.last_loss(),
                    r.mean_step_wall()
                );
                rows.push((s.label(), r));
            }
            Err(e) => {
                eprintln!("train failed: {e:#}");
                return 1;
            }
        }
    }
    if rows.len() == 2 {
        let fifo = rows[0].1.mean_step_wall().as_secs_f64();
        let mx = rows[1].1.mean_step_wall().as_secs_f64();
        println!("\nstep-time speedup (fifo/mxdag): {:.3}x", fifo / mx);
    }
    0
}

fn cmd_whatif(args: &Args) -> i32 {
    use mxdag::whatif::{explore, single_pipeline_toggles};
    let threads = args.usize_or("threads", 1).max(1);
    let (g, _) = workloads::fig3_dag();
    let cluster = workloads::figs::fig3_cluster();
    let base = Plan { ann: Annotations::default(), policy: Policy::fifo() };
    let hypos = single_pipeline_toggles(&g, &base);
    let ex = match explore(&g, &cluster, &base, &hypos, threads) {
        Ok(ex) => ex,
        Err(e) => {
            eprintln!("baseline failed: {e}");
            return 1;
        }
    };
    println!("baseline JCT: {:.3}  ({} hypotheticals, {threads} thread(s))", ex.baseline, ex.results.len());
    let mut t = Table::new("what-if: single pipeline toggles", &["JCT", "delta"]);
    for w in &ex.results {
        match &w.outcome {
            Ok((jct, delta)) => t.row_f64(&w.label, &[*jct, *delta]),
            // a failing hypothetical is reported in place, not fatal
            Err(e) => t.row(&w.label, &[format!("failed: {e}"), String::new()]),
        }
    }
    t.print();
    0
}

fn cmd_monitor() -> i32 {
    use mxdag::monitor::detect_stragglers;
    let g = workloads::fig1_dag();
    let plan = Plan::fair();
    let healthy = Cluster::uniform(3);
    let exp = evaluate(&g, &healthy, &plan).unwrap();

    let mut net_bad = Cluster::uniform(3);
    net_bad.hosts[1].nic_up = 0.25;
    let obs = evaluate(&g, &net_bad, &plan).unwrap();
    println!("== degraded uplink on host 1 ==");
    for s in detect_stragglers(&g, &exp, &obs, 1.5) {
        println!("  {} ({:?}) {:.1}x slower", s.name, s.kind, s.slowdown);
    }

    let mut cpu_bad = Cluster::uniform(3);
    cpu_bad.hosts[1].cores = 0.25;
    let obs = evaluate(&g, &cpu_bad, &plan).unwrap();
    println!("== degraded CPU on host 1 ==");
    for s in detect_stragglers(&g, &exp, &obs, 1.5) {
        println!("  {} ({:?}) {:.1}x slower", s.name, s.kind, s.slowdown);
    }
    0
}

fn cmd_simulate(args: &Args) -> i32 {
    let Some(path) = args.get("dag") else {
        eprintln!("--dag FILE.json required");
        return 1;
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("read {path}: {e}");
            return 1;
        }
    };
    let json = match mxdag::util::json::Json::parse(&text) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("parse {path}: {e}");
            return 1;
        }
    };
    let g = match MXDag::from_json(&json) {
        Ok(g) => g,
        Err(e) => {
            eprintln!("invalid DAG: {e}");
            return 1;
        }
    };
    let hosts = g.hosts().into_iter().max().map(|h| h + 1).unwrap_or(1).max(1);
    // cluster: a declared one must cover every referenced host (padding
    // would silently shift the rack partition); otherwise default to a
    // uniform big switch sized to the DAG
    let mut cluster = match json.get("cluster") {
        Ok(cj) => match Cluster::from_json(cj) {
            Ok(c) => {
                if c.n_hosts() < hosts {
                    eprintln!(
                        "invalid cluster: declares {} hosts but the DAG references host {}",
                        c.n_hosts(),
                        hosts - 1
                    );
                    return 1;
                }
                c
            }
            Err(e) => {
                eprintln!("invalid cluster: {e}");
                return 1;
            }
        },
        Err(_) => Cluster::uniform(hosts),
    };
    // --topology overrides whatever the scenario declared
    if let Some(spec) = args.get("topology") {
        match mxdag::sim::Topology::parse(spec) {
            Ok(t) => cluster.topology = t,
            Err(e) => {
                eprintln!("--topology: {e}");
                return 1;
            }
        }
    }
    let sched: Box<dyn Scheduler> = match args.get_or("scheduler", "mxdag").as_str() {
        "fair" => Box::new(FairScheduler),
        "fifo" => Box::new(FifoScheduler),
        "packing" => Box::new(PackingScheduler),
        "coflow" => Box::new(CoflowScheduler::new(Grouping::ByDst)),
        _ => Box::new(MxScheduler::default()),
    };
    // engine configuration: a scenario "engine" object first, then the
    // CLI flags override it — the same layering as cluster vs --topology
    let mut cfg = SimConfig::default();
    if let Ok(ej) = json.get("engine") {
        if let Err(e) = cfg.apply_json(ej) {
            eprintln!("invalid engine config: {e}");
            return 1;
        }
    }
    if let Some(v) = args.get("queue") {
        match QueueKind::parse(v) {
            Ok(q) => cfg.queue = q,
            Err(e) => {
                eprintln!("--queue: {e}");
                return 1;
            }
        }
    }
    if let Some(v) = args.get("alloc") {
        match AllocKind::parse(v) {
            Ok(a) => cfg.alloc = a,
            Err(e) => {
                eprintln!("--alloc: {e}");
                return 1;
            }
        }
    }
    if let Some(v) = args.get("horizon") {
        match HorizonKind::parse(v) {
            Ok(h) => cfg.horizon = h,
            Err(e) => {
                eprintln!("--horizon: {e}");
                return 1;
            }
        }
    }
    if let Some(v) = args.get("threads") {
        match v.parse::<usize>() {
            Ok(t) if t >= 1 => cfg.threads = t,
            _ => {
                eprintln!("--threads: expected an integer >= 1, got {v:?}");
                return 1;
            }
        }
    }
    if let Some(v) = args.get("recovery") {
        match RecoveryPolicy::parse(&v) {
            Ok(p) => cfg.recovery = p,
            Err(e) => {
                eprintln!("--recovery: {e}");
                return 1;
            }
        }
    }
    // cluster dynamics: a scenario "dynamics" array first, then
    // --dynamics FILE overrides it — the same layering as the engine
    // object vs the engine flags
    if let Ok(dj) = json.get("dynamics") {
        match mxdag::sim::DynTimeline::from_json(dj) {
            Ok(t) => cfg.dynamics = t,
            Err(e) => {
                eprintln!("invalid dynamics block: {e}");
                return 1;
            }
        }
    }
    if let Some(dpath) = args.get("dynamics") {
        let dtext = match std::fs::read_to_string(&dpath) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("read {dpath}: {e}");
                return 1;
            }
        };
        let djson = match mxdag::util::json::Json::parse(&dtext) {
            Ok(j) => j,
            Err(e) => {
                eprintln!("parse {dpath}: {e}");
                return 1;
            }
        };
        match mxdag::sim::DynTimeline::from_json(&djson) {
            Ok(t) => cfg.dynamics = t,
            Err(e) => {
                eprintln!("--dynamics: {e}");
                return 1;
            }
        }
    }
    // validate against the *final* cluster (after --topology overrides)
    if let Err(e) = cfg.dynamics.validate(&cluster) {
        eprintln!("invalid dynamics: {e}");
        return 1;
    }
    let plan = sched.plan(&g, &cluster);
    // --open switches from one closed run to the era-chained open-system
    // driver: one copy of the (planned, expanded) DAG per arrival
    if let Some(opath) = args.get("open") {
        return simulate_open(&opath, args, &g, &cluster, &plan, &cfg, sched.name());
    }
    match evaluate_with(&g, &cluster, &plan, &cfg) {
        Ok(r) => {
            println!(
                "scheduler={} hosts={} topology={:?} queue={:?} alloc={:?} horizon={:?} \
                 threads={} dynamics={} recovery={} tasks={} makespan={:.4} events={} \
                 retries={} lost_work={:.4}",
                sched.name(),
                cluster.n_hosts(),
                cluster.topology,
                cfg.queue,
                cfg.alloc,
                cfg.horizon,
                cfg.threads,
                cfg.dynamics.len(),
                cfg.recovery.label(),
                g.real_tasks().count(),
                r.makespan,
                r.events,
                r.retries,
                r.lost_work
            );
            let jobs: Vec<Json> =
                r.jobs.iter().enumerate().map(|(j, o)| o.to_json(j)).collect();
            println!(
                "{}",
                Json::obj(vec![
                    ("status", Json::Str("ok".into())),
                    ("makespan", Json::Num(r.makespan)),
                    ("events", Json::Num(r.events as f64)),
                    ("retries", Json::Num(r.retries as f64)),
                    ("lost_work", Json::Num(r.lost_work)),
                    ("jobs", Json::Arr(jobs)),
                ])
            );
            0
        }
        Err(e) => {
            // structured report on failure too, with the failure class
            // in the exit code: 2 = deadlock (the plan/cluster starved),
            // 3 = event limit (the run never converged) — distinct from
            // 1, which is reserved for config/input errors above
            eprintln!("simulation failed: {e}");
            sim_error_report(&e)
        }
    }
}

/// Print the structured error line for a failed simulation and return
/// the failure-class exit code ([`SimError::exit_code`]: 2 = deadlock,
/// 3 = event-limit) — shared by the closed and open `simulate` paths
/// so the documented kind/code mapping cannot drift between them.
fn sim_error_report(e: &SimError) -> i32 {
    println!(
        "{}",
        Json::obj(vec![
            ("status", Json::Str("error".into())),
            ("kind", Json::Str(e.kind_str().into())),
            ("error", Json::Str(e.to_string())),
            ("jobs", Json::Arr(Vec::new())),
        ])
    );
    e.exit_code()
}

/// The `simulate --open` tail: stream `spec`-driven arrivals of the
/// planned DAG through the open-loop driver and print the same
/// human-line + JSON-outcome-line pair as the closed path, extended
/// with admission/shedding counters and the JCT/deadline metrics.
fn simulate_open(
    path: &str,
    args: &Args,
    g: &MXDag,
    cluster: &Cluster,
    plan: &Plan,
    cfg: &SimConfig,
    sched_name: &str,
) -> i32 {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("read {path}: {e}");
            return 1;
        }
    };
    let json = match Json::parse(&text) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("parse {path}: {e}");
            return 1;
        }
    };
    let mut spec = match OpenSpec::from_json(&json) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("--open: {e}");
            return 1;
        }
    };
    if let Some(v) = args.get("watermark") {
        match v.parse::<f64>() {
            Ok(w) if w >= 0.0 => spec.watermark = w,
            _ => {
                eprintln!("--watermark: expected a number >= 0, got {v:?}");
                return 1;
            }
        }
    }
    if let Some(v) = args.get("defer-max") {
        match v.parse::<f64>() {
            Ok(d) if d >= 0.0 && d.is_finite() => spec.defer_max = d,
            _ => {
                eprintln!("--defer-max: expected a finite number >= 0, got {v:?}");
                return 1;
            }
        }
    }
    let sim = expand(g, &plan.ann);
    let jobs = spec.jobs(&sim);
    let ocfg = OpenConfig {
        watermark: spec.watermark,
        defer_max: spec.defer_max,
        engine: SimConfig { policy: plan.policy, ..cfg.clone() },
    };
    match run_open(&jobs, cluster, &ocfg) {
        Ok(r) => {
            println!(
                "scheduler={sched_name} hosts={} open_jobs={} watermark={} defer_max={} \
                 admitted={} rejected={} quarantined={} completed={} eras={} makespan={:.4} \
                 events={} retries={} lost_work={:.4}",
                cluster.n_hosts(),
                jobs.len(),
                spec.watermark,
                spec.defer_max,
                r.admitted,
                r.rejected,
                r.quarantined,
                r.completed,
                r.eras,
                r.makespan,
                r.events,
                r.retries,
                r.lost_work
            );
            let Json::Obj(mut kv) = r.to_json() else { unreachable!("to_json is an object") };
            kv.insert("status".into(), Json::Str("ok".into()));
            kv.insert("jobs".into(), r.jobs_json());
            println!("{}", Json::Obj(kv));
            0
        }
        Err(e) => {
            eprintln!("open-loop simulation failed: {e}");
            sim_error_report(&e)
        }
    }
}

fn cmd_info(args: &Args) -> i32 {
    let dir = args.get_or("artifacts", "artifacts");
    match mxdag::runtime::Engine::load(Path::new(&dir)) {
        Ok(e) => {
            println!("platform: {}", e.platform());
            println!(
                "model: {}-{:?}-{} batch={} params={}",
                e.manifest.model.input_dim,
                e.manifest.model.hidden,
                e.manifest.model.classes,
                e.manifest.model.batch,
                e.manifest.model.param_count
            );
            for name in e.artifact_names() {
                let a = e.manifest.artifact(name).unwrap();
                println!("  {name}: {} inputs -> {} outputs", a.inputs.len(), a.n_outputs);
            }
            0
        }
        Err(e) => {
            eprintln!("info failed (run `make artifacts`?): {e:#}");
            1
        }
    }
}
