//! Minimal JSON substrate (serde_json is not vendored in this image).
//!
//! Supports the full JSON grammar minus exotic number forms; used for the
//! artifact manifest, workload traces, and CLI experiment dumps.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse / access error.
#[derive(Debug)]
pub enum JsonError {
    Parse(usize, String),
    MissingKey(String),
    Type(&'static str),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse(at, msg) => write!(f, "parse error at byte {at}: {msg}"),
            JsonError::MissingKey(k) => write!(f, "missing key `{k}`"),
            JsonError::Type(want) => write!(f, "type mismatch: wanted {want}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(JsonError::Parse(p.i, "trailing data".into()));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(JsonError::Type("number")),
        }
    }
    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_f64()? as usize)
    }
    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(JsonError::Type("string")),
        }
    }
    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(JsonError::Type("bool")),
        }
    }
    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => Err(JsonError::Type("array")),
        }
    }
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => Err(JsonError::Type("object")),
        }
    }
    /// `obj["k"]` with a proper error.
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::MissingKey(key.to_string()))
    }
    /// Convenience: object → `Vec<usize>` under key.
    pub fn usize_vec(&self) -> Result<Vec<usize>, JsonError> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(JsonError::Parse(self.i, format!("expected `{}`", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(JsonError::Parse(self.i, format!("expected `{s}`")))
        }
    }
    fn value(&mut self) -> Result<Json, JsonError> {
        self.ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(JsonError::Parse(self.i, "unexpected byte".into())),
        }
    }
    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| JsonError::Parse(start, e.to_string()))
    }
    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(JsonError::Parse(self.i, "unterminated string".into())),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 5 > self.b.len() {
                                return Err(JsonError::Parse(self.i, "bad \\u".into()));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|e| JsonError::Parse(self.i, e.to_string()))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(JsonError::Parse(self.i, "bad escape".into())),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| JsonError::Parse(self.i, e.to_string()))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }
    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(JsonError::Parse(self.i, "expected , or ]".into())),
            }
        }
    }
    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(JsonError::Parse(self.i, "expected , or }".into())),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_f64().unwrap(), 2.0);
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v, Json::Str("Aé".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"n":-3,"o":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::Num(1.0).as_str().is_err());
        assert!(Json::obj(vec![]).get("nope").is_err());
    }

    #[test]
    fn display_escapes() {
        let s = Json::Str("a\"b\\c\nd".into()).to_string();
        assert_eq!(s, r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&s).unwrap(), Json::Str("a\"b\\c\nd".into()));
    }

    #[test]
    fn whole_number_display() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }
}
