//! Minimal JSON substrate (serde_json is not vendored in this image).
//!
//! Supports the full JSON grammar minus exotic number forms; used for the
//! artifact manifest, workload traces, CLI experiment dumps, and the
//! `mxdag serve` wire API. Because `serve` feeds *hostile* request bodies
//! through this parser, it must never panic: malformed UTF-8, truncated
//! `\uXXXX` escapes, huge numbers and deep nesting all surface as
//! `JsonError::Parse` (see the `malformed_corpus` test).

use std::collections::BTreeMap;
use std::fmt;

/// Nesting depth cap: recursive-descent parsing of `[[[[...]]]]` must not
/// overflow the stack on adversarial input.
const MAX_DEPTH: usize = 512;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse / access error.
#[derive(Debug)]
pub enum JsonError {
    Parse(usize, String),
    MissingKey(String),
    Type { want: &'static str, got: &'static str },
}

impl JsonError {
    /// Shorthand used by typed accessors across the crate.
    pub fn type_err(want: &'static str, got: &Json) -> JsonError {
        JsonError::Type { want, got: got.kind() }
    }
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse(at, msg) => write!(f, "parse error at byte {at}: {msg}"),
            JsonError::MissingKey(k) => write!(f, "missing key `{k}`"),
            JsonError::Type { want, got } => {
                write!(f, "type mismatch: wanted {want}, got {got}")
            }
        }
    }
}

impl std::error::Error for JsonError {}

/// Bit-exact `f64` serialization for WAL records and snapshots: `Json::Num`
/// round-trips through decimal text and cannot preserve every bit pattern,
/// so crash-safe state uses the hex of `f64::to_bits` instead.
pub fn f64_bits_hex(x: f64) -> String {
    format!("{:016x}", x.to_bits())
}

/// Inverse of [`f64_bits_hex`].
pub fn f64_from_bits_hex(s: &str) -> Result<f64, JsonError> {
    if s.len() != 16 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
        return Err(JsonError::Parse(0, format!("bad f64 bits `{s}`")));
    }
    let bits = u64::from_str_radix(s, 16)
        .map_err(|e| JsonError::Parse(0, format!("bad f64 bits `{s}`: {e}")))?;
    Ok(f64::from_bits(bits))
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        Json::parse_bytes(s.as_bytes())
    }

    /// Parse from raw bytes (e.g. an HTTP body that may not be UTF-8).
    /// Non-UTF-8 sequences inside strings are parse errors, not panics.
    pub fn parse_bytes(b: &[u8]) -> Result<Json, JsonError> {
        let mut p = Parser { b, i: 0, depth: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(JsonError::Parse(p.i, "trailing data".into()));
        }
        Ok(v)
    }

    /// Human label for this value's variant (used in type-mismatch errors).
    pub fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(JsonError::type_err("number", self)),
        }
    }
    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_f64()? as usize)
    }
    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(JsonError::type_err("string", self)),
        }
    }
    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(JsonError::type_err("bool", self)),
        }
    }
    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => Err(JsonError::type_err("array", self)),
        }
    }
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => Err(JsonError::type_err("object", self)),
        }
    }
    /// `obj["k"]` with a proper error.
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::MissingKey(key.to_string()))
    }
    /// Convenience: object → `Vec<usize>` under key.
    pub fn usize_vec(&self) -> Result<Vec<usize>, JsonError> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(JsonError::Parse(self.i, format!("expected `{}`", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(JsonError::Parse(self.i, format!("expected `{s}`")))
        }
    }
    fn value(&mut self) -> Result<Json, JsonError> {
        self.ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(JsonError::Parse(self.i, "unexpected byte".into())),
        }
    }
    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        // The scanned span is ASCII by construction, but hostile input must
        // not be able to panic the parser, so no `unwrap` here.
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|e| JsonError::Parse(start, e.to_string()))?;
        let n: f64 = s
            .parse()
            .map_err(|e: std::num::ParseFloatError| JsonError::Parse(start, e.to_string()))?;
        if !n.is_finite() {
            return Err(JsonError::Parse(start, format!("number out of range: `{s}`")));
        }
        Ok(Json::Num(n))
    }
    /// Read exactly four hex digits of a `\uXXXX` escape; `self.i` points at
    /// the `u`. Truncated or non-hex (including non-UTF-8) bytes are errors.
    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 5 > self.b.len() {
            return Err(JsonError::Parse(self.i, "truncated \\u escape".into()));
        }
        let mut cp: u32 = 0;
        for k in 1..=4 {
            let d = self.b[self.i + k];
            let v = match d {
                b'0'..=b'9' => (d - b'0') as u32,
                b'a'..=b'f' => (d - b'a' + 10) as u32,
                b'A'..=b'F' => (d - b'A' + 10) as u32,
                _ => {
                    return Err(JsonError::Parse(
                        self.i + k,
                        "non-hex digit in \\u escape".into(),
                    ))
                }
            };
            cp = cp << 4 | v;
        }
        self.i += 4;
        Ok(cp)
    }
    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(JsonError::Parse(self.i, "unterminated string".into())),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.hex4()?;
                            if (0xd800..0xdc00).contains(&cp)
                                && self.b[self.i + 1..].starts_with(b"\\u")
                            {
                                // High surrogate followed by another escape:
                                // decode the pair per RFC 8259 §7.
                                let save = self.i;
                                self.i += 2;
                                let lo = self.hex4()?;
                                if (0xdc00..0xe000).contains(&lo) {
                                    let c = 0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00);
                                    out.push(char::from_u32(c).unwrap_or('\u{fffd}'));
                                } else {
                                    // Not a low surrogate: emit U+FFFD and
                                    // re-scan the second escape normally.
                                    self.i = save;
                                    out.push('\u{fffd}');
                                }
                            } else {
                                // Lone surrogates map to U+FFFD (lenient,
                                // matching pre-hardening behavior).
                                out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            }
                        }
                        _ => return Err(JsonError::Parse(self.i, "bad escape".into())),
                    }
                    self.i += 1;
                }
                Some(first) => {
                    if first < 0x20 {
                        return Err(JsonError::Parse(self.i, "raw control byte in string".into()));
                    }
                    // Decode one UTF-8 scalar from its own slice: validating
                    // only `len` bytes keeps parsing linear and makes invalid
                    // UTF-8 a local parse error instead of a panic.
                    let len = match first {
                        0x00..=0x7f => 1,
                        0xc2..=0xdf => 2,
                        0xe0..=0xef => 3,
                        0xf0..=0xf4 => 4,
                        _ => return Err(JsonError::Parse(self.i, "invalid utf-8 byte".into())),
                    };
                    if self.i + len > self.b.len() {
                        return Err(JsonError::Parse(self.i, "truncated utf-8 sequence".into()));
                    }
                    let s = std::str::from_utf8(&self.b[self.i..self.i + len])
                        .map_err(|e| JsonError::Parse(self.i, e.to_string()))?;
                    out.push_str(s);
                    self.i += len;
                }
            }
        }
    }
    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(JsonError::Parse(self.i, "nesting too deep".into()));
        }
        Ok(())
    }
    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        self.enter()?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(JsonError::Parse(self.i, "expected , or ]".into())),
            }
        }
    }
    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        self.enter()?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(JsonError::Parse(self.i, "expected , or }".into())),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_f64().unwrap(), 2.0);
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v, Json::Str("Aé".into()));
        // \uXXXX escapes, including an astral surrogate pair.
        assert_eq!(Json::parse(r#""\u0041""#).unwrap(), Json::Str("A".into()));
        assert_eq!(
            Json::parse(r#""\ud83d\ude00""#).unwrap(),
            Json::Str("\u{1f600}".into())
        );
        // Lone surrogate stays lenient: replacement char, not a panic.
        assert_eq!(
            Json::parse(r#""\ud83dx""#).unwrap(),
            Json::Str("\u{fffd}x".into())
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"n":-3,"o":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::Num(1.0).as_str().is_err());
        assert!(Json::obj(vec![]).get("nope").is_err());
        let e = Json::Num(1.0).as_str().unwrap_err();
        assert_eq!(e.to_string(), "type mismatch: wanted string, got number");
    }

    /// Hostile-input corpus: every case must return `Err`, never panic.
    /// These are exactly the shapes an attacker can put in a request body.
    #[test]
    fn malformed_corpus() {
        let bad: &[&[u8]] = &[
            // truncated \u escapes (previously panicked via slice/utf8 unwraps)
            br#""\u"#,
            br#""\u0"#,
            br#""\u00"#,
            br#""\u004"#,
            br#""\uzzzz""#,
            b"\"\\u00\xff\xff\"",
            // non-UTF-8 raw bytes inside and outside strings
            b"\"\xff\xfe\"",
            b"\"\xc3\"",        // truncated 2-byte sequence
            b"\"\xe2\x82\"",    // truncated 3-byte sequence
            b"\"a\x80b\"",      // bare continuation byte
            b"\xff",
            // raw control bytes in strings
            b"\"a\x00b\"",
            b"\"a\x1fb\"",
            // stray / trailing bytes
            b"nul",
            b"truex",
            b"1 2",
            b"[1,2",
            b"{\"a\"1}",
            b"{\"a\":}",
            b"[,]",
            b"-",
            b"1e",
            b"--1",
            b".5",
            b"+1",
            // huge numbers overflow f64
            b"1e999",
            b"-1e999",
        ];
        for (k, b) in bad.iter().enumerate() {
            assert!(
                Json::parse_bytes(b).is_err(),
                "corpus case {k} ({:?}) should fail",
                String::from_utf8_lossy(b)
            );
        }
        // Deep nesting: bounded recursion, clean error past the cap.
        let deep_ok = format!("{}0{}", "[".repeat(400), "]".repeat(400));
        assert!(Json::parse(&deep_ok).is_ok());
        let deep_bad = format!("{}0{}", "[".repeat(4000), "]".repeat(4000));
        assert!(Json::parse(&deep_bad).is_err());
        let deep_obj = "{\"k\":".repeat(4000) + "0" + &"}".repeat(4000);
        assert!(Json::parse(&deep_obj).is_err());
    }

    #[test]
    fn f64_bits_roundtrip() {
        for x in [0.0, -0.0, 1.5, f64::INFINITY, f64::MIN_POSITIVE, 1.0 / 3.0] {
            let s = f64_bits_hex(x);
            let y = f64_from_bits_hex(&s).unwrap();
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert!(f64_from_bits_hex("xyz").is_err());
        assert!(f64_from_bits_hex("0123").is_err());
    }

    #[test]
    fn display_escapes() {
        let s = Json::Str("a\"b\\c\nd".into()).to_string();
        assert_eq!(s, r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&s).unwrap(), Json::Str("a\"b\\c\nd".into()));
    }

    #[test]
    fn whole_number_display() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }
}
