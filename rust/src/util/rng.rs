//! Deterministic PRNG substrate (the `rand` crate is not vendored).
//!
//! SplitMix64 core — tiny, fast, passes BigCrush for our purposes
//! (workload generation, property-test case generation, jitter).

/// SplitMix64 PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
