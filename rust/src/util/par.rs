//! Zero-dependency deterministic parallel map over `std::thread::scope`.
//!
//! The batched plan-space engine fans what-if evaluations across
//! workers ([`crate::whatif::explore`], MxScheduler's move batches),
//! and the simulation engine's parallel event loop fans per-component
//! refills over warm [`par_map_with`] worker states
//! (`SimConfig.threads`, see `docs/ARCHITECTURE.md` "Parallel event
//! loop").
//! Determinism contract: results are returned **in item order**, and as
//! long as `f` is a pure function of `(index, item)` — per-worker state
//! is a cache, never an input — the output is bit-identical for every
//! `threads` value, including the fully inline `threads == 1` path.
//! Work is dealt round-robin (worker `w` takes items `w, w+W, …`), so
//! the assignment itself is deterministic too.

/// Apply `f` to every item with per-worker state built by `init`
/// (e.g. an evaluation context), on `threads` workers (`<= 1` runs
/// inline on the calling thread, spawning nothing). States are built
/// fresh per call; loops that fan out repeatedly over the same workers
/// keep their states warm across calls via [`par_map_with`].
///
/// Panics in `f` propagate (the join unwraps), so a poisoned sweep
/// fails loudly instead of returning partial results.
pub fn par_map_indexed<T, R, S, I, F>(items: &[T], threads: usize, mut init: I, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    S: Send,
    I: FnMut() -> S,
    F: Fn(&mut S, usize, &T) -> R + Sync,
{
    let workers = threads.max(1).min(items.len().max(1));
    let mut states: Vec<S> = (0..workers).map(|_| init()).collect();
    par_map_with(items, &mut states, f)
}

/// As [`par_map_indexed`], but over caller-owned worker states that
/// survive the call — round-based callers (MxScheduler's move loop)
/// build their evaluation contexts once and stay warm across every
/// round instead of paying a cold context per round. Worker count is
/// `min(states.len(), items.len())`; a single state (or single item)
/// runs inline on the calling thread, spawning nothing. The
/// determinism contract is unchanged: item-order results, round-robin
/// dealing, so for a pure `f` the output is identical for any state
/// count.
pub fn par_map_with<T, R, S, F>(items: &[T], states: &mut [S], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    S: Send,
    F: Fn(&mut S, usize, &T) -> R + Sync,
{
    assert!(!states.is_empty(), "need at least one worker state");
    let workers = states.len().min(items.len().max(1));
    if workers <= 1 {
        let state = &mut states[0];
        return items.iter().enumerate().map(|(i, it)| f(state, i, it)).collect();
    }
    let mut out: Vec<Option<R>> = Vec::with_capacity(items.len());
    out.resize_with(items.len(), || None);
    std::thread::scope(|scope| {
        let handles: Vec<_> = states[..workers]
            .iter_mut()
            .enumerate()
            .map(|(w, state)| {
                let f = &f;
                scope.spawn(move || {
                    let mut res: Vec<(usize, R)> = Vec::new();
                    let mut i = w;
                    while i < items.len() {
                        res.push((i, f(state, i, &items[i])));
                        i += workers;
                    }
                    res
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("parallel map worker panicked") {
                out[i] = Some(r);
            }
        }
    });
    out.into_iter().map(|r| r.expect("every index produced exactly once")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_item_order_for_all_thread_counts() {
        let items: Vec<usize> = (0..37).collect();
        let serial = par_map_indexed(&items, 1, || 0usize, |_, i, &x| (i, x * x));
        for threads in [2, 3, 8, 64] {
            let par = par_map_indexed(&items, threads, || 0usize, |_, i, &x| (i, x * x));
            assert_eq!(serial, par, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_single_item_run_inline() {
        let none: Vec<u8> = Vec::new();
        assert!(par_map_indexed(&none, 8, || (), |_, _, _| 1).is_empty());
        let one = [41u8];
        assert_eq!(par_map_indexed(&one, 8, || (), |_, _, &x| x + 1), vec![42]);
    }

    #[test]
    fn caller_owned_states_survive_across_calls() {
        let items = [1u8, 2, 3, 4, 5];
        let mut states = vec![0usize; 2];
        let _ = par_map_with(&items, &mut states, |s, _, _| *s += 1);
        let _ = par_map_with(&items, &mut states, |s, _, _| *s += 1);
        // 5 calls per round, dealt round-robin over the two states
        assert_eq!(states.iter().sum::<usize>(), 10);
    }

    #[test]
    fn worker_state_is_reused_within_a_worker() {
        // state counts calls; with 1 thread all items share one state
        let items = [0u8; 5];
        let counts = par_map_indexed(&items, 1, || 0usize, |s, _, _| {
            *s += 1;
            *s
        });
        assert_eq!(counts, vec![1, 2, 3, 4, 5]);
    }
}
