//! Substrates this repo had to build because the offline image only
//! vendors the `xla` crate's dependency closure (see DESIGN.md §5):
//! JSON, PRNG, CLI parsing, micro-benchmarking, property testing.

pub mod bench;
pub mod cli;
pub mod json;
pub mod propcheck;
pub mod rng;
