//! Substrates this repo builds in-tree so the default `cargo build`
//! needs **zero external crates** (see DESIGN.md §5): JSON, PRNG, CLI
//! parsing, micro-benchmarking, property testing, deterministic
//! scoped-thread parallelism, and an `anyhow`-shaped error type.

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod par;
pub mod propcheck;
pub mod rng;
