//! Minimal CLI argument parser substrate (clap is not vendored).
//!
//! Grammar: `prog <subcommand> [--key value]... [--flag]... [positional]...`
//! `--key=value` is also accepted.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if matches!(it.peek(), Some(nxt) if !nxt.starts_with("--")) {
                    out.options.insert(body.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants an integer, got `{v}`")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants a number, got `{v}`")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        // NB: `--key value` binds greedily, so bare flags go last (or use
        // `--flag` followed by another `--…` token).
        let a = p("run --steps 10 --lr 0.5 trace.json --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.usize_or("steps", 1), 10);
        assert_eq!(a.f64_or("lr", 0.0), 0.5);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["trace.json"]);
    }

    #[test]
    fn eq_form() {
        let a = p("bench --n=32 --mode=fast");
        assert_eq!(a.usize_or("n", 0), 32);
        assert_eq!(a.get("mode"), Some("fast"));
    }

    #[test]
    fn trailing_flag() {
        let a = p("x --quiet");
        assert!(a.flag("quiet"));
        assert!(a.get("quiet").is_none());
    }

    #[test]
    fn no_subcommand() {
        let a = p("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.flag("help"));
    }

    #[test]
    fn defaults() {
        let a = p("run");
        assert_eq!(a.usize_or("missing", 7), 7);
        assert_eq!(a.f64_or("missing", 1.5), 1.5);
        assert_eq!(a.get_or("missing", "d"), "d");
    }
}
