//! Minimal error substrate (`anyhow` is not vendored in this image).
//!
//! Provides the `anyhow`-shaped surface the runtime/coordinator layers
//! use — [`Error`], [`Result`], the [`Context`] extension trait and the
//! [`anyhow!`](crate::anyhow) macro — with a flattened message chain
//! instead of a boxed source chain. Like `anyhow::Error`, [`Error`]
//! deliberately does **not** implement `std::error::Error`, so the
//! blanket `From<E: std::error::Error>` conversion stays coherent.

use std::fmt;

/// A flattened, context-carrying error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg(m: impl Into<String>) -> Error {
        Error { msg: m.into() }
    }

    /// Prepend a context line (`context: original`).
    pub fn context(self, c: impl fmt::Display) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(&format!(": {s}"));
            src = s.source();
        }
        Error { msg }
    }
}

/// `Result` defaulting to [`Error`], mirroring `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a failing `Result`, mirroring `anyhow::Context`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::util::error::Error::msg(format!($msg))
    };
    ($msg:literal, $($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($msg, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg(format!("{}", $err))
    };
}

// Let call sites write `use crate::util::error::anyhow;`.
pub use crate::anyhow;

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn from_std_error_flattens_chain() {
        let e: Error = io_err().into();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_prepends() {
        let r: Result<()> = Err(io_err()).context("loading file");
        let msg = r.unwrap_err().to_string();
        assert!(msg.starts_with("loading file: "), "{msg}");
        assert!(msg.contains("gone"));
    }

    #[test]
    fn with_context_lazy() {
        let ok: Result<u32> = Ok::<u32, std::io::Error>(7).with_context(|| -> String {
            unreachable!("context closure must be lazy")
        });
        assert_eq!(ok.unwrap(), 7);
        let e: Result<u32> = Err(io_err()).with_context(|| format!("attempt {}", 2));
        assert!(e.unwrap_err().to_string().starts_with("attempt 2: "));
    }

    #[test]
    fn context_on_error_result() {
        // the Context impl must also cover Result<_, Error> itself
        let base: Result<()> = Err(Error::msg("inner"));
        let msg = base.context("outer").unwrap_err().to_string();
        assert_eq!(msg, "outer: inner");
    }

    #[test]
    fn anyhow_macro_forms() {
        assert_eq!(anyhow!("plain").to_string(), "plain");
        let n = 3;
        assert_eq!(anyhow!("got {}", n).to_string(), "got 3");
        assert_eq!(anyhow!("got {n}").to_string(), "got 3");
        let e = io_err();
        assert_eq!(anyhow!(e).to_string(), "gone");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }
}
