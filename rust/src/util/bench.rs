//! Micro-benchmark substrate (criterion is not vendored).
//!
//! `cargo bench` targets use `harness = false` and drive this directly.
//! Auto-calibrates iteration counts, reports min/median/mean, and renders
//! aligned tables for the paper-figure benches. Perf-tracking benches
//! additionally persist machine-readable results through
//! [`write_bench_json`] so the trajectory survives across PRs instead of
//! only scrolling by as printed tables.

use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub iters: u64,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Run `f` repeatedly, auto-scaling the iteration count so that total
/// measurement time is ~`target`. Returns timing stats.
pub fn bench_with<F: FnMut()>(name: &str, target: Duration, mut f: F) -> Sample {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as u64;
    let per_round = (target.as_nanos() as u64 / 8 / once).clamp(1, 1_000_000);

    let mut times = Vec::with_capacity(8);
    for _ in 0..8 {
        let t = Instant::now();
        for _ in 0..per_round {
            f();
        }
        times.push(t.elapsed().as_nanos() as f64 / per_round as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let min = times[0];
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    Sample {
        name: name.to_string(),
        iters: per_round * 8,
        min_ns: min,
        median_ns: median,
        mean_ns: mean,
    }
}

/// Convenience wrapper: ~200 ms per case and immediate printing.
pub fn bench<F: FnMut()>(name: &str, f: F) -> Sample {
    let s = bench_with(name, Duration::from_millis(200), f);
    println!(
        "{:<44} {:>12} {:>12} {:>12}  ({} iters)",
        s.name,
        fmt_ns(s.min_ns),
        fmt_ns(s.median_ns),
        fmt_ns(s.mean_ns),
        s.iters
    );
    s
}

/// Default path of the machine-readable bench results file (relative to
/// the invocation directory — the workspace root under `cargo bench`).
pub const BENCH_JSON_PATH: &str = "BENCH_sim.json";

/// Merge `value` under `section` into `BENCH_sim.json`.
///
/// Each bench owns one top-level section and overwrites only that, so
/// `sim_throughput` and `sched_scaling` can both contribute to the same
/// file and CI / analysis scripts can diff events-per-second across
/// PRs. A malformed or missing file is replaced wholesale; write errors
/// are reported but non-fatal (benches must not fail on a read-only
/// checkout).
pub fn write_bench_json(section: &str, value: Json) {
    let mut root = std::fs::read_to_string(BENCH_JSON_PATH)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .and_then(|j| match j {
            Json::Obj(m) => Some(m),
            _ => None,
        })
        .unwrap_or_default();
    root.insert(section.to_string(), value);
    if let Err(e) = std::fs::write(BENCH_JSON_PATH, Json::Obj(root).to_string()) {
        eprintln!("warning: could not write {BENCH_JSON_PATH}: {e}");
    }
}

pub fn bench_header(title: &str) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>12} {:>12} {:>12}",
        "case", "min", "median", "mean"
    );
}

/// Aligned result table for figure benches (rows of label -> columns).
pub struct Table {
    title: String,
    columns: Vec<String>,
    rows: Vec<(String, Vec<String>)>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, label: &str, values: &[String]) {
        assert_eq!(values.len(), self.columns.len(), "row width mismatch");
        self.rows.push((label.to_string(), values.to_vec()));
    }

    pub fn row_f64(&mut self, label: &str, values: &[f64]) {
        let vs: Vec<String> = values.iter().map(|v| format!("{v:.4}")).collect();
        self.row(label, &vs);
    }

    pub fn print(&self) {
        let mut widths = vec![self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain(std::iter::once(4))
            .max()
            .unwrap()];
        for (i, c) in self.columns.iter().enumerate() {
            let w = self
                .rows
                .iter()
                .map(|(_, v)| v[i].len())
                .chain(std::iter::once(c.len()))
                .max()
                .unwrap();
            widths.push(w);
        }
        println!("\n== {} ==", self.title);
        print!("{:<w$}", "", w = widths[0] + 2);
        for (i, c) in self.columns.iter().enumerate() {
            print!("{:>w$}", c, w = widths[i + 1] + 2);
        }
        println!();
        for (label, vals) in &self.rows {
            print!("{:<w$}", label, w = widths[0] + 2);
            for (i, v) in vals.iter().enumerate() {
                print!("{:>w$}", v, w = widths[i + 1] + 2);
            }
            println!();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let s = bench_with("noop-ish", Duration::from_millis(20), || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(s.min_ns > 0.0);
        assert!(s.min_ns <= s.median_ns);
        assert!(s.iters >= 8);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }

    #[test]
    fn table_rows() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row_f64("r1", &[1.0, 2.0]);
        t.row("r2", &["x".into(), "y".into()]);
        assert_eq!(t.rows.len(), 2);
        t.print(); // smoke: must not panic
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row("r", &["only-one".into()]);
    }
}
