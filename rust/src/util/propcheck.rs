//! Property-testing substrate (proptest is not vendored).
//!
//! Seeded generation + a simple halving shrinker over the *seed sequence*
//! is enough for the invariants we check (scheduler/simulator/graph
//! properties). On failure it reports the failing seed so the case can be
//! replayed deterministically.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xC0FFEE }
    }
}

/// Check `prop(gen(rng))` for `cfg.cases` generated inputs.
///
/// `prop` returns `Err(msg)` to signal a violation; the failing seed and
/// case index are included in the panic message for replay.
pub fn check<T: std::fmt::Debug, G, P>(name: &str, cfg: &Config, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed at case {case} (replay seed {case_seed:#x}):\n  \
                 {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<T, G, P>(seed: u64, mut gen: G, mut prop: P) -> Result<(), String>
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    prop(&gen(&mut rng))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(
            "sum-commutes",
            &Config::default(),
            |r| (r.below(1000), r.below(1000)),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_panics_with_seed() {
        check(
            "always-fails",
            &Config { cases: 3, seed: 1 },
            |r| r.below(10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn replay_roundtrip() {
        // find the failing case seed semantics: same seed -> same input
        let seed = 42;
        let a = replay(seed, |r| r.next_u64(), |_| Ok(()));
        assert!(a.is_ok());
        let mut r1 = Rng::new(seed);
        let mut r2 = Rng::new(seed);
        assert_eq!(r1.next_u64(), r2.next_u64());
    }
}
