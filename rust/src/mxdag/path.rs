//! Paths, Copaths, and the path-length equations (1) and (2) of §3.2.
//!
//! * `Len(P_seq) = Σ Size(v_i)/Rsrc(v_i)`                       (Eq. 1)
//! * `Len(P_pipe) = Σ Unit(v_i)/Rsrc(v_i) + max_i Size(v_i)/Rsrc(v_i)
//!                  − max_i Unit(v_i)/Rsrc(v_i)`                 (Eq. 2)
//!
//! A *Copath* is a group of paths sharing the same head and tail task;
//! its length is the length of its longest member (its critical path).

use super::graph::MXDag;
use super::task::TaskId;

/// Eq. (1): sequential path length given per-task resource shares.
pub fn len_seq(dag: &MXDag, path: &[TaskId], rsrc: &dyn Fn(TaskId) -> f64) -> f64 {
    path.iter().map(|&v| dag.task(v).size / rsrc(v)).sum()
}

/// Eq. (2): pipelineable-only path length given per-task resource shares.
///
/// The sum of unit times is the pipeline fill; steady state is dominated
/// by the slowest stage (`max Size/Rsrc`), whose own fill unit is counted
/// once already (`− max Unit/Rsrc`).
pub fn len_pipe(dag: &MXDag, path: &[TaskId], rsrc: &dyn Fn(TaskId) -> f64) -> f64 {
    if path.is_empty() {
        return 0.0;
    }
    let unit_sum: f64 = path.iter().map(|&v| dag.task(v).unit / rsrc(v)).sum();
    let size_max = path
        .iter()
        .map(|&v| dag.task(v).size / rsrc(v))
        .fold(0.0, f64::max);
    let unit_max = path
        .iter()
        .map(|&v| dag.task(v).unit / rsrc(v))
        .fold(0.0, f64::max);
    unit_sum + size_max - unit_max
}

/// Mixed path length: consecutive tasks that are both in `pipelined`
/// form pipeline segments evaluated by Eq. (2); everything else is
/// sequential (Eq. 1). This is the recursive decomposition of §3.2
/// specialised to a single path.
pub fn len_mixed(
    dag: &MXDag,
    path: &[TaskId],
    pipelined: &dyn Fn(TaskId) -> bool,
    rsrc: &dyn Fn(TaskId) -> f64,
) -> f64 {
    let mut total = 0.0;
    let mut i = 0;
    while i < path.len() {
        if pipelined(path[i]) && dag.task(path[i]).pipelineable() {
            let mut j = i + 1;
            while j < path.len() && pipelined(path[j]) && dag.task(path[j]).pipelineable() {
                j += 1;
            }
            if j - i >= 2 {
                total += len_pipe(dag, &path[i..j], rsrc);
            } else {
                total += len_seq(dag, &path[i..j], rsrc);
            }
            i = j;
        } else {
            total += dag.task(path[i]).size / rsrc(path[i]);
            i += 1;
        }
    }
    total
}

/// Enumerate all simple paths from `head` to `tail` (inclusive), up to
/// `limit` paths (DAG path counts can be exponential).
pub fn enumerate_paths(dag: &MXDag, head: TaskId, tail: TaskId, limit: usize) -> Vec<Vec<TaskId>> {
    let mut out = Vec::new();
    let mut stack = vec![head];
    fn dfs(
        dag: &MXDag,
        cur: TaskId,
        tail: TaskId,
        stack: &mut Vec<TaskId>,
        out: &mut Vec<Vec<TaskId>>,
        limit: usize,
    ) {
        if out.len() >= limit {
            return;
        }
        if cur == tail {
            out.push(stack.clone());
            return;
        }
        for &s in dag.succs(cur) {
            stack.push(s);
            dfs(dag, s, tail, stack, out, limit);
            stack.pop();
        }
    }
    dfs(dag, head, tail, &mut stack, &mut out, limit);
    out
}

/// The Copath between `head` and `tail`: all simple paths joining them.
/// Returns `None` if fewer than two paths exist (not a Copath).
pub fn copath(dag: &MXDag, head: TaskId, tail: TaskId, limit: usize) -> Option<Vec<Vec<TaskId>>> {
    let paths = enumerate_paths(dag, head, tail, limit);
    if paths.len() >= 2 {
        Some(paths)
    } else {
        None
    }
}

/// Length of a Copath = length of its longest member path (its critical
/// path), interior tasks only evaluated (head/tail excluded so Copath
/// composition does not double-count).
pub fn copath_length(
    dag: &MXDag,
    paths: &[Vec<TaskId>],
    pipelined: &dyn Fn(TaskId) -> bool,
    rsrc: &dyn Fn(TaskId) -> f64,
) -> f64 {
    paths
        .iter()
        .map(|p| {
            let interior = if p.len() > 2 { &p[1..p.len() - 1] } else { &[] as &[TaskId] };
            len_mixed(dag, interior, pipelined, rsrc)
        })
        .fold(0.0, f64::max)
}

/// Critical member of a Copath (index into `paths`).
pub fn copath_critical(
    dag: &MXDag,
    paths: &[Vec<TaskId>],
    pipelined: &dyn Fn(TaskId) -> bool,
    rsrc: &dyn Fn(TaskId) -> f64,
) -> usize {
    let mut best = 0;
    let mut best_len = f64::MIN;
    for (i, p) in paths.iter().enumerate() {
        let interior = if p.len() > 2 { &p[1..p.len() - 1] } else { &[] as &[TaskId] };
        let l = len_mixed(dag, interior, pipelined, rsrc);
        if l > best_len {
            best_len = l;
            best = i;
        }
    }
    best
}

pub fn full_rsrc(_: TaskId) -> f64 {
    1.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mxdag::graph::MXDag;

    fn job_x() -> MXDag {
        // Fig 4(a)-like: A -> f1 -> B -> f2 -> C and A -> f3 -> C
        let mut b = MXDag::builder();
        let a = b.compute("A", 0, 1.0);
        let f1 = b.flow("f1", 0, 1, 2.0);
        let bb = b.compute("B", 1, 1.0);
        let f2 = b.flow("f2", 1, 2, 2.0);
        let f3 = b.flow("f3", 0, 2, 3.0);
        let c = b.compute("C", 2, 1.0);
        b.chain(&[a, f1, bb, f2, c]);
        b.dep(a, f3).dep(f3, c);
        b.finalize().unwrap()
    }

    #[test]
    fn eq1_sums_sizes() {
        let g = job_x();
        let p = vec![g.by_name("A").unwrap(), g.by_name("f1").unwrap(), g.by_name("B").unwrap()];
        assert_eq!(len_seq(&g, &p, &full_rsrc), 4.0);
        // half resource on everything doubles the length
        assert_eq!(len_seq(&g, &p, &|_| 0.5), 8.0);
    }

    #[test]
    fn eq2_pipeline_dominated_by_slowest() {
        // two pipelineable tasks: sizes 10, 6; units 1, 2
        let mut b = MXDag::builder();
        let t1 = b.compute_full("t1", 0, 10.0, 1.0);
        let t2 = b.flow_full("t2", 0, 1, 6.0, 2.0);
        b.dep(t1, t2);
        let g = b.finalize().unwrap();
        let p = vec![t1, t2];
        // Eq2 = (1+2) + max(10,6) - max(1,2) = 3 + 10 - 2 = 11
        assert_eq!(len_pipe(&g, &p, &full_rsrc), 11.0);
        // sequential would be 16
        assert_eq!(len_seq(&g, &p, &full_rsrc), 16.0);
    }

    #[test]
    fn eq2_empty_path() {
        let g = job_x();
        assert_eq!(len_pipe(&g, &[], &full_rsrc), 0.0);
    }

    #[test]
    fn mixed_groups_consecutive_pipelined() {
        let mut b = MXDag::builder();
        let t1 = b.compute_full("t1", 0, 4.0, 1.0);
        let t2 = b.flow_full("t2", 0, 1, 4.0, 1.0);
        let t3 = b.compute("t3", 1, 5.0); // not pipelineable
        b.chain(&[t1, t2, t3]);
        let g = b.finalize().unwrap();
        let p = vec![t1, t2, t3];
        let all = |_: TaskId| true;
        // pipe(t1,t2) = (1+1) + 4 - 1 = 5, then t3 = 5 => 10
        assert_eq!(len_mixed(&g, &p, &all, &full_rsrc), 10.0);
        let none = |_: TaskId| false;
        assert_eq!(len_mixed(&g, &p, &none, &full_rsrc), 13.0);
    }

    #[test]
    fn enumerate_finds_both_paths() {
        let g = job_x();
        let paths = enumerate_paths(&g, g.by_name("A").unwrap(), g.by_name("C").unwrap(), 100);
        assert_eq!(paths.len(), 2);
    }

    #[test]
    fn copath_requires_two_paths() {
        let g = job_x();
        let a = g.by_name("A").unwrap();
        let c = g.by_name("C").unwrap();
        let b = g.by_name("B").unwrap();
        assert!(copath(&g, a, c, 100).is_some());
        assert!(copath(&g, a, b, 100).is_none()); // single path only
    }

    #[test]
    fn copath_length_is_max_member() {
        let g = job_x();
        let a = g.by_name("A").unwrap();
        let c = g.by_name("C").unwrap();
        let paths = copath(&g, a, c, 100).unwrap();
        let none = |_: TaskId| false;
        // interiors: f1,B,f2 = 5 ; f3 = 3 -> copath length 5
        assert_eq!(copath_length(&g, &paths, &none, &full_rsrc), 5.0);
        let crit = copath_critical(&g, &paths, &none, &full_rsrc);
        assert_eq!(paths[crit].len(), 5); // A f1 B f2 C
    }

    #[test]
    fn path_limit_respected() {
        let g = job_x();
        let a = g.by_name("A").unwrap();
        let c = g.by_name("C").unwrap();
        let paths = enumerate_paths(&g, a, c, 1);
        assert_eq!(paths.len(), 1);
    }
}
