//! The MXDAG abstraction (§3): compute and network tasks as first-class
//! DAG nodes, with `Size`/`Unit` annotations, Copath analysis, the
//! path-length equations, and critical-path machinery.

pub mod critical;
pub mod graph;
pub mod path;
pub mod task;

pub use critical::{cpm, cpm_with, Cpm, CpmCache};
pub use graph::{GraphError, MXDag, MXDagBuilder};
pub use task::{HostId, MXTask, TaskId, TaskKind};
