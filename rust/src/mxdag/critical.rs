//! Critical-path analysis (CPM) over an MXDAG.
//!
//! Durations default to `Size(v)` (full-resource completion time, §3.1).
//! Produces earliest/latest start/finish, slack, the makespan lower
//! bound, and one zero-slack critical path — the quantities Principles 1
//! and 2 (§4) schedule by.

use super::graph::MXDag;
use super::task::TaskId;

/// Result of a CPM pass.
#[derive(Debug, Clone)]
pub struct Cpm {
    pub est: Vec<f64>,
    pub eft: Vec<f64>,
    pub lst: Vec<f64>,
    pub lft: Vec<f64>,
    pub slack: Vec<f64>,
    /// Contention-free makespan lower bound (length of the critical path).
    pub makespan: f64,
    /// One critical (zero-slack) path from `v_S` to `v_E`, inclusive.
    pub critical: Vec<TaskId>,
}

const EPS: f64 = 1e-9;

/// CPM with explicit per-task durations.
pub fn cpm_with(dag: &MXDag, dur: &[f64]) -> Cpm {
    let n = dag.len();
    assert_eq!(dur.len(), n, "durations must cover every task");
    let mut est = vec![0.0; n];
    let mut eft = vec![0.0; n];
    for &u in dag.topo() {
        est[u] = dag
            .preds(u)
            .iter()
            .map(|&p| eft[p])
            .fold(0.0, f64::max);
        eft[u] = est[u] + dur[u];
    }
    let makespan = eft[dag.end()];

    let mut lft = vec![makespan; n];
    let mut lst = vec![makespan; n];
    for &u in dag.topo().iter().rev() {
        lft[u] = dag
            .succs(u)
            .iter()
            .map(|&s| lst[s])
            .fold(makespan, f64::min);
        lst[u] = lft[u] - dur[u];
    }

    let slack: Vec<f64> = (0..n).map(|i| (lst[i] - est[i]).max(0.0)).collect();

    // follow a zero-slack chain from start to end
    let mut critical = vec![dag.start()];
    let mut cur = dag.start();
    while cur != dag.end() {
        let next = dag
            .succs(cur)
            .iter()
            .copied()
            .filter(|&s| slack[s] <= EPS)
            // among zero-slack succs prefer the one whose EST matches our EFT
            .min_by(|&a, &b| {
                let ka = (est[a] - eft[cur]).abs();
                let kb = (est[b] - eft[cur]).abs();
                ka.partial_cmp(&kb).unwrap()
            })
            .expect("critical path must reach v_E");
        critical.push(next);
        cur = next;
    }

    Cpm { est, eft, lst, lft, slack, makespan, critical }
}

/// CPM with durations = `Size(v)` (full resource assigned).
pub fn cpm(dag: &MXDag) -> Cpm {
    let dur: Vec<f64> = dag.tasks().iter().map(|t| t.size).collect();
    cpm_with(dag, &dur)
}

impl Cpm {
    /// Is `t` on the (a) critical path?
    pub fn is_critical(&self, t: TaskId) -> bool {
        self.slack[t] <= EPS
    }

    /// Rank tasks by criticality: ascending slack. Tasks with (numerically)
    /// equal slack share one priority level, so symmetric siblings — e.g.
    /// the flows of a balanced shuffle — are served fairly within the
    /// level instead of being serialized arbitrarily. Higher = more
    /// critical.
    pub fn priorities(&self) -> Vec<i64> {
        let n = self.slack.len();
        let mut order: Vec<TaskId> = (0..n).collect();
        order.sort_by(|&a, &b| self.slack[a].partial_cmp(&self.slack[b]).unwrap());
        let mut prio = vec![0i64; n];
        let mut level = n as i64;
        let mut prev_slack = f64::NEG_INFINITY;
        for &t in &order {
            if (self.slack[t] - prev_slack).abs() > EPS {
                level -= 1;
                prev_slack = self.slack[t];
            }
            prio[t] = level;
        }
        prio
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mxdag::graph::MXDag;

    /// a(2) -> f1(3) -> c(1); a -> f2(1) -> c   => critical a,f1,c = 6
    fn diamond() -> MXDag {
        let mut b = MXDag::builder();
        let a = b.compute("a", 0, 2.0);
        let f1 = b.flow("f1", 0, 1, 3.0);
        let f2 = b.flow("f2", 0, 2, 1.0);
        let c = b.compute("c", 1, 1.0);
        b.dep(a, f1).dep(a, f2).dep(f1, c).dep(f2, c);
        b.finalize().unwrap()
    }

    #[test]
    fn makespan_is_longest_path() {
        let g = diamond();
        let r = cpm(&g);
        assert_eq!(r.makespan, 6.0);
    }

    #[test]
    fn est_lst_slack() {
        let g = diamond();
        let r = cpm(&g);
        let f1 = g.by_name("f1").unwrap();
        let f2 = g.by_name("f2").unwrap();
        assert_eq!(r.est[f1], 2.0);
        assert_eq!(r.est[f2], 2.0);
        assert_eq!(r.slack[f1], 0.0);
        assert_eq!(r.slack[f2], 2.0); // can be delayed by 2 without hurting
        assert_eq!(r.lst[f2], 4.0);
    }

    #[test]
    fn critical_path_follows_zero_slack() {
        let g = diamond();
        let r = cpm(&g);
        let names: Vec<&str> = r.critical.iter().map(|&t| g.task(t).name.as_str()).collect();
        assert_eq!(names, vec!["v_S", "a", "f1", "c", "v_E"]);
    }

    #[test]
    fn critical_membership() {
        let g = diamond();
        let r = cpm(&g);
        assert!(r.is_critical(g.by_name("f1").unwrap()));
        assert!(!r.is_critical(g.by_name("f2").unwrap()));
    }

    #[test]
    fn priorities_rank_critical_highest() {
        let g = diamond();
        let r = cpm(&g);
        let p = r.priorities();
        assert!(p[g.by_name("f1").unwrap()] > p[g.by_name("f2").unwrap()]);
    }

    #[test]
    fn custom_durations() {
        let g = diamond();
        let mut dur: Vec<f64> = g.tasks().iter().map(|t| t.size).collect();
        dur[g.by_name("f2").unwrap()] = 10.0; // now f2 path dominates
        let r = cpm_with(&g, &dur);
        assert_eq!(r.makespan, 13.0);
        assert!(r.is_critical(g.by_name("f2").unwrap()));
        assert!(!r.is_critical(g.by_name("f1").unwrap()));
    }

    #[test]
    fn chain_slack_zero_everywhere() {
        let mut b = MXDag::builder();
        let x = b.compute("x", 0, 1.0);
        let y = b.compute("y", 0, 2.0);
        let z = b.compute("z", 0, 3.0);
        b.chain(&[x, y, z]);
        let g = b.finalize().unwrap();
        let r = cpm(&g);
        assert_eq!(r.makespan, 6.0);
        for t in [x, y, z] {
            assert!(r.is_critical(t));
        }
    }
}
