//! Critical-path analysis (CPM) over an MXDAG.
//!
//! Durations default to `Size(v)` (full-resource completion time, §3.1).
//! Produces earliest/latest start/finish, slack, the makespan lower
//! bound, and one zero-slack critical path — the quantities Principles 1
//! and 2 (§4) schedule by.
//!
//! [`CpmCache`] adds *incremental* CPM: when a plan-search move changes
//! a handful of durations, the cached pass is patched cone-restricted
//! (forward est/eft from the changed tasks, backward lst/lft, with a
//! bitwise early exit as soon as values stabilise) instead of re-run
//! over the whole graph — with [`cpm_with`] kept as the bitwise oracle.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::graph::MXDag;
use super::task::TaskId;

/// Result of a CPM pass.
#[derive(Debug, Clone)]
pub struct Cpm {
    pub est: Vec<f64>,
    pub eft: Vec<f64>,
    pub lst: Vec<f64>,
    pub lft: Vec<f64>,
    pub slack: Vec<f64>,
    /// Contention-free makespan lower bound (length of the critical path).
    pub makespan: f64,
    /// One critical (zero-slack) path from `v_S` to `v_E`, inclusive.
    pub critical: Vec<TaskId>,
}

const EPS: f64 = 1e-9;

/// CPM with explicit per-task durations.
pub fn cpm_with(dag: &MXDag, dur: &[f64]) -> Cpm {
    let n = dag.len();
    assert_eq!(dur.len(), n, "durations must cover every task");
    let mut est = vec![0.0; n];
    let mut eft = vec![0.0; n];
    for &u in dag.topo() {
        est[u] = dag
            .preds(u)
            .iter()
            .map(|&p| eft[p])
            .fold(0.0, f64::max);
        eft[u] = est[u] + dur[u];
    }
    let makespan = eft[dag.end()];

    let mut lft = vec![makespan; n];
    let mut lst = vec![makespan; n];
    for &u in dag.topo().iter().rev() {
        lft[u] = dag
            .succs(u)
            .iter()
            .map(|&s| lst[s])
            .fold(makespan, f64::min);
        lst[u] = lft[u] - dur[u];
    }

    let slack: Vec<f64> = (0..n).map(|i| (lst[i] - est[i]).max(0.0)).collect();

    let critical = critical_of(dag, &est, &eft, &slack);

    Cpm { est, eft, lst, lft, slack, makespan, critical }
}

/// Follow one zero-slack chain from `v_S` to `v_E` — shared by the full
/// pass ([`cpm_with`]) and the incremental patch ([`CpmCache::update`]),
/// so both produce the identical path for identical inputs.
fn critical_of(dag: &MXDag, est: &[f64], eft: &[f64], slack: &[f64]) -> Vec<TaskId> {
    let mut critical = vec![dag.start()];
    let mut cur = dag.start();
    while cur != dag.end() {
        let next = dag
            .succs(cur)
            .iter()
            .copied()
            .filter(|&s| slack[s] <= EPS)
            // among zero-slack succs prefer the one whose EST matches our EFT
            .min_by(|&a, &b| {
                let ka = (est[a] - eft[cur]).abs();
                let kb = (est[b] - eft[cur]).abs();
                ka.partial_cmp(&kb).unwrap()
            })
            .expect("critical path must reach v_E");
        critical.push(next);
        cur = next;
    }
    critical
}

/// CPM with durations = `Size(v)` (full resource assigned).
pub fn cpm(dag: &MXDag) -> Cpm {
    let dur: Vec<f64> = dag.tasks().iter().map(|t| t.size).collect();
    cpm_with(dag, &dur)
}

/// Incremental CPM: a cached [`Cpm`] over explicit durations that is
/// *patched* — not recomputed — when a few durations change, the
/// primitive behind MxScheduler's move-loop re-ranking.
///
/// [`update`](CpmCache::update) runs a forward est/eft sweep restricted
/// to the cone reachable from the changed tasks and a matching backward
/// lst/lft sweep, each with a **bitwise early exit**: a node whose
/// recomputed value has identical bits stops the propagation through
/// it, so an off-critical patch touches `O(cone)` nodes, not `O(V+E)`.
/// Because every recomputation replays the exact fold `cpm_with`
/// performs (same iteration order over preds/succs, same `f64`
/// arithmetic), the patched state is **bit-for-bit equal** to a fresh
/// `cpm_with(dag, durations)` pass — the oracle the
/// `prop_cpm_cache_matches_full_recompute_bitwise` test holds it to.
///
/// One deliberate degenerate case: when a patch moves the makespan
/// (`eft[v_E]`), the backward fold's initial value changes for *every*
/// node, so the backward sweep falls back to the full reverse-topo pass
/// — still allocation-free, and exactly as expensive as the thing it
/// replaces, never more.
///
/// The cache borrows nothing: the caller passes the same `dag` to every
/// call (checked by length assertions only).
#[derive(Debug, Clone)]
pub struct CpmCache {
    dur: Vec<f64>,
    cpm: Cpm,
    /// topo position per task — worklists pop in topo order (forward)
    /// or reverse topo order (backward)
    tpos: Vec<usize>,
    fwd: BinaryHeap<Reverse<(usize, TaskId)>>,
    bwd: BinaryHeap<(usize, TaskId)>,
    in_fwd: Vec<bool>,
    in_bwd: Vec<bool>,
    /// nodes whose est or lst changed this update → slack recompute
    touched: Vec<TaskId>,
    touched_mark: Vec<bool>,
}

impl CpmCache {
    /// Full pass over `dur`, cached for patching.
    pub fn new(dag: &MXDag, dur: Vec<f64>) -> CpmCache {
        let cpm = cpm_with(dag, &dur);
        CpmCache::from_parts(dag, dur, cpm)
    }

    /// Wrap a full pass the caller already paid for. `cpm` **must** be
    /// the result of `cpm_with(dag, &dur)` for exactly these inputs —
    /// the cache trusts it as its starting state (length-checked only).
    pub fn from_parts(dag: &MXDag, dur: Vec<f64>, cpm: Cpm) -> CpmCache {
        let n = dag.len();
        assert_eq!(dur.len(), n, "durations must cover every task");
        assert_eq!(cpm.est.len(), n, "pass must cover every task");
        let mut tpos = vec![0usize; n];
        for (i, &t) in dag.topo().iter().enumerate() {
            tpos[t] = i;
        }
        CpmCache {
            dur,
            cpm,
            tpos,
            fwd: BinaryHeap::new(),
            bwd: BinaryHeap::new(),
            in_fwd: vec![false; n],
            in_bwd: vec![false; n],
            touched: Vec::new(),
            touched_mark: vec![false; n],
        }
    }

    /// The cached pass (always consistent with [`durations`](CpmCache::durations)).
    pub fn cpm(&self) -> &Cpm {
        &self.cpm
    }

    /// The durations the cached pass is over.
    pub fn durations(&self) -> &[f64] {
        &self.dur
    }

    fn mark_touched(&mut self, t: TaskId) {
        if !self.touched_mark[t] {
            self.touched_mark[t] = true;
            self.touched.push(t);
        }
    }

    /// Apply duration patches `(task, new_duration)` (later entries win
    /// on duplicates) and repair est/eft/lst/lft/slack/makespan and the
    /// critical path, bitwise-equal to a fresh full pass.
    pub fn update(&mut self, dag: &MXDag, changes: &[(TaskId, f64)]) {
        debug_assert_eq!(self.dur.len(), dag.len(), "cache built for a different DAG");
        for &(t, d) in changes {
            if self.dur[t].to_bits() != d.to_bits() {
                self.dur[t] = d;
                if !self.in_fwd[t] {
                    self.in_fwd[t] = true;
                    self.fwd.push(Reverse((self.tpos[t], t)));
                }
                if !self.in_bwd[t] {
                    self.in_bwd[t] = true;
                    self.bwd.push((self.tpos[t], t));
                }
            }
        }

        // forward cone, in topo order: est from preds' eft, early exit
        // where eft bits stabilise
        while let Some(Reverse((_, u))) = self.fwd.pop() {
            self.in_fwd[u] = false;
            let est_new = dag
                .preds(u)
                .iter()
                .map(|&p| self.cpm.eft[p])
                .fold(0.0, f64::max);
            let eft_new = est_new + self.dur[u];
            if est_new.to_bits() != self.cpm.est[u].to_bits() {
                self.cpm.est[u] = est_new;
                self.mark_touched(u);
            }
            if eft_new.to_bits() != self.cpm.eft[u].to_bits() {
                self.cpm.eft[u] = eft_new;
                for &s in dag.succs(u) {
                    if !self.in_fwd[s] {
                        self.in_fwd[s] = true;
                        self.fwd.push(Reverse((self.tpos[s], s)));
                    }
                }
            }
        }

        let makespan_new = self.cpm.eft[dag.end()];
        let makespan_changed = makespan_new.to_bits() != self.cpm.makespan.to_bits();
        self.cpm.makespan = makespan_new;

        if makespan_changed {
            // the backward fold's initial value changed for every node:
            // full reverse-topo sweep (the seeded worklist is subsumed)
            while let Some((_, u)) = self.bwd.pop() {
                self.in_bwd[u] = false;
            }
            for &u in dag.topo().iter().rev() {
                let lft_new = dag
                    .succs(u)
                    .iter()
                    .map(|&s| self.cpm.lst[s])
                    .fold(makespan_new, f64::min);
                let lst_new = lft_new - self.dur[u];
                if lft_new.to_bits() != self.cpm.lft[u].to_bits()
                    || lst_new.to_bits() != self.cpm.lst[u].to_bits()
                {
                    self.cpm.lft[u] = lft_new;
                    self.cpm.lst[u] = lst_new;
                    self.mark_touched(u);
                }
            }
        } else {
            // backward cone, in reverse topo order: lft from succs'
            // lst, early exit where lst bits stabilise (lft alone
            // changing cannot propagate — preds read only lst)
            while let Some((_, u)) = self.bwd.pop() {
                self.in_bwd[u] = false;
                let lft_new = dag
                    .succs(u)
                    .iter()
                    .map(|&s| self.cpm.lst[s])
                    .fold(self.cpm.makespan, f64::min);
                let lst_new = lft_new - self.dur[u];
                if lft_new.to_bits() != self.cpm.lft[u].to_bits() {
                    self.cpm.lft[u] = lft_new;
                }
                if lst_new.to_bits() != self.cpm.lst[u].to_bits() {
                    self.cpm.lst[u] = lst_new;
                    self.mark_touched(u);
                    for &p in dag.preds(u) {
                        if !self.in_bwd[p] {
                            self.in_bwd[p] = true;
                            self.bwd.push((self.tpos[p], p));
                        }
                    }
                }
            }
        }

        // slack only where est or lst moved; untouched nodes keep
        // bitwise-identical slack by construction
        for i in 0..self.touched.len() {
            let t = self.touched[i];
            self.cpm.slack[t] = (self.cpm.lst[t] - self.cpm.est[t]).max(0.0);
        }
        for i in 0..self.touched.len() {
            let t = self.touched[i];
            self.touched_mark[t] = false;
        }
        self.touched.clear();

        // the zero-slack chase is O(path); re-run it unconditionally
        self.cpm.critical = critical_of(dag, &self.cpm.est, &self.cpm.eft, &self.cpm.slack);
    }
}

impl Cpm {
    /// Is `t` on the (a) critical path?
    pub fn is_critical(&self, t: TaskId) -> bool {
        self.slack[t] <= EPS
    }

    /// Rank tasks by criticality: ascending slack. Tasks with (numerically)
    /// equal slack share one priority level, so symmetric siblings — e.g.
    /// the flows of a balanced shuffle — are served fairly within the
    /// level instead of being serialized arbitrarily. Higher = more
    /// critical.
    pub fn priorities(&self) -> Vec<i64> {
        let n = self.slack.len();
        let mut order: Vec<TaskId> = (0..n).collect();
        order.sort_by(|&a, &b| self.slack[a].partial_cmp(&self.slack[b]).unwrap());
        let mut prio = vec![0i64; n];
        let mut level = n as i64;
        let mut prev_slack = f64::NEG_INFINITY;
        for &t in &order {
            if (self.slack[t] - prev_slack).abs() > EPS {
                level -= 1;
                prev_slack = self.slack[t];
            }
            prio[t] = level;
        }
        prio
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mxdag::graph::MXDag;

    /// a(2) -> f1(3) -> c(1); a -> f2(1) -> c   => critical a,f1,c = 6
    fn diamond() -> MXDag {
        let mut b = MXDag::builder();
        let a = b.compute("a", 0, 2.0);
        let f1 = b.flow("f1", 0, 1, 3.0);
        let f2 = b.flow("f2", 0, 2, 1.0);
        let c = b.compute("c", 1, 1.0);
        b.dep(a, f1).dep(a, f2).dep(f1, c).dep(f2, c);
        b.finalize().unwrap()
    }

    #[test]
    fn makespan_is_longest_path() {
        let g = diamond();
        let r = cpm(&g);
        assert_eq!(r.makespan, 6.0);
    }

    #[test]
    fn est_lst_slack() {
        let g = diamond();
        let r = cpm(&g);
        let f1 = g.by_name("f1").unwrap();
        let f2 = g.by_name("f2").unwrap();
        assert_eq!(r.est[f1], 2.0);
        assert_eq!(r.est[f2], 2.0);
        assert_eq!(r.slack[f1], 0.0);
        assert_eq!(r.slack[f2], 2.0); // can be delayed by 2 without hurting
        assert_eq!(r.lst[f2], 4.0);
    }

    #[test]
    fn critical_path_follows_zero_slack() {
        let g = diamond();
        let r = cpm(&g);
        let names: Vec<&str> = r.critical.iter().map(|&t| g.task(t).name.as_str()).collect();
        assert_eq!(names, vec!["v_S", "a", "f1", "c", "v_E"]);
    }

    #[test]
    fn critical_membership() {
        let g = diamond();
        let r = cpm(&g);
        assert!(r.is_critical(g.by_name("f1").unwrap()));
        assert!(!r.is_critical(g.by_name("f2").unwrap()));
    }

    #[test]
    fn priorities_rank_critical_highest() {
        let g = diamond();
        let r = cpm(&g);
        let p = r.priorities();
        assert!(p[g.by_name("f1").unwrap()] > p[g.by_name("f2").unwrap()]);
    }

    #[test]
    fn custom_durations() {
        let g = diamond();
        let mut dur: Vec<f64> = g.tasks().iter().map(|t| t.size).collect();
        dur[g.by_name("f2").unwrap()] = 10.0; // now f2 path dominates
        let r = cpm_with(&g, &dur);
        assert_eq!(r.makespan, 13.0);
        assert!(r.is_critical(g.by_name("f2").unwrap()));
        assert!(!r.is_critical(g.by_name("f1").unwrap()));
    }

    fn assert_cache_matches(g: &MXDag, cache: &CpmCache) {
        let full = cpm_with(g, cache.durations());
        let got = cache.cpm();
        assert_eq!(full.makespan.to_bits(), got.makespan.to_bits(), "makespan");
        for i in 0..g.len() {
            assert_eq!(full.est[i].to_bits(), got.est[i].to_bits(), "est[{i}]");
            assert_eq!(full.eft[i].to_bits(), got.eft[i].to_bits(), "eft[{i}]");
            assert_eq!(full.lst[i].to_bits(), got.lst[i].to_bits(), "lst[{i}]");
            assert_eq!(full.lft[i].to_bits(), got.lft[i].to_bits(), "lft[{i}]");
            assert_eq!(full.slack[i].to_bits(), got.slack[i].to_bits(), "slack[{i}]");
        }
        assert_eq!(full.critical, got.critical, "critical path");
    }

    /// The incremental-CPM oracle: random duration patch batches on
    /// random layered DAGs — including no-op patches, zeroed durations
    /// and makespan-moving changes — must leave the cache bitwise equal
    /// to a fresh full pass, every field, every round.
    #[test]
    fn prop_cpm_cache_matches_full_recompute_bitwise() {
        use crate::util::rng::Rng;
        use crate::workloads::{random_dag, RandomParams};
        for seed in 0..6u64 {
            let p = RandomParams {
                layers: 5,
                width: 4,
                hosts: 6,
                seed,
                ..Default::default()
            };
            let g = random_dag(&p);
            let n = g.len();
            let mut rng = Rng::new(seed ^ 0xC91A);
            let dur0: Vec<f64> = g.tasks().iter().map(|t| t.size).collect();
            let mut cache = CpmCache::new(&g, dur0);
            assert_cache_matches(&g, &cache);
            for round in 0..30 {
                let mut changes = Vec::new();
                if round % 7 == 3 {
                    // identity patch: must be a bitwise no-op
                    let t = rng.below(n);
                    changes.push((t, cache.durations()[t]));
                } else {
                    for _ in 0..rng.below(4) + 1 {
                        let t = rng.below(n);
                        let d = if rng.bool(0.25) { 0.0 } else { rng.range_f64(0.0, 3.0) };
                        changes.push((t, d));
                    }
                }
                cache.update(&g, &changes);
                assert_cache_matches(&g, &cache);
            }
        }
    }

    /// An off-critical patch that leaves the makespan alone must still
    /// repair slacks in its cone (the diamond's short arm).
    #[test]
    fn cache_patch_off_critical_cone() {
        let g = diamond();
        let dur: Vec<f64> = g.tasks().iter().map(|t| t.size).collect();
        let mut cache = CpmCache::new(&g, dur);
        let f2 = g.by_name("f2").unwrap();
        // grow the slack arm from 1 to 2: still off-critical
        cache.update(&g, &[(f2, 2.0)]);
        assert_eq!(cache.cpm().makespan, 6.0);
        assert_eq!(cache.cpm().slack[f2], 1.0);
        assert_cache_matches(&g, &cache);
        // now dominate: the critical path must flip to the f2 arm
        cache.update(&g, &[(f2, 10.0)]);
        assert_eq!(cache.cpm().makespan, 13.0);
        assert!(cache.cpm().is_critical(f2));
        assert!(!cache.cpm().is_critical(g.by_name("f1").unwrap()));
        assert_cache_matches(&g, &cache);
    }

    #[test]
    fn chain_slack_zero_everywhere() {
        let mut b = MXDag::builder();
        let x = b.compute("x", 0, 1.0);
        let y = b.compute("y", 0, 2.0);
        let z = b.compute("z", 0, 3.0);
        b.chain(&[x, y, z]);
        let g = b.finalize().unwrap();
        let r = cpm(&g);
        assert_eq!(r.makespan, 6.0);
        for t in [x, y, z] {
            assert!(r.is_critical(t));
        }
    }
}
