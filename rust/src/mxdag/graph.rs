//! MXDAG — the graph G = (V, E) of MXTasks (§3.1).
//!
//! Built through [`MXDagBuilder`]; `finalize()` validates acyclicity,
//! attaches the dummy `v_S`/`v_E` nodes to all sources/sinks, and caches
//! the topological order.

use std::collections::BTreeMap;

use super::task::{HostId, MXTask, TaskId, TaskKind};
use crate::util::json::Json;

/// Errors surfaced by graph construction/validation.
#[derive(Debug)]
pub enum GraphError {
    Cycle(TaskId),
    UnknownTask(TaskId),
    SelfDep(TaskId),
    Invalid(String),
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GraphError::Cycle(t) => write!(f, "cycle detected involving task {t}"),
            GraphError::UnknownTask(t) => write!(f, "unknown task id {t}"),
            GraphError::SelfDep(t) => write!(f, "self-dependency on task {t}"),
            GraphError::Invalid(msg) => write!(f, "invalid task: {msg}"),
        }
    }
}

impl std::error::Error for GraphError {}

/// An immutable, validated MXDAG.
#[derive(Debug, Clone)]
pub struct MXDag {
    tasks: Vec<MXTask>,
    succs: Vec<Vec<TaskId>>,
    preds: Vec<Vec<TaskId>>,
    topo: Vec<TaskId>,
    start: TaskId,
    end: TaskId,
}

impl MXDag {
    pub fn builder() -> MXDagBuilder {
        MXDagBuilder::default()
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }
    pub fn task(&self, id: TaskId) -> &MXTask {
        &self.tasks[id]
    }
    pub fn tasks(&self) -> &[MXTask] {
        &self.tasks
    }
    pub fn succs(&self, id: TaskId) -> &[TaskId] {
        &self.succs[id]
    }
    pub fn preds(&self, id: TaskId) -> &[TaskId] {
        &self.preds[id]
    }
    /// Cached topological order (starts with `v_S`, ends with `v_E`).
    pub fn topo(&self) -> &[TaskId] {
        &self.topo
    }
    pub fn start(&self) -> TaskId {
        self.start
    }
    pub fn end(&self) -> TaskId {
        self.end
    }

    /// Ids of all real (non-dummy) tasks.
    pub fn real_tasks(&self) -> impl Iterator<Item = TaskId> + '_ {
        self.tasks
            .iter()
            .filter(|t| !t.kind.is_dummy())
            .map(|t| t.id)
    }

    /// Find a task id by name (test/bench convenience).
    pub fn by_name(&self, name: &str) -> Option<TaskId> {
        self.tasks.iter().find(|t| t.name == name).map(|t| t.id)
    }

    /// Number of edges.
    pub fn n_edges(&self) -> usize {
        self.succs.iter().map(|s| s.len()).sum()
    }

    /// All hosts referenced by any task.
    pub fn hosts(&self) -> Vec<HostId> {
        let mut hs: Vec<HostId> = self
            .tasks
            .iter()
            .flat_map(|t| match t.kind {
                TaskKind::Compute { host } => vec![host],
                TaskKind::Flow { src, dst } => vec![src, dst],
                _ => vec![],
            })
            .collect();
        hs.sort();
        hs.dedup();
        hs
    }

    /// JSON dump (used by the CLI and trace tooling).
    pub fn to_json(&self) -> Json {
        let tasks: Vec<Json> = self
            .tasks
            .iter()
            .map(|t| {
                let (kind, a, b) = match t.kind {
                    TaskKind::Start => ("start", 0, 0),
                    TaskKind::End => ("end", 0, 0),
                    TaskKind::Compute { host } => ("compute", host, 0),
                    TaskKind::Flow { src, dst } => ("flow", src, dst),
                };
                Json::obj(vec![
                    ("id", Json::Num(t.id as f64)),
                    ("name", Json::Str(t.name.clone())),
                    ("kind", Json::Str(kind.into())),
                    ("a", Json::Num(a as f64)),
                    ("b", Json::Num(b as f64)),
                    ("size", Json::Num(t.size)),
                    ("unit", Json::Num(t.unit)),
                ])
            })
            .collect();
        let edges: Vec<Json> = self
            .succs
            .iter()
            .enumerate()
            .flat_map(|(u, vs)| {
                vs.iter()
                    .map(move |&v| Json::Arr(vec![Json::Num(u as f64), Json::Num(v as f64)]))
            })
            .collect();
        Json::obj(vec![("tasks", Json::Arr(tasks)), ("edges", Json::Arr(edges))])
    }

    /// Parse back a graph dumped by [`MXDag::to_json`].
    pub fn from_json(j: &Json) -> Result<MXDag, GraphError> {
        let mut b = MXDag::builder();
        let mut id_map: BTreeMap<usize, Option<TaskId>> = BTreeMap::new();
        let tasks = j
            .get("tasks")
            .and_then(|t| t.as_arr().map(|a| a.to_vec()))
            .map_err(|e| GraphError::Invalid(e.to_string()))?;
        for t in &tasks {
            let get = |k: &str| t.get(k).map_err(|e| GraphError::Invalid(e.to_string()));
            let id = get("id")?.as_usize().map_err(|e| GraphError::Invalid(e.to_string()))?;
            let kind = get("kind")?.as_str().map_err(|e| GraphError::Invalid(e.to_string()))?.to_string();
            let name = get("name")?.as_str().map_err(|e| GraphError::Invalid(e.to_string()))?.to_string();
            let a = get("a")?.as_usize().map_err(|e| GraphError::Invalid(e.to_string()))?;
            let bb = get("b")?.as_usize().map_err(|e| GraphError::Invalid(e.to_string()))?;
            let size = get("size")?.as_f64().map_err(|e| GraphError::Invalid(e.to_string()))?;
            let unit = get("unit")?.as_f64().map_err(|e| GraphError::Invalid(e.to_string()))?;
            let new_id = match kind.as_str() {
                "start" | "end" => None, // re-added by finalize
                "compute" => Some(b.compute_full(&name, a, size, unit)),
                "flow" => Some(b.flow_full(&name, a, bb, size, unit)),
                other => return Err(GraphError::Invalid(format!("kind `{other}`"))),
            };
            id_map.insert(id, new_id);
        }
        let edges = j
            .get("edges")
            .and_then(|e| e.as_arr().map(|a| a.to_vec()))
            .map_err(|e| GraphError::Invalid(e.to_string()))?;
        for e in &edges {
            let pair = e.as_arr().map_err(|e| GraphError::Invalid(e.to_string()))?;
            let [u, v] = pair else {
                return Err(GraphError::Invalid(format!(
                    "edge must be a [from, to] pair, got {} elements",
                    pair.len()
                )));
            };
            let u = u.as_usize().map_err(|e| GraphError::Invalid(e.to_string()))?;
            let v = v.as_usize().map_err(|e| GraphError::Invalid(e.to_string()))?;
            if let (Some(Some(u)), Some(Some(v))) = (id_map.get(&u), id_map.get(&v)) {
                b.dep(*u, *v);
            }
        }
        b.finalize()
    }
}

/// Mutable builder for [`MXDag`].
#[derive(Debug, Default)]
pub struct MXDagBuilder {
    tasks: Vec<MXTask>,
    edges: Vec<(TaskId, TaskId)>,
}

impl MXDagBuilder {
    fn push(&mut self, name: &str, kind: TaskKind, size: f64, unit: f64) -> TaskId {
        assert!(size >= 0.0 && unit >= 0.0, "sizes must be non-negative");
        let unit = if unit == 0.0 || unit > size { size } else { unit };
        let id = self.tasks.len();
        self.tasks.push(MXTask { id, name: name.to_string(), kind, size, unit });
        id
    }

    /// Add a non-pipelineable compute task.
    pub fn compute(&mut self, name: &str, host: HostId, size: f64) -> TaskId {
        self.push(name, TaskKind::Compute { host }, size, size)
    }

    /// Add a compute task with an explicit pipeline unit.
    pub fn compute_full(&mut self, name: &str, host: HostId, size: f64, unit: f64) -> TaskId {
        self.push(name, TaskKind::Compute { host }, size, unit)
    }

    /// Add a non-pipelineable network flow.
    pub fn flow(&mut self, name: &str, src: HostId, dst: HostId, size: f64) -> TaskId {
        self.push(name, TaskKind::Flow { src, dst }, size, size)
    }

    /// Add a network flow with an explicit pipeline unit.
    pub fn flow_full(&mut self, name: &str, src: HostId, dst: HostId, size: f64, unit: f64) -> TaskId {
        self.push(name, TaskKind::Flow { src, dst }, size, unit)
    }

    /// Declare that `b` cannot start before `a` ends.
    pub fn dep(&mut self, a: TaskId, b: TaskId) -> &mut Self {
        self.edges.push((a, b));
        self
    }

    /// Chain of dependencies a -> b -> c ...
    pub fn chain(&mut self, ids: &[TaskId]) -> &mut Self {
        for w in ids.windows(2) {
            self.dep(w[0], w[1]);
        }
        self
    }

    /// Validate, attach `v_S`/`v_E`, compute the topological order.
    pub fn finalize(mut self) -> Result<MXDag, GraphError> {
        let n_real = self.tasks.len();
        for &(a, b) in &self.edges {
            if a >= n_real {
                return Err(GraphError::UnknownTask(a));
            }
            if b >= n_real {
                return Err(GraphError::UnknownTask(b));
            }
            if a == b {
                return Err(GraphError::SelfDep(a));
            }
        }

        // dummy start/end
        let start = self.push("v_S", TaskKind::Start, 0.0, 0.0);
        let end = self.push("v_E", TaskKind::End, 0.0, 0.0);
        let n = self.tasks.len();

        let mut succs = vec![Vec::new(); n];
        let mut preds = vec![Vec::new(); n];
        let mut seen = std::collections::BTreeSet::new();
        for &(a, b) in &self.edges {
            if seen.insert((a, b)) {
                succs[a].push(b);
                preds[b].push(a);
            }
        }
        for id in 0..n_real {
            if preds[id].is_empty() {
                succs[start].push(id);
                preds[id].push(start);
            }
            if succs[id].is_empty() {
                succs[id].push(end);
                preds[end].push(id);
            }
        }

        // Kahn topological order
        let mut indeg: Vec<usize> = preds.iter().map(|p| p.len()).collect();
        let mut queue: Vec<TaskId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut topo = Vec::with_capacity(n);
        while let Some(u) = queue.pop() {
            topo.push(u);
            for &v in &succs[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push(v);
                }
            }
        }
        if topo.len() != n {
            let culprit = (0..n).find(|&i| indeg[i] > 0).unwrap();
            return Err(GraphError::Cycle(culprit));
        }

        Ok(MXDag { tasks: self.tasks, succs, preds, topo, start, end })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> MXDag {
        let mut b = MXDag::builder();
        let a = b.compute("a", 0, 1.0);
        let f1 = b.flow("f1", 0, 1, 2.0);
        let f2 = b.flow("f2", 0, 2, 2.0);
        let c = b.compute("c", 1, 1.0);
        b.dep(a, f1).dep(a, f2).dep(f1, c).dep(f2, c);
        b.finalize().unwrap()
    }

    #[test]
    fn builds_and_validates() {
        let g = diamond();
        assert_eq!(g.len(), 6); // 4 real + start + end
        assert_eq!(g.real_tasks().count(), 4);
        assert_eq!(g.topo()[0], g.start());
        assert_eq!(*g.topo().last().unwrap(), g.end());
    }

    #[test]
    fn start_end_attached() {
        let g = diamond();
        let a = g.by_name("a").unwrap();
        let c = g.by_name("c").unwrap();
        assert_eq!(g.preds(a), &[g.start()]);
        assert_eq!(g.succs(c), &[g.end()]);
    }

    #[test]
    fn topo_respects_edges() {
        let g = diamond();
        let pos: Vec<usize> = {
            let mut p = vec![0; g.len()];
            for (i, &t) in g.topo().iter().enumerate() {
                p[t] = i;
            }
            p
        };
        for u in 0..g.len() {
            for &v in g.succs(u) {
                assert!(pos[u] < pos[v], "edge {u}->{v} violates topo");
            }
        }
    }

    #[test]
    fn cycle_rejected() {
        let mut b = MXDag::builder();
        let x = b.compute("x", 0, 1.0);
        let y = b.compute("y", 0, 1.0);
        b.dep(x, y).dep(y, x);
        assert!(matches!(b.finalize(), Err(GraphError::Cycle(_))));
    }

    #[test]
    fn self_dep_rejected() {
        let mut b = MXDag::builder();
        let x = b.compute("x", 0, 1.0);
        b.dep(x, x);
        assert!(matches!(b.finalize(), Err(GraphError::SelfDep(_))));
    }

    #[test]
    fn unknown_task_rejected() {
        let mut b = MXDag::builder();
        let x = b.compute("x", 0, 1.0);
        b.dep(x, 99);
        assert!(matches!(b.finalize(), Err(GraphError::UnknownTask(99))));
    }

    #[test]
    fn duplicate_edges_deduped() {
        let mut b = MXDag::builder();
        let x = b.compute("x", 0, 1.0);
        let y = b.compute("y", 0, 1.0);
        b.dep(x, y).dep(x, y);
        let g = b.finalize().unwrap();
        assert_eq!(g.succs(x), &[y]);
    }

    #[test]
    fn unit_clamped_to_size() {
        let mut b = MXDag::builder();
        let x = b.compute_full("x", 0, 1.0, 5.0); // unit > size -> clamp
        let g = b.finalize().unwrap();
        assert_eq!(g.task(x).unit, 1.0);
        assert!(!g.task(x).pipelineable());
    }

    #[test]
    fn hosts_collected() {
        let g = diamond();
        assert_eq!(g.hosts(), vec![0, 1, 2]);
    }

    #[test]
    fn from_json_rejects_malformed_edges_without_panicking() {
        let g = diamond();
        let Json::Obj(mut m) = g.to_json() else { unreachable!() };
        m.insert("edges".into(), Json::Arr(vec![Json::Arr(vec![])]));
        assert!(MXDag::from_json(&Json::Obj(m.clone())).is_err());
        m.insert(
            "edges".into(),
            Json::Arr(vec![Json::Arr(vec![Json::Num(0.0)])]),
        );
        assert!(MXDag::from_json(&Json::Obj(m)).is_err());
    }

    #[test]
    fn json_roundtrip() {
        let g = diamond();
        let j = g.to_json();
        let g2 = MXDag::from_json(&j).unwrap();
        assert_eq!(g.len(), g2.len());
        assert_eq!(g.n_edges(), g2.n_edges());
        for t in g.tasks() {
            if t.kind.is_dummy() {
                continue;
            }
            let t2 = g2.task(g2.by_name(&t.name).unwrap());
            assert_eq!(t.kind, t2.kind);
            assert_eq!(t.size, t2.size);
            assert_eq!(t.unit, t2.unit);
        }
    }
}
