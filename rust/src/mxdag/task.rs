//! MXTask — the node type of an MXDAG (§3.1).
//!
//! An MXTask is either a *compute* task pinned to a host (CPU/GPU) or a
//! *network* task: one flow with a single sender and a single receiver.
//! Both carry `Size` (completion time at full resource) and `Unit` (the
//! smallest pipelineable unit; `unit == size` means not pipelineable).

/// Index of a task within its MXDAG.
pub type TaskId = usize;
/// Index of a host within the cluster.
pub type HostId = usize;

/// What kind of physical process a task is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// Dummy source node `v_S`.
    Start,
    /// Dummy sink node `v_E`.
    End,
    /// Host-local computation occupying one compute slot on `host`.
    Compute { host: HostId },
    /// A single network flow from `src`'s NIC-up to `dst`'s NIC-down.
    Flow { src: HostId, dst: HostId },
}

impl TaskKind {
    pub fn is_flow(&self) -> bool {
        matches!(self, TaskKind::Flow { .. })
    }
    pub fn is_compute(&self) -> bool {
        matches!(self, TaskKind::Compute { .. })
    }
    pub fn is_dummy(&self) -> bool {
        matches!(self, TaskKind::Start | TaskKind::End)
    }
}

/// One node of an MXDAG.
#[derive(Debug, Clone)]
pub struct MXTask {
    pub id: TaskId,
    pub name: String,
    pub kind: TaskKind,
    /// `Size(v)`: completion time with maximum resource assigned.
    pub size: f64,
    /// `Unit(v)`: smallest pipeline unit; == `size` when not pipelineable.
    pub unit: f64,
}

impl MXTask {
    /// A task is pipelineable iff its unit is strictly smaller than its size.
    pub fn pipelineable(&self) -> bool {
        self.unit < self.size && self.size > 0.0
    }

    /// Number of pipeline chunks when executed in a pipeline.
    pub fn chunks(&self) -> usize {
        if !self.pipelineable() {
            1
        } else {
            (self.size / self.unit).ceil() as usize
        }
    }

    /// Completion time with `rsrc` (fraction of max resource, 0 < rsrc <= 1).
    pub fn len_with(&self, rsrc: f64) -> f64 {
        assert!(rsrc > 0.0, "resource share must be positive");
        self.size / rsrc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(size: f64, unit: f64) -> MXTask {
        MXTask { id: 0, name: "t".into(), kind: TaskKind::Compute { host: 0 }, size, unit }
    }

    #[test]
    fn pipelineable_iff_unit_lt_size() {
        assert!(t(10.0, 1.0).pipelineable());
        assert!(!t(10.0, 10.0).pipelineable());
        assert!(!t(0.0, 0.0).pipelineable());
    }

    #[test]
    fn chunk_count() {
        assert_eq!(t(10.0, 1.0).chunks(), 10);
        assert_eq!(t(10.0, 3.0).chunks(), 4); // ceil
        assert_eq!(t(5.0, 5.0).chunks(), 1);
    }

    #[test]
    fn len_scales_with_resource() {
        assert_eq!(t(10.0, 10.0).len_with(1.0), 10.0);
        assert_eq!(t(10.0, 10.0).len_with(0.5), 20.0);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_resource_rejected() {
        t(1.0, 1.0).len_with(0.0);
    }

    #[test]
    fn kind_predicates() {
        assert!(TaskKind::Flow { src: 0, dst: 1 }.is_flow());
        assert!(TaskKind::Compute { host: 0 }.is_compute());
        assert!(TaskKind::Start.is_dummy() && TaskKind::End.is_dummy());
        assert!(!TaskKind::Start.is_flow());
    }
}
